"""Pure-jnp reference (oracle) for the path-layer kernels.

The sparse path layer of the paper (Fig 3), in segment-sum form:

    y[b, idx_out[p]] += w[p] * relu(x[b, idx_in[p]])        (forward)

and its two backward products:

    gx[b, idx_in[p]] += w[p] * gy[b, idx_out[p]] * (x[b, idx_in[p]] > 0)
    gw[p]            = sum_b gy[b, idx_out[p]] * relu(x[b, idx_in[p]])

These are the ground truth the Pallas kernels are checked against by
``python/tests/test_kernel.py`` (hypothesis sweeps shapes/dtypes) — the
core correctness signal of the L1 layer.
"""

import jax.numpy as jnp


def path_layer_fwd_ref(x, w, idx_in, idx_out, n_out):
    """Forward: gather → scale → scatter-add (segment sum).

    Args:
      x:       [B, n_in] activations of the previous layer.
      w:       [P] path weights.
      idx_in:  [P] int32 source neuron per path.
      idx_out: [P] int32 destination neuron per path.
      n_out:   static output width.

    Returns:
      [B, n_out] pre-activations of the next layer.
    """
    gathered = jnp.maximum(x[:, idx_in], 0.0)  # [B, P]
    contrib = gathered * w[None, :]
    # scatter-add along axis 1 via one-hot matmul (same math the MXU
    # mapping uses; exact in f32 for the sizes under test)
    onehot = (idx_out[:, None] == jnp.arange(n_out)[None, :]).astype(x.dtype)  # [P, n_out]
    return contrib @ onehot


def path_layer_bwd_input_ref(x, w, idx_in, idx_out, gy):
    """Input gradient of the path layer."""
    gate = (x[:, idx_in] > 0.0).astype(x.dtype)  # [B, P]
    ggath = gy[:, idx_out] * w[None, :] * gate  # [B, P]
    n_in = x.shape[1]
    onehot = (idx_in[:, None] == jnp.arange(n_in)[None, :]).astype(x.dtype)  # [P, n_in]
    return ggath @ onehot


def path_layer_bwd_weight_ref(x, w, idx_in, idx_out, gy):
    """Weight gradient of the path layer (w only enters linearly)."""
    del w  # unused: gradient is independent of w
    gathered = jnp.maximum(x[:, idx_in], 0.0)  # [B, P]
    return jnp.sum(gy[:, idx_out] * gathered, axis=0)  # [P]


def sparse_mlp_forward_ref(weights, idx, x, layer_sizes):
    """Whole-network reference forward (logits)."""
    h = x
    for t in range(len(layer_sizes) - 1):
        h = path_layer_fwd_ref(h, weights[t], idx[t], idx[t + 1], layer_sizes[t + 1])
    return h


def masked_dense_forward_ref(weights, idx, x, layer_sizes):
    """Footnote-1 emulation: coalesce duplicate edges into a dense
    matrix and run ordinary dense layers.  Agrees with the path form
    exactly (duplicate edges sum their weights in both forms).
    """
    h = x
    for t in range(len(layer_sizes) - 1):
        n_in, n_out = layer_sizes[t], layer_sizes[t + 1]
        dense = jnp.zeros((n_in, n_out), x.dtype)
        dense = dense.at[idx[t], idx[t + 1]].add(weights[t])
        h = jnp.maximum(h, 0.0) @ dense
    return h
