"""Layer-1 Pallas kernels for the path-sparse layer (paper Fig 3).

TPU mapping (DESIGN.md §Hardware-Adaptation): a block of 2^k paths —
one Sobol' permutation block — becomes a VMEM tile; the gather is a
VPU-friendly take, the scatter is a one-hot matmul that lands on the
MXU systolic array (the paper's §4.1/§4.4 crossbar argument: a
permutation scatter *is* a permutation-matrix multiply).

All kernels run ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO ops that the
rust runtime executes (see /opt/xla-example/README.md).  Correctness is
pinned to ``ref.py`` by ``python/tests/test_kernel.py``.

The forward/backward trio is wired into ``jax.custom_vjp`` so the L2
model trains through ``jax.grad`` with these kernels on both passes.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Path-block size: one Sobol' permutation block per grid step.  256
# paths × f32 weight + two i32 indices = 3 KiB/step of index traffic;
# with B×n tiles this keeps the working set well inside a TPU core's
# ~16 MiB VMEM for every shape used by the models here (see
# ``aot.py --report`` for the per-artifact accounting).
PATH_BLOCK = 256


def _fwd_kernel(x_ref, w_ref, ii_ref, io_ref, o_ref, *, n_out):
    """One grid step: accumulate a block of paths into the output tile."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [B, n_in] tile
    w = w_ref[...]  # [PB]
    ii = ii_ref[...]  # [PB] int32
    io = io_ref[...]  # [PB] int32
    gathered = jnp.maximum(jnp.take(x, ii, axis=1), 0.0)  # [B, PB]
    contrib = gathered * w[None, :]
    onehot = jax.nn.one_hot(io, n_out, dtype=x.dtype)  # [PB, n_out] → MXU
    o_ref[...] += contrib @ onehot


def _bwd_input_kernel(x_ref, w_ref, ii_ref, io_ref, gy_ref, o_ref, *, n_in):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    gy = gy_ref[...]
    w = w_ref[...]
    ii = ii_ref[...]
    io = io_ref[...]
    gate = (jnp.take(x, ii, axis=1) > 0.0).astype(x.dtype)  # [B, PB]
    ggath = jnp.take(gy, io, axis=1) * w[None, :] * gate
    onehot = jax.nn.one_hot(ii, n_in, dtype=x.dtype)  # [PB, n_in]
    o_ref[...] += ggath @ onehot


def _bwd_weight_kernel(x_ref, ii_ref, io_ref, gy_ref, o_ref):
    x = x_ref[...]
    gy = gy_ref[...]
    ii = ii_ref[...]
    io = io_ref[...]
    gathered = jnp.maximum(jnp.take(x, ii, axis=1), 0.0)  # [B, PB]
    o_ref[...] = jnp.sum(jnp.take(gy, io, axis=1) * gathered, axis=0)  # [PB]


def _path_grid(p):
    """Grid size and effective block for P paths."""
    pb = min(PATH_BLOCK, p)
    assert p % pb == 0, f"paths {p} must be a multiple of the block {pb}"
    return p // pb, pb


def path_layer_fwd(x, w, idx_in, idx_out, n_out):
    """Pallas forward: ``y[b, idx_out[p]] += w[p] · relu(x[b, idx_in[p]])``."""
    b, _ = x.shape
    (p,) = w.shape
    grid, pb = _path_grid(p)
    return pl.pallas_call(
        partial(_fwd_kernel, n_out=n_out),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(x.shape, lambda i: (0, 0)),
            pl.BlockSpec((pb,), lambda i: (i,)),
            pl.BlockSpec((pb,), lambda i: (i,)),
            pl.BlockSpec((pb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((b, n_out), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_out), x.dtype),
        interpret=True,
    )(x, w, idx_in, idx_out)


def path_layer_bwd_input(x, w, idx_in, idx_out, gy):
    """Pallas input-gradient kernel."""
    b, n_in = x.shape
    (p,) = w.shape
    grid, pb = _path_grid(p)
    return pl.pallas_call(
        partial(_bwd_input_kernel, n_in=n_in),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(x.shape, lambda i: (0, 0)),
            pl.BlockSpec((pb,), lambda i: (i,)),
            pl.BlockSpec((pb,), lambda i: (i,)),
            pl.BlockSpec((pb,), lambda i: (i,)),
            pl.BlockSpec(gy.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, n_in), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_in), x.dtype),
        interpret=True,
    )(x, w, idx_in, idx_out, gy)


def path_layer_bwd_weight(x, idx_in, idx_out, gy):
    """Pallas weight-gradient kernel (blocked over paths, no revisit)."""
    (p,) = idx_in.shape
    grid, pb = _path_grid(p)
    return pl.pallas_call(
        _bwd_weight_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(x.shape, lambda i: (0, 0)),
            pl.BlockSpec((pb,), lambda i: (i,)),
            pl.BlockSpec((pb,), lambda i: (i,)),
            pl.BlockSpec(gy.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((pb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), x.dtype),
        interpret=True,
    )(x, idx_in, idx_out, gy)


@partial(jax.custom_vjp, nondiff_argnums=(4,))
def path_layer(x, w, idx_in, idx_out, n_out):
    """Differentiable path layer; fwd and bwd are Pallas kernels."""
    return path_layer_fwd(x, w, idx_in, idx_out, n_out)


def _vjp_fwd(x, w, idx_in, idx_out, n_out):
    y = path_layer_fwd(x, w, idx_in, idx_out, n_out)
    return y, (x, w, idx_in, idx_out)


def _vjp_bwd(n_out, res, gy):
    del n_out
    x, w, idx_in, idx_out = res
    gx = path_layer_bwd_input(x, w, idx_in, idx_out, gy)
    gw = path_layer_bwd_weight(x, idx_in, idx_out, gy)
    # indices are integers: no gradient
    return gx, gw, None, None


path_layer.defvjp(_vjp_fwd, _vjp_bwd)


def vmem_estimate_bytes(batch, n_in, n_out, path_block=PATH_BLOCK, dtype_bytes=4):
    """Static VMEM footprint estimate of one forward grid step (used by
    ``aot.py --report`` and DESIGN.md §Perf): input tile + output tile +
    path block (w + 2×i32) + one-hot staging.
    """
    x_tile = batch * n_in * dtype_bytes
    o_tile = batch * n_out * dtype_bytes
    path_blk = path_block * (dtype_bytes + 4 + 4)
    onehot = path_block * n_out * dtype_bytes
    gathered = batch * path_block * dtype_bytes
    return x_tile + o_tile + path_blk + onehot + gathered


def mxu_utilization_estimate(batch, n_out, path_block=PATH_BLOCK):
    """Fraction of MXU 128×128 systolic slots doing useful work in the
    one-hot matmul ``[B,PB] @ [PB,n_out]`` (bfloat16 tiling assumption).
    """
    def eff(dim, tile=128):
        full, rem = divmod(dim, tile)
        used = full * tile + rem
        alloc = (full + (1 if rem else 0)) * tile
        return used / alloc

    return eff(batch) * eff(path_block) * eff(n_out)
