"""Layer-2 JAX model: the paper's path-sparse MLP (fwd/bwd) built on the
Pallas path-layer kernels, plus the fused SGD-with-momentum train step
that gets AOT-lowered by ``aot.py``.

Conventions (the contract with the rust coordinator,
``rust/src/coordinator/train.rs``):

* weights ``w``    — ``[T, P]`` f32, row t = transition t;
* momentum ``m``   — ``[T, P]`` f32;
* topology ``idx`` — ``[L, P]`` int32, row l = neuron index per path in
  layer l (a *runtime input*: rust generates Sobol'/random topologies);
* batch ``x``      — ``[B, F]`` f32, labels ``y`` — ``[B]`` int32;
* ``lr``           — scalar f32 input (schedule lives in rust).

Momentum and weight decay are static (0.9 / 1e-4, the paper's §5.2
hyperparameters); the learning rate is runtime so the rust side owns the
schedule without recompiling.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels.path_layer import path_layer

MOMENTUM = 0.9
WEIGHT_DECAY = 1e-4

# Default model geometry baked into the artifacts (power-of-two hidden
# widths per paper §4.3; input/output are not powers of two, which only
# costs the permutation property on those layers).
LAYER_SIZES = (784, 256, 256, 10)
PATHS = 2048
BATCH = 64


def forward(w, idx, x, layer_sizes=LAYER_SIZES):
    """Logits of the path-sparse MLP (Fig 3 inference, batched)."""
    h = x
    t_count = len(layer_sizes) - 1
    for t in range(t_count):
        h = path_layer(h, w[t], idx[t], idx[t + 1], int(layer_sizes[t + 1]))
    return h


def loss_fn(w, idx, x, y, layer_sizes=LAYER_SIZES):
    """Mean softmax cross-entropy."""
    logits = forward(w, idx, x, layer_sizes)
    logz = jax.nn.logsumexp(logits, axis=1)
    picked = jnp.take_along_axis(logits, y[:, None], axis=1)[:, 0]
    return jnp.mean(logz - picked)


@partial(jax.jit, static_argnums=(6,), donate_argnums=(0, 1))
def train_step(w, m, idx, x, y, lr, layer_sizes=LAYER_SIZES):
    """One SGD+momentum step; returns ``(w', m', loss)``.

    Buffers ``w``/``m`` are donated: XLA updates them in place, so the
    rust ping-pong driver pays no copy on the hot path.
    """
    loss, grad = jax.value_and_grad(loss_fn)(w, idx, x, y, layer_sizes)
    grad = grad + WEIGHT_DECAY * w
    m_new = MOMENTUM * m + grad
    w_new = w - lr * m_new
    return w_new, m_new, loss


@partial(jax.jit, static_argnums=(3,))
def forward_jit(w, idx, x, layer_sizes=LAYER_SIZES):
    """Jitted forward for the serving artifact."""
    return forward(w, idx, x, layer_sizes)


def init_weights(key, layer_sizes=LAYER_SIZES, paths=PATHS):
    """Constant-magnitude random-sign init (paper §3.1 default for
    sparse nets), matching ``rust/src/nn/init.rs`` magnitudes."""
    t_count = len(layer_sizes) - 1
    rows = []
    for t in range(t_count):
        fan_in = max(paths // layer_sizes[t + 1], 1)
        fan_out = max(paths // layer_sizes[t], 1)
        mag = (6.0 / (fan_in + fan_out)) ** 0.5
        key, sub = jax.random.split(key)
        signs = jnp.where(jax.random.bernoulli(sub, 0.5, (paths,)), 1.0, -1.0)
        rows.append(mag * signs)
    return jnp.stack(rows).astype(jnp.float32)
