"""AOT lowering: JAX/Pallas model → HLO **text** artifacts + manifest.

Run once by ``make artifacts``; the rust runtime
(``rust/src/runtime``) loads the text, compiles it on the PJRT CPU
client and executes it.  HLO text (not serialized ``HloModuleProto``)
is the interchange format: jax ≥ 0.5 emits 64-bit instruction ids that
xla_extension 0.5.1 rejects, while the text parser reassigns ids (see
/opt/xla-example/README.md).

Artifacts (shapes baked at lowering time, topology is a runtime input):

* ``sparse_train_step(w[T,P], m[T,P], idx[L,P]i32, x[B,F], y[B]i32, lr[])
  → (w', m', loss)``
* ``sparse_forward(w[T,P], idx[L,P]i32, x[B,F]) → logits[B,C]``
* ``path_layer_fwd(x[B,n], w[P], ii[P]i32, io[P]i32) → y[B,n']`` — the
  bare L1 kernel, for runtime micro-benches.

``--report`` prints HLO statistics and the static VMEM/MXU estimates of
the kernel BlockSpecs (DESIGN.md §Perf / §Hardware-Adaptation).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import path_layer as pk


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifacts(layer_sizes=model.LAYER_SIZES, paths=model.PATHS, batch=model.BATCH):
    """Lower all artifacts; returns ``[(name, hlo_text, inputs, outputs, meta)]``."""
    t_count = len(layer_sizes) - 1
    l_count = len(layer_sizes)
    f = layer_sizes[0]
    c = layer_sizes[-1]
    f32 = jnp.float32
    i32 = jnp.int32
    spec = jax.ShapeDtypeStruct

    w = spec((t_count, paths), f32)
    m = spec((t_count, paths), f32)
    idx = spec((l_count, paths), i32)
    x = spec((batch, f), f32)
    y = spec((batch,), i32)
    lr = spec((), f32)

    meta = {"layer_sizes": list(layer_sizes), "paths": paths, "batch": batch}
    arts = []

    step = jax.jit(
        lambda w, m, idx, x, y, lr: model.train_step(w, m, idx, x, y, lr, tuple(layer_sizes))
    ).lower(w, m, idx, x, y, lr)
    arts.append((
        "sparse_train_step",
        to_hlo_text(step),
        [list(s.shape) for s in (w, m, idx, x, y, lr)],
        [[t_count, paths], [t_count, paths], []],
        meta,
    ))

    fwd = jax.jit(lambda w, idx, x: model.forward(w, idx, x, tuple(layer_sizes))).lower(w, idx, x)
    arts.append((
        "sparse_forward",
        to_hlo_text(fwd),
        [list(s.shape) for s in (w, idx, x)],
        [[batch, c]],
        meta,
    ))

    # bare L1 kernel over the first transition's geometry
    n_in, n_out = layer_sizes[0], layer_sizes[1]
    kx = spec((batch, n_in), f32)
    kw = spec((paths,), f32)
    ki = spec((paths,), i32)
    kernel = jax.jit(
        lambda x, w, ii, io: pk.path_layer_fwd(x, w, ii, io, n_out)
    ).lower(kx, kw, ki, ki)
    arts.append((
        "path_layer_fwd",
        to_hlo_text(kernel),
        [[batch, n_in], [paths], [paths], [paths]],
        [[batch, n_out]],
        {**meta, "n_in": n_in, "n_out": n_out},
    ))
    return arts


def write_artifacts(out_dir: str, arts) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"artifacts": []}
    for name, hlo, inputs, outputs, meta in arts:
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as fh:
            fh.write(hlo)
        manifest["artifacts"].append(
            {"name": name, "file": fname, "inputs": inputs, "outputs": outputs, "meta": meta}
        )
        print(f"wrote {fname}: {len(hlo)} chars")
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


def report(arts) -> None:
    """HLO op statistics + static kernel efficiency estimates."""
    for name, hlo, _, _, meta in arts:
        ops = {}
        for line in hlo.splitlines():
            line = line.strip()
            if "=" in line and not line.startswith(("HloModule", "ENTRY", "}")):
                rhs = line.split("=", 1)[1].strip()
                head = rhs.split("(")[0].split()
                if not head:
                    continue
                op = head[-1].split(".")[0]
                ops[op] = ops.get(op, 0) + 1
        top = sorted(ops.items(), key=lambda kv: -kv[1])[:8]
        print(f"\n[{name}] {len(hlo.splitlines())} HLO lines; top ops: {top}")
        if "n_in" in meta:
            b = meta["batch"]
            vmem = pk.vmem_estimate_bytes(b, meta["n_in"], meta["n_out"])
            mxu = pk.mxu_utilization_estimate(b, meta["n_out"])
            print(
                f"  kernel block={pk.PATH_BLOCK}: VMEM/step ≈ {vmem / 1024:.1f} KiB "
                f"(≤16 MiB budget), MXU tile utilization ≈ {mxu:.2%}"
            )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--report", action="store_true", help="print HLO/VMEM report only")
    ap.add_argument("--paths", type=int, default=model.PATHS)
    ap.add_argument("--batch", type=int, default=model.BATCH)
    args = ap.parse_args()
    arts = lower_artifacts(paths=args.paths, batch=args.batch)
    if args.report:
        report(arts)
    else:
        write_artifacts(args.out, arts)


if __name__ == "__main__":
    main()
