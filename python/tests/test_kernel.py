"""L1 correctness: Pallas path-layer kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, path counts and index patterns;
``assert_allclose`` against ``ref.py`` is the core correctness signal
required by the architecture contract.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import path_layer as pk
from compile.kernels import ref


def make_case(rng, batch, n_in, n_out, paths):
    x = rng.standard_normal((batch, n_in), dtype=np.float32)
    w = rng.standard_normal(paths).astype(np.float32)
    ii = rng.integers(0, n_in, paths).astype(np.int32)
    io = rng.integers(0, n_out, paths).astype(np.int32)
    gy = rng.standard_normal((batch, n_out), dtype=np.float32)
    return (
        jnp.asarray(x),
        jnp.asarray(w),
        jnp.asarray(ii),
        jnp.asarray(io),
        jnp.asarray(gy),
    )


shape_strategy = st.tuples(
    st.integers(1, 9),  # batch
    st.integers(1, 37),  # n_in
    st.integers(1, 23),  # n_out
    st.sampled_from([1, 2, 4, 8, 16, 64, 256, 512]),  # paths (mult of block or < block)
    st.integers(0, 2**31 - 1),  # seed
)


@settings(max_examples=40, deadline=None)
@given(shape_strategy)
def test_fwd_matches_ref(case):
    batch, n_in, n_out, paths, seed = case
    rng = np.random.default_rng(seed)
    x, w, ii, io, _ = make_case(rng, batch, n_in, n_out, paths)
    got = pk.path_layer_fwd(x, w, ii, io, n_out)
    want = ref.path_layer_fwd_ref(x, w, ii, io, n_out)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(shape_strategy)
def test_bwd_input_matches_ref(case):
    batch, n_in, n_out, paths, seed = case
    rng = np.random.default_rng(seed)
    x, w, ii, io, gy = make_case(rng, batch, n_in, n_out, paths)
    got = pk.path_layer_bwd_input(x, w, ii, io, gy)
    want = ref.path_layer_bwd_input_ref(x, w, ii, io, gy)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(shape_strategy)
def test_bwd_weight_matches_ref(case):
    batch, n_in, n_out, paths, seed = case
    rng = np.random.default_rng(seed)
    x, w, ii, io, gy = make_case(rng, batch, n_in, n_out, paths)
    got = pk.path_layer_bwd_weight(x, ii, io, gy)
    want = ref.path_layer_bwd_weight_ref(x, w, ii, io, gy)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_custom_vjp_matches_autodiff_of_ref():
    """jax.grad through the Pallas custom_vjp must equal autodiff of the
    reference implementation."""
    rng = np.random.default_rng(7)
    x, w, ii, io, _ = make_case(rng, 4, 12, 8, 64)

    def loss_pallas(x, w):
        return jnp.sum(pk.path_layer(x, w, ii, io, 8) ** 2)

    def loss_ref(x, w):
        return jnp.sum(ref.path_layer_fwd_ref(x, w, ii, io, 8) ** 2)

    gx_p, gw_p = jax.grad(loss_pallas, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw_p, gw_r, rtol=1e-4, atol=1e-4)


def test_relu_gating_boundary():
    """Zero activations do NOT contribute (strict > 0, per Fig 3)."""
    x = jnp.array([[0.0, -1.0, 2.0]], dtype=jnp.float32)
    w = jnp.array([5.0, 5.0, 5.0], dtype=jnp.float32)
    ii = jnp.array([0, 1, 2], dtype=jnp.int32)
    io = jnp.array([0, 0, 0], dtype=jnp.int32)
    y = pk.path_layer_fwd(x, w, ii, io, 1)
    np.testing.assert_allclose(y, [[10.0]])
    # gradient gates exactly at > 0
    gy = jnp.ones((1, 1), dtype=jnp.float32)
    gx = pk.path_layer_bwd_input(x, w, ii, io, gy)
    np.testing.assert_allclose(gx, [[0.0, 0.0, 5.0]])


def test_duplicate_edges_accumulate():
    """Multiple paths on the same edge sum (footnote 1 coalescing)."""
    x = jnp.array([[1.0, 3.0]], dtype=jnp.float32)
    w = jnp.array([0.5, 0.25, 1.0], dtype=jnp.float32)
    ii = jnp.array([0, 0, 1], dtype=jnp.int32)
    io = jnp.array([0, 0, 0], dtype=jnp.int32)
    y = pk.path_layer_fwd(x, w, ii, io, 1)
    np.testing.assert_allclose(y, [[0.5 + 0.25 + 3.0]])


def test_blocked_grid_equals_single_block():
    """Paths spanning several PATH_BLOCK grid steps accumulate correctly."""
    rng = np.random.default_rng(11)
    paths = pk.PATH_BLOCK * 3
    x, w, ii, io, _ = make_case(rng, 3, 20, 15, paths)
    got = pk.path_layer_fwd(x, w, ii, io, 15)
    want = ref.path_layer_fwd_ref(x, w, ii, io, 15)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_vmem_estimate_reasonable():
    b = pk.vmem_estimate_bytes(64, 784, 256)
    assert 0 < b < 16 * 1024 * 1024, "default geometry must fit VMEM"
    u = pk.mxu_utilization_estimate(64, 256)
    assert 0.0 < u <= 1.0


@pytest.mark.parametrize("paths", [3, 257])
def test_non_multiple_paths_rejected(paths):
    """Path counts must tile the block (explicit contract, not silent)."""
    rng = np.random.default_rng(0)
    x, w, ii, io, _ = make_case(rng, 2, 4, 4, paths)
    if paths < pk.PATH_BLOCK:
        # smaller than one block is allowed (block shrinks)
        pk.path_layer_fwd(x, w, ii, io, 4)
    else:
        with pytest.raises(AssertionError):
            pk.path_layer_fwd(x, w, ii, io, 4)
