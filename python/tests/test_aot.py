"""AOT lowering tests: HLO text generation and the manifest contract
with the rust runtime (`rust/src/runtime/artifact.rs`)."""

import json

from compile import aot, model

SMALL_SIZES = (12, 16, 8)
SMALL_PATHS = 32
SMALL_BATCH = 4


def lower_small():
    return aot.lower_artifacts(SMALL_SIZES, SMALL_PATHS, SMALL_BATCH)


def test_lowering_produces_hlo_text():
    arts = lower_small()
    names = [a[0] for a in arts]
    assert names == ["sparse_train_step", "sparse_forward", "path_layer_fwd"]
    for name, hlo, inputs, outputs, meta in arts:
        assert hlo.startswith("HloModule"), name
        assert "ENTRY" in hlo, name
        assert len(inputs) > 0 and len(outputs) > 0
        assert meta["paths"] == SMALL_PATHS
    # train step: 6 inputs, 3 outputs
    ts = arts[0]
    assert len(ts[2]) == 6
    assert ts[3] == [[2, SMALL_PATHS], [2, SMALL_PATHS], []]


def test_no_custom_calls_in_hlo():
    """interpret=True must lower Pallas to plain HLO — a Mosaic
    custom-call would be unloadable by the CPU PJRT client."""
    for name, hlo, *_ in lower_small():
        assert "custom-call" not in hlo or "Sharding" in hlo, f"{name} has custom calls"


def test_manifest_written(tmp_path):
    arts = lower_small()
    aot.write_artifacts(str(tmp_path), arts)
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert len(manifest["artifacts"]) == 3
    for a in manifest["artifacts"]:
        assert (tmp_path / a["file"]).exists()
        assert a["meta"]["layer_sizes"] == list(SMALL_SIZES)
    # rust-side parser contract: names it looks up
    names = {a["name"] for a in manifest["artifacts"]}
    assert {"sparse_train_step", "sparse_forward"} <= names


def test_report_runs(capsys):
    aot.report(lower_small())
    out = capsys.readouterr().out
    assert "top ops" in out
    assert "VMEM" in out


def test_default_geometry_constants():
    assert model.LAYER_SIZES[0] == 784
    assert model.LAYER_SIZES[-1] == 10
    assert model.PATHS % 256 == 0, "paths must tile the kernel block"
