"""L2 correctness: the path-sparse MLP model and its train step."""

import numpy as np

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

SIZES = (12, 16, 16, 4)
PATHS = 64
BATCH = 8


def make_net(seed=0):
    rng = np.random.default_rng(seed)
    idx = np.stack([rng.integers(0, n, PATHS) for n in SIZES]).astype(np.int32)
    w = model.init_weights(jax.random.PRNGKey(seed), SIZES, PATHS)
    x = rng.standard_normal((BATCH, SIZES[0]), dtype=np.float32)
    y = rng.integers(0, SIZES[-1], BATCH).astype(np.int32)
    return jnp.asarray(w), jnp.asarray(idx), jnp.asarray(x), jnp.asarray(y)


def test_forward_shapes_and_ref_agreement():
    w, idx, x, _ = make_net()
    logits = model.forward(w, idx, x, SIZES)
    assert logits.shape == (BATCH, SIZES[-1])
    want = ref.sparse_mlp_forward_ref(
        [w[t] for t in range(len(SIZES) - 1)], [idx[l] for l in range(len(SIZES))], x, SIZES
    )
    np.testing.assert_allclose(logits, want, rtol=1e-5, atol=1e-5)


def test_forward_matches_masked_dense_emulation():
    """Footnote 1: the matrix emulation coalesces duplicates but computes
    the same function — except the input layer gate. The path form gates
    inputs with relu too, so feed non-negative inputs for exact match."""
    w, idx, x, _ = make_net(3)
    x = jnp.abs(x)
    logits = model.forward(w, idx, x, SIZES)
    want = ref.masked_dense_forward_ref(
        [w[t] for t in range(len(SIZES) - 1)], [idx[l] for l in range(len(SIZES))], x, SIZES
    )
    np.testing.assert_allclose(logits, want, rtol=1e-4, atol=1e-4)


def test_loss_is_lnC_at_zero_weights():
    w, idx, x, y = make_net()
    w = jnp.zeros_like(w)
    loss = model.loss_fn(w, idx, x, y, SIZES)
    np.testing.assert_allclose(loss, np.log(SIZES[-1]), rtol=1e-5)


def test_train_step_reduces_loss():
    w, idx, x, y = make_net(5)
    m = jnp.zeros_like(w)
    losses = []
    for _ in range(60):
        w, m, loss = model.train_step(w, m, idx, x, y, jnp.float32(0.05), SIZES)
        losses.append(float(loss))
    assert losses[-1] < 0.5 * losses[0], f"{losses[0]} -> {losses[-1]}"
    assert np.isfinite(losses).all()


def test_train_step_grad_matches_finite_difference():
    w, idx, x, y = make_net(9)
    g = jax.grad(model.loss_fn)(w, idx, x, y, SIZES)
    eps = 1e-3
    for (t, p) in [(0, 0), (1, 17), (2, 63)]:
        wp = w.at[t, p].add(eps)
        wm = w.at[t, p].add(-eps)
        fd = (model.loss_fn(wp, idx, x, y, SIZES) - model.loss_fn(wm, idx, x, y, SIZES)) / (
            2 * eps
        )
        np.testing.assert_allclose(g[t, p], fd, rtol=5e-2, atol=5e-4)


def test_init_weights_magnitude():
    w = model.init_weights(jax.random.PRNGKey(0), SIZES, PATHS)
    assert w.shape == (len(SIZES) - 1, PATHS)
    # transition 0: fan_in = P/n1 = 4, fan_out = P/n0 = 5
    mag = (6.0 / (PATHS // SIZES[1] + PATHS // SIZES[0])) ** 0.5
    np.testing.assert_allclose(np.abs(w[0]), mag, rtol=1e-5)
    # roughly balanced signs
    pos = int((w > 0).sum())
    assert 0.3 * w.size < pos < 0.7 * w.size


def test_topology_is_runtime_input():
    """Different idx arrays through the SAME jitted function give
    different logits (no topology baked into the compilation)."""
    w, idx, x, _ = make_net(1)
    rng = np.random.default_rng(42)
    idx2 = jnp.asarray(np.stack([rng.integers(0, n, PATHS) for n in SIZES]).astype(np.int32))
    a = model.forward_jit(w, idx, x, SIZES)
    b = model.forward_jit(w, idx2, x, SIZES)
    assert not np.allclose(np.asarray(a), np.asarray(b))
