//! Layer-3 coordinator: process lifecycle, training orchestration over
//! the AOT runtime, metrics and checkpoints.  The inference engine
//! itself lives in [`crate::engine`] (admission + dispatch + worker
//! shards; [`crate::serve`] is its blocking compatibility surface);
//! [`server`] keeps the historical names as deprecated aliases.
//!
//! Rust owns the event loop; the compiled HLO artifacts (JAX+Pallas,
//! lowered once at build time) are the only compute the request path
//! touches.

pub mod checkpoint;
pub mod metrics;
pub mod server;
pub mod train;

pub use metrics::Metrics;
pub use server::InferenceBackend;
#[allow(deprecated)]
pub use server::{InferenceServer, ServerConfig};
pub use train::{AotTrainer, AotTrainerConfig};
