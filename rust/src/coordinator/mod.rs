//! Layer-3 coordinator: process lifecycle, training orchestration over
//! the AOT runtime, metrics and checkpoints.  The inference server
//! itself lives in [`crate::serve`] (sharded multi-worker subsystem);
//! [`server`] re-exports it under the historical names.
//!
//! Rust owns the event loop; the compiled HLO artifacts (JAX+Pallas,
//! lowered once at build time) are the only compute the request path
//! touches.

pub mod checkpoint;
pub mod metrics;
pub mod server;
pub mod train;

pub use metrics::Metrics;
pub use server::{InferenceBackend, InferenceServer, ServerConfig};
pub use train::{AotTrainer, AotTrainerConfig};
