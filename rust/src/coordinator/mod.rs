//! Layer-3 coordinator: process lifecycle, training orchestration over
//! the AOT runtime, metrics and checkpoints.  The inference engine
//! itself lives in [`crate::engine`] (admission + dispatch + worker
//! shards).
//!
//! Rust owns the event loop; the compiled HLO artifacts (JAX+Pallas,
//! lowered once at build time) are the only compute the request path
//! touches.

pub mod checkpoint;
pub mod metrics;
pub mod train;

pub use crate::engine::InferenceBackend;
pub use metrics::Metrics;
pub use train::{AotTrainer, AotTrainerConfig};
