//! Layer-3 coordinator: process lifecycle, training orchestration over
//! the AOT runtime, the inference server (request router + dynamic
//! batcher + worker pool), metrics and checkpoints.
//!
//! Rust owns the event loop; the compiled HLO artifacts (JAX+Pallas,
//! lowered once at build time) are the only compute the request path
//! touches.

pub mod checkpoint;
pub mod metrics;
pub mod server;
pub mod train;

pub use metrics::Metrics;
pub use server::{InferenceBackend, InferenceServer, ServerConfig};
pub use train::{AotTrainer, AotTrainerConfig};
