//! AOT training driver: owns the parameter state and ping-pongs it
//! through the compiled `sparse_train_step` HLO (JAX fwd/bwd + SGD
//! update, with the Pallas path-layer kernels inside), entirely from
//! rust.
//!
//! Artifact contract (see `python/compile/aot.py`):
//!
//! ```text
//! sparse_train_step(w[T,P], m[T,P], idx[L,P]i32, x[B,F], y[B]i32, lr[])
//!     -> (w'[T,P], m'[T,P], loss[])
//! sparse_forward(w[T,P], idx[L,P]i32, x[B,F]) -> logits[B,C]
//! ```
//!
//! The topology `idx` is a *runtime input*: the same compiled artifact
//! serves Sobol', scrambled and PRNG topologies generated on the rust
//! side — the coordinator decides the connectivity, the artifact only
//! fixes shapes.

use crate::nn::init::{w_init_magnitude, Init};
use crate::runtime::client::{literal_f32, literal_i32, to_scalar_f32, to_vec_f32};
use crate::runtime::xla_stub as xla;
use crate::runtime::{ArtifactManifest, Executable, Runtime};
use crate::engine::InferenceBackend;
use crate::topology::PathTopology;
use crate::util::error::{Context, Result};

/// Configuration of the AOT trainer.
#[derive(Debug, Clone)]
pub struct AotTrainerConfig {
    /// Directory containing `manifest.json` and the HLO artifacts.
    pub artifacts_dir: String,
    /// Initialization scheme for the path weights.
    pub init: Init,
    /// Seed for random init schemes.
    pub seed: u64,
}

impl Default for AotTrainerConfig {
    fn default() -> Self {
        AotTrainerConfig { artifacts_dir: "artifacts".into(), init: Init::ConstantRandomSign, seed: 0 }
    }
}

/// Static shape info baked into the artifacts, parsed from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct AotShapes {
    /// Layer sizes (input first).
    pub layer_sizes: Vec<usize>,
    /// Paths per transition.
    pub paths: usize,
    /// Training batch size.
    pub batch: usize,
    /// Transitions = layers − 1.
    pub transitions: usize,
    /// Classes.
    pub classes: usize,
    /// Input features.
    pub features: usize,
}

impl AotShapes {
    fn from_manifest(m: &ArtifactManifest) -> Result<AotShapes> {
        let spec = m
            .find("sparse_train_step")
            .context("manifest lacks sparse_train_step (re-run `make artifacts`)")?;
        let meta = &spec.meta;
        let layer_sizes: Vec<usize> = meta
            .get("layer_sizes")
            .and_then(|v| v.as_array())
            .context("meta.layer_sizes")?
            .iter()
            .map(|v| v.as_usize().context("layer size"))
            .collect::<Result<_>>()?;
        let paths = meta.get("paths").and_then(|v| v.as_usize()).context("meta.paths")?;
        let batch = meta.get("batch").and_then(|v| v.as_usize()).context("meta.batch")?;
        Ok(AotShapes {
            transitions: layer_sizes.len() - 1,
            classes: *layer_sizes.last().unwrap(),
            features: layer_sizes[0],
            layer_sizes,
            paths,
            batch,
        })
    }
}

/// Trains the path-sparse MLP by repeatedly executing the AOT step.
///
/// Hot-path note (EXPERIMENTS.md §Perf): parameters and momentum live
/// as PJRT **literals** between steps — the step's tuple outputs become
/// the next step's inputs directly, with no literal→Vec→literal
/// round-trip; the topology literal is built once.
pub struct AotTrainer {
    #[allow(dead_code)]
    rt: Runtime,
    step_exe: Executable,
    fwd_exe: Executable,
    /// Shapes baked into the artifacts.
    pub shapes: AotShapes,
    w_lit: xla::Literal,
    m_lit: xla::Literal,
    idx_lit: xla::Literal,
    /// Topology index `[L·P]` as i32 (host copy, for checkpointing).
    pub idx: Vec<i32>,
    /// Steps executed.
    pub steps: usize,
}

impl AotTrainer {
    /// Load artifacts, validate the topology against the baked shapes,
    /// and initialize parameters.
    pub fn new(cfg: &AotTrainerConfig, topo: &PathTopology) -> Result<AotTrainer> {
        let manifest = ArtifactManifest::load(&cfg.artifacts_dir).map_err(crate::util::error::Error::msg)?;
        let shapes = AotShapes::from_manifest(&manifest)?;
        crate::ensure!(
            topo.layer_sizes == shapes.layer_sizes,
            "topology layers {:?} != artifact layers {:?}",
            topo.layer_sizes,
            shapes.layer_sizes
        );
        crate::ensure!(
            topo.paths == shapes.paths,
            "topology paths {} != artifact paths {}",
            topo.paths,
            shapes.paths
        );
        let rt = Runtime::cpu()?;
        let step_spec = manifest.find("sparse_train_step").unwrap();
        let fwd_spec = manifest.find("sparse_forward").context("manifest lacks sparse_forward")?;
        let step_exe = rt.load_hlo_text(manifest.path_of(step_spec).to_str().unwrap())?;
        let fwd_exe = rt.load_hlo_text(manifest.path_of(fwd_spec).to_str().unwrap())?;

        // weights: per-transition magnitude from average valence
        let t_cnt = shapes.transitions;
        let p = shapes.paths;
        let mut w = vec![0.0f32; t_cnt * p];
        for t in 0..t_cnt {
            let fan_in = (p as f32 / shapes.layer_sizes[t + 1] as f32).max(1.0) as usize;
            let fan_out = (p as f32 / shapes.layer_sizes[t] as f32).max(1.0) as usize;
            let mag = w_init_magnitude(fan_in, fan_out);
            cfg.init.fill(
                &mut w[t * p..(t + 1) * p],
                mag,
                topo.signs.as_deref(),
                cfg.seed ^ (t as u64) << 17,
            );
        }
        let idx: Vec<i32> =
            topo.index.iter().flat_map(|layer| layer.iter().map(|&v| v as i32)).collect();
        let w_lit = literal_f32(&w, &[shapes.transitions, shapes.paths])?;
        let m_lit = literal_f32(&vec![0.0; w.len()], &[shapes.transitions, shapes.paths])?;
        let idx_lit = literal_i32(&idx, &[shapes.layer_sizes.len(), shapes.paths])?;
        Ok(AotTrainer { rt, step_exe, fwd_exe, w_lit, m_lit, idx_lit, idx, shapes, steps: 0 })
    }

    /// Host copy of the current weights `[T·P]`.
    pub fn weights(&self) -> Result<Vec<f32>> {
        to_vec_f32(&self.w_lit)
    }

    /// Host copy of the momentum buffer `[T·P]`.
    pub fn momentum(&self) -> Result<Vec<f32>> {
        to_vec_f32(&self.m_lit)
    }

    /// Install weights (e.g. restored from a checkpoint).
    pub fn set_weights(&mut self, w: &[f32]) -> Result<()> {
        let s = &self.shapes;
        crate::ensure!(w.len() == s.transitions * s.paths, "weight shape");
        self.w_lit = literal_f32(w, &[s.transitions, s.paths])?;
        Ok(())
    }

    /// Execute one SGD step on a `[batch × features]` batch.  Returns
    /// the batch loss.
    pub fn train_step(&mut self, x: &[f32], y: &[i32], lr: f32) -> Result<f32> {
        let s = &self.shapes;
        crate::ensure!(x.len() == s.batch * s.features, "x shape");
        crate::ensure!(y.len() == s.batch, "y shape");
        let x_lit = literal_f32(x, &[s.batch, s.features])?;
        let y_lit = literal_i32(y, &[s.batch])?;
        let lr_lit = literal_f32(&[lr], &[])?;
        let inputs = [&self.w_lit, &self.m_lit, &self.idx_lit, &x_lit, &y_lit, &lr_lit];
        let mut out = self.step_exe.run(&inputs)?;
        crate::ensure!(out.len() == 3, "train_step must return (w, m, loss)");
        let loss = to_scalar_f32(&out[2])?;
        self.m_lit = out.swap_remove(1);
        self.w_lit = out.swap_remove(0);
        self.steps += 1;
        Ok(loss)
    }

    /// Forward pass on a full `[batch × features]` buffer.
    pub fn forward(&self, x: &[f32]) -> Result<Vec<f32>> {
        let s = &self.shapes;
        crate::ensure!(x.len() == s.batch * s.features, "x shape");
        let x_lit = literal_f32(x, &[s.batch, s.features])?;
        let inputs = [&self.w_lit, &self.idx_lit, &x_lit];
        let out = self.fwd_exe.run(&inputs)?;
        to_vec_f32(&out[0])
    }

    /// Evaluate accuracy over a dataset (runs ⌈n/batch⌉ padded batches).
    pub fn evaluate(&self, xs: &[f32], ys: &[i32]) -> Result<f64> {
        let s = &self.shapes;
        let n = ys.len();
        crate::ensure!(xs.len() == n * s.features, "xs shape");
        let mut correct = 0usize;
        let mut xbuf = vec![0.0f32; s.batch * s.features];
        let mut i = 0usize;
        while i < n {
            let take = (n - i).min(s.batch);
            xbuf.iter_mut().for_each(|v| *v = 0.0);
            xbuf[..take * s.features]
                .copy_from_slice(&xs[i * s.features..(i + take) * s.features]);
            let logits = self.forward(&xbuf)?;
            for k in 0..take {
                let row = &logits[k * s.classes..(k + 1) * s.classes];
                let mut best = 0usize;
                for (j, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = j;
                    }
                }
                if best as i32 == ys[i + k] {
                    correct += 1;
                }
            }
            i += take;
        }
        Ok(correct as f64 / n as f64)
    }

    /// Wrap the forward executable as a serving backend (weights are
    /// snapshot at call time).
    pub fn into_backend(self) -> AotForward {
        AotForward { trainer: self }
    }
}

/// Serving adapter over a trained [`AotTrainer`].
pub struct AotForward {
    trainer: AotTrainer,
}

impl InferenceBackend for AotForward {
    fn batch_capacity(&self) -> usize {
        self.trainer.shapes.batch
    }

    fn features(&self) -> usize {
        self.trainer.shapes.features
    }

    fn classes(&self) -> usize {
        self.trainer.shapes.classes
    }

    fn infer_batch(&mut self, x: &[f32]) -> Vec<f32> {
        self.trainer.forward(x).expect("AOT forward")
    }
}

// Integration tests (require `make artifacts`) live in
// rust/tests/aot_integration.rs; shape-parsing tests below run always.
#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn shapes_from_manifest_meta() {
        let manifest = ArtifactManifest::parse(
            r#"{"artifacts": [{
                "name": "sparse_train_step",
                "file": "x.hlo.txt",
                "inputs": [], "outputs": [],
                "meta": {"layer_sizes": [784, 256, 256, 10], "paths": 2048, "batch": 64}
            }]}"#,
            PathBuf::from("."),
        )
        .unwrap();
        let s = AotShapes::from_manifest(&manifest).unwrap();
        assert_eq!(s.transitions, 3);
        assert_eq!(s.features, 784);
        assert_eq!(s.classes, 10);
        assert_eq!(s.paths, 2048);
        assert_eq!(s.batch, 64);
    }

    #[test]
    fn missing_artifact_is_error() {
        let manifest = ArtifactManifest::parse(r#"{"artifacts": []}"#, PathBuf::from(".")).unwrap();
        assert!(AotShapes::from_manifest(&manifest).is_err());
    }
}
