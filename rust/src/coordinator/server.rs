//! Inference server: request router + dynamic batcher + worker thread —
//! the vLLM-router-shaped L3 component, serving a path-sparse model
//! behind a channel API.
//!
//! Requests (single samples) are queued; a worker drains the queue into
//! fixed-capacity batches (AOT executables have a static batch size),
//! padding the tail, runs the backend once per batch, and answers each
//! request through its response channel.  Batching policy: wait up to
//! `max_wait` for a full batch, then flush whatever is pending.

use super::metrics::Metrics;
use crate::util::timer::Timer;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Something that can classify a fixed-size batch.
///
/// Implemented by the AOT executable wrapper (see
/// `coordinator::train::AotForward`) and by the pure-rust models (via
/// [`ModelBackend`]), so the same server fronts both.
///
/// Backends need not be `Send`: the server constructs them *on* the
/// worker thread via a factory (PJRT handles are `Rc`-based and cannot
/// cross threads).
pub trait InferenceBackend {
    /// Static batch capacity of one execution.
    fn batch_capacity(&self) -> usize;

    /// Features per sample.
    fn features(&self) -> usize;

    /// Classes per sample.
    fn classes(&self) -> usize;

    /// Run on a `[capacity × features]` buffer (padded rows arbitrary);
    /// returns `[capacity × classes]` logits.
    fn infer_batch(&mut self, x: &[f32]) -> Vec<f32>;
}

/// Blanket adapter for pure-rust [`crate::nn::Model`]s.
pub struct ModelBackend<M: crate::nn::Model + Send> {
    /// Wrapped model.
    pub model: M,
    /// Fixed batch capacity to emulate.
    pub capacity: usize,
    /// Input features.
    pub features: usize,
    /// Output classes.
    pub classes: usize,
}

impl<M: crate::nn::Model + Send> InferenceBackend for ModelBackend<M> {
    fn batch_capacity(&self) -> usize {
        self.capacity
    }

    fn features(&self) -> usize {
        self.features
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn infer_batch(&mut self, x: &[f32]) -> Vec<f32> {
        let t = crate::nn::tensor::Tensor::from_vec(x.to_vec(), &[self.capacity, self.features]);
        self.model.forward(&t, false).data
    }
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max time to wait for a full batch before flushing.
    pub max_wait: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_wait: Duration::from_millis(2) }
    }
}

struct Request {
    x: Vec<f32>,
    respond: Sender<Vec<f32>>,
    t_start: Timer,
}

/// Handle to a running inference server.
pub struct InferenceServer {
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
    /// Shared metrics.
    pub metrics: Arc<Metrics>,
    features: usize,
}

impl InferenceServer {
    /// Spawn the worker thread around a backend built by `factory`
    /// (construction happens on the worker thread so non-`Send` PJRT
    /// backends work).
    pub fn start_with<F>(factory: F, cfg: ServerConfig) -> InferenceServer
    where
        F: FnOnce() -> Box<dyn InferenceBackend> + Send + 'static,
    {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let (meta_tx, meta_rx) = channel();
        let metrics = Arc::new(Metrics::new());
        let m = metrics.clone();
        let worker = std::thread::spawn(move || {
            let mut backend = factory();
            let cap = backend.batch_capacity();
            meta_tx.send(backend.features()).expect("server alive");
            let feat = backend.features();
            let classes = backend.classes();
            let mut pending: Vec<Request> = Vec::with_capacity(cap);
            let mut xbuf = vec![0.0f32; cap * feat];
            loop {
                // block for the first request, then drain for max_wait
                let first = match rx.recv() {
                    Ok(r) => r,
                    Err(_) => return, // server dropped
                };
                pending.push(first);
                let deadline = Timer::start();
                while pending.len() < cap {
                    let remaining = cfg.max_wait.saturating_sub(Duration::from_secs_f64(
                        deadline.elapsed_secs(),
                    ));
                    match rx.recv_timeout(remaining) {
                        Ok(r) => pending.push(r),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                // assemble the padded batch
                xbuf.iter_mut().for_each(|v| *v = 0.0);
                for (i, r) in pending.iter().enumerate() {
                    xbuf[i * feat..(i + 1) * feat].copy_from_slice(&r.x);
                }
                let logits = backend.infer_batch(&xbuf);
                m.record_batch(pending.len(), cap);
                for (i, r) in pending.drain(..).enumerate() {
                    let out = logits[i * classes..(i + 1) * classes].to_vec();
                    m.record_latency(r.t_start.elapsed_secs());
                    let _ = r.respond.send(out);
                }
            }
        });
        let features = meta_rx.recv().expect("backend constructed");
        InferenceServer { tx: Some(tx), worker: Some(worker), metrics, features }
    }

    /// Spawn around an already-constructed `Send` backend.
    pub fn start(backend: Box<dyn InferenceBackend + Send>, cfg: ServerConfig) -> InferenceServer {
        Self::start_with(move || backend as Box<dyn InferenceBackend>, cfg)
    }

    /// Submit one sample; returns a receiver for the logits.
    pub fn submit(&self, x: Vec<f32>) -> Receiver<Vec<f32>> {
        assert_eq!(x.len(), self.features, "wrong feature count");
        let (rtx, rrx) = channel();
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("server running")
            .send(Request { x, respond: rtx, t_start: Timer::start() })
            .expect("worker alive");
        rrx
    }

    /// Convenience: submit and wait.
    pub fn infer(&self, x: Vec<f32>) -> Vec<f32> {
        self.submit(x).recv().expect("response")
    }

    /// Graceful shutdown (drains in-flight work).
    pub fn shutdown(mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Backend that sums features into class 0 and counts calls.
    struct Echo {
        calls: Arc<Metrics>,
    }

    impl InferenceBackend for Echo {
        fn batch_capacity(&self) -> usize {
            4
        }
        fn features(&self) -> usize {
            3
        }
        fn classes(&self) -> usize {
            2
        }
        fn infer_batch(&mut self, x: &[f32]) -> Vec<f32> {
            self.calls.batches.fetch_add(1, Ordering::Relaxed);
            let mut out = vec![0.0; 4 * 2];
            for i in 0..4 {
                out[i * 2] = x[i * 3] + x[i * 3 + 1] + x[i * 3 + 2];
                out[i * 2 + 1] = -1.0;
            }
            out
        }
    }

    #[test]
    fn single_request_roundtrip() {
        let srv = InferenceServer::start(
            Box::new(Echo { calls: Arc::new(Metrics::new()) }),
            ServerConfig { max_wait: Duration::from_millis(1) },
        );
        let y = srv.infer(vec![1.0, 2.0, 3.0]);
        assert_eq!(y, vec![6.0, -1.0]);
        let (p50, _, _) = srv.metrics.latency_percentiles();
        assert!(p50 > 0.0);
        srv.shutdown();
    }

    #[test]
    fn batching_coalesces_requests() {
        let counter = Arc::new(Metrics::new());
        let srv = InferenceServer::start(
            Box::new(Echo { calls: counter.clone() }),
            ServerConfig { max_wait: Duration::from_millis(50) },
        );
        // submit 4 requests quickly: should execute as ONE batch
        let rxs: Vec<_> = (0..4).map(|i| srv.submit(vec![i as f32, 0.0, 0.0])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let y = rx.recv().unwrap();
            assert_eq!(y[0], i as f32);
        }
        assert_eq!(counter.batches.load(Ordering::Relaxed), 1, "one coalesced batch");
        assert_eq!(srv.metrics.mean_batch_size(), 4.0);
        srv.shutdown();
    }

    #[test]
    fn flushes_partial_batch_on_timeout() {
        let srv = InferenceServer::start(
            Box::new(Echo { calls: Arc::new(Metrics::new()) }),
            ServerConfig { max_wait: Duration::from_millis(5) },
        );
        let y = srv.infer(vec![1.0, 1.0, 1.0]); // alone in its batch
        assert_eq!(y[0], 3.0);
        assert!(srv.metrics.padded_slots.load(Ordering::Relaxed) >= 3);
        srv.shutdown();
    }

    #[test]
    fn many_concurrent_clients() {
        let srv = Arc::new(InferenceServer::start(
            Box::new(Echo { calls: Arc::new(Metrics::new()) }),
            ServerConfig::default(),
        ));
        let mut handles = Vec::new();
        for k in 0..16 {
            let s = srv.clone();
            handles.push(std::thread::spawn(move || {
                let y = s.infer(vec![k as f32, k as f32, 0.0]);
                assert_eq!(y[0], 2.0 * k as f32);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(srv.metrics.completed.load(Ordering::Relaxed), 16);
    }
}
