//! Legacy location of the inference server — deprecated aliases only.
//!
//! The single-worker router/batcher that lived here grew first into the
//! sharded [`crate::serve::ShardedServer`] and then into the unified
//! [`crate::engine::Engine`] (backpressure-aware admission, ticket
//! requests, pluggable dispatch).  The historical names below keep old
//! imports compiling; they are `#[deprecated]` and new code should use
//! `crate::engine` (or `crate::serve` for the blocking compatibility
//! surface).

pub use crate::engine::InferenceBackend;

/// Deprecated alias of [`crate::engine::ModelBackend`].
#[deprecated(since = "0.1.0", note = "use crate::engine::ModelBackend")]
pub type ModelBackend<M> = crate::engine::ModelBackend<M>;

/// Deprecated alias of [`crate::serve::Dispatch`]; the engine's
/// [`crate::engine::DispatchKind`] supersedes both.
#[deprecated(since = "0.1.0", note = "use crate::engine::DispatchKind")]
pub type Dispatch = crate::serve::Dispatch;

/// Deprecated alias of [`crate::serve::ServeConfig`].
#[deprecated(since = "0.1.0", note = "use crate::engine::EngineBuilder")]
pub type ServeConfig = crate::serve::ServeConfig;

/// Deprecated alias of [`crate::serve::ServeConfig`].
#[deprecated(since = "0.1.0", note = "use crate::engine::EngineBuilder")]
pub type ServerConfig = crate::serve::ServeConfig;

/// Deprecated alias of [`crate::serve::ShardedServer`].
#[deprecated(since = "0.1.0", note = "use crate::engine::Engine via EngineBuilder")]
pub type ShardedServer = crate::serve::ShardedServer;

/// Deprecated alias of [`crate::serve::ShardedServer`].
#[deprecated(since = "0.1.0", note = "use crate::engine::Engine via EngineBuilder")]
pub type InferenceServer = crate::serve::ShardedServer;
