//! Legacy location of the inference server — deprecated aliases only.
//!
//! The single-worker router/batcher that lived here grew first into the
//! sharded [`crate::serve::ShardedServer`] and then into the unified
//! [`crate::engine::Engine`] (backpressure-aware admission, ticket
//! requests, pluggable dispatch, and the multi-process socket
//! transport in [`crate::engine::remote`]).  The historical names
//! below keep old imports compiling; they are `#[deprecated]` and new
//! code should use `crate::engine` (or `crate::serve` for the blocking
//! compatibility surface).  The engine layering is documented in
//! [`crate::engine`] and `docs/ARCHITECTURE.md`.

pub use crate::engine::InferenceBackend;

/// Deprecated alias of [`crate::engine::ModelBackend`].
#[deprecated(
    since = "0.1.0",
    note = "use crate::engine::ModelBackend (engine layering: see crate::engine docs and docs/ARCHITECTURE.md)"
)]
pub type ModelBackend<M> = crate::engine::ModelBackend<M>;

/// Deprecated alias of [`crate::serve::Dispatch`]; the engine's
/// [`crate::engine::DispatchKind`] supersedes both.
#[deprecated(
    since = "0.1.0",
    note = "use crate::engine::DispatchKind (engine layering: see crate::engine docs and docs/ARCHITECTURE.md)"
)]
pub type Dispatch = crate::serve::Dispatch;

/// Deprecated alias of [`crate::serve::ServeConfig`].
#[deprecated(
    since = "0.1.0",
    note = "use crate::engine::EngineBuilder (engine layering: see crate::engine docs and docs/ARCHITECTURE.md)"
)]
pub type ServeConfig = crate::serve::ServeConfig;

/// Deprecated alias of [`crate::serve::ServeConfig`].
#[deprecated(
    since = "0.1.0",
    note = "use crate::engine::EngineBuilder (engine layering: see crate::engine docs and docs/ARCHITECTURE.md)"
)]
pub type ServerConfig = crate::serve::ServeConfig;

/// Deprecated alias of [`crate::serve::ShardedServer`] (itself a thin
/// compat wrapper over the engine — its docs carry the migration
/// snippet).
#[deprecated(
    since = "0.1.0",
    note = "use crate::engine::Engine via EngineBuilder (engine layering: see crate::engine docs and docs/ARCHITECTURE.md)"
)]
pub type ShardedServer = crate::serve::ShardedServer;

/// Deprecated alias of [`crate::serve::ShardedServer`] (itself a thin
/// compat wrapper over the engine — its docs carry the migration
/// snippet).
#[deprecated(
    since = "0.1.0",
    note = "use crate::engine::Engine via EngineBuilder (engine layering: see crate::engine docs and docs/ARCHITECTURE.md)"
)]
pub type InferenceServer = crate::serve::ShardedServer;
