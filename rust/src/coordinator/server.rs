//! Legacy location of the inference server.
//!
//! The single-worker router/batcher that lived here grew into the
//! sharded multi-worker serving subsystem at [`crate::serve`]
//! (dispatcher + per-worker queues/batchers/metrics).  This module
//! re-exports the new types under their historical names so existing
//! imports (`coordinator::server::{InferenceServer, ServerConfig}`)
//! keep working; new code should use `crate::serve` directly.

pub use crate::serve::{Dispatch, InferenceBackend, ModelBackend};
pub use crate::serve::{ServeConfig, ServeConfig as ServerConfig};
pub use crate::serve::{ShardedServer, ShardedServer as InferenceServer};
