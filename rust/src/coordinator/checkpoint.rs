//! Checkpointing: save/restore the sparse model state (weights,
//! momentum, topology index) in a small self-describing binary format:
//!
//! ```text
//! magic "SBNC" | u32 version | u32 header_len | header JSON | blobs…
//! ```
//!
//! The JSON header records blob names, dtypes, lengths and arbitrary
//! metadata; blobs are raw little-endian arrays in header order.

use crate::config::json::{self, JsonValue};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"SBNC";
const VERSION: u32 = 1;

/// An in-memory checkpoint: named f32/i32 blobs plus metadata.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Checkpoint {
    /// f32 arrays by name.
    pub f32s: BTreeMap<String, Vec<f32>>,
    /// i32 arrays by name.
    pub i32s: BTreeMap<String, Vec<i32>>,
    /// Arbitrary metadata.
    pub meta: BTreeMap<String, JsonValue>,
}

impl Checkpoint {
    /// New empty checkpoint.
    pub fn new() -> Self {
        Self::default()
    }

    /// Serialize to a writer.
    pub fn write_to<W: Write>(&self, mut w: W) -> std::io::Result<()> {
        // header
        let mut blobs = Vec::new();
        for (name, data) in &self.f32s {
            let mut o = BTreeMap::new();
            o.insert("name".into(), JsonValue::String(name.clone()));
            o.insert("dtype".into(), JsonValue::String("f32".into()));
            o.insert("len".into(), JsonValue::Number(data.len() as f64));
            blobs.push(JsonValue::Object(o));
        }
        for (name, data) in &self.i32s {
            let mut o = BTreeMap::new();
            o.insert("name".into(), JsonValue::String(name.clone()));
            o.insert("dtype".into(), JsonValue::String("i32".into()));
            o.insert("len".into(), JsonValue::Number(data.len() as f64));
            blobs.push(JsonValue::Object(o));
        }
        let mut header = BTreeMap::new();
        header.insert("blobs".into(), JsonValue::Array(blobs));
        header.insert("meta".into(), JsonValue::Object(self.meta.clone()));
        let header_text = JsonValue::Object(header).to_string_compact();
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(header_text.len() as u32).to_le_bytes())?;
        w.write_all(header_text.as_bytes())?;
        for data in self.f32s.values() {
            for v in data {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        for data in self.i32s.values() {
            for v in data {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Deserialize from a reader.
    pub fn read_from<R: Read>(mut r: R) -> Result<Checkpoint, String> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic).map_err(|e| e.to_string())?;
        if &magic != MAGIC {
            return Err("bad magic (not a sobolnet checkpoint)".into());
        }
        let mut buf4 = [0u8; 4];
        r.read_exact(&mut buf4).map_err(|e| e.to_string())?;
        let version = u32::from_le_bytes(buf4);
        if version != VERSION {
            return Err(format!("unsupported checkpoint version {version}"));
        }
        r.read_exact(&mut buf4).map_err(|e| e.to_string())?;
        let hlen = u32::from_le_bytes(buf4) as usize;
        let mut htext = vec![0u8; hlen];
        r.read_exact(&mut htext).map_err(|e| e.to_string())?;
        let header = json::parse(std::str::from_utf8(&htext).map_err(|e| e.to_string())?)?;
        let mut ckpt = Checkpoint::new();
        if let Some(JsonValue::Object(meta)) = header.get("meta") {
            ckpt.meta = meta.clone();
        }
        let blobs = header.get("blobs").and_then(|b| b.as_array()).ok_or("missing blobs")?;
        for b in blobs {
            let name = b.get("name").and_then(|v| v.as_str()).ok_or("blob name")?.to_string();
            let dtype = b.get("dtype").and_then(|v| v.as_str()).ok_or("blob dtype")?;
            let len = b.get("len").and_then(|v| v.as_usize()).ok_or("blob len")?;
            let mut raw = vec![0u8; len * 4];
            r.read_exact(&mut raw).map_err(|e| format!("blob {name}: {e}"))?;
            match dtype {
                "f32" => {
                    let data =
                        raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
                    ckpt.f32s.insert(name, data);
                }
                "i32" => {
                    let data =
                        raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();
                    ckpt.i32s.insert(name, data);
                }
                other => return Err(format!("unknown dtype {other}")),
            }
        }
        Ok(ckpt)
    }

    /// Save to a file.
    #[deprecated(
        since = "0.1.0",
        note = "use `registry::persist::save_checkpoint_file` — the registry \
                owns checkpoint-file IO now (one String error type shared \
                with snapshot persistence)"
    )]
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let f = std::fs::File::create(path)?;
        self.write_to(std::io::BufWriter::new(f))
    }

    /// Load from a file.
    #[deprecated(
        since = "0.1.0",
        note = "use `registry::persist::load_checkpoint_file` — the registry \
                owns checkpoint-file IO now (one String error type shared \
                with snapshot persistence)"
    )]
    pub fn load(path: &Path) -> Result<Checkpoint, String> {
        let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::read_from(std::io::BufReader::new(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_in_memory() {
        let mut c = Checkpoint::new();
        c.f32s.insert("w".into(), vec![1.5, -2.25, 0.0]);
        c.f32s.insert("m".into(), vec![0.125; 8]);
        c.i32s.insert("idx".into(), vec![3, -1, 700000]);
        c.meta.insert("paths".into(), JsonValue::Number(1024.0));
        c.meta.insert("source".into(), JsonValue::String("sobol".into()));
        let mut buf = Vec::new();
        c.write_to(&mut buf).unwrap();
        let back = Checkpoint::read_from(buf.as_slice()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    // pins that the deprecated convenience wrappers still function
    // until their removal; new code goes through registry::persist
    #[allow(deprecated)]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("sobolnet_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.ckpt");
        let mut c = Checkpoint::new();
        c.f32s.insert("w".into(), (0..100).map(|i| i as f32 * 0.5).collect());
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.f32s["w"].len(), 100);
        assert_eq!(back.f32s["w"][7], 3.5);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        assert!(Checkpoint::read_from(&b"NOPE...."[..]).is_err());
        let mut buf = Vec::new();
        Checkpoint::new().write_to(&mut buf).unwrap();
        buf[4] = 99; // corrupt version
        assert!(Checkpoint::read_from(buf.as_slice()).is_err());
    }

    #[test]
    fn empty_checkpoint_roundtrips() {
        let c = Checkpoint::new();
        let mut buf = Vec::new();
        c.write_to(&mut buf).unwrap();
        assert_eq!(Checkpoint::read_from(buf.as_slice()).unwrap(), c);
    }
}
