//! Lightweight metrics: counters and latency histograms for the
//! inference server and training driver.

use crate::util::stats::latency_percentiles;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared metrics registry (cheap to clone via `Arc`).
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted.
    pub requests: AtomicU64,
    /// Requests completed.
    pub completed: AtomicU64,
    /// Batches executed.
    pub batches: AtomicU64,
    /// Total samples padded into batches (wasted slots).
    pub padded_slots: AtomicU64,
    latencies: Mutex<Vec<f64>>,
    batch_sizes: Mutex<Vec<usize>>,
}

impl Metrics {
    /// New empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a completed request with its end-to-end latency.
    pub fn record_latency(&self, secs: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies.lock().unwrap().push(secs);
    }

    /// Record an executed batch (`used` real samples of `capacity`).
    pub fn record_batch(&self, used: usize, capacity: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padded_slots.fetch_add((capacity - used) as u64, Ordering::Relaxed);
        self.batch_sizes.lock().unwrap().push(used);
    }

    /// Latency percentiles `(p50, p90, p99)` in seconds.
    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        let l = self.latencies.lock().unwrap();
        latency_percentiles(&l)
    }

    /// Mean executed batch occupancy.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batch_sizes.lock().unwrap();
        if b.is_empty() {
            0.0
        } else {
            b.iter().sum::<usize>() as f64 / b.len() as f64
        }
    }

    /// Human-readable summary line.
    pub fn summary(&self) -> String {
        let (p50, p90, p99) = self.latency_percentiles();
        format!(
            "requests={} completed={} batches={} mean_batch={:.1} p50={:.3}ms p90={:.3}ms p99={:.3}ms",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            p50 * 1e3,
            p90 * 1e3,
            p99 * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.record_latency(0.010);
        m.record_latency(0.020);
        m.record_batch(2, 4);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.padded_slots.load(Ordering::Relaxed), 2);
        let (p50, _, p99) = m.latency_percentiles();
        assert!(p50 >= 0.010 && p99 <= 0.020 + 1e-9);
        assert_eq!(m.mean_batch_size(), 2.0);
        let s = m.summary();
        assert!(s.contains("requests=3"));
        assert!(s.contains("batches=1"));
    }

    #[test]
    fn empty_is_safe() {
        let m = Metrics::new();
        let (p50, _, _) = m.latency_percentiles();
        assert!(p50.is_nan());
        assert_eq!(m.mean_batch_size(), 0.0);
        let _ = m.summary();
    }
}
