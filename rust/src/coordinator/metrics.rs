//! Lightweight metrics: counters and latency histograms for the
//! inference engine and training driver.
//!
//! Aggregation contract: each worker shard records latency **samples**
//! only into its own `Metrics`; engine-wide percentiles are computed
//! with [`Metrics::merged_percentiles`], which pools the per-worker
//! samples *before* taking percentiles.  Averaging per-worker
//! percentiles is not a percentile (a shard that answered 10 requests
//! would weigh as much as one that answered 10 000, and tail values
//! from a slow shard would be diluted instead of dominating the
//! aggregate tail) — the unit tests pin the difference.

use crate::util::stats::latency_percentiles;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared metrics registry (cheap to clone via `Arc`).
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted.
    pub requests: AtomicU64,
    /// Requests completed.
    pub completed: AtomicU64,
    /// Requests shed by admission control (rejected or evicted).
    pub shed: AtomicU64,
    /// Batches executed.
    pub batches: AtomicU64,
    /// Total samples padded into batches (wasted slots).
    pub padded_slots: AtomicU64,
    latencies: Mutex<Vec<f64>>,
    batch_sizes: Mutex<Vec<usize>>,
}

impl Metrics {
    /// New empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a completed request with its end-to-end latency.
    pub fn record_latency(&self, secs: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies.lock().unwrap().push(secs);
    }

    /// Record an executed batch (`used` real samples of `capacity`).
    pub fn record_batch(&self, used: usize, capacity: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padded_slots.fetch_add((capacity - used) as u64, Ordering::Relaxed);
        self.batch_sizes.lock().unwrap().push(used);
    }

    /// Latency percentiles `(p50, p90, p99)` in seconds.
    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        let l = self.latencies.lock().unwrap();
        latency_percentiles(&l)
    }

    /// Number of latency samples recorded.
    pub fn latency_count(&self) -> usize {
        self.latencies.lock().unwrap().len()
    }

    /// Append this registry's latency samples to `out` (the merge step
    /// of cross-worker aggregation).
    pub fn extend_latencies_into(&self, out: &mut Vec<f64>) {
        out.extend_from_slice(&self.latencies.lock().unwrap());
    }

    /// Append at most the `cap` most recent latency samples to `out`.
    /// The bounded snapshot of the remote stats path: the copy cost
    /// per poll (taken under the same lock the hot path's
    /// `record_latency` needs) stays `O(cap)` no matter how long the
    /// worker has been running.
    pub fn extend_recent_latencies_into(&self, out: &mut Vec<f64>, cap: usize) {
        let l = self.latencies.lock().unwrap();
        out.extend_from_slice(&l[l.len().saturating_sub(cap)..]);
    }

    /// Percentiles `(p50, p90, p99)` over the **union** of several
    /// registries' latency samples.  This is the correct way to
    /// aggregate per-worker histograms: merge first, then take
    /// percentiles — never average per-worker percentiles.
    pub fn merged_percentiles<'a, I>(parts: I) -> (f64, f64, f64)
    where
        I: IntoIterator<Item = &'a Metrics>,
    {
        let mut all = Vec::new();
        for m in parts {
            m.extend_latencies_into(&mut all);
        }
        latency_percentiles(&all)
    }

    /// Fold a remote worker's stats frame into this registry: the
    /// frame carries the worker's **cumulative** counters since
    /// process start plus its most recent raw latency samples (the
    /// sender bounds the window), so the fold *replaces* the registry
    /// contents wholesale (idempotent — folding the same frame twice
    /// is a no-op).  The coordinator keeps one registry per remote
    /// shard and aggregates them with [`Metrics::merged_percentiles`];
    /// shipping raw samples instead of per-worker percentiles is what
    /// makes that merge correct.
    pub fn fold_remote(&self, completed: u64, shed: u64, batches: u64, latencies: &[f64]) {
        self.completed.store(completed, Ordering::Relaxed);
        self.shed.store(shed, Ordering::Relaxed);
        self.batches.store(batches, Ordering::Relaxed);
        let mut l = self.latencies.lock().unwrap();
        l.clear();
        l.extend_from_slice(latencies);
    }

    /// Mean executed batch occupancy.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batch_sizes.lock().unwrap();
        if b.is_empty() {
            0.0
        } else {
            b.iter().sum::<usize>() as f64 / b.len() as f64
        }
    }

    /// Human-readable summary line.
    pub fn summary(&self) -> String {
        let (p50, p90, p99) = self.latency_percentiles();
        format!(
            "requests={} completed={} shed={} batches={} mean_batch={:.1} p50={:.3}ms p90={:.3}ms p99={:.3}ms",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            p50 * 1e3,
            p90 * 1e3,
            p99 * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.record_latency(0.010);
        m.record_latency(0.020);
        m.record_batch(2, 4);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.padded_slots.load(Ordering::Relaxed), 2);
        let (p50, _, p99) = m.latency_percentiles();
        assert!(p50 >= 0.010 && p99 <= 0.020 + 1e-9);
        assert_eq!(m.mean_batch_size(), 2.0);
        let s = m.summary();
        assert!(s.contains("requests=3"));
        assert!(s.contains("batches=1"));
    }

    #[test]
    fn empty_is_safe() {
        let m = Metrics::new();
        let (p50, _, _) = m.latency_percentiles();
        assert!(p50.is_nan());
        assert_eq!(m.mean_batch_size(), 0.0);
        let _ = m.summary();
    }

    /// Known distribution: worker A answers 99 fast requests (1 ms),
    /// worker B answers a single slow one (101 ms).  The true merged
    /// p99 (per `util::stats::percentile_sorted`, linear interpolation
    /// over 100 samples) interpolates 1% of the way between the two
    /// modes, landing at 2 ms; the p50 stays at 1 ms.  Averaging the
    /// per-worker "percentiles" instead gives 51 ms for *every*
    /// percentile — off by an order of magnitude in both directions.
    #[test]
    fn merged_percentiles_pool_samples_before_ranking() {
        let a = Metrics::new();
        for _ in 0..99 {
            a.record_latency(0.001);
        }
        let b = Metrics::new();
        b.record_latency(0.101);

        let (p50, p90, p99) = Metrics::merged_percentiles([&a, &b]);
        assert!((p50 - 0.001).abs() < 1e-9, "merged p50 = 1ms, got {p50}");
        assert!((p90 - 0.001).abs() < 1e-9, "merged p90 = 1ms, got {p90}");
        // rank 99 * 0.99 = 98.01 → interpolates 1% of the way from
        // 1ms (sample 98) to 101ms (sample 99): 1ms + 0.01·100ms = 2ms
        assert!((p99 - 0.002).abs() < 1e-6, "merged p99 = 2ms, got {p99}");

        // the broken aggregation (mean of per-worker percentiles)
        let (a50, _, a99) = a.latency_percentiles();
        let (b50, _, b99) = b.latency_percentiles();
        let avg50 = (a50 + b50) / 2.0;
        let avg99 = (a99 + b99) / 2.0;
        assert!((avg50 - 0.051).abs() < 1e-9, "averaged 'p50' is 51ms");
        assert!(avg99 > 25.0 * p99, "averaged 'p99' ({avg99}) wildly overstates merged ({p99})");
    }

    /// The multi-process fold: one registry per remote shard, each
    /// replaced wholesale by that shard's cumulative stats frame;
    /// merging the folded registries must equal percentiles over the
    /// union of samples, and re-folding the same frame is a no-op.
    #[test]
    fn fold_remote_is_idempotent_and_merges_exactly() {
        let a = Metrics::new();
        let b = Metrics::new();
        let sa: Vec<f64> = (1..=99).map(|i| i as f64 * 1e-3).collect();
        let sb = vec![0.101];
        a.fold_remote(99, 2, 10, &sa);
        b.fold_remote(1, 0, 1, &sb);
        // folding the same cumulative frame again changes nothing
        a.fold_remote(99, 2, 10, &sa);
        assert_eq!(a.latency_count(), 99);
        assert_eq!(a.completed.load(Ordering::Relaxed), 99);
        assert_eq!(a.shed.load(Ordering::Relaxed), 2);
        assert_eq!(b.batches.load(Ordering::Relaxed), 1);
        let merged = Metrics::merged_percentiles([&a, &b]);
        let pooled = Metrics::new();
        for s in sa.iter().chain(&sb) {
            pooled.record_latency(*s);
        }
        assert_eq!(merged, pooled.latency_percentiles(), "fold+merge == pooled percentiles");
    }

    #[test]
    fn merged_percentiles_edge_cases() {
        let empty = Metrics::new();
        let (p50, _, _) = Metrics::merged_percentiles([&empty]);
        assert!(p50.is_nan(), "no samples anywhere → NaN");
        let one = Metrics::new();
        one.record_latency(0.005);
        let (p50, p90, p99) = Metrics::merged_percentiles([&empty, &one]);
        assert_eq!((p50, p90, p99), (0.005, 0.005, 0.005));
        assert_eq!(one.latency_count(), 1);
        let mut pooled = Vec::new();
        one.extend_latencies_into(&mut pooled);
        one.extend_latencies_into(&mut pooled);
        assert_eq!(pooled, vec![0.005, 0.005]);
    }
}
