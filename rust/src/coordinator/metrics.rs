//! Lightweight metrics: counters and latency histograms for the
//! inference engine and training driver.
//!
//! Aggregation contract: each worker shard records latency **samples**
//! only into its own `Metrics`; engine-wide percentiles are computed
//! with [`Metrics::merged_percentiles`], which pools the per-worker
//! samples *before* taking percentiles.  Averaging per-worker
//! percentiles is not a percentile (a shard that answered 10 requests
//! would weigh as much as one that answered 10 000, and tail values
//! from a slow shard would be diluted instead of dominating the
//! aggregate tail) — the unit tests pin the difference.
//!
//! Storage contract: sample storage is a **fixed-capacity ring buffer**
//! ([`DEFAULT_SAMPLE_WINDOW`] samples by default,
//! `EngineBuilder::metrics_window` to resize).  Counters stay
//! cumulative for the registry's lifetime, but latency/batch-size
//! samples retain only the most recent window — a long-lived serving
//! process holds O(window) memory no matter how many requests it has
//! answered (the pre-ring `Vec` grew without bound, the leak the
//! ROADMAP flagged).  Every percentile/merge/fold operation is defined
//! over the retained window.

use crate::util::stats::percentile_sorted;
use crate::util::sync::plock;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Default ring capacity for latency and batch-size samples (64 Ki
/// samples ≈ 512 KiB of f64 — far more than any percentile needs, and
/// deliberately equal to the remote stats frames' per-poll sample cap
/// so an in-process registry and a folded remote one retain the same
/// window).
pub const DEFAULT_SAMPLE_WINDOW: usize = 64 * 1024;

/// `(p50, p90, p99)` of an owned sample vector, sorted in place — the
/// copy the caller already made to linearize a ring (or merge several)
/// doubles as the sort buffer, so percentile reads cost one copy, not
/// two.
fn percentiles_of(mut samples: Vec<f64>) -> (f64, f64, f64) {
    samples.sort_by(f64::total_cmp);
    (
        percentile_sorted(&samples, 0.50),
        percentile_sorted(&samples, 0.90),
        percentile_sorted(&samples, 0.99),
    )
}

/// Fixed-capacity ring buffer preserving arrival order.  Backing
/// storage grows lazily up to `cap` (an idle registry costs nothing),
/// then stays put: the oldest sample is overwritten in place.
#[derive(Debug)]
struct Ring<T> {
    cap: usize,
    buf: Vec<T>,
    /// Index of the oldest element once `buf.len() == cap`.
    start: usize,
}

impl<T: Copy> Ring<T> {
    fn new(cap: usize) -> Self {
        Ring { cap: cap.max(1), buf: Vec::new(), start: 0 }
    }

    fn push(&mut self, v: T) {
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            self.buf[self.start] = v;
            self.start = (self.start + 1) % self.cap;
        }
    }

    fn len(&self) -> usize {
        self.buf.len()
    }

    fn clear(&mut self) {
        self.buf.clear();
        self.start = 0;
    }

    /// Oldest → newest.
    fn iter(&self) -> impl Iterator<Item = &T> {
        self.buf[self.start..].iter().chain(self.buf[..self.start].iter())
    }
}

/// Shared metrics registry (cheap to clone via `Arc`).
#[derive(Debug)]
pub struct Metrics {
    /// Requests accepted.
    pub requests: AtomicU64,
    /// Requests completed.
    pub completed: AtomicU64,
    /// Requests shed by admission control (rejected or evicted).
    pub shed: AtomicU64,
    /// Batches executed.
    pub batches: AtomicU64,
    /// Total samples padded into batches (wasted slots).
    pub padded_slots: AtomicU64,
    /// Per-shard model-cache hits (multi-tenant weight cache,
    /// [`crate::registry::cache::ModelCache`]).
    pub cache_hits: AtomicU64,
    /// Per-shard model-cache misses (each one is a cold load from the
    /// registry).
    pub cache_misses: AtomicU64,
    /// Per-shard model-cache evictions (LRU entry retired at
    /// capacity).
    pub cache_evictions: AtomicU64,
    latencies: Mutex<Ring<f64>>,
    batch_sizes: Mutex<Ring<usize>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::with_window(DEFAULT_SAMPLE_WINDOW)
    }
}

impl Metrics {
    /// New empty registry with the default sample window
    /// ([`DEFAULT_SAMPLE_WINDOW`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// New empty registry retaining at most `window` latency samples
    /// and `window` batch-size samples (clamped to ≥ 1).  Memory is
    /// O(window) for the registry's whole lifetime.
    pub fn with_window(window: usize) -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            padded_slots: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            cache_evictions: AtomicU64::new(0),
            latencies: Mutex::new(Ring::new(window)),
            batch_sizes: Mutex::new(Ring::new(window)),
        }
    }

    /// Model-cache hit rate `hits / (hits + misses)` over this
    /// registry's lifetime; `None` before any lookup happened.
    pub fn cache_hit_rate(&self) -> Option<f64> {
        let h = self.cache_hits.load(Ordering::Relaxed);
        let m = self.cache_misses.load(Ordering::Relaxed);
        if h + m == 0 {
            None
        } else {
            Some(h as f64 / (h + m) as f64)
        }
    }

    /// Sample-window capacity (max latency samples retained).
    pub fn window(&self) -> usize {
        plock(&self.latencies).cap
    }

    /// Record a completed request with its end-to-end latency.
    pub fn record_latency(&self, secs: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        plock(&self.latencies).push(secs);
    }

    /// Record an executed batch (`used` real samples of `capacity`).
    /// `used > capacity` is a caller bug (debug assert), tolerated in
    /// release as zero padding rather than a wrapped garbage counter.
    pub fn record_batch(&self, used: usize, capacity: usize) {
        debug_assert!(
            used <= capacity,
            "record_batch: used {used} exceeds batch capacity {capacity}"
        );
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padded_slots.fetch_add(capacity.saturating_sub(used) as u64, Ordering::Relaxed);
        plock(&self.batch_sizes).push(used);
    }

    /// Latency percentiles `(p50, p90, p99)` in seconds over the
    /// retained window.
    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        let l = plock(&self.latencies);
        let samples: Vec<f64> = l.iter().copied().collect();
        drop(l);
        percentiles_of(samples)
    }

    /// Number of latency samples currently retained (≤ the window).
    pub fn latency_count(&self) -> usize {
        plock(&self.latencies).len()
    }

    /// Append this registry's retained latency samples to `out`
    /// (oldest first — the merge step of cross-worker aggregation).
    pub fn extend_latencies_into(&self, out: &mut Vec<f64>) {
        out.extend(plock(&self.latencies).iter());
    }

    /// Append at most the `cap` most recent latency samples to `out`.
    /// The bounded snapshot of the remote stats path: the copy cost
    /// per poll (taken under the same lock the hot path's
    /// `record_latency` needs) stays `O(cap)` no matter how long the
    /// worker has been running.
    pub fn extend_recent_latencies_into(&self, out: &mut Vec<f64>, cap: usize) {
        let l = plock(&self.latencies);
        out.extend(l.iter().skip(l.len().saturating_sub(cap)));
    }

    /// Percentiles `(p50, p90, p99)` over the **union** of several
    /// registries' retained latency samples.  This is the correct way
    /// to aggregate per-worker histograms: merge first, then take
    /// percentiles — never average per-worker percentiles.
    pub fn merged_percentiles<'a, I>(parts: I) -> (f64, f64, f64)
    where
        I: IntoIterator<Item = &'a Metrics>,
    {
        let mut all = Vec::new();
        for m in parts {
            m.extend_latencies_into(&mut all);
        }
        percentiles_of(all)
    }

    /// Fold a remote worker's stats frame into this registry: the
    /// frame carries the worker's **cumulative** counters since
    /// process start plus its most recent raw latency samples (the
    /// sender bounds the window), so the fold *replaces* the registry
    /// contents wholesale (idempotent — folding the same frame twice
    /// is a no-op; a frame longer than this registry's window retains
    /// its newest `window` samples).  The coordinator keeps one
    /// registry per remote shard and aggregates them with
    /// [`Metrics::merged_percentiles`]; shipping raw samples instead
    /// of per-worker percentiles is what makes that merge correct.
    pub fn fold_remote(&self, completed: u64, shed: u64, batches: u64, latencies: &[f64]) {
        self.completed.store(completed, Ordering::Relaxed);
        self.shed.store(shed, Ordering::Relaxed);
        self.batches.store(batches, Ordering::Relaxed);
        let mut l = plock(&self.latencies);
        l.clear();
        for &s in latencies {
            l.push(s);
        }
    }

    /// Mean executed batch occupancy over the retained window.
    pub fn mean_batch_size(&self) -> f64 {
        let b = plock(&self.batch_sizes);
        let n = b.len();
        if n == 0 {
            0.0
        } else {
            b.iter().sum::<usize>() as f64 / n as f64
        }
    }

    /// Human-readable summary line.
    pub fn summary(&self) -> String {
        let (p50, p90, p99) = self.latency_percentiles();
        format!(
            "requests={} completed={} shed={} batches={} mean_batch={:.1} p50={:.3}ms p90={:.3}ms p99={:.3}ms",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            p50 * 1e3,
            p90 * 1e3,
            p99 * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = Metrics::new();
        assert_eq!(m.window(), DEFAULT_SAMPLE_WINDOW);
        assert_eq!(m.cache_hit_rate(), None, "no cache lookups yet");
        m.cache_hits.fetch_add(3, Ordering::Relaxed);
        m.cache_misses.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.cache_hit_rate(), Some(0.75));
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.record_latency(0.010);
        m.record_latency(0.020);
        m.record_batch(2, 4);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.padded_slots.load(Ordering::Relaxed), 2);
        let (p50, _, p99) = m.latency_percentiles();
        assert!(p50 >= 0.010 && p99 <= 0.020 + 1e-9);
        assert_eq!(m.mean_batch_size(), 2.0);
        let s = m.summary();
        assert!(s.contains("requests=3"));
        assert!(s.contains("batches=1"));
    }

    #[test]
    fn empty_is_safe() {
        let m = Metrics::new();
        let (p50, _, _) = m.latency_percentiles();
        assert!(p50.is_nan());
        assert_eq!(m.mean_batch_size(), 0.0);
        let _ = m.summary();
    }

    /// The headline leak fix: feed a registry far more samples than
    /// its window and verify storage stays O(window) — only the newest
    /// `window` samples are retained, in arrival order, and the
    /// percentile/merge surface operates on exactly that window.
    #[test]
    fn sample_storage_is_bounded_by_the_window() {
        let cap = 64usize;
        let m = Metrics::with_window(cap);
        assert_eq!(m.window(), cap);
        let total = 2 * cap + 17; // > 2× capacity, not a multiple
        for i in 0..total {
            m.record_latency(i as f64);
            m.record_batch(i % 5, 8);
        }
        // counters stay cumulative; sample storage does not
        assert_eq!(m.completed.load(Ordering::Relaxed), total as u64);
        assert_eq!(m.batches.load(Ordering::Relaxed), total as u64);
        assert_eq!(m.latency_count(), cap, "retained at most window samples");

        // retained window is exactly the newest `cap`, oldest first
        let mut got = Vec::new();
        m.extend_latencies_into(&mut got);
        let want: Vec<f64> = ((total - cap)..total).map(|i| i as f64).collect();
        assert_eq!(got, want, "ring retains the newest window in arrival order");

        // percentiles are over the retained window, not the lifetime
        let (p50, _, p99) = m.latency_percentiles();
        assert!(p50 >= (total - cap) as f64, "p50 computed over retained window, got {p50}");
        assert!(p99 <= (total - 1) as f64 + 1e-9);

        // the recent-sample snapshot is the tail of the window
        let mut recent = Vec::new();
        m.extend_recent_latencies_into(&mut recent, 10);
        let want_recent: Vec<f64> = ((total - 10)..total).map(|i| i as f64).collect();
        assert_eq!(recent, want_recent);
        // asking for more than retained yields the whole window
        let mut all = Vec::new();
        m.extend_recent_latencies_into(&mut all, cap * 10);
        assert_eq!(all.len(), cap);

        // batch-size window mirrors the latency window
        let want_mean = ((total - cap)..total).map(|i| (i % 5) as f64).sum::<f64>() / cap as f64;
        assert!((m.mean_batch_size() - want_mean).abs() < 1e-12);
    }

    #[test]
    fn record_batch_tolerates_overfull_reports() {
        let m = Metrics::new();
        m.record_batch(4, 4); // exactly full: no padding
        assert_eq!(m.padded_slots.load(Ordering::Relaxed), 0);
        // a caller reporting used > capacity is a bug (debug_assert),
        // but release builds must saturate to 0 padding instead of
        // wrapping the counter to ~2^64
        if cfg!(debug_assertions) {
            let r = std::panic::catch_unwind(|| m.record_batch(9, 4));
            assert!(r.is_err(), "debug build asserts on used > capacity");
        } else {
            m.record_batch(9, 4);
            assert_eq!(m.padded_slots.load(Ordering::Relaxed), 0, "saturates, never wraps");
        }
    }

    /// Known distribution: worker A answers 99 fast requests (1 ms),
    /// worker B answers a single slow one (101 ms).  The true merged
    /// p99 (per `util::stats::percentile_sorted`, linear interpolation
    /// over 100 samples) interpolates 1% of the way between the two
    /// modes, landing at 2 ms; the p50 stays at 1 ms.  Averaging the
    /// per-worker "percentiles" instead gives 51 ms for *every*
    /// percentile — off by an order of magnitude in both directions.
    #[test]
    fn merged_percentiles_pool_samples_before_ranking() {
        let a = Metrics::new();
        for _ in 0..99 {
            a.record_latency(0.001);
        }
        let b = Metrics::new();
        b.record_latency(0.101);

        let (p50, p90, p99) = Metrics::merged_percentiles([&a, &b]);
        assert!((p50 - 0.001).abs() < 1e-9, "merged p50 = 1ms, got {p50}");
        assert!((p90 - 0.001).abs() < 1e-9, "merged p90 = 1ms, got {p90}");
        // rank 99 * 0.99 = 98.01 → interpolates 1% of the way from
        // 1ms (sample 98) to 101ms (sample 99): 1ms + 0.01·100ms = 2ms
        assert!((p99 - 0.002).abs() < 1e-6, "merged p99 = 2ms, got {p99}");

        // the broken aggregation (mean of per-worker percentiles)
        let (a50, _, a99) = a.latency_percentiles();
        let (b50, _, b99) = b.latency_percentiles();
        let avg50 = (a50 + b50) / 2.0;
        let avg99 = (a99 + b99) / 2.0;
        assert!((avg50 - 0.051).abs() < 1e-9, "averaged 'p50' is 51ms");
        assert!(avg99 > 25.0 * p99, "averaged 'p99' ({avg99}) wildly overstates merged ({p99})");
    }

    /// The multi-process fold: one registry per remote shard, each
    /// replaced wholesale by that shard's cumulative stats frame;
    /// merging the folded registries must equal percentiles over the
    /// union of samples, and re-folding the same frame is a no-op.
    #[test]
    fn fold_remote_is_idempotent_and_merges_exactly() {
        let a = Metrics::new();
        let b = Metrics::new();
        let sa: Vec<f64> = (1..=99).map(|i| i as f64 * 1e-3).collect();
        let sb = vec![0.101];
        a.fold_remote(99, 2, 10, &sa);
        b.fold_remote(1, 0, 1, &sb);
        // folding the same cumulative frame again changes nothing
        a.fold_remote(99, 2, 10, &sa);
        assert_eq!(a.latency_count(), 99);
        assert_eq!(a.completed.load(Ordering::Relaxed), 99);
        assert_eq!(a.shed.load(Ordering::Relaxed), 2);
        assert_eq!(b.batches.load(Ordering::Relaxed), 1);
        let merged = Metrics::merged_percentiles([&a, &b]);
        let pooled = Metrics::new();
        for s in sa.iter().chain(&sb) {
            pooled.record_latency(*s);
        }
        assert_eq!(merged, pooled.latency_percentiles(), "fold+merge == pooled percentiles");
    }

    /// fold+merge == pooled percentiles must also hold when the folded
    /// frames ride a *small* window: the retained suffixes behave
    /// exactly like registries that only ever saw those samples.
    #[test]
    fn fold_remote_respects_the_window() {
        let cap = 16usize;
        let a = Metrics::with_window(cap);
        let frame: Vec<f64> = (0..50).map(|i| i as f64 * 1e-3).collect();
        a.fold_remote(50, 0, 5, &frame);
        assert_eq!(a.latency_count(), cap, "oversized frame truncated to the window");
        let mut got = Vec::new();
        a.extend_latencies_into(&mut got);
        assert_eq!(got, &frame[50 - cap..], "newest samples retained");
        // idempotent under the window too
        a.fold_remote(50, 0, 5, &frame);
        assert_eq!(a.latency_count(), cap);

        let b = Metrics::with_window(cap);
        b.fold_remote(1, 0, 1, &[0.999]);
        let merged = Metrics::merged_percentiles([&a, &b]);
        let pooled = Metrics::new();
        for s in frame[50 - cap..].iter().chain(&[0.999]) {
            pooled.record_latency(*s);
        }
        assert_eq!(merged, pooled.latency_percentiles(), "windowed fold+merge == pooled");
    }

    #[test]
    fn merged_percentiles_edge_cases() {
        let empty = Metrics::new();
        let (p50, _, _) = Metrics::merged_percentiles([&empty]);
        assert!(p50.is_nan(), "no samples anywhere → NaN");
        let one = Metrics::new();
        one.record_latency(0.005);
        let (p50, p90, p99) = Metrics::merged_percentiles([&empty, &one]);
        assert_eq!((p50, p90, p99), (0.005, 0.005, 0.005));
        assert_eq!(one.latency_count(), 1);
        let mut pooled = Vec::new();
        one.extend_latencies_into(&mut pooled);
        one.extend_latencies_into(&mut pooled);
        assert_eq!(pooled, vec![0.005, 0.005]);
    }
}
