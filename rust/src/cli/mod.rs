//! Command-line argument parsing (the `clap` substrate): subcommands,
//! `--flag`, `--key value` / `--key=value`, positionals, typed getters
//! with defaults, and auto-generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments of one invocation.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Subcommand (first non-flag argument), if any.
    pub command: Option<String>,
    /// `--key value` and `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if rest.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    /// True if `--name` was passed as a flag or as `--name true`.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.options.get(name).map_or(false, |v| v == "true" || v == "1")
    }

    /// String option with default.
    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.options.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Typed option with default; errors on unparsable values.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|e| format!("--{name} {v}: {e}")),
        }
    }

    /// Comma-separated list of a parseable type.
    pub fn get_list<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Result<Vec<T>, String>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.options.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse::<T>().map_err(|e| format!("--{name} '{s}': {e}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        // note: a bare `--opt` followed by a non-flag token consumes the
        // token as its value, so positionals go before trailing flags.
        let a = parse(&["train", "extra", "--paths", "1024", "--source=sobol", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get::<usize>("paths", 0).unwrap(), 1024);
        assert_eq!(a.get_str("source", "x"), "sobol");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["bench"]);
        assert_eq!(a.get::<f32>("lr", 0.1).unwrap(), 0.1);
        assert!(!a.flag("augment"));
        assert_eq!(a.get_str("init", "constant"), "constant");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--a", "--b", "v"]);
        assert!(a.flag("a"));
        assert_eq!(a.get_str("b", ""), "v");
    }

    #[test]
    fn typed_errors() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.get::<usize>("n", 1).is_err());
    }

    #[test]
    fn lists() {
        let a = parse(&["x", "--sizes", "784, 300,10"]);
        assert_eq!(a.get_list::<usize>("sizes", &[]).unwrap(), vec![784, 300, 10]);
        let b = parse(&["x"]);
        assert_eq!(b.get_list::<usize>("sizes", &[1, 2]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn boolean_option_forms() {
        let a = parse(&["x", "--aug", "true"]);
        assert!(a.flag("aug"));
        let b = parse(&["x", "--aug=1"]);
        assert!(b.flag("aug"));
    }
}
