//! One worker shard: a dedicated OS thread owning a backend instance
//! and draining its private request queue through the adaptive
//! [`Batcher`](super::batcher::Batcher).
//!
//! The backend is constructed *on* the worker thread via a factory, so
//! non-`Send` backends (PJRT handles are `Rc`-based) work unchanged.
//! Each worker keeps its own [`Metrics`] and additionally records into
//! the server-wide aggregate, and maintains an in-flight gauge the
//! dispatcher uses for least-loaded routing.

use super::batcher::Batcher;
use super::InferenceBackend;
use crate::coordinator::metrics::Metrics;
use crate::util::timer::Timer;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// One queued inference request (a single sample).
pub struct Request {
    /// Flattened input features.
    pub x: Vec<f32>,
    /// Channel the logits are answered on.
    pub respond: Sender<Vec<f32>>,
    /// End-to-end latency stopwatch, started at submit.
    pub t_start: Timer,
}

/// Handle to a running worker shard.
pub struct WorkerHandle {
    /// Queue sender (`None` once shutdown begins).
    pub(crate) tx: Option<Sender<Request>>,
    /// Requests dispatched to this shard but not yet answered.
    pub(crate) inflight: Arc<AtomicUsize>,
    /// This worker's own metrics (the server aggregates them).
    pub metrics: Arc<Metrics>,
    pub(crate) join: Option<JoinHandle<()>>,
}

/// Spawn a worker shard.  Returns the handle plus a one-shot channel
/// carrying `(features, classes)` once the backend is constructed.
pub(crate) fn spawn<F>(
    worker_id: usize,
    factory: F,
    max_wait: Duration,
    aggregate: Arc<Metrics>,
) -> (WorkerHandle, Receiver<(usize, usize)>)
where
    F: FnOnce() -> Box<dyn InferenceBackend> + Send + 'static,
{
    let (tx, rx) = channel::<Request>();
    let (meta_tx, meta_rx) = channel();
    let metrics = Arc::new(Metrics::new());
    let inflight = Arc::new(AtomicUsize::new(0));
    let own = metrics.clone();
    let gauge = inflight.clone();
    let join = std::thread::Builder::new()
        .name(format!("sobolnet-serve-{worker_id}"))
        .spawn(move || {
            let mut backend = factory();
            let cap = backend.batch_capacity();
            let feat = backend.features();
            let classes = backend.classes();
            let _ = meta_tx.send((feat, classes));
            let batcher = Batcher { capacity: cap, max_wait };
            let mut xbuf = vec![0.0f32; cap * feat];
            while let Some(batch) = batcher.next_batch(&rx) {
                // assemble the padded batch (tail rows stay zero)
                xbuf.iter_mut().for_each(|v| *v = 0.0);
                for (i, r) in batch.iter().enumerate() {
                    xbuf[i * feat..(i + 1) * feat].copy_from_slice(&r.x);
                }
                let logits = backend.infer_batch(&xbuf);
                own.record_batch(batch.len(), cap);
                aggregate.record_batch(batch.len(), cap);
                for (i, r) in batch.into_iter().enumerate() {
                    let out = logits[i * classes..(i + 1) * classes].to_vec();
                    let secs = r.t_start.elapsed_secs();
                    own.record_latency(secs);
                    aggregate.record_latency(secs);
                    gauge.fetch_sub(1, Ordering::Relaxed);
                    let _ = r.respond.send(out);
                }
            }
        })
        .expect("spawn serve worker thread");
    (WorkerHandle { tx: Some(tx), inflight, metrics, join: Some(join) }, meta_rx)
}
