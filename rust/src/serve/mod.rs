//! Legacy serving surface: a thin compatibility layer over
//! [`crate::engine`].
//!
//! The sharded dispatcher/batcher/worker machinery that lived here
//! grew into the unified engine (`rust/src/engine/`): non-blocking
//! ticket submission, bounded per-shard admission queues with
//! [`AdmissionPolicy`](crate::engine::AdmissionPolicy), and a
//! pluggable [`DispatchPolicy`](crate::engine::DispatchPolicy)
//! replacing the [`Dispatch`] enum kept here.  New code should build an
//! [`crate::engine::EngineBuilder`]; this module keeps the historical
//! `ShardedServer` API working on top of it:
//!
//! * [`ShardedServer::submit`] is the blocking path — it maps to the
//!   engine with `AdmissionPolicy::Block` over unbounded queues, which
//!   is exactly the old behavior (never sheds, never rejects),
//! * [`ServeConfig`] carries the old three knobs and converts into an
//!   engine configuration,
//! * [`InferenceBackend`] / [`ModelBackend`] moved to
//!   [`crate::engine::backend`] and are re-exported under their old
//!   paths.
//!
//! The still-older single-worker `coordinator::server::InferenceServer`
//! names remain as `#[deprecated]` aliases one layer further out.

use crate::engine::ticket::ReplyTx;
use crate::engine::{AdmissionPolicy, DispatchKind, Engine, EngineBuilder};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::Duration;

pub use crate::coordinator::metrics::Metrics;
pub use crate::engine::{InferenceBackend, ModelBackend};

/// How `submit` picks a worker shard (legacy enum; the engine's
/// [`DispatchKind`](crate::engine::DispatchKind) supersedes it and
/// adds the p99-aware EWMA policy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatch {
    /// Strict rotation over the shards.
    RoundRobin,
    /// Shard with the fewest in-flight requests (rotating tie-break).
    LeastLoaded,
}

impl Dispatch {
    fn kind(self) -> DispatchKind {
        match self {
            Dispatch::RoundRobin => DispatchKind::RoundRobin,
            Dispatch::LeastLoaded => DispatchKind::LeastLoaded,
        }
    }
}

/// Server configuration (legacy knobs; `EngineBuilder` absorbs these
/// plus admission policy and queue bounds).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Number of worker shards (each owns one backend instance).
    pub workers: usize,
    /// Max time a worker waits for a full batch before flushing.
    pub max_wait: Duration,
    /// Dispatch policy across shards.
    pub dispatch: Dispatch,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(2),
            dispatch: Dispatch::LeastLoaded,
        }
    }
}

impl ServeConfig {
    fn builder(&self) -> EngineBuilder {
        EngineBuilder::new()
            .workers(self.workers)
            .max_wait(self.max_wait)
            .dispatch(self.dispatch.kind())
            // legacy semantics: unbounded queues, blocking admission
            .queue_depth(0)
            .admission(AdmissionPolicy::Block)
    }
}

/// Handle to a running sharded inference server.
///
/// **This is a compatibility wrapper** over [`crate::engine::Engine`],
/// kept so pre-engine call sites keep compiling with the historical
/// blocking semantics (unbounded queues, `Block` admission, bare-logits
/// replies).  It will not grow new features — admission policies,
/// ticket timeouts, and multi-process sharding only exist on the
/// engine.  Migration is mechanical:
///
/// ```no_run
/// use sobolnet::engine::{AdmissionPolicy, DispatchKind, EngineBuilder};
/// # let model: sobolnet::nn::sparse::SparseMlp = todo!();
/// // before:
/// //   let cfg = ServeConfig { workers: 4, max_wait, dispatch: Dispatch::LeastLoaded };
/// //   let server = ShardedServer::start_sharded_with(factory, cfg);
/// //   let logits = server.infer(x);
/// // after (identical semantics spelled out):
/// let engine = EngineBuilder::new()
///     .workers(4)
///     .max_wait(std::time::Duration::from_millis(2))
///     .dispatch(DispatchKind::LeastLoaded)
///     .queue_depth(0)                    // unbounded queue…
///     .admission(AdmissionPolicy::Block) // …blocking admission
///     .build_model(model, 784, 10);
/// let logits = engine.infer(vec![0.0; 784]).logits().expect("served");
/// ```
///
/// From there the engine's extra surface is opt-in: bounded
/// `queue_depth` + shedding admission for backpressure,
/// `try_submit` → [`Ticket`](crate::engine::Ticket) for non-blocking
/// submission, and `remote(addrs)`/`spawn_workers(n, spec)` +
/// `build_remote()` for multi-process shards (see
/// [`crate::engine::remote`] and `docs/ARCHITECTURE.md`).
pub struct ShardedServer {
    engine: Engine,
    /// Aggregate *counters* across all shards.  Latency samples now
    /// live per-worker and are merged on read, so calling
    /// `latency_percentiles()`/`summary()` on this registry yields NaN
    /// percentiles — use [`ShardedServer::latency_percentiles`] (or
    /// [`ShardedServer::report`]), which merge the per-worker
    /// histograms before ranking.
    pub metrics: Arc<Metrics>,
}

impl ShardedServer {
    fn wrap(engine: Engine) -> ShardedServer {
        let metrics = engine.metrics.clone();
        ShardedServer { engine, metrics }
    }

    /// Spawn `cfg.workers` shards, each building its own backend by
    /// calling a clone of `factory` on its worker thread.
    pub fn start_sharded_with<F>(factory: F, cfg: ServeConfig) -> ShardedServer
    where
        F: Fn() -> Box<dyn InferenceBackend> + Clone + Send + 'static,
    {
        Self::wrap(cfg.builder().build_with(factory))
    }

    /// Spawn a single shard around a backend built by `factory` on the
    /// worker thread (a `FnOnce` factory can only build one backend, so
    /// `cfg.workers` is ignored; use [`ShardedServer::start_sharded_with`]
    /// for N > 1).
    pub fn start_with<F>(factory: F, cfg: ServeConfig) -> ShardedServer
    where
        F: FnOnce() -> Box<dyn InferenceBackend> + Send + 'static,
    {
        let boxed: crate::engine::BackendFactory = Box::new(factory);
        Self::wrap(cfg.builder().workers(1).build_each(vec![boxed]))
    }

    /// Spawn a single shard around an already-constructed `Send` backend.
    pub fn start(backend: Box<dyn InferenceBackend + Send>, cfg: ServeConfig) -> ShardedServer {
        Self::start_with(move || backend as Box<dyn InferenceBackend>, cfg)
    }

    /// The engine underneath (tickets, stats, admission control).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Number of worker shards.
    pub fn workers(&self) -> usize {
        self.engine.workers()
    }

    /// Submit one sample; returns a receiver for the logits.  Blocking
    /// legacy path: admission never sheds (unbounded queues), so the
    /// receiver always gets an answer while the server lives.
    pub fn submit(&self, x: Vec<f32>) -> Receiver<Vec<f32>> {
        assert_eq!(x.len(), self.engine.features(), "wrong feature count");
        let (rtx, rrx) = channel();
        self.engine.admit(0, 0, x, ReplyTx::Legacy(rtx)).expect("server running");
        rrx
    }

    /// Convenience: submit and wait.
    pub fn infer(&self, x: Vec<f32>) -> Vec<f32> {
        self.submit(x).recv().expect("response")
    }

    /// Per-worker metrics, shard order.
    pub fn worker_metrics(&self) -> Vec<Arc<Metrics>> {
        self.engine.worker_metrics()
    }

    /// Server-wide latency percentiles `(p50, p90, p99)` in seconds,
    /// merged across the per-worker histograms.
    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        self.engine.latency_percentiles()
    }

    /// Multi-line report: aggregate summary plus one line per shard.
    pub fn report(&self) -> String {
        self.engine.report()
    }

    /// Graceful shutdown (drains in-flight work on every shard).
    pub fn shutdown(self) {
        self.engine.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    /// Backend that sums features into class 0 and counts calls.
    struct Echo {
        calls: Arc<Metrics>,
    }

    impl InferenceBackend for Echo {
        fn batch_capacity(&self) -> usize {
            4
        }
        fn features(&self) -> usize {
            3
        }
        fn classes(&self) -> usize {
            2
        }
        fn infer_batch(&mut self, x: &[f32]) -> Vec<f32> {
            self.calls.batches.fetch_add(1, Ordering::Relaxed);
            let mut out = vec![0.0; 4 * 2];
            for i in 0..4 {
                out[i * 2] = x[i * 3] + x[i * 3 + 1] + x[i * 3 + 2];
                out[i * 2 + 1] = -1.0;
            }
            out
        }
    }

    #[test]
    fn single_request_roundtrip() {
        let srv = ShardedServer::start(
            Box::new(Echo { calls: Arc::new(Metrics::new()) }),
            ServeConfig { max_wait: Duration::from_millis(1), ..Default::default() },
        );
        let y = srv.infer(vec![1.0, 2.0, 3.0]);
        assert_eq!(y, vec![6.0, -1.0]);
        let (p50, _, _) = srv.latency_percentiles();
        assert!(p50 > 0.0);
        srv.shutdown();
    }

    #[test]
    fn batching_coalesces_requests() {
        let counter = Arc::new(Metrics::new());
        let srv = ShardedServer::start(
            Box::new(Echo { calls: counter.clone() }),
            ServeConfig { max_wait: Duration::from_millis(50), ..Default::default() },
        );
        // submit 4 requests quickly: should execute as ONE batch
        let rxs: Vec<_> = (0..4).map(|i| srv.submit(vec![i as f32, 0.0, 0.0])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let y = rx.recv().unwrap();
            assert_eq!(y[0], i as f32);
        }
        assert_eq!(counter.batches.load(Ordering::Relaxed), 1, "one coalesced batch");
        assert_eq!(srv.metrics.mean_batch_size(), 4.0);
        srv.shutdown();
    }

    #[test]
    fn flushes_partial_batch_on_timeout() {
        let srv = ShardedServer::start(
            Box::new(Echo { calls: Arc::new(Metrics::new()) }),
            ServeConfig { max_wait: Duration::from_millis(5), ..Default::default() },
        );
        let y = srv.infer(vec![1.0, 1.0, 1.0]); // alone in its batch
        assert_eq!(y[0], 3.0);
        assert!(srv.metrics.padded_slots.load(Ordering::Relaxed) >= 3);
        srv.shutdown();
    }

    #[test]
    fn many_concurrent_clients() {
        let srv = Arc::new(ShardedServer::start(
            Box::new(Echo { calls: Arc::new(Metrics::new()) }),
            ServeConfig::default(),
        ));
        let mut handles = Vec::new();
        for k in 0..16 {
            let s = srv.clone();
            handles.push(std::thread::spawn(move || {
                let y = s.infer(vec![k as f32, k as f32, 0.0]);
                assert_eq!(y[0], 2.0 * k as f32);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(srv.metrics.completed.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn sharded_workers_all_serve_round_robin() {
        let srv = ShardedServer::start_sharded_with(
            || Box::new(Echo { calls: Arc::new(Metrics::new()) }) as Box<dyn InferenceBackend>,
            ServeConfig {
                workers: 3,
                max_wait: Duration::from_micros(200),
                dispatch: Dispatch::RoundRobin,
            },
        );
        assert_eq!(srv.workers(), 3);
        for i in 0..12 {
            let y = srv.infer(vec![i as f32, 1.0, 0.0]);
            assert_eq!(y[0], i as f32 + 1.0);
        }
        // strict rotation: every shard answered exactly a third
        for (i, m) in srv.worker_metrics().iter().enumerate() {
            assert_eq!(m.completed.load(Ordering::Relaxed), 4, "worker {i}");
        }
        assert_eq!(srv.metrics.completed.load(Ordering::Relaxed), 12);
        srv.shutdown();
    }

    #[test]
    fn least_loaded_prefers_idle_shard() {
        let srv = ShardedServer::start_sharded_with(
            || Box::new(Echo { calls: Arc::new(Metrics::new()) }) as Box<dyn InferenceBackend>,
            ServeConfig {
                workers: 2,
                max_wait: Duration::from_millis(40),
                dispatch: Dispatch::LeastLoaded,
            },
        );
        // four un-awaited submissions: the gauge steers them across both
        // shards (each shard waits for its batch, so inflight stays up)
        let rxs: Vec<_> = (0..4).map(|i| srv.submit(vec![i as f32, 0.0, 0.0])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap()[0], i as f32);
        }
        let served: Vec<u64> = srv
            .worker_metrics()
            .iter()
            .map(|m| m.completed.load(Ordering::Relaxed))
            .collect();
        assert_eq!(served.iter().sum::<u64>(), 4);
        assert!(served.iter().all(|&c| c > 0), "both shards served: {served:?}");
        srv.shutdown();
    }

    #[test]
    fn engine_accessor_exposes_ticket_path() {
        let srv = ShardedServer::start(
            Box::new(Echo { calls: Arc::new(Metrics::new()) }),
            ServeConfig { max_wait: Duration::from_millis(1), ..Default::default() },
        );
        let t = srv.engine().try_submit(vec![2.0, 2.0, 2.0]).expect("block policy admits");
        match t.wait() {
            crate::engine::Response::Logits(l) => assert_eq!(l[0], 6.0),
            other => panic!("unexpected {other:?}"),
        }
        srv.shutdown();
    }
}
