//! Sharded inference serving subsystem (L3): N worker shards, each
//! owning a backend instance and a private request queue, behind a
//! round-robin / least-loaded dispatcher.
//!
//! This realizes the paper's parallel-hardware argument *end-to-end*:
//! path-sparse networks stream weights as contiguous blocks
//! (§3, §4.4), the engine's forward pass shards conflict-free over
//! batch columns ([`crate::nn::sparse`]), and this layer shards request
//! traffic over backend replicas — so throughput scales with both
//! threads-per-forward (`SOBOLNET_THREADS`) and workers-per-server.
//! All worker shards dispatch onto the single process-wide persistent
//! pool of [`crate::util::parallel`] (one job at a time, each using
//! every pool thread), so per-forward fan-out costs a park/wake
//! round-trip instead of thread spawns even at batch sizes of a few
//! thousand edge-work units.
//!
//! Architecture (one [`ShardedServer`]):
//!
//! ```text
//! submit(x) ──► dispatcher (round-robin | least-loaded inflight gauge)
//!                 │                │
//!                 ▼                ▼
//!             worker 0         worker N-1          (each: own thread,
//!            ┌─────────┐      ┌─────────┐           own backend built
//!            │ queue    │  …  │ queue    │          on-thread via the
//!            │ batcher  │     │ batcher  │          factory, so non-
//!            │ backend  │     │ backend  │          `Send` PJRT works)
//!            │ metrics  │     │ metrics  │
//!            └─────────┘      └─────────┘
//! ```
//!
//! The [`batcher::Batcher`] flushes on a full batch or `max_wait`,
//! whichever comes first; per-worker [`Metrics`] are aggregated into
//! server-wide latency percentiles and throughput counters.
//!
//! The single-worker `coordinator::server::InferenceServer` of earlier
//! revisions is absorbed here; `coordinator::server` re-exports these
//! types under their old names for compatibility.

pub mod batcher;
pub mod worker;

use crate::coordinator::metrics::Metrics;
use crate::util::timer::Timer;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::Duration;
use worker::{Request, WorkerHandle};

/// Something that can classify a fixed-size batch.
///
/// Implemented by the AOT executable wrapper (see
/// `coordinator::train::AotForward`) and by the pure-rust models (via
/// [`ModelBackend`]), so the same server fronts both.
///
/// Backends need not be `Send`: workers construct them *on* their own
/// thread via a factory (PJRT handles are `Rc`-based and cannot cross
/// threads).
pub trait InferenceBackend {
    /// Static batch capacity of one execution.
    fn batch_capacity(&self) -> usize;

    /// Features per sample.
    fn features(&self) -> usize;

    /// Classes per sample.
    fn classes(&self) -> usize;

    /// Run on a `[capacity × features]` buffer (padded rows arbitrary);
    /// returns `[capacity × classes]` logits.
    fn infer_batch(&mut self, x: &[f32]) -> Vec<f32>;
}

/// Blanket adapter for pure-rust [`crate::nn::Model`]s.
///
/// Holds reusable input/output tensors, so on the serve hot path each
/// batch costs one forward pass plus a single logits copy — the model's
/// own scratch (e.g. `SparseMlp`) allocates nothing once warm, and the
/// forward fans out on the shared process-wide worker pool of
/// [`crate::util::parallel`].
pub struct ModelBackend<M: crate::nn::Model + Send> {
    /// Wrapped model.
    pub model: M,
    /// Fixed batch capacity to emulate.
    pub capacity: usize,
    /// Input features.
    pub features: usize,
    /// Output classes.
    pub classes: usize,
    /// Reused `[capacity, features]` input staging tensor.
    xbuf: crate::nn::tensor::Tensor,
    /// Reused logits tensor.
    obuf: crate::nn::tensor::Tensor,
}

impl<M: crate::nn::Model + Send> ModelBackend<M> {
    /// Wrap `model` behind a fixed `[capacity × features] →
    /// [capacity × classes]` serving contract.
    pub fn new(model: M, capacity: usize, features: usize, classes: usize) -> Self {
        ModelBackend {
            model,
            capacity,
            features,
            classes,
            xbuf: crate::nn::tensor::Tensor::empty(),
            obuf: crate::nn::tensor::Tensor::empty(),
        }
    }
}

impl<M: crate::nn::Model + Send> InferenceBackend for ModelBackend<M> {
    fn batch_capacity(&self) -> usize {
        self.capacity
    }

    fn features(&self) -> usize {
        self.features
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn infer_batch(&mut self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.capacity * self.features, "infer_batch input shape");
        self.xbuf.shape.clear();
        self.xbuf.shape.push(self.capacity);
        self.xbuf.shape.push(self.features);
        self.xbuf.data.clear();
        self.xbuf.data.extend_from_slice(x);
        self.model.forward_into(&self.xbuf, false, &mut self.obuf);
        self.obuf.data.clone()
    }
}

/// How `submit` picks a worker shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatch {
    /// Strict rotation over the shards.
    RoundRobin,
    /// Shard with the fewest in-flight requests (rotating tie-break).
    LeastLoaded,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Number of worker shards (each owns one backend instance).
    pub workers: usize,
    /// Max time a worker waits for a full batch before flushing.
    pub max_wait: Duration,
    /// Dispatch policy across shards.
    pub dispatch: Dispatch,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(2),
            dispatch: Dispatch::LeastLoaded,
        }
    }
}

/// Handle to a running sharded inference server.
pub struct ShardedServer {
    shards: Vec<WorkerHandle>,
    rr: AtomicUsize,
    dispatch: Dispatch,
    /// Aggregate metrics across all shards (plus accepted-request count).
    pub metrics: Arc<Metrics>,
    features: usize,
}

impl ShardedServer {
    /// Spawn `cfg.workers` shards, each building its own backend by
    /// calling a clone of `factory` on its worker thread.
    pub fn start_sharded_with<F>(factory: F, cfg: ServeConfig) -> ShardedServer
    where
        F: Fn() -> Box<dyn InferenceBackend> + Clone + Send + 'static,
    {
        let n = cfg.workers.max(1);
        let metrics = Arc::new(Metrics::new());
        let mut shards = Vec::with_capacity(n);
        // spawn every worker first so the backends construct concurrently,
        // then collect their metadata
        let mut metas = Vec::with_capacity(n);
        for wid in 0..n {
            let f = factory.clone();
            let (handle, meta_rx) = worker::spawn(wid, move || f(), cfg.max_wait, metrics.clone());
            shards.push(handle);
            metas.push(meta_rx);
        }
        let mut features: Option<usize> = None;
        for meta_rx in metas {
            let (feat, _classes) = meta_rx.recv().expect("backend constructed");
            match features {
                None => features = Some(feat),
                Some(prev) => assert_eq!(prev, feat, "workers disagree on feature count"),
            }
        }
        ShardedServer {
            shards,
            rr: AtomicUsize::new(0),
            dispatch: cfg.dispatch,
            metrics,
            features: features.expect("at least one worker"),
        }
    }

    /// Spawn a single shard around a backend built by `factory` on the
    /// worker thread (a `FnOnce` factory can only build one backend, so
    /// `cfg.workers` is ignored; use [`ShardedServer::start_sharded_with`]
    /// for N > 1).
    pub fn start_with<F>(factory: F, cfg: ServeConfig) -> ShardedServer
    where
        F: FnOnce() -> Box<dyn InferenceBackend> + Send + 'static,
    {
        let metrics = Arc::new(Metrics::new());
        let (handle, meta_rx) = worker::spawn(0, factory, cfg.max_wait, metrics.clone());
        let (features, _classes) = meta_rx.recv().expect("backend constructed");
        ShardedServer {
            shards: vec![handle],
            rr: AtomicUsize::new(0),
            dispatch: cfg.dispatch,
            metrics,
            features,
        }
    }

    /// Spawn a single shard around an already-constructed `Send` backend.
    pub fn start(backend: Box<dyn InferenceBackend + Send>, cfg: ServeConfig) -> ShardedServer {
        Self::start_with(move || backend as Box<dyn InferenceBackend>, cfg)
    }

    /// Number of worker shards.
    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    fn pick_shard(&self) -> usize {
        let n = self.shards.len();
        if n == 1 {
            return 0;
        }
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        match self.dispatch {
            Dispatch::RoundRobin => start,
            Dispatch::LeastLoaded => {
                let mut best = start;
                let mut best_load = self.shards[start].inflight.load(Ordering::Relaxed);
                for k in 1..n {
                    let i = (start + k) % n;
                    let load = self.shards[i].inflight.load(Ordering::Relaxed);
                    if load < best_load {
                        best = i;
                        best_load = load;
                    }
                }
                best
            }
        }
    }

    /// Submit one sample; returns a receiver for the logits.
    pub fn submit(&self, x: Vec<f32>) -> Receiver<Vec<f32>> {
        assert_eq!(x.len(), self.features, "wrong feature count");
        let (rtx, rrx) = channel();
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let shard = &self.shards[self.pick_shard()];
        shard.metrics.requests.fetch_add(1, Ordering::Relaxed);
        shard.inflight.fetch_add(1, Ordering::Relaxed);
        shard
            .tx
            .as_ref()
            .expect("server running")
            .send(Request { x, respond: rtx, t_start: Timer::start() })
            .expect("worker alive");
        rrx
    }

    /// Convenience: submit and wait.
    pub fn infer(&self, x: Vec<f32>) -> Vec<f32> {
        self.submit(x).recv().expect("response")
    }

    /// Per-worker metrics, shard order.
    pub fn worker_metrics(&self) -> Vec<Arc<Metrics>> {
        self.shards.iter().map(|s| s.metrics.clone()).collect()
    }

    /// Multi-line report: aggregate summary plus one line per shard.
    pub fn report(&self) -> String {
        let mut out = format!("aggregate ({} workers): {}", self.shards.len(), self.metrics.summary());
        for (i, s) in self.shards.iter().enumerate() {
            out.push_str(&format!("\n  worker {i}: {}", s.metrics.summary()));
        }
        out
    }

    fn stop(&mut self) {
        for s in self.shards.iter_mut() {
            s.tx.take();
        }
        for s in self.shards.iter_mut() {
            if let Some(j) = s.join.take() {
                let _ = j.join();
            }
        }
    }

    /// Graceful shutdown (drains in-flight work on every shard).
    pub fn shutdown(mut self) {
        self.stop();
    }
}

impl Drop for ShardedServer {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Backend that sums features into class 0 and counts calls.
    struct Echo {
        calls: Arc<Metrics>,
    }

    impl InferenceBackend for Echo {
        fn batch_capacity(&self) -> usize {
            4
        }
        fn features(&self) -> usize {
            3
        }
        fn classes(&self) -> usize {
            2
        }
        fn infer_batch(&mut self, x: &[f32]) -> Vec<f32> {
            self.calls.batches.fetch_add(1, Ordering::Relaxed);
            let mut out = vec![0.0; 4 * 2];
            for i in 0..4 {
                out[i * 2] = x[i * 3] + x[i * 3 + 1] + x[i * 3 + 2];
                out[i * 2 + 1] = -1.0;
            }
            out
        }
    }

    #[test]
    fn single_request_roundtrip() {
        let srv = ShardedServer::start(
            Box::new(Echo { calls: Arc::new(Metrics::new()) }),
            ServeConfig { max_wait: Duration::from_millis(1), ..Default::default() },
        );
        let y = srv.infer(vec![1.0, 2.0, 3.0]);
        assert_eq!(y, vec![6.0, -1.0]);
        let (p50, _, _) = srv.metrics.latency_percentiles();
        assert!(p50 > 0.0);
        srv.shutdown();
    }

    #[test]
    fn batching_coalesces_requests() {
        let counter = Arc::new(Metrics::new());
        let srv = ShardedServer::start(
            Box::new(Echo { calls: counter.clone() }),
            ServeConfig { max_wait: Duration::from_millis(50), ..Default::default() },
        );
        // submit 4 requests quickly: should execute as ONE batch
        let rxs: Vec<_> = (0..4).map(|i| srv.submit(vec![i as f32, 0.0, 0.0])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let y = rx.recv().unwrap();
            assert_eq!(y[0], i as f32);
        }
        assert_eq!(counter.batches.load(Ordering::Relaxed), 1, "one coalesced batch");
        assert_eq!(srv.metrics.mean_batch_size(), 4.0);
        srv.shutdown();
    }

    #[test]
    fn flushes_partial_batch_on_timeout() {
        let srv = ShardedServer::start(
            Box::new(Echo { calls: Arc::new(Metrics::new()) }),
            ServeConfig { max_wait: Duration::from_millis(5), ..Default::default() },
        );
        let y = srv.infer(vec![1.0, 1.0, 1.0]); // alone in its batch
        assert_eq!(y[0], 3.0);
        assert!(srv.metrics.padded_slots.load(Ordering::Relaxed) >= 3);
        srv.shutdown();
    }

    #[test]
    fn many_concurrent_clients() {
        let srv = Arc::new(ShardedServer::start(
            Box::new(Echo { calls: Arc::new(Metrics::new()) }),
            ServeConfig::default(),
        ));
        let mut handles = Vec::new();
        for k in 0..16 {
            let s = srv.clone();
            handles.push(std::thread::spawn(move || {
                let y = s.infer(vec![k as f32, k as f32, 0.0]);
                assert_eq!(y[0], 2.0 * k as f32);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(srv.metrics.completed.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn sharded_workers_all_serve_round_robin() {
        let srv = ShardedServer::start_sharded_with(
            || Box::new(Echo { calls: Arc::new(Metrics::new()) }) as Box<dyn InferenceBackend>,
            ServeConfig {
                workers: 3,
                max_wait: Duration::from_micros(200),
                dispatch: Dispatch::RoundRobin,
            },
        );
        assert_eq!(srv.workers(), 3);
        for i in 0..12 {
            let y = srv.infer(vec![i as f32, 1.0, 0.0]);
            assert_eq!(y[0], i as f32 + 1.0);
        }
        // strict rotation: every shard answered exactly a third
        for (i, m) in srv.worker_metrics().iter().enumerate() {
            assert_eq!(m.completed.load(Ordering::Relaxed), 4, "worker {i}");
        }
        assert_eq!(srv.metrics.completed.load(Ordering::Relaxed), 12);
        srv.shutdown();
    }

    #[test]
    fn least_loaded_prefers_idle_shard() {
        let srv = ShardedServer::start_sharded_with(
            || Box::new(Echo { calls: Arc::new(Metrics::new()) }) as Box<dyn InferenceBackend>,
            ServeConfig {
                workers: 2,
                max_wait: Duration::from_millis(40),
                dispatch: Dispatch::LeastLoaded,
            },
        );
        // four un-awaited submissions: the gauge steers them across both
        // shards (each shard waits for its batch, so inflight stays up)
        let rxs: Vec<_> = (0..4).map(|i| srv.submit(vec![i as f32, 0.0, 0.0])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap()[0], i as f32);
        }
        let served: Vec<u64> = srv
            .worker_metrics()
            .iter()
            .map(|m| m.completed.load(Ordering::Relaxed))
            .collect();
        assert_eq!(served.iter().sum::<u64>(), 4);
        assert!(served.iter().all(|&c| c > 0), "both shards served: {served:?}");
        srv.shutdown();
    }
}
