//! Pure-rust neural network engine.
//!
//! Implements both the paper's **path-sparse** networks (the Fig 3
//! algorithm, [`sparse::SparseMlp`], and the channel-sparse CNN
//! [`cnn::Cnn`]) and their **dense** baselines, together with the
//! optimizer, losses, batch norm, and the training loop.
//!
//! This engine drives the table/figure reproduction benches where
//! arbitrary widths and path counts are swept; the AOT JAX/Pallas stack
//! ([`crate::runtime`] + `python/compile/`) carries the fixed-shape
//! MLP end-to-end (training and serving) to prove the three-layer
//! architecture.

pub mod batchnorm;
pub mod cnn;
pub mod conv;
pub mod dense;
pub mod init;
pub mod kernel;
pub mod loss;
pub mod matmul;
pub mod mlp;
pub mod optim;
pub mod sparse;
pub mod tensor;
pub mod trainer;

use optim::Sgd;
use tensor::Tensor;

/// A trainable classifier: maps `[B, features…]` to logits `[B, C]`.
pub trait Model {
    /// Forward pass; when `train`, caches whatever backward needs.
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor;

    /// Forward pass writing the logits into a caller-held tensor
    /// (reshaped/resized as needed).  The default delegates to
    /// [`Model::forward`]; models with reusable scratch override it so
    /// the train/serve hot loop performs no per-call allocation once
    /// warm ([`sparse::SparseMlp`] does).
    fn forward_into(&mut self, x: &Tensor, train: bool, out: &mut Tensor) {
        *out = self.forward(x, train);
    }

    /// Backward from the loss gradient w.r.t. the logits; accumulates
    /// parameter gradients internally.
    fn backward(&mut self, glogits: &Tensor);

    /// Apply one optimizer step and clear gradients.
    fn step(&mut self, opt: &Sgd);

    /// Select the compute kernel for the forward/backward hot loops
    /// ([`kernel::KernelKind`]).  Returns `true` if the model supports
    /// pluggable kernels ([`sparse::SparseMlp`] does); the default is
    /// a no-op returning `false`, so kernel selection composes with
    /// any [`Model`] (engine plumbing calls this unconditionally).
    fn set_kernel(&mut self, kernel: kernel::KernelKind) -> bool {
        let _ = kernel;
        false
    }

    /// Number of trainable parameters (sparsity-aware).
    fn nparams(&self) -> usize;

    /// Effective non-zero weights (coalesced duplicate edges counted
    /// once; excludes biases and batch-norm parameters).
    fn nnz(&self) -> usize;
}
