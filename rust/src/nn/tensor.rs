//! A minimal row-major f32 tensor for the pure-rust engine.
//!
//! Deliberately simple: contiguous `Vec<f32>` plus a shape.  The engine
//! only needs 2-D `[batch, features]` and 4-D `[batch, c, h, w]` views,
//! elementwise ops, and matmul (in [`super::matmul`]).

/// Row-major dense tensor of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Flat storage, row-major.
    pub data: Vec<f32>,
    /// Dimension sizes.
    pub shape: Vec<usize>,
}

impl Tensor {
    /// All-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    /// Empty scratch tensor (performs no allocation) — the reusable
    /// output slot for `*_into` fillers like
    /// [`crate::nn::Model::forward_into`] and
    /// [`crate::nn::loss::softmax_xent_into`].
    pub fn empty() -> Self {
        Tensor { data: Vec::new(), shape: Vec::new() }
    }

    /// Tensor from existing data (checked).
    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Tensor { data, shape: shape.to_vec() }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// First shape dimension (batch size by convention).
    pub fn batch(&self) -> usize {
        self.shape[0]
    }

    /// Product of all dims except the first (features per sample).
    pub fn features(&self) -> usize {
        self.shape[1..].iter().product()
    }

    /// Reshape in place (element count must match).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(self.len(), shape.iter().product::<usize>(), "reshape size mismatch");
        self.shape = shape.to_vec();
        self
    }

    /// Row `b` of a 2-D view `[batch, features]`.
    pub fn row(&self, b: usize) -> &[f32] {
        let f = self.features();
        &self.data[b * f..(b + 1) * f]
    }

    /// Mutable row.
    pub fn row_mut(&mut self, b: usize) -> &mut [f32] {
        let f = self.features();
        &mut self.data[b * f..(b + 1) * f]
    }

    /// Elementwise ReLU (new tensor).
    pub fn relu(&self) -> Tensor {
        Tensor {
            data: self.data.iter().map(|&v| v.max(0.0)).collect(),
            shape: self.shape.clone(),
        }
    }

    /// In-place `self += other`.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// In-place scale.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// Max absolute difference against another tensor (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_views() {
        let t = Tensor::zeros(&[4, 3]);
        assert_eq!(t.len(), 12);
        assert_eq!(t.batch(), 4);
        assert_eq!(t.features(), 3);
        let t = Tensor::from_vec((0..12).map(|v| v as f32).collect(), &[4, 3]);
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
        let t4 = t.clone().reshape(&[2, 2, 3, 1]);
        assert_eq!(t4.shape, vec![2, 2, 3, 1]);
        assert_eq!(t4.features(), 6);
    }

    #[test]
    #[should_panic(expected = "reshape size mismatch")]
    fn bad_reshape_panics() {
        let _ = Tensor::zeros(&[2, 2]).reshape(&[5]);
    }

    #[test]
    fn elementwise() {
        let mut a = Tensor::from_vec(vec![-1.0, 2.0], &[2]);
        let r = a.relu();
        assert_eq!(r.data, vec![0.0, 2.0]);
        a.add_assign(&Tensor::from_vec(vec![1.0, 1.0], &[2]));
        assert_eq!(a.data, vec![0.0, 3.0]);
        a.scale(2.0);
        assert_eq!(a.data, vec![0.0, 6.0]);
        assert_eq!(a.max_abs_diff(&Tensor::from_vec(vec![0.0, 5.0], &[2])), 1.0);
        assert!((Tensor::from_vec(vec![3.0, 4.0], &[2]).norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn row_mut_writes() {
        let mut t = Tensor::zeros(&[2, 2]);
        t.row_mut(1).copy_from_slice(&[7.0, 8.0]);
        assert_eq!(t.data, vec![0.0, 0.0, 7.0, 8.0]);
    }
}
