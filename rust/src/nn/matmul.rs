//! Blocked, multithreaded matrix multiplication kernels for the dense
//! baselines and the im2col convolutions.
//!
//! Row-major layouts throughout.  Three variants cover forward and both
//! backward products of a linear layer without materializing
//! transposes:
//!
//! * [`matmul_nt`]: `C[M,N] = A[M,K] · B[N,K]ᵀ` — forward (`x · wᵀ`).
//! * [`matmul_nn`]: `C[M,N] = A[M,K] · B[K,N]` — input gradient (`g · w`).
//! * [`matmul_tn`]: `C[M,N] = A[K,M]ᵀ · B[K,N]` — weight gradient (`gᵀ · x`).
//!
//! The inner loops are written so LLVM auto-vectorizes them; the M
//! dimension is parallelized across threads.

use crate::util::parallel::parallel_rows;

/// `C[M,N] += A[M,K] · B[N,K]ᵀ`, i.e. dot products of rows — the natural
/// layout for `y = x · wᵀ` with `w` stored `[out][in]`.
pub fn matmul_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(c.len(), m * n);
    parallel_rows(c, n, |i, crow| {
        let arow = &a[i * k..(i + 1) * k];
        for (j, cv) in crow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            // 4-way unrolled dot product: independent accumulator chains
            // let LLVM keep several FMA pipes busy (EXPERIMENTS.md §Perf)
            let chunks = k / 4;
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for t in 0..chunks {
                let base = t * 4;
                s0 += arow[base] * brow[base];
                s1 += arow[base + 1] * brow[base + 1];
                s2 += arow[base + 2] * brow[base + 2];
                s3 += arow[base + 3] * brow[base + 3];
            }
            let mut acc = (s0 + s1) + (s2 + s3);
            for t in chunks * 4..k {
                acc += arow[t] * brow[t];
            }
            *cv += acc;
        }
    });
}

/// `C[M,N] += A[M,K] · B[K,N]` (classic row-major GEMM, k-panel order so
/// the B row is streamed and C row stays hot).
pub fn matmul_nn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    parallel_rows(c, n, |i, crow| {
        let arow = &a[i * k..(i + 1) * k];
        for (t, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                let brow = &b[t * n..(t + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    });
}

/// `C[M,N] += A[K,M]ᵀ · B[K,N]` — weight gradients `gᵀ · x` without
/// transposing `g`.
pub fn matmul_tn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    parallel_rows(c, n, |i, crow| {
        // C row i accumulates Σ_t A[t][i] * B[t][:]
        for t in 0..k {
            let av = a[t * m + i];
            if av != 0.0 {
                let brow = &b[t * n..(t + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg32, Rng};

    fn naive_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for t in 0..k {
                    c[i * n + j] += a[i * k + t] * b[t * n + j];
                }
            }
        }
        c
    }

    fn rand_vec(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..len).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    #[test]
    fn nn_matches_naive() {
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (16, 16, 16), (33, 65, 17)] {
            let a = rand_vec(m * k, 1);
            let b = rand_vec(k * n, 2);
            let mut c = vec![0.0; m * n];
            matmul_nn(&a, &b, &mut c, m, k, n);
            let want = naive_nn(&a, &b, m, k, n);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn nt_matches_naive_via_transpose() {
        let (m, k, n) = (9, 13, 11);
        let a = rand_vec(m * k, 3);
        let bt = rand_vec(n * k, 4); // B stored [N,K]
        // build B [K,N] for the naive reference
        let mut b = vec![0.0; k * n];
        for j in 0..n {
            for t in 0..k {
                b[t * n + j] = bt[j * k + t];
            }
        }
        let mut c = vec![0.0; m * n];
        matmul_nt(&a, &bt, &mut c, m, k, n);
        let want = naive_nn(&a, &b, m, k, n);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn tn_matches_naive_via_transpose() {
        let (m, k, n) = (7, 10, 5);
        let at = rand_vec(k * m, 5); // A stored [K,M]
        let b = rand_vec(k * n, 6);
        let mut a = vec![0.0; m * k];
        for t in 0..k {
            for i in 0..m {
                a[i * k + t] = at[t * m + i];
            }
        }
        let mut c = vec![0.0; m * n];
        matmul_tn(&at, &b, &mut c, m, k, n);
        let want = naive_nn(&a, &b, m, k, n);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn accumulates_into_c() {
        let a = [1.0f32, 0.0, 0.0, 1.0];
        let b = [2.0f32, 0.0, 0.0, 2.0];
        let mut c = vec![1.0f32; 4];
        matmul_nn(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, vec![3.0, 1.0, 1.0, 3.0]);
    }

    #[test]
    fn large_parallel_consistency() {
        let (m, k, n) = (128, 64, 96);
        let a = rand_vec(m * k, 7);
        let b = rand_vec(k * n, 8);
        let mut c1 = vec![0.0; m * n];
        matmul_nn(&a, &b, &mut c1, m, k, n);
        // run again; determinism across parallel schedules
        let mut c2 = vec![0.0; m * n];
        matmul_nn(&a, &b, &mut c2, m, k, n);
        assert_eq!(c1, c2);
    }
}
