//! Weight initialization schemes (paper §3.1, §5.4, Table 3).
//!
//! The paper's central observation: sparse path networks do **not** need
//! random initialization — the heterogeneous connectivity breaks the
//! symmetry that forces dense layers to initialize randomly.  All that
//! matters is the *magnitude* `w_init`, chosen to control the operator
//! norm of each neuron's affine map.
//!
//! Following the paper's reference to He et al. / Glorot-style analysis
//! we use the fan-based magnitude
//! `w_init = sqrt(6 / (fan_in + fan_out))`.  (The paper's text prints
//! `6/sqrt(fan_in+fan_out)`; the Glorot-uniform bound `sqrt(6/(…))` is
//! the standard form of the quantity cited and keeps the operator norm
//! O(1) — see DESIGN.md §Substitutions.)

use crate::rng::{Pcg32, Rng};

/// Magnitude used for constant initialization.
pub fn w_init_magnitude(fan_in: usize, fan_out: usize) -> f32 {
    (6.0 / (fan_in + fan_out) as f32).sqrt()
}

/// The initialization strategies of Table 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Init {
    /// "Uniformly random": U(−w_init, +w_init).
    UniformRandom,
    /// "Constant, positive": every weight `+w_init`.
    ConstantPositive,
    /// "Constant, alternating sign": positive for even *neuron* indices
    /// and negative for odd (paper Table 3 caption).  [`Init::fill`]
    /// alternates by flat weight index; the layer constructors
    /// (`Dense::new`, `Conv2d::new`, `SparseMlp::new`) re-stamp the sign
    /// by output-neuron index, which is the semantics the paper means.
    ConstantAlternating,
    /// "Constant, random sign": magnitude `w_init`, sign ±1 uniformly.
    ConstantRandomSign,
    /// "Constant, sign along path": magnitude `w_init`, sign given by the
    /// topology's per-path sign (sparse networks only; §3.2).
    ConstantSignAlongPath,
}

impl Init {
    /// Parse from CLI/config strings.
    pub fn parse(s: &str) -> Option<Init> {
        match s {
            "uniform" | "random" => Some(Init::UniformRandom),
            "constant" | "constant-positive" => Some(Init::ConstantPositive),
            "alternating" | "constant-alternating" => Some(Init::ConstantAlternating),
            "random-sign" | "constant-random-sign" => Some(Init::ConstantRandomSign),
            "sign-along-path" => Some(Init::ConstantSignAlongPath),
            _ => None,
        }
    }

    /// Human-readable Table 3 row label.
    pub fn label(&self) -> &'static str {
        match self {
            Init::UniformRandom => "Uniformly random",
            Init::ConstantPositive => "Constant, positive",
            Init::ConstantAlternating => "Constant, alternating sign",
            Init::ConstantRandomSign => "Constant, random sign",
            Init::ConstantSignAlongPath => "Constant, sign along path",
        }
    }

    /// Fill `w` (flat weight slice) according to the scheme.
    ///
    /// * `magnitude` — the constant `w_init`.
    /// * `path_signs` — per-weight signs for [`Init::ConstantSignAlongPath`]
    ///   (must be provided for that scheme; one sign per weight slot).
    /// * `seed` — randomness for the random schemes.
    pub fn fill(
        &self,
        w: &mut [f32],
        magnitude: f32,
        path_signs: Option<&[f32]>,
        seed: u64,
    ) {
        let mut rng = Pcg32::seeded(seed);
        match self {
            Init::UniformRandom => {
                for v in w.iter_mut() {
                    *v = (rng.next_f32() * 2.0 - 1.0) * magnitude;
                }
            }
            Init::ConstantPositive => w.fill(magnitude),
            Init::ConstantAlternating => {
                for (i, v) in w.iter_mut().enumerate() {
                    *v = if i % 2 == 0 { magnitude } else { -magnitude };
                }
            }
            Init::ConstantRandomSign => {
                for v in w.iter_mut() {
                    *v = if rng.next_u32() & 1 == 0 { magnitude } else { -magnitude };
                }
            }
            Init::ConstantSignAlongPath => {
                let signs = path_signs.expect("sign-along-path requires topology signs");
                assert_eq!(signs.len(), w.len());
                for (v, &s) in w.iter_mut().zip(signs) {
                    *v = magnitude * s.signum();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn magnitude_formula() {
        let m = w_init_magnitude(300, 300);
        assert!((m - (6.0f32 / 600.0).sqrt()).abs() < 1e-7);
        assert!(w_init_magnitude(10, 10) > w_init_magnitude(1000, 1000));
    }

    #[test]
    fn parse_labels_roundtrip() {
        for s in ["uniform", "constant", "alternating", "random-sign", "sign-along-path"] {
            assert!(Init::parse(s).is_some(), "{s}");
        }
        assert!(Init::parse("bogus").is_none());
        assert_eq!(Init::ConstantPositive.label(), "Constant, positive");
    }

    #[test]
    fn fill_constant_positive() {
        let mut w = vec![0.0; 8];
        Init::ConstantPositive.fill(&mut w, 0.5, None, 0);
        assert!(w.iter().all(|&v| v == 0.5));
    }

    #[test]
    fn fill_alternating() {
        let mut w = vec![0.0; 6];
        Init::ConstantAlternating.fill(&mut w, 1.0, None, 0);
        assert_eq!(w, vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0]);
    }

    #[test]
    fn fill_random_sign_balances_roughly() {
        let mut w = vec![0.0; 10_000];
        Init::ConstantRandomSign.fill(&mut w, 1.0, None, 3);
        assert!(w.iter().all(|&v| v.abs() == 1.0));
        let pos = w.iter().filter(|&&v| v > 0.0).count();
        assert!((4500..5500).contains(&pos), "pos={pos}");
    }

    #[test]
    fn fill_uniform_within_bounds_nonconstant() {
        let mut w = vec![0.0; 1000];
        Init::UniformRandom.fill(&mut w, 0.3, None, 5);
        assert!(w.iter().all(|&v| v.abs() <= 0.3));
        let distinct: std::collections::HashSet<u32> = w.iter().map(|v| v.to_bits()).collect();
        assert!(distinct.len() > 900);
    }

    #[test]
    fn fill_sign_along_path() {
        let mut w = vec![0.0; 4];
        let signs = [1.0f32, -1.0, -1.0, 1.0];
        Init::ConstantSignAlongPath.fill(&mut w, 2.0, Some(&signs), 0);
        assert_eq!(w, vec![2.0, -2.0, -2.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "sign-along-path requires topology signs")]
    fn sign_along_path_needs_signs() {
        let mut w = vec![0.0; 4];
        Init::ConstantSignAlongPath.fill(&mut w, 1.0, None, 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = vec![0.0; 64];
        let mut b = vec![0.0; 64];
        Init::UniformRandom.fill(&mut a, 1.0, None, 9);
        Init::UniformRandom.fill(&mut b, 1.0, None, 9);
        assert_eq!(a, b);
    }
}
