//! Softmax cross-entropy loss and classification metrics.

use super::tensor::Tensor;

/// Numerically stable softmax cross-entropy.
///
/// `logits` `[B, C]`, `labels[b] ∈ 0..C`.  Returns `(mean_loss, dL/dlogits)`
/// with the gradient already averaged over the batch.
pub fn softmax_xent(logits: &Tensor, labels: &[u32]) -> (f32, Tensor) {
    let mut grad = Tensor::empty();
    let loss = softmax_xent_into(logits, labels, &mut grad);
    (loss, grad)
}

/// [`softmax_xent`] writing the gradient into a caller-held tensor
/// (reshaped/resized as needed) — the train-loop form: with a reused
/// `grad`, allocation-free once warm.
pub fn softmax_xent_into(logits: &Tensor, labels: &[u32], grad: &mut Tensor) -> f32 {
    let b = logits.batch();
    let c = logits.features();
    assert_eq!(labels.len(), b);
    grad.shape.clear();
    grad.shape.extend_from_slice(&logits.shape);
    // no clear: the per-row loop below writes every element
    grad.data.resize(b * c, 0.0);
    let mut loss = 0.0f64;
    let inv_b = 1.0 / b as f32;
    for i in 0..b {
        let row = logits.row(i);
        let y = labels[i] as usize;
        debug_assert!(y < c);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for &v in row {
            sum += (v - mx).exp();
        }
        let log_z = mx + sum.ln();
        loss += (log_z - row[y]) as f64;
        let grow = grad.row_mut(i);
        for (j, g) in grow.iter_mut().enumerate() {
            let p = (row[j] - log_z).exp();
            *g = (p - if j == y { 1.0 } else { 0.0 }) * inv_b;
        }
    }
    (loss / b as f64) as f32
}

/// Fraction of rows whose argmax equals the label.
pub fn accuracy(logits: &Tensor, labels: &[u32]) -> f64 {
    let b = logits.batch();
    assert_eq!(labels.len(), b);
    let mut correct = 0usize;
    for i in 0..b {
        let row = logits.row(i);
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        if best == labels[i] as usize {
            correct += 1;
        }
    }
    correct as f64 / b as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_for_uniform_logits_is_log_c() {
        let logits = Tensor::zeros(&[4, 10]);
        let labels = [0u32, 3, 7, 9];
        let (loss, grad) = softmax_xent(&logits, &labels);
        assert!((loss - (10.0f32).ln()).abs() < 1e-5);
        // gradient rows sum to zero
        for i in 0..4 {
            let s: f32 = grad.row(i).iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn perfect_prediction_low_loss() {
        let mut logits = Tensor::zeros(&[2, 3]);
        logits.row_mut(0)[1] = 50.0;
        logits.row_mut(1)[2] = 50.0;
        let (loss, _) = softmax_xent(&logits, &[1, 2]);
        assert!(loss < 1e-5, "loss={loss}");
        assert_eq!(accuracy(&logits, &[1, 2]), 1.0);
        assert_eq!(accuracy(&logits, &[0, 0]), 0.0);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let logits = Tensor::from_vec(vec![0.3, -0.7, 1.2, 0.0, 0.5, -0.1], &[2, 3]);
        let labels = [2u32, 0];
        let (_, grad) = softmax_xent(&logits, &labels);
        let eps = 1e-3f32;
        for idx in 0..6 {
            let mut lp = logits.clone();
            lp.data[idx] += eps;
            let mut lm = logits.clone();
            lm.data[idx] -= eps;
            let (fp, _) = softmax_xent(&lp, &labels);
            let (fm, _) = softmax_xent(&lm, &labels);
            let fd = (fp - fm) / (2.0 * eps);
            assert!(
                (fd - grad.data[idx]).abs() < 1e-3,
                "idx={idx} fd={fd} grad={}",
                grad.data[idx]
            );
        }
    }

    #[test]
    fn stable_for_large_logits() {
        let logits = Tensor::from_vec(vec![1e4, -1e4], &[1, 2]);
        let (loss, grad) = softmax_xent(&logits, &[0]);
        assert!(loss.is_finite() && loss < 1e-5);
        assert!(grad.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn accuracy_ties_pick_first() {
        let logits = Tensor::from_vec(vec![1.0, 1.0], &[1, 2]);
        assert_eq!(accuracy(&logits, &[0]), 1.0);
        assert_eq!(accuracy(&logits, &[1]), 0.0);
    }
}
