//! Stochastic gradient descent with momentum and weight decay, plus the
//! paper's step-decay learning-rate schedule (§5.2: SGD momentum 0.9,
//! lr 0.1 decayed ×0.1 at epochs 91 and 136 of 182 ⇒ at 50% and 75%),
//! and the fixed-sign constraint of Table 3 ("signs fixed, train only
//! magnitude").

/// SGD hyperparameters; `lr` is the *current* learning rate (the trainer
/// applies the schedule).
#[derive(Debug, Clone, Copy)]
pub struct Sgd {
    /// Current learning rate.
    pub lr: f32,
    /// Momentum coefficient (0 = plain SGD).
    pub momentum: f32,
    /// L2 weight decay coefficient.
    pub weight_decay: f32,
}

impl Default for Sgd {
    fn default() -> Self {
        Sgd { lr: 0.1, momentum: 0.9, weight_decay: 1e-4 }
    }
}

impl Sgd {
    /// One parameter-group update: `m ← μ·m + g + wd·w`, `w ← w − lr·m`,
    /// then zero the gradient.  If `fixed_signs` is given, weights whose
    /// update would flip the stored sign are clamped to zero magnitude
    /// (training only magnitudes, paper Table 3 / §3.2).
    pub fn update(
        &self,
        w: &mut [f32],
        g: &mut [f32],
        m: &mut [f32],
        fixed_signs: Option<&[f32]>,
    ) {
        debug_assert_eq!(w.len(), g.len());
        debug_assert_eq!(w.len(), m.len());
        for i in 0..w.len() {
            let grad = g[i] + self.weight_decay * w[i];
            m[i] = self.momentum * m[i] + grad;
            w[i] -= self.lr * m[i];
            g[i] = 0.0;
        }
        if let Some(signs) = fixed_signs {
            debug_assert_eq!(w.len(), signs.len());
            for i in 0..w.len() {
                // sign(w) must stay sign(signs[i]); clamp crossings to 0.
                if w[i] * signs[i] < 0.0 {
                    w[i] = 0.0;
                }
            }
        }
    }

    /// Update without weight decay (biases, batch-norm parameters).
    pub fn update_no_decay(&self, w: &mut [f32], g: &mut [f32], m: &mut [f32]) {
        let nodecay = Sgd { weight_decay: 0.0, ..*self };
        nodecay.update(w, g, m, None);
    }
}

/// Learning-rate schedules.
#[derive(Debug, Clone)]
pub enum LrSchedule {
    /// Constant learning rate.
    Constant(f32),
    /// Paper schedule: `base` decayed by ×`factor` at each fraction of
    /// total epochs in `milestones` (e.g. `[0.5, 0.75]`).
    StepDecay {
        /// Initial learning rate.
        base: f32,
        /// Multiplicative decay applied at each milestone.
        factor: f32,
        /// Milestones as fractions of total epochs, ascending.
        milestones: Vec<f32>,
    },
}

impl LrSchedule {
    /// Paper §5.2 default: 0.1, ×0.1 at 50% and 75%.
    pub fn paper_default() -> Self {
        LrSchedule::StepDecay { base: 0.1, factor: 0.1, milestones: vec![0.5, 0.75] }
    }

    /// Learning rate for `epoch` (0-based) of `total` epochs.
    pub fn lr_at(&self, epoch: usize, total: usize) -> f32 {
        match self {
            LrSchedule::Constant(lr) => *lr,
            LrSchedule::StepDecay { base, factor, milestones } => {
                let frac = (epoch as f32 + 0.5) / total.max(1) as f32;
                let hits = milestones.iter().filter(|&&m| frac >= m).count() as i32;
                base * factor.powi(hits)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_step() {
        let opt = Sgd { lr: 0.5, momentum: 0.0, weight_decay: 0.0 };
        let mut w = vec![1.0f32];
        let mut g = vec![2.0f32];
        let mut m = vec![0.0f32];
        opt.update(&mut w, &mut g, &mut m, None);
        assert_eq!(w[0], 0.0); // 1 - 0.5*2
        assert_eq!(g[0], 0.0, "gradient zeroed");
    }

    #[test]
    fn momentum_accumulates() {
        let opt = Sgd { lr: 1.0, momentum: 0.5, weight_decay: 0.0 };
        let mut w = vec![0.0f32];
        let mut m = vec![0.0f32];
        let mut g = vec![1.0f32];
        opt.update(&mut w, &mut g, &mut m, None); // m=1, w=-1
        g[0] = 1.0;
        opt.update(&mut w, &mut g, &mut m, None); // m=1.5, w=-2.5
        assert!((w[0] + 2.5).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_pulls_to_zero() {
        let opt = Sgd { lr: 0.1, momentum: 0.0, weight_decay: 1.0 };
        let mut w = vec![1.0f32];
        let mut g = vec![0.0f32];
        let mut m = vec![0.0f32];
        opt.update(&mut w, &mut g, &mut m, None);
        assert!((w[0] - 0.9).abs() < 1e-6);
    }

    #[test]
    fn fixed_signs_clamp_crossings() {
        let opt = Sgd { lr: 1.0, momentum: 0.0, weight_decay: 0.0 };
        let signs = vec![1.0f32, -1.0];
        let mut w = vec![0.5f32, -0.5];
        let mut g = vec![2.0f32, -2.0]; // would push w to -1.5 and +1.5
        let mut m = vec![0.0f32; 2];
        opt.update(&mut w, &mut g, &mut m, Some(&signs));
        assert_eq!(w, vec![0.0, 0.0], "crossing weights clamp to zero");
        // non-crossing updates pass through
        let mut w = vec![0.5f32, -0.5];
        let mut g = vec![-0.1f32, 0.1];
        let mut m = vec![0.0f32; 2];
        opt.update(&mut w, &mut g, &mut m, Some(&signs));
        assert!(w[0] > 0.5 && w[1] < -0.5);
    }

    #[test]
    fn schedule_paper_default() {
        let s = LrSchedule::paper_default();
        let total = 182;
        assert!((s.lr_at(0, total) - 0.1).abs() < 1e-7);
        assert!((s.lr_at(90, total) - 0.1).abs() < 1e-7, "before 50%");
        assert!((s.lr_at(91, total) - 0.01).abs() < 1e-7, "after 50%");
        assert!((s.lr_at(136, total) - 0.001).abs() < 1e-7, "after 75%");
        assert!((s.lr_at(181, total) - 0.001).abs() < 1e-7);
    }

    #[test]
    fn schedule_constant() {
        let s = LrSchedule::Constant(0.05);
        assert_eq!(s.lr_at(0, 10), 0.05);
        assert_eq!(s.lr_at(9, 10), 0.05);
    }
}
