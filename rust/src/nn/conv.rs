//! 2-D convolution (im2col + GEMM), max pooling and global average
//! pooling for the paper's CIFAR CNN (§5.2).
//!
//! Channel-level path sparsity (§2.2): a path through a convolutional
//! layer selects one input channel per output filter; the active
//! `(c_out, c_in)` pairs form a channel mask and each active pair
//! carries a full `kh × kw` filter slice — the "coarse sparsity on the
//! filter level" the paper notes is hardware-friendlier than per-weight
//! sparsity.

use super::init::{w_init_magnitude, Init};
use super::matmul::{matmul_nn, matmul_nt, matmul_tn};
use super::optim::Sgd;
use super::tensor::Tensor;

/// 3×3 (or general) convolution with stride 1 and symmetric padding.
#[derive(Debug, Clone)]
pub struct Conv2d {
    /// Input channels.
    pub c_in: usize,
    /// Output channels (filters).
    pub c_out: usize,
    /// Kernel height/width.
    pub k: usize,
    /// Padding on each side.
    pub pad: usize,
    /// Weights `[c_out][c_in·k·k]` flattened.
    pub w: Vec<f32>,
    /// Bias `[c_out]`.
    pub b: Vec<f32>,
    /// Channel mask `[c_out][c_in]` (1 = active pair); `None` = dense.
    pub channel_mask: Option<Vec<f32>>,
    /// Active `(c_out, c_in)` pairs, derived from the mask.  When the
    /// mask density is low the forward/backward passes iterate only the
    /// active pairs — compute **linear in the number of paths** instead
    /// of quadratic in the width (the paper's §2/§3 complexity claim;
    /// this is what keeps the width-8× sweeps of Table 2/Figs 10-12
    /// tractable).
    pub active_pairs: Option<Vec<(u32, u32)>>,
    /// Fixed signs for magnitude-only training (same layout as `w`).
    pub fixed_signs: Option<Vec<f32>>,
    gw: Vec<f32>,
    gb: Vec<f32>,
    mw: Vec<f32>,
    mb: Vec<f32>,
    cols_cache: Vec<f32>,
    x_cache: Tensor,
    in_shape: Vec<usize>,
}

impl Conv2d {
    /// New convolution layer.
    pub fn new(c_in: usize, c_out: usize, k: usize, init: Init, seed: u64) -> Self {
        let len = c_out * c_in * k * k;
        let mut w = vec![0.0f32; len];
        let fan_in = c_in * k * k;
        let fan_out = c_out * k * k;
        let mag = w_init_magnitude(fan_in, fan_out);
        init.fill(&mut w, mag, None, seed);
        if init == Init::ConstantAlternating {
            // paper semantics: sign alternates by output FILTER index
            for co in 0..c_out {
                let s = if co % 2 == 0 { mag } else { -mag };
                w[co * c_in * k * k..(co + 1) * c_in * k * k].fill(s);
            }
        }
        Conv2d {
            c_in,
            c_out,
            k,
            pad: k / 2,
            w,
            b: vec![0.0; c_out],
            channel_mask: None,
            active_pairs: None,
            fixed_signs: None,
            gw: vec![0.0; len],
            gb: vec![0.0; c_out],
            mw: vec![0.0; len],
            mb: vec![0.0; c_out],
            cols_cache: Vec::new(),
            x_cache: Tensor::zeros(&[0]),
            in_shape: Vec::new(),
        }
    }

    /// Apply a channel mask `[c_out][c_in]`: inactive pairs are zeroed
    /// now and their gradients zeroed every backward pass.  With
    /// `sign_per_pair`, the whole filter slice additionally takes the
    /// path sign (paper §5.4 caution: this constrains the features a
    /// slice can express).
    pub fn set_channel_mask(&mut self, mask: Vec<f32>, sign_per_pair: Option<&[f32]>) {
        assert_eq!(mask.len(), self.c_out * self.c_in);
        let kk = self.k * self.k;
        for co in 0..self.c_out {
            for ci in 0..self.c_in {
                let m = mask[co * self.c_in + ci];
                let base = (co * self.c_in + ci) * kk;
                for t in 0..kk {
                    self.w[base + t] *= m;
                    if let Some(signs) = sign_per_pair {
                        let s = signs[co * self.c_in + ci];
                        self.w[base + t] = self.w[base + t].abs() * s.signum() * m;
                    }
                }
            }
        }
        let mut pairs = Vec::new();
        for co in 0..self.c_out {
            for ci in 0..self.c_in {
                if mask[co * self.c_in + ci] > 0.0 {
                    pairs.push((co as u32, ci as u32));
                }
            }
        }
        self.active_pairs = Some(pairs);
        self.channel_mask = Some(mask);
    }

    /// Use the pair-sparse path when it saves work (density below half).
    fn use_sparse_path(&self) -> bool {
        match &self.active_pairs {
            Some(p) => p.len() * 2 < self.c_out * self.c_in,
            None => false,
        }
    }

    /// Freeze current signs (train only magnitudes).
    pub fn freeze_signs(&mut self) {
        self.fixed_signs = Some(self.w.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect());
    }

    /// Output spatial size for an input of `h × w` (stride 1, padded).
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (h + 2 * self.pad + 1 - self.k, w + 2 * self.pad + 1 - self.k)
    }

    fn im2col(&self, x: &Tensor) -> (Vec<f32>, usize, usize) {
        let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        let (oh, ow) = self.out_hw(h, w);
        let kk = self.k * self.k;
        let row_len = c * kk;
        let mut cols = vec![0.0f32; b * oh * ow * row_len];
        for bi in 0..b {
            for oy in 0..oh {
                for ox in 0..ow {
                    let dst_base = ((bi * oh + oy) * ow + ox) * row_len;
                    for ci in 0..c {
                        let src_plane = (bi * c + ci) * h * w;
                        for ky in 0..self.k {
                            let iy = oy + ky;
                            let iy = iy as isize - self.pad as isize;
                            for kx in 0..self.k {
                                let ix = (ox + kx) as isize - self.pad as isize;
                                let v = if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w
                                {
                                    x.data[src_plane + iy as usize * w + ix as usize]
                                } else {
                                    0.0
                                };
                                cols[dst_base + ci * kk + ky * self.k + kx] = v;
                            }
                        }
                    }
                }
            }
        }
        (cols, oh, ow)
    }

    fn col2im(&self, gcols: &[f32], shape: &[usize]) -> Tensor {
        let (b, c, h, w) = (shape[0], shape[1], shape[2], shape[3]);
        let (oh, ow) = self.out_hw(h, w);
        let kk = self.k * self.k;
        let row_len = c * kk;
        let mut gx = Tensor::zeros(shape);
        for bi in 0..b {
            for oy in 0..oh {
                for ox in 0..ow {
                    let src_base = ((bi * oh + oy) * ow + ox) * row_len;
                    for ci in 0..c {
                        let dst_plane = (bi * c + ci) * h * w;
                        for ky in 0..self.k {
                            let iy = (oy + ky) as isize - self.pad as isize;
                            if iy < 0 || iy as usize >= h {
                                continue;
                            }
                            for kx in 0..self.k {
                                let ix = (ox + kx) as isize - self.pad as isize;
                                if ix < 0 || ix as usize >= w {
                                    continue;
                                }
                                gx.data[dst_plane + iy as usize * w + ix as usize] +=
                                    gcols[src_base + ci * kk + ky * self.k + kx];
                            }
                        }
                    }
                }
            }
        }
        gx
    }

    /// Pair-sparse forward: iterate only active `(c_out, c_in)` pairs —
    /// O(pairs · k² · H·W · B), independent of the dense width.
    fn forward_sparse(&mut self, x: &Tensor, train: bool) -> Tensor {
        let (b, _, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        let (oh, ow) = self.out_hw(h, w);
        let mut y = Tensor::zeros(&[b, self.c_out, oh, ow]);
        let pairs = self.active_pairs.as_ref().unwrap();
        let kk = self.k * self.k;
        let pad = self.pad as isize;
        let plane_out = oh * ow;
        let sample_out = self.c_out * plane_out;
        crate::util::parallel::parallel_rows(&mut y.data, sample_out, |bi, ysample| {
            for &(co, ci) in pairs {
                let wslice = &self.w[(co as usize * self.c_in + ci as usize) * kk..][..kk];
                let xin = &x.data[(bi * self.c_in + ci as usize) * h * w..][..h * w];
                let yplane = &mut ysample[co as usize * plane_out..][..plane_out];
                for (kidx, &wv) in wslice.iter().enumerate() {
                    if wv == 0.0 {
                        continue;
                    }
                    let ky = (kidx / self.k) as isize - pad;
                    let kx = (kidx % self.k) as isize - pad;
                    let y0 = (-ky).max(0) as usize;
                    let y1 = ((h as isize - ky).min(oh as isize)).max(0) as usize;
                    let x0 = (-kx).max(0) as usize;
                    let x1 = ((w as isize - kx).min(ow as isize)).max(0) as usize;
                    for oy in y0..y1 {
                        let src = ((oy as isize + ky) as usize) * w;
                        let dst = oy * ow;
                        for ox in x0..x1 {
                            yplane[dst + ox] += wv * xin[src + (ox as isize + kx) as usize];
                        }
                    }
                }
            }
            // bias
            for co in 0..self.c_out {
                let bv = self.b[co];
                if bv != 0.0 {
                    for v in &mut ysample[co * plane_out..(co + 1) * plane_out] {
                        *v += bv;
                    }
                }
            }
        });
        if train {
            self.cols_cache.clear(); // sparse path caches x, not cols
            self.x_cache = x.clone();
            self.in_shape = x.shape.clone();
        }
        y
    }

    /// Pair-sparse backward.
    fn backward_sparse(&mut self, gy: &Tensor) -> Tensor {
        let shape = self.in_shape.clone();
        let (b, _, h, w) = (shape[0], shape[1], shape[2], shape[3]);
        let (oh, ow) = self.out_hw(h, w);
        let pairs = self.active_pairs.as_ref().unwrap().clone();
        let kk = self.k * self.k;
        let pad = self.pad as isize;
        let x = &self.x_cache;
        // bias grads
        for bi in 0..b {
            for co in 0..self.c_out {
                let plane = &gy.data[((bi * self.c_out + co) * oh * ow)..][..oh * ow];
                self.gb[co] += plane.iter().sum::<f32>();
            }
        }
        // weight grads per active pair
        for &(co, ci) in &pairs {
            let gw = &mut self.gw[(co as usize * self.c_in + ci as usize) * kk..][..kk];
            for bi in 0..b {
                let gplane = &gy.data[((bi * self.c_out + co as usize) * oh * ow)..][..oh * ow];
                let xin = &x.data[(bi * self.c_in + ci as usize) * h * w..][..h * w];
                for kidx in 0..kk {
                    let ky = (kidx / self.k) as isize - pad;
                    let kx = (kidx % self.k) as isize - pad;
                    let y0 = (-ky).max(0) as usize;
                    let y1 = ((h as isize - ky).min(oh as isize)).max(0) as usize;
                    let x0 = (-kx).max(0) as usize;
                    let x1 = ((w as isize - kx).min(ow as isize)).max(0) as usize;
                    let mut acc = 0.0f32;
                    for oy in y0..y1 {
                        let src = ((oy as isize + ky) as usize) * w;
                        let dst = oy * ow;
                        for ox in x0..x1 {
                            acc += gplane[dst + ox] * xin[src + (ox as isize + kx) as usize];
                        }
                    }
                    gw[kidx] += acc;
                }
            }
        }
        // input grads (transposed conv over active pairs)
        let mut gx = Tensor::zeros(&shape);
        let sample_in = self.c_in * h * w;
        crate::util::parallel::parallel_rows(&mut gx.data, sample_in, |bi, gxs| {
            for &(co, ci) in &pairs {
                let wslice = &self.w[(co as usize * self.c_in + ci as usize) * kk..][..kk];
                let gplane = &gy.data[((bi * self.c_out + co as usize) * oh * ow)..][..oh * ow];
                let gxin = &mut gxs[ci as usize * h * w..][..h * w];
                for (kidx, &wv) in wslice.iter().enumerate() {
                    if wv == 0.0 {
                        continue;
                    }
                    let ky = (kidx / self.k) as isize - pad;
                    let kx = (kidx % self.k) as isize - pad;
                    let y0 = (-ky).max(0) as usize;
                    let y1 = ((h as isize - ky).min(oh as isize)).max(0) as usize;
                    let x0 = (-kx).max(0) as usize;
                    let x1 = ((w as isize - kx).min(ow as isize)).max(0) as usize;
                    for oy in y0..y1 {
                        let src = ((oy as isize + ky) as usize) * w;
                        let dst = oy * ow;
                        for ox in x0..x1 {
                            gxin[src + (ox as isize + kx) as usize] += wv * gplane[dst + ox];
                        }
                    }
                }
            }
        });
        gx
    }

    /// Forward: `[B, c_in, H, W] → [B, c_out, H', W']`.
    pub fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        assert_eq!(x.shape.len(), 4);
        assert_eq!(x.shape[1], self.c_in);
        if self.use_sparse_path() {
            return self.forward_sparse(x, train);
        }
        let (cols, oh, ow) = self.im2col(x);
        let b = x.shape[0];
        let rows = b * oh * ow;
        let row_len = self.c_in * self.k * self.k;
        // y[rows, c_out] = cols[rows, row_len] · wᵀ
        let mut y_rows = vec![0.0f32; rows * self.c_out];
        matmul_nt(&cols, &self.w, &mut y_rows, rows, row_len, self.c_out);
        // reorder to [B, c_out, oh, ow] and add bias
        let mut y = Tensor::zeros(&[b, self.c_out, oh, ow]);
        for bi in 0..b {
            for oy in 0..oh {
                for ox in 0..ow {
                    let r = (bi * oh + oy) * ow + ox;
                    for co in 0..self.c_out {
                        y.data[((bi * self.c_out + co) * oh + oy) * ow + ox] =
                            y_rows[r * self.c_out + co] + self.b[co];
                    }
                }
            }
        }
        if train {
            self.cols_cache = cols;
            self.in_shape = x.shape.clone();
        }
        y
    }

    /// Backward: accumulates `gw`/`gb`, returns input gradient.
    pub fn backward(&mut self, gy: &Tensor) -> Tensor {
        let (b, co_, oh, ow) = (gy.shape[0], gy.shape[1], gy.shape[2], gy.shape[3]);
        assert_eq!(co_, self.c_out);
        assert!(!self.in_shape.is_empty(), "forward(train=true) must precede backward");
        if self.use_sparse_path() {
            return self.backward_sparse(gy);
        }
        let rows = b * oh * ow;
        let row_len = self.c_in * self.k * self.k;
        // reorder gy to [rows, c_out]
        let mut gy_rows = vec![0.0f32; rows * self.c_out];
        for bi in 0..b {
            for co in 0..self.c_out {
                for oy in 0..oh {
                    for ox in 0..ow {
                        gy_rows[((bi * oh + oy) * ow + ox) * self.c_out + co] =
                            gy.data[((bi * self.c_out + co) * oh + oy) * ow + ox];
                    }
                }
            }
        }
        // gw[c_out, row_len] += gy_rowsᵀ[rows,c_out] · cols[rows,row_len]
        matmul_tn(&gy_rows, &self.cols_cache, &mut self.gw, self.c_out, rows, row_len);
        for r in 0..rows {
            for co in 0..self.c_out {
                self.gb[co] += gy_rows[r * self.c_out + co];
            }
        }
        if let Some(mask) = &self.channel_mask {
            let kk = self.k * self.k;
            for co in 0..self.c_out {
                for ci in 0..self.c_in {
                    let m = mask[co * self.c_in + ci];
                    if m == 0.0 {
                        let base = (co * self.c_in + ci) * kk;
                        self.gw[base..base + kk].fill(0.0);
                    }
                }
            }
        }
        // gcols[rows, row_len] = gy_rows · w
        let mut gcols = vec![0.0f32; rows * row_len];
        matmul_nn(&gy_rows, &self.w, &mut gcols, rows, self.c_out, row_len);
        self.col2im(&gcols, &self.in_shape.clone())
    }

    /// SGD step (mask re-applied to defeat weight decay drift).
    pub fn step(&mut self, opt: &Sgd) {
        opt.update(&mut self.w, &mut self.gw, &mut self.mw, self.fixed_signs.as_deref());
        opt.update_no_decay(&mut self.b, &mut self.gb, &mut self.mb);
        if let Some(mask) = &self.channel_mask {
            let kk = self.k * self.k;
            for co in 0..self.c_out {
                for ci in 0..self.c_in {
                    if mask[co * self.c_in + ci] == 0.0 {
                        let base = (co * self.c_in + ci) * kk;
                        self.w[base..base + kk].fill(0.0);
                    }
                }
            }
        }
    }

    /// Non-zero weight count (mask-aware, excluding bias).
    pub fn nnz(&self) -> usize {
        match &self.channel_mask {
            None => self.w.len(),
            Some(m) => {
                m.iter().filter(|&&v| v > 0.0).count() * self.k * self.k
            }
        }
    }

    /// Trainable parameters (nnz + bias).
    pub fn nparams(&self) -> usize {
        self.nnz() + self.b.len()
    }
}

/// 2×2 max pooling with stride 2.
#[derive(Debug, Clone, Default)]
pub struct MaxPool2 {
    argmax: Vec<usize>,
    in_shape: Vec<usize>,
}

impl MaxPool2 {
    /// New pooling layer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Forward `[B,C,H,W] → [B,C,H/2,W/2]` (H, W even).
    pub fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        assert!(h % 2 == 0 && w % 2 == 0, "maxpool needs even dims, got {h}x{w}");
        let (oh, ow) = (h / 2, w / 2);
        let mut y = Tensor::zeros(&[b, c, oh, ow]);
        let mut argmax = vec![0usize; y.len()];
        for bc in 0..b * c {
            let xin = &x.data[bc * h * w..(bc + 1) * h * w];
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_i = 0usize;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let i = (oy * 2 + dy) * w + ox * 2 + dx;
                            if xin[i] > best {
                                best = xin[i];
                                best_i = i;
                            }
                        }
                    }
                    let oi = bc * oh * ow + oy * ow + ox;
                    y.data[oi] = best;
                    argmax[oi] = bc * h * w + best_i;
                }
            }
        }
        if train {
            self.argmax = argmax;
            self.in_shape = x.shape.clone();
        }
        y
    }

    /// Backward: route gradients to the argmax positions.
    pub fn backward(&self, gy: &Tensor) -> Tensor {
        let mut gx = Tensor::zeros(&self.in_shape);
        for (i, &g) in gy.data.iter().enumerate() {
            gx.data[self.argmax[i]] += g;
        }
        gx
    }
}

/// Global average pooling `[B,C,H,W] → [B,C]`.
#[derive(Debug, Clone, Default)]
pub struct GlobalAvgPool {
    in_shape: Vec<usize>,
}

impl GlobalAvgPool {
    /// New layer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Forward.
    pub fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let (b, c) = (x.shape[0], x.shape[1]);
        let hw: usize = x.shape[2..].iter().product();
        let mut y = Tensor::zeros(&[b, c]);
        for bc in 0..b * c {
            let s: f32 = x.data[bc * hw..(bc + 1) * hw].iter().sum();
            y.data[bc] = s / hw as f32;
        }
        if train {
            self.in_shape = x.shape.clone();
        }
        y
    }

    /// Backward.
    pub fn backward(&self, gy: &Tensor) -> Tensor {
        let hw: usize = self.in_shape[2..].iter().product();
        let mut gx = Tensor::zeros(&self.in_shape);
        for (bc, &g) in gy.data.iter().enumerate() {
            let v = g / hw as f32;
            gx.data[bc * hw..(bc + 1) * hw].fill(v);
        }
        gx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with identity weights passes input through
        let mut conv = Conv2d::new(2, 2, 1, Init::ConstantPositive, 0);
        conv.w.copy_from_slice(&[1.0, 0.0, 0.0, 1.0]);
        conv.pad = 0;
        let x = Tensor::from_vec((0..8).map(|v| v as f32).collect(), &[1, 2, 2, 2]);
        let y = conv.forward(&x, false);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn conv_known_3x3() {
        // single channel, 3x3 all-ones kernel on a 3x3 input of ones:
        // center output = 9, corners = 4, edges = 6 (with padding 1)
        let mut conv = Conv2d::new(1, 1, 3, Init::ConstantPositive, 0);
        conv.w.iter_mut().for_each(|w| *w = 1.0);
        let x = Tensor::from_vec(vec![1.0; 9], &[1, 1, 3, 3]);
        let y = conv.forward(&x, false);
        assert_eq!(y.shape, vec![1, 1, 3, 3]);
        assert_eq!(y.data[4], 9.0);
        assert_eq!(y.data[0], 4.0);
        assert_eq!(y.data[1], 6.0);
    }

    #[test]
    fn conv_gradients_match_finite_difference() {
        let mut conv = Conv2d::new(2, 3, 3, Init::UniformRandom, 11);
        let x = Tensor::from_vec(
            (0..2 * 2 * 4 * 4).map(|i| ((i as f32) * 0.23).sin()).collect(),
            &[2, 2, 4, 4],
        );
        let y = conv.forward(&x, true);
        let gy = Tensor::from_vec((0..y.len()).map(|i| 0.01 * i as f32 - 0.2).collect(), &y.shape);
        let gx = conv.backward(&gy);
        let loss = |conv: &mut Conv2d, x: &Tensor| -> f32 {
            conv.forward(x, false).data.iter().zip(&gy.data).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-2;
        for idx in [0usize, 7, 20, conv.w.len() - 1] {
            let orig = conv.w[idx];
            conv.w[idx] = orig + eps;
            let lp = loss(&mut conv, &x);
            conv.w[idx] = orig - eps;
            let lm = loss(&mut conv, &x);
            conv.w[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - conv.gw[idx]).abs() < 3e-2 * (1.0 + fd.abs()),
                "w[{idx}] fd={fd} anal={}",
                conv.gw[idx]
            );
        }
        for idx in [0usize, 13, 40] {
            let mut xp = x.clone();
            xp.data[idx] += eps;
            let mut xm = x.clone();
            xm.data[idx] -= eps;
            let fd = (loss(&mut conv, &xp) - loss(&mut conv, &xm)) / (2.0 * eps);
            assert!(
                (fd - gx.data[idx]).abs() < 3e-2 * (1.0 + fd.abs()),
                "x[{idx}] fd={fd} anal={}",
                gx.data[idx]
            );
        }
    }

    #[test]
    fn channel_mask_zeroes_slices() {
        let mut conv = Conv2d::new(2, 2, 3, Init::ConstantPositive, 0);
        // only pairs (0,0) and (1,1) active
        conv.set_channel_mask(vec![1.0, 0.0, 0.0, 1.0], None);
        let kk = 9;
        assert!(conv.w[kk..2 * kk].iter().all(|&v| v == 0.0));
        assert!(conv.w[2 * kk..3 * kk].iter().all(|&v| v == 0.0));
        assert_eq!(conv.nnz(), 2 * 9);
        assert_eq!(conv.nparams(), 18 + 2);
        // grads masked after backward
        let x = Tensor::from_vec(vec![1.0; 2 * 16], &[1, 2, 4, 4]);
        let y = conv.forward(&x, true);
        conv.backward(&Tensor::from_vec(vec![1.0; y.len()], &y.shape));
        assert!(conv.gw[kk..2 * kk].iter().all(|&v| v == 0.0));
        conv.step(&Sgd::default());
        assert!(conv.w[kk..2 * kk].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn maxpool_forward_backward() {
        let mut pool = MaxPool2::new();
        let x = Tensor::from_vec(
            vec![
                1.0, 2.0, 3.0, 4.0, //
                5.0, 6.0, 7.0, 8.0, //
                1.0, 1.0, 1.0, 1.0, //
                1.0, 9.0, 1.0, 1.0,
            ],
            &[1, 1, 4, 4],
        );
        let y = pool.forward(&x, true);
        assert_eq!(y.shape, vec![1, 1, 2, 2]);
        assert_eq!(y.data, vec![6.0, 8.0, 9.0, 1.0]);
        let gy = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &y.shape);
        let gx = pool.backward(&gy);
        assert_eq!(gx.data[5], 1.0); // position of 6
        assert_eq!(gx.data[7], 2.0); // position of 8
        assert_eq!(gx.data[13], 3.0); // position of 9
        assert_eq!(gx.data.iter().filter(|&&v| v != 0.0).count(), 4);
    }

    #[test]
    fn sparse_path_matches_masked_dense() {
        // forward + both gradients must agree between the pair-sparse
        // implementation and the masked im2col path
        let mk = || {
            let mut c = Conv2d::new(6, 8, 3, Init::UniformRandom, 3);
            // low-density mask triggers the sparse path
            let mut mask = vec![0.0f32; 48];
            for (i, m) in mask.iter_mut().enumerate() {
                if i % 5 == 0 {
                    *m = 1.0;
                }
            }
            c.set_channel_mask(mask, None);
            c
        };
        let mut sparse = mk();
        let mut dense = mk();
        assert!(sparse.use_sparse_path());
        dense.active_pairs = None; // force the im2col path
        let x = Tensor::from_vec(
            (0..2 * 6 * 5 * 5).map(|i| ((i as f32) * 0.17).sin()).collect(),
            &[2, 6, 5, 5],
        );
        let ys = sparse.forward(&x, true);
        let yd = dense.forward(&x, true);
        assert!(ys.max_abs_diff(&yd) < 1e-4, "fwd diff {}", ys.max_abs_diff(&yd));
        let gy = Tensor::from_vec((0..ys.len()).map(|i| 0.01 * i as f32 - 0.5).collect(), &ys.shape);
        let gxs = sparse.backward(&gy);
        let gxd = dense.backward(&gy);
        assert!(gxs.max_abs_diff(&gxd) < 1e-3, "gx diff {}", gxs.max_abs_diff(&gxd));
        for (a, b) in sparse.gw.iter().zip(&dense.gw) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "gw {a} vs {b}");
        }
        for (a, b) in sparse.gb.iter().zip(&dense.gb) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "gb {a} vs {b}");
        }
    }

    #[test]
    fn global_avg_pool() {
        let mut gap = GlobalAvgPool::new();
        let x = Tensor::from_vec(vec![1.0, 3.0, 5.0, 7.0, 2.0, 2.0, 2.0, 2.0], &[1, 2, 2, 2]);
        let y = gap.forward(&x, true);
        assert_eq!(y.data, vec![4.0, 2.0]);
        let gx = gap.backward(&Tensor::from_vec(vec![4.0, 8.0], &[1, 2]));
        assert!(gx.data[..4].iter().all(|&v| v == 1.0));
        assert!(gx.data[4..].iter().all(|&v| v == 2.0));
    }
}
