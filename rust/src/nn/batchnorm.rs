//! Batch normalization [IS15], used by the paper's CNN (§5.2: every
//! convolutional layer is followed by BatchNorm and ReLU).  Scale is
//! initialized to 1 and shift to 0 (§3.1).
//!
//! Operates per channel over `[B, C, H, W]` tensors (or per feature
//! over `[B, C]` with `H=W=1` semantics).

use super::optim::Sgd;
use super::tensor::Tensor;

/// Batch normalization layer over channel dimension 1.
#[derive(Debug, Clone)]
pub struct BatchNorm {
    /// Channels.
    pub c: usize,
    /// Scale γ (init 1).
    pub gamma: Vec<f32>,
    /// Shift β (init 0).
    pub beta: Vec<f32>,
    /// Running mean (eval mode).
    pub running_mean: Vec<f32>,
    /// Running variance (eval mode).
    pub running_var: Vec<f32>,
    /// Momentum of the running statistics.
    pub bn_momentum: f32,
    eps: f32,
    gg: Vec<f32>,
    gb: Vec<f32>,
    mg: Vec<f32>,
    mb: Vec<f32>,
    // caches for backward
    xhat: Vec<f32>,
    inv_std: Vec<f32>,
    cached_shape: Vec<usize>,
}

impl BatchNorm {
    /// New batch-norm over `c` channels.
    pub fn new(c: usize) -> Self {
        BatchNorm {
            c,
            gamma: vec![1.0; c],
            beta: vec![0.0; c],
            running_mean: vec![0.0; c],
            running_var: vec![1.0; c],
            bn_momentum: 0.1,
            eps: 1e-5,
            gg: vec![0.0; c],
            gb: vec![0.0; c],
            mg: vec![0.0; c],
            mb: vec![0.0; c],
            xhat: Vec::new(),
            inv_std: vec![0.0; c],
            cached_shape: Vec::new(),
        }
    }

    fn plane(shape: &[usize]) -> (usize, usize) {
        // (batch, spatial-per-channel)
        let b = shape[0];
        let hw: usize = shape[2..].iter().product::<usize>().max(1);
        (b, hw)
    }

    /// Forward; uses batch statistics in train mode and running
    /// statistics in eval mode.
    pub fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        assert!(x.shape.len() >= 2 && x.shape[1] == self.c, "batchnorm channel dim");
        let (b, hw) = Self::plane(&x.shape);
        let n = (b * hw) as f32;
        let mut y = Tensor::zeros(&x.shape);
        if train {
            self.xhat = vec![0.0; x.len()];
            self.cached_shape = x.shape.clone();
        }
        for ch in 0..self.c {
            let (mean, var) = if train {
                let mut s = 0.0f64;
                let mut s2 = 0.0f64;
                for bi in 0..b {
                    let base = (bi * self.c + ch) * hw;
                    for k in 0..hw {
                        let v = x.data[base + k] as f64;
                        s += v;
                        s2 += v * v;
                    }
                }
                let mean = (s / n as f64) as f32;
                let var = ((s2 / n as f64) - (mean as f64) * (mean as f64)).max(0.0) as f32;
                self.running_mean[ch] =
                    (1.0 - self.bn_momentum) * self.running_mean[ch] + self.bn_momentum * mean;
                self.running_var[ch] =
                    (1.0 - self.bn_momentum) * self.running_var[ch] + self.bn_momentum * var;
                (mean, var)
            } else {
                (self.running_mean[ch], self.running_var[ch])
            };
            let inv_std = 1.0 / (var + self.eps).sqrt();
            if train {
                self.inv_std[ch] = inv_std;
            }
            let g = self.gamma[ch];
            let bta = self.beta[ch];
            for bi in 0..b {
                let base = (bi * self.c + ch) * hw;
                for k in 0..hw {
                    let xh = (x.data[base + k] - mean) * inv_std;
                    if train {
                        self.xhat[base + k] = xh;
                    }
                    y.data[base + k] = g * xh + bta;
                }
            }
        }
        y
    }

    /// Backward through the batch statistics (full formula).
    pub fn backward(&mut self, gy: &Tensor) -> Tensor {
        assert_eq!(gy.shape, self.cached_shape, "train-mode forward must precede backward");
        let (b, hw) = Self::plane(&gy.shape);
        let n = (b * hw) as f32;
        let mut gx = Tensor::zeros(&gy.shape);
        for ch in 0..self.c {
            let mut sum_gy = 0.0f64;
            let mut sum_gy_xhat = 0.0f64;
            for bi in 0..b {
                let base = (bi * self.c + ch) * hw;
                for k in 0..hw {
                    let g = gy.data[base + k] as f64;
                    sum_gy += g;
                    sum_gy_xhat += g * self.xhat[base + k] as f64;
                }
            }
            self.gb[ch] += sum_gy as f32;
            self.gg[ch] += sum_gy_xhat as f32;
            let gamma = self.gamma[ch];
            let inv_std = self.inv_std[ch];
            let k1 = (sum_gy / n as f64) as f32;
            let k2 = (sum_gy_xhat / n as f64) as f32;
            for bi in 0..b {
                let base = (bi * self.c + ch) * hw;
                for k in 0..hw {
                    let g = gy.data[base + k];
                    let xh = self.xhat[base + k];
                    gx.data[base + k] = gamma * inv_std * (g - k1 - xh * k2);
                }
            }
        }
        gx
    }

    /// SGD update of γ/β (no weight decay, per common practice).
    pub fn step(&mut self, opt: &Sgd) {
        opt.update_no_decay(&mut self.gamma, &mut self.gg, &mut self.mg);
        opt.update_no_decay(&mut self.beta, &mut self.gb, &mut self.mb);
    }

    /// Parameter count (γ + β).
    pub fn nparams(&self) -> usize {
        2 * self.c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalizes_batch_statistics() {
        let mut bn = BatchNorm::new(2);
        // x: B=4, C=2, spatial 1
        let x = Tensor::from_vec(vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0], &[4, 2]);
        let y = bn.forward(&x, true);
        for ch in 0..2 {
            let vals: Vec<f32> = (0..4).map(|b| y.data[b * 2 + ch]).collect();
            let m: f32 = vals.iter().sum::<f32>() / 4.0;
            let v: f32 = vals.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / 4.0;
            assert!(m.abs() < 1e-5, "mean {m}");
            assert!((v - 1.0).abs() < 1e-2, "var {v}");
        }
    }

    #[test]
    fn eval_uses_running_stats() {
        let mut bn = BatchNorm::new(1);
        let x = Tensor::from_vec(vec![4.0, 6.0], &[2, 1]);
        for _ in 0..200 {
            bn.forward(&x, true); // converge running stats to mean=5, var=1
        }
        let y = bn.forward(&Tensor::from_vec(vec![5.0], &[1, 1]), false);
        assert!(y.data[0].abs() < 0.05, "eval-normalized mean should be ~0, got {}", y.data[0]);
    }

    #[test]
    fn backward_matches_finite_difference() {
        let mut bn = BatchNorm::new(2);
        bn.gamma = vec![1.5, 0.5];
        bn.beta = vec![0.1, -0.2];
        let x = Tensor::from_vec(
            vec![0.5, -1.0, 1.5, 2.0, -0.5, 0.3, 0.9, -2.0],
            &[2, 2, 2, 1], // B=2, C=2, H=2, W=1
        );
        let y = bn.forward(&x, true);
        let gy = Tensor::from_vec((0..y.len()).map(|i| 0.1 * i as f32 - 0.3).collect(), &y.shape);
        let gx = bn.backward(&gy);
        let loss = |bn: &mut BatchNorm, x: &Tensor| -> f32 {
            let y = bn.forward(x, true);
            y.data.iter().zip(&gy.data).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-3;
        for idx in [0usize, 3, 5, 7] {
            let mut xp = x.clone();
            xp.data[idx] += eps;
            let mut xm = x.clone();
            xm.data[idx] -= eps;
            let fd = (loss(&mut bn, &xp) - loss(&mut bn, &xm)) / (2.0 * eps);
            assert!(
                (fd - gx.data[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "idx={idx} fd={fd} anal={}",
                gx.data[idx]
            );
        }
    }

    #[test]
    fn step_updates_gamma_beta() {
        let mut bn = BatchNorm::new(1);
        let x = Tensor::from_vec(vec![1.0, 3.0], &[2, 1]);
        let y = bn.forward(&x, true);
        let gy = Tensor::from_vec(vec![1.0, 1.0], &y.shape);
        bn.backward(&gy);
        let g0 = bn.gamma[0];
        let b0 = bn.beta[0];
        bn.step(&Sgd { lr: 0.1, momentum: 0.0, weight_decay: 0.0 });
        assert_ne!(bn.beta[0], b0, "beta should move (sum gy != 0)");
        // gamma grad = sum gy*xhat ≈ 0 for symmetric batch
        assert!((bn.gamma[0] - g0).abs() < 1e-4);
    }

    #[test]
    fn nparams_counts() {
        assert_eq!(BatchNorm::new(16).nparams(), 32);
    }
}
