//! Blocked/unrolled SIMD kernel: AVX2 intrinsics on x86_64 behind
//! runtime feature detection, an 8-column blocked scalar loop
//! everywhere else.
//!
//! **Bitwise contract.**  Both paths reproduce the scalar kernel's
//! bits exactly, by IEEE-754 argument (pinned defensively at ≤ 1e-6 in
//! `tests/kernel_golden.rs`, and bitwise thread-invariant per kernel):
//!
//! * No FMA: the vector forward uses separate `mul` + `add`, so each
//!   column sees the identical `acc + w·max(v, 0)` rounding sequence
//!   as the scalar loop.
//! * `_mm256_max_ps(v, 0)` differs from `f32::max(v, 0.0)` only in
//!   NaN handling (both return `0` here — the intrinsic takes the
//!   second operand on NaN) and in the sign of a zero result, which
//!   cannot reach the accumulator bits: an accumulator that starts at
//!   `+0.0` never becomes `-0.0` under round-to-nearest addition.
//! * The backward `gacc` reduction stores the 8 lane products and sums
//!   them **in lane order** — the same left-to-right add sequence as
//!   the scalar column loop — instead of a horizontal tree reduction.
//! * The ReLU gate is applied by masking the *gradient* with the
//!   `v > 0` compare; masked lanes contribute `±0` exactly as the
//!   scalar `g · 0.0` does.
//!
//! Block starts depend only on the column index (`bi` advances from
//! `c0` in steps of 8), and every op order is per-column, so shard
//! placement — and therefore the thread count — never changes a bit.

use super::{bias_row_sums, init_bias_columns, BwdCtx, FwdCtx, KernelKind, SparseKernel};

/// Columns per block in the fallback path (one AVX2 register of f32).
const BLOCK: usize = 8;

/// See the [module docs](self).
pub struct SimdKernel;

impl SparseKernel for SimdKernel {
    fn kind(&self) -> KernelKind {
        KernelKind::Simd
    }

    fn forward_columns(&self, ctx: &FwdCtx<'_>, c0: usize, c1: usize) {
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            // Safety: AVX2 presence just checked; pointer/range
            // contract identical to the scalar kernel's.
            unsafe { fwd_avx2(ctx, c0, c1) };
            return;
        }
        fwd_blocked(ctx, c0, c1);
    }

    fn backward_shard(&self, ctx: &BwdCtx<'_>, c0: usize, c1: usize) {
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            // Safety: as above.
            unsafe { bwd_avx2(ctx, c0, c1) };
            return;
        }
        bwd_blocked(ctx, c0, c1);
    }
}

/// Fallback forward: the scalar loops restructured into fixed 8-column
/// blocks (a constant-trip inner loop LLVM unrolls and vectorizes);
/// per-column op order is unchanged, so the bits are too.
fn fwd_blocked(ctx: &FwdCtx<'_>, c0: usize, c1: usize) {
    let b = ctx.batch;
    for t in 0..ctx.w.len() {
        let src_idx = &ctx.index[t];
        let dst_idx = &ctx.index[t + 1];
        let wt = &ctx.w[t];
        let zprev = ctx.zptrs[t].get() as *const f32;
        let znext = ctx.zptrs[t + 1].get();
        if !ctx.bias[t].is_empty() {
            // Safety: disjoint columns [c0, c1) of a [sizes[t+1], b]
            // buffer.
            unsafe { init_bias_columns(&ctx.bias[t], znext, b, c0, c1) };
        }
        for p in 0..ctx.paths {
            let s = src_idx[p] as usize * b;
            let d = dst_idx[p] as usize * b;
            let w = wt[p];
            let mut bi = c0;
            while bi + BLOCK <= c1 {
                for k in 0..BLOCK {
                    unsafe {
                        *znext.add(d + bi + k) += w * (*zprev.add(s + bi + k)).max(0.0);
                    }
                }
                bi += BLOCK;
            }
            while bi < c1 {
                unsafe {
                    *znext.add(d + bi) += w * (*zprev.add(s + bi)).max(0.0);
                }
                bi += 1;
            }
        }
    }
}

/// Fallback backward: fixed 8-column blocks, scalar op order per
/// column (`gacc` accumulates left-to-right exactly as in the scalar
/// kernel).
fn bwd_blocked(ctx: &BwdCtx<'_>, c0: usize, c1: usize) {
    let b = ctx.batch;
    let t_cnt = ctx.w.len();
    let s_idx = c0 / ctx.shard_width;
    let tp = t_cnt * ctx.paths;
    // Safety: shard-exclusive shadow rows (see the scalar kernel).
    let gwb = unsafe { ctx.gw_shadow.get().add(s_idx * tp) };
    let gbb = unsafe { ctx.gb_shadow.get().add(s_idx * ctx.brow) };
    for t in (0..t_cnt).rev() {
        let gznext = ctx.gzptrs[t + 1].get() as *const f32;
        let gzprev = ctx.gzptrs[t].get();
        if !ctx.bias[t].is_empty() {
            unsafe { bias_row_sums(gznext, gbb, ctx.gb_off[t], ctx.sizes[t + 1], b, c0, c1) };
        }
        let src_idx = &ctx.index[t];
        let dst_idx = &ctx.index[t + 1];
        let wt = &ctx.w[t];
        let zprev = &ctx.z[t];
        for p in 0..ctx.paths {
            let sb = src_idx[p] as usize * b;
            let db = dst_idx[p] as usize * b;
            let w = wt[p];
            let mut gacc = 0.0f32;
            let mut bi = c0;
            while bi + BLOCK <= c1 {
                for k in 0..BLOCK {
                    let v = zprev[sb + bi + k];
                    let gate = if v > 0.0 { 1.0f32 } else { 0.0 };
                    let g = unsafe { *gznext.add(db + bi + k) } * gate;
                    gacc += g * v;
                    unsafe { *gzprev.add(sb + bi + k) += w * g };
                }
                bi += BLOCK;
            }
            while bi < c1 {
                let v = zprev[sb + bi];
                let gate = if v > 0.0 { 1.0f32 } else { 0.0 };
                let g = unsafe { *gznext.add(db + bi) } * gate;
                gacc += g * v;
                unsafe { *gzprev.add(sb + bi) += w * g };
                bi += 1;
            }
            unsafe { *gwb.add(t * ctx.paths + p) += gacc };
        }
    }
}

/// AVX2 forward: 8 columns per vector step, separate mul + add (no
/// FMA), scalar tail.
///
/// # Safety
/// Caller must have verified AVX2 support; pointer/range contract as
/// in the scalar kernel.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn fwd_avx2(ctx: &FwdCtx<'_>, c0: usize, c1: usize) {
    use std::arch::x86_64::*;
    let b = ctx.batch;
    let zero = _mm256_setzero_ps();
    for t in 0..ctx.w.len() {
        let src_idx = &ctx.index[t];
        let dst_idx = &ctx.index[t + 1];
        let wt = &ctx.w[t];
        let zprev = ctx.zptrs[t].get() as *const f32;
        let znext = ctx.zptrs[t + 1].get();
        if !ctx.bias[t].is_empty() {
            init_bias_columns(&ctx.bias[t], znext, b, c0, c1);
        }
        for p in 0..ctx.paths {
            let s = src_idx[p] as usize * b;
            let d = dst_idx[p] as usize * b;
            let w = wt[p];
            let wv = _mm256_set1_ps(w);
            let mut bi = c0;
            while bi + 8 <= c1 {
                let v = _mm256_loadu_ps(zprev.add(s + bi));
                let r = _mm256_max_ps(v, zero); // NaN → 0, like f32::max
                let acc = _mm256_loadu_ps(znext.add(d + bi) as *const f32);
                let acc = _mm256_add_ps(acc, _mm256_mul_ps(wv, r));
                _mm256_storeu_ps(znext.add(d + bi), acc);
                bi += 8;
            }
            while bi < c1 {
                *znext.add(d + bi) += w * (*zprev.add(s + bi)).max(0.0);
                bi += 1;
            }
        }
    }
}

/// AVX2 backward: the ReLU gate masks the gradient vector
/// (`g = gz & (v > 0)`), lane products are summed **in lane order**
/// into `gacc`, and `gz_prev += w·g` uses separate mul + add.
///
/// # Safety
/// Caller must have verified AVX2 support; pointer/range contract as
/// in the scalar kernel.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn bwd_avx2(ctx: &BwdCtx<'_>, c0: usize, c1: usize) {
    use std::arch::x86_64::*;
    let b = ctx.batch;
    let t_cnt = ctx.w.len();
    let s_idx = c0 / ctx.shard_width;
    let tp = t_cnt * ctx.paths;
    let gwb = ctx.gw_shadow.get().add(s_idx * tp);
    let gbb = ctx.gb_shadow.get().add(s_idx * ctx.brow);
    let zero = _mm256_setzero_ps();
    let mut lanes = [0.0f32; 8];
    for t in (0..t_cnt).rev() {
        let gznext = ctx.gzptrs[t + 1].get() as *const f32;
        let gzprev = ctx.gzptrs[t].get();
        if !ctx.bias[t].is_empty() {
            bias_row_sums(gznext, gbb, ctx.gb_off[t], ctx.sizes[t + 1], b, c0, c1);
        }
        let src_idx = &ctx.index[t];
        let dst_idx = &ctx.index[t + 1];
        let wt = &ctx.w[t];
        let zprev = &ctx.z[t];
        for p in 0..ctx.paths {
            let sb = src_idx[p] as usize * b;
            let db = dst_idx[p] as usize * b;
            let w = wt[p];
            let wv = _mm256_set1_ps(w);
            let mut gacc = 0.0f32;
            let mut bi = c0;
            while bi + 8 <= c1 {
                let v = _mm256_loadu_ps(zprev.as_ptr().add(sb + bi));
                let mask = _mm256_cmp_ps::<_CMP_GT_OQ>(v, zero);
                let g = _mm256_and_ps(_mm256_loadu_ps(gznext.add(db + bi)), mask);
                let prod = _mm256_mul_ps(g, v);
                _mm256_storeu_ps(lanes.as_mut_ptr(), prod);
                for &l in &lanes {
                    gacc += l;
                }
                let prev = _mm256_loadu_ps(gzprev.add(sb + bi) as *const f32);
                _mm256_storeu_ps(gzprev.add(sb + bi), _mm256_add_ps(prev, _mm256_mul_ps(wv, g)));
                bi += 8;
            }
            while bi < c1 {
                let v = zprev[sb + bi];
                let gate = if v > 0.0 { 1.0f32 } else { 0.0 };
                let g = *gznext.add(db + bi) * gate;
                gacc += g * v;
                *gzprev.add(sb + bi) += w * g;
                bi += 1;
            }
            *gwb.add(t * ctx.paths + p) += gacc;
        }
    }
}
