//! Pluggable compute kernels for the sparse forward/backward hot path.
//!
//! [`crate::nn::sparse::SparseMlp`] shards both passes over batch
//! columns (forward via `parallel_ranges`, backward via
//! `parallel_chunks` at a fixed shard width) and hands each column
//! range to a [`SparseKernel`].  The kernel owns only the *innermost*
//! per-transition/per-path loops; the shard partition, the shadow
//! merge order, and the scratch lifecycle stay in `sparse.rs`, so the
//! determinism contract — **bitwise identical results for every
//! `SOBOLNET_THREADS` setting** — is preserved per kernel by
//! construction, provided the kernel computes each column with a fixed
//! floating-point op order independent of `(c0, c1)` placement.
//!
//! Four implementations are selectable via
//! [`SparseMlpConfig::kernel`](crate::nn::sparse::SparseMlpConfig) /
//! the `SOBOLNET_KERNEL` environment variable /
//! [`EngineBuilder::kernel`](crate::engine::EngineBuilder::kernel):
//!
//! | kernel   | idea | vs [`Scalar`](KernelKind::Scalar) |
//! |----------|------|-----------------------------------|
//! | `scalar` | the pre-refactor loops, extracted verbatim | bitwise (it *is* the golden reference) |
//! | `simd`   | 8-column blocks; AVX2 intrinsics on x86_64 (runtime-detected), blocked scalar elsewhere | bitwise by IEEE-754 analysis, pinned ≤ 1e-6 |
//! | `sign`   | fixed-sign nets: multiply collapses to gated add/sub over a magnitude-free block representation | bitwise |
//! | `int8`   | per-transition symmetric int8 weights, f32 accumulate | quantization tolerance (≈ `amax/254` per weight) |
//!
//! Every kernel keeps the **zero-alloc steady state**: derived weight
//! representations ([`KernelScratch`]) are rebuilt each pass into
//! capacity-retaining buffers (`tests/alloc_hotpath.rs` runs its
//! counting-allocator audit under all four kernels).

use crate::util::parallel::SendPtr;

mod int8;
mod scalar;
mod sign;
mod simd;

pub use int8::Int8Kernel;
pub use scalar::ScalarKernel;
pub use sign::SignKernel;
pub use simd::SimdKernel;

/// Which [`SparseKernel`] a model runs its hot loops through.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelKind {
    /// Resolve from the `SOBOLNET_KERNEL` environment variable at
    /// model build time; unset or unrecognized falls back to
    /// [`Scalar`](KernelKind::Scalar) (the golden reference — default
    /// output bits never change behind the operator's back).
    #[default]
    Auto,
    /// The pre-refactor per-path loops, verbatim: the bitwise-golden
    /// reference every other kernel is tested against.
    Scalar,
    /// Explicitly blocked 8-column loops; AVX2 intrinsics on x86_64
    /// when the CPU has them (runtime-detected), blocked scalar
    /// otherwise.  No FMA and in-order lane reduction keep it bitwise
    /// equal to `scalar`.
    Simd,
    /// Sign-only kernel for `freeze_signs` nets: weights split into a
    /// magnitude block and packed sign bits, the multiply collapses to
    /// a gated add/sub.  Falls back to `scalar` on nets without fixed
    /// signs.
    Sign,
    /// Weights quantized to int8 per transition (symmetric scale
    /// `amax/127`, f32 accumulate) via [`crate::quantize::int8`].
    Int8,
}

impl KernelKind {
    /// The four concrete kernels, in bench/report order.
    pub const ALL: [KernelKind; 4] =
        [KernelKind::Scalar, KernelKind::Simd, KernelKind::Sign, KernelKind::Int8];

    /// Parse a CLI/env/config spelling; `None` if unrecognized.
    pub fn parse(s: &str) -> Option<KernelKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(KernelKind::Auto),
            "scalar" => Some(KernelKind::Scalar),
            "simd" => Some(KernelKind::Simd),
            "sign" => Some(KernelKind::Sign),
            "int8" => Some(KernelKind::Int8),
            _ => None,
        }
    }

    /// Canonical spelling (round-trips through [`KernelKind::parse`]).
    pub fn as_str(self) -> &'static str {
        match self {
            KernelKind::Auto => "auto",
            KernelKind::Scalar => "scalar",
            KernelKind::Simd => "simd",
            KernelKind::Sign => "sign",
            KernelKind::Int8 => "int8",
        }
    }

    /// Resolve [`Auto`](KernelKind::Auto) via the `SOBOLNET_KERNEL`
    /// environment variable (unset, empty, or unrecognized → `Scalar`;
    /// a concrete kind passes through).  Reads the environment — call
    /// at model build time, never per pass (the hot path must not
    /// allocate, and `std::env::var` does).
    pub fn resolve(self) -> KernelKind {
        if self != KernelKind::Auto {
            return self;
        }
        match std::env::var("SOBOLNET_KERNEL") {
            Ok(v) => match KernelKind::parse(&v) {
                Some(KernelKind::Auto) | None => KernelKind::Scalar,
                Some(k) => k,
            },
            Err(_) => KernelKind::Scalar,
        }
    }

    /// The kind that will actually run for a model:
    /// [`KernelKind::Sign`] requires frozen signs and downgrades to
    /// `Scalar` otherwise; a stray `Auto` (defensive — models store
    /// resolved kinds) is treated as `Scalar`.
    pub fn effective(self, has_fixed_signs: bool) -> KernelKind {
        match self {
            KernelKind::Auto => KernelKind::Scalar,
            KernelKind::Sign if !has_fixed_signs => KernelKind::Scalar,
            k => k,
        }
    }

    /// The kernel implementation for this kind (`Auto` → scalar;
    /// callers resolve first).
    pub fn instance(self) -> &'static dyn SparseKernel {
        match self {
            KernelKind::Auto | KernelKind::Scalar => &ScalarKernel,
            KernelKind::Simd => &SimdKernel,
            KernelKind::Sign => &SignKernel,
            KernelKind::Int8 => &Int8Kernel,
        }
    }
}

/// Per-model derived weight representations, rebuilt by
/// [`SparseKernel::prepare`] each pass into capacity-retaining buffers
/// (no allocation at steady state).  Unused fields stay empty for
/// kernels that don't need them.
#[derive(Debug, Default)]
pub struct KernelScratch {
    /// `int8`: per-transition quantized weights.
    pub qw: Vec<Vec<i8>>,
    /// `int8`: per-transition symmetric dequantization scale.
    pub qscale: Vec<f32>,
    /// `sign`: per-transition weight magnitudes `|w[t][p]|`.
    pub mags: Vec<Vec<f32>>,
    /// `sign`: per-transition packed sign bits, bit `p` set iff
    /// `w[t][p]` has a negative sign bit.
    pub neg: Vec<Vec<u64>>,
    /// `sign`: the uniform magnitude of transition `t` when every
    /// `|w[t][p]|` shares one bit pattern (the magnitude-free block
    /// representation — true for `ConstantSignAlongPath` at init);
    /// `None` once training has diversified the magnitudes.
    pub uniform: Vec<Option<f32>>,
}

/// Everything a kernel needs to run the forward loops for a column
/// range.  All fields borrow model state that outlives the fan-out;
/// `zptrs` alias per-layer activation buffers whose column ranges are
/// disjoint across concurrent calls.
pub struct FwdCtx<'a> {
    /// Per-layer activation buffer base pointers (`[sizes[l], B]`).
    pub zptrs: &'a [SendPtr<f32>],
    /// Per-layer path→neuron index (`index[l][p]`).
    pub index: &'a [Vec<u32>],
    /// Path weights `w[t][p]`.
    pub w: &'a [Vec<f32>],
    /// Per-transition biases of layer `t+1` (empty when disabled).
    pub bias: &'a [Vec<f32>],
    /// Batch size (columns per neuron row).
    pub batch: usize,
    /// Paths per transition.
    pub paths: usize,
    /// Derived weight representations from [`SparseKernel::prepare`].
    pub scratch: &'a KernelScratch,
}

/// Everything a kernel needs to run the backward loops for one fixed
/// column shard `[c0, c1)`.  Cross-column reductions go to the shard's
/// slice of the shadow accumulators (`gw_shadow`/`gb_shadow`), which
/// `sparse.rs` merges in fixed shard order afterwards.
pub struct BwdCtx<'a> {
    /// Per-layer gradient buffer base pointers (`[sizes[l], B]`).
    pub gzptrs: &'a [SendPtr<f32>],
    /// Per-layer cached forward activations (`[sizes[l], B]`).
    pub z: &'a [Vec<f32>],
    /// Per-layer path→neuron index.
    pub index: &'a [Vec<u32>],
    /// Path weights `w[t][p]`.
    pub w: &'a [Vec<f32>],
    /// Per-transition biases (empty when disabled).
    pub bias: &'a [Vec<f32>],
    /// Layer sizes (`layer_sizes[l]` neurons in layer `l`).
    pub sizes: &'a [usize],
    /// Offset of transition `t`'s bias segment inside one `gb` shadow
    /// row.
    pub gb_off: &'a [usize],
    /// Base of the per-shard `gw` shadows, `[shards][T·P]` flat.
    pub gw_shadow: SendPtr<f32>,
    /// Base of the per-shard `gb` shadows, `[shards][Σ sizes[1..]]`
    /// flat.
    pub gb_shadow: SendPtr<f32>,
    /// Fixed shard width in columns (`bwd_shard_width(b)`); shard
    /// index = `c0 / shard_width`.
    pub shard_width: usize,
    /// Length of one `gb` shadow row (`Σ sizes[1..]`).
    pub brow: usize,
    /// Batch size.
    pub batch: usize,
    /// Paths per transition.
    pub paths: usize,
    /// Derived weight representations from [`SparseKernel::prepare`].
    pub scratch: &'a KernelScratch,
}

/// One hot-path implementation.  `forward_columns` and
/// `backward_shard` are called concurrently for disjoint column
/// ranges; each must compute every column with a floating-point op
/// order that depends only on the column index — never on `(c0, c1)`
/// placement — so results stay bitwise thread-invariant.
pub trait SparseKernel: Send + Sync {
    /// This kernel's kind (for labels and dispatch assertions).
    fn kind(&self) -> KernelKind;

    /// Rebuild derived weight representations into `scratch`.  Called
    /// once at the top of each forward *and* backward (weights may
    /// have stepped in between); must be allocation-free once the
    /// buffers are warm.
    fn prepare(&self, w: &[Vec<f32>], scratch: &mut KernelScratch) {
        let _ = (w, scratch);
    }

    /// Run the whole multi-transition forward loop for columns
    /// `[c0, c1)` of every layer buffer.
    fn forward_columns(&self, ctx: &FwdCtx<'_>, c0: usize, c1: usize);

    /// Run the whole reversed multi-transition backward loop for the
    /// fixed shard `[c0, c1)`.
    fn backward_shard(&self, ctx: &BwdCtx<'_>, c0: usize, c1: usize);
}

/// Forward bias seeding for columns `[c0, c1)` of layer `t+1`
/// (extracted verbatim from the pre-kernel forward; shared by every
/// kernel).
///
/// # Safety
/// `znext` must point to a `[rows, b]` buffer with `bias.len() ≤ rows`
/// and `c1 ≤ b`, and no concurrent access to these columns.
#[inline]
pub(crate) unsafe fn init_bias_columns(
    bias: &[f32],
    znext: *mut f32,
    b: usize,
    c0: usize,
    c1: usize,
) {
    for (i, &bv) in bias.iter().enumerate() {
        for bi in c0..c1 {
            *znext.add(i * b + bi) = bv;
        }
    }
}

/// Backward bias-gradient row sums for one shard (extracted verbatim
/// from the pre-kernel backward; shared by every kernel):
/// `gbb[off + i] += Σ_{bi ∈ [c0, c1)} gznext[i·b + bi]`.
///
/// # Safety
/// `gznext` must point to an `[n, b]` buffer with `c1 ≤ b`; `gbb` to a
/// shadow row with `off + n` elements owned by this shard.
#[inline]
pub(crate) unsafe fn bias_row_sums(
    gznext: *const f32,
    gbb: *mut f32,
    off: usize,
    n: usize,
    b: usize,
    c0: usize,
    c1: usize,
) {
    for i in 0..n {
        let mut s = 0.0f32;
        for bi in c0..c1 {
            s += *gznext.add(i * b + bi);
        }
        *gbb.add(off + i) += s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_canonical_spellings() {
        for k in [
            KernelKind::Auto,
            KernelKind::Scalar,
            KernelKind::Simd,
            KernelKind::Sign,
            KernelKind::Int8,
        ] {
            assert_eq!(KernelKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(KernelKind::parse(" SIMD "), Some(KernelKind::Simd));
        assert_eq!(KernelKind::parse("avx512"), None);
        assert_eq!(KernelKind::parse(""), None);
    }

    #[test]
    fn effective_downgrades_sign_without_frozen_signs() {
        assert_eq!(KernelKind::Sign.effective(false), KernelKind::Scalar);
        assert_eq!(KernelKind::Sign.effective(true), KernelKind::Sign);
        assert_eq!(KernelKind::Auto.effective(true), KernelKind::Scalar);
        assert_eq!(KernelKind::Int8.effective(false), KernelKind::Int8);
    }

    #[test]
    fn concrete_kinds_resolve_to_themselves() {
        for k in KernelKind::ALL {
            assert_eq!(k.resolve(), k);
            assert_eq!(k.instance().kind(), k);
        }
    }
}
