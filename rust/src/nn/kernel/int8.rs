//! Int8 weight kernel: weights stream as one byte per path, activations
//! and accumulation stay f32.
//!
//! [`SparseKernel::prepare`] re-quantizes each transition through
//! [`crate::quantize::int8`] (symmetric per-transition scale
//! `amax/127`) into reused [`KernelScratch`] buffers — weights change
//! every optimizer step, so the codes are rebuilt per pass,
//! allocation-free once warm.  The column loops are the scalar
//! kernel's with one substitution: the path weight is
//! `dequant(qw[t][p], scale[t])`, computed once per column run.
//!
//! **Contract.**  Dequantization is exact in f32, so this kernel is
//! **bitwise identical** to the scalar kernel running on the
//! round-tripped weights ([`crate::quantize::int8::dequantized`]) —
//! and therefore bitwise thread-invariant — while the deviation from
//! the full-precision net is bounded by the quantization step
//! (per-weight error ≤ `amax/254`; `tests/kernel_golden.rs` states
//! and pins both tolerances).

use super::{
    bias_row_sums, init_bias_columns, BwdCtx, FwdCtx, KernelKind, KernelScratch, SparseKernel,
};
use crate::quantize::int8;

/// See the [module docs](self).
pub struct Int8Kernel;

impl SparseKernel for Int8Kernel {
    fn kind(&self) -> KernelKind {
        KernelKind::Int8
    }

    fn prepare(&self, w: &[Vec<f32>], scratch: &mut KernelScratch) {
        let t_cnt = w.len();
        if scratch.qw.len() != t_cnt {
            scratch.qw.resize_with(t_cnt, Vec::new);
        }
        scratch.qscale.clear();
        for (t, wt) in w.iter().enumerate() {
            let scale = int8::scale_for(int8::amax(wt));
            int8::quantize_into(wt, scale, &mut scratch.qw[t]);
            scratch.qscale.push(scale);
        }
    }

    fn forward_columns(&self, ctx: &FwdCtx<'_>, c0: usize, c1: usize) {
        let b = ctx.batch;
        for t in 0..ctx.w.len() {
            let src_idx = &ctx.index[t];
            let dst_idx = &ctx.index[t + 1];
            let qwt = &ctx.scratch.qw[t];
            let scale = ctx.scratch.qscale[t];
            let zprev = ctx.zptrs[t].get() as *const f32;
            let znext = ctx.zptrs[t + 1].get();
            if !ctx.bias[t].is_empty() {
                // Safety: disjoint columns of a [sizes[t+1], b] buffer.
                unsafe { init_bias_columns(&ctx.bias[t], znext, b, c0, c1) };
            }
            for p in 0..ctx.paths {
                let s = src_idx[p] as usize * b;
                let d = dst_idx[p] as usize * b;
                let w = int8::dequant(qwt[p], scale);
                for bi in c0..c1 {
                    unsafe {
                        *znext.add(d + bi) += w * (*zprev.add(s + bi)).max(0.0);
                    }
                }
            }
        }
    }

    fn backward_shard(&self, ctx: &BwdCtx<'_>, c0: usize, c1: usize) {
        let b = ctx.batch;
        let t_cnt = ctx.w.len();
        let s_idx = c0 / ctx.shard_width;
        let tp = t_cnt * ctx.paths;
        // Safety: shard-exclusive shadow rows (see the scalar kernel).
        let gwb = unsafe { ctx.gw_shadow.get().add(s_idx * tp) };
        let gbb = unsafe { ctx.gb_shadow.get().add(s_idx * ctx.brow) };
        for t in (0..t_cnt).rev() {
            let gznext = ctx.gzptrs[t + 1].get() as *const f32;
            let gzprev = ctx.gzptrs[t].get();
            if !ctx.bias[t].is_empty() {
                unsafe { bias_row_sums(gznext, gbb, ctx.gb_off[t], ctx.sizes[t + 1], b, c0, c1) };
            }
            let src_idx = &ctx.index[t];
            let dst_idx = &ctx.index[t + 1];
            let qwt = &ctx.scratch.qw[t];
            let scale = ctx.scratch.qscale[t];
            let zprev = &ctx.z[t];
            for p in 0..ctx.paths {
                let sb = src_idx[p] as usize * b;
                let db = dst_idx[p] as usize * b;
                let w = int8::dequant(qwt[p], scale);
                let mut gacc = 0.0f32;
                for bi in c0..c1 {
                    let v = zprev[sb + bi];
                    let gate = if v > 0.0 { 1.0f32 } else { 0.0 };
                    let g = unsafe { *gznext.add(db + bi) } * gate;
                    gacc += g * v;
                    unsafe { *gzprev.add(sb + bi) += w * g };
                }
                unsafe { *gwb.add(t * ctx.paths + p) += gacc };
            }
        }
    }
}
