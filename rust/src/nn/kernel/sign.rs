//! Sign-only kernel for `freeze_signs` nets (paper §3.2 / §4.4).
//!
//! With frozen signs the weight of path `p` is `±|w[p]|`, so
//! [`SparseKernel::prepare`] splits each transition into a
//! **magnitude-free block representation**: packed sign bits (one
//! `u64` word per 64 paths) plus either a single broadcast magnitude —
//! when every `|w[t][p]|` shares one bit pattern, as
//! `ConstantSignAlongPath` init guarantees — or a per-path magnitude
//! block once training has diversified them.  The inner multiply then
//! collapses to a gated add/sub: `acc ± mag·max(v, 0)`.
//!
//! **Bitwise contract.**  IEEE-754 negation is exact:
//! `(-m)·r == -(m·r)` bit-for-bit, and `acc -= x` is the same
//! operation as `acc += (-x)`.  Signs are derived from the *weight
//! bits* (`is_sign_negative`), and magnitudes as `|w|`, so
//! `±mag ≡ w` exactly and every column reproduces the scalar kernel's
//! rounding sequence — the kernel is bitwise equal to `scalar`
//! (pinned by `tests/kernel_golden.rs`), not merely close.
//!
//! On a net without frozen signs [`KernelKind::effective`] downgrades
//! this kernel to `scalar` before dispatch; it never runs there.

use super::{
    bias_row_sums, init_bias_columns, BwdCtx, FwdCtx, KernelKind, KernelScratch, SparseKernel,
};

/// See the [module docs](self).
pub struct SignKernel;

/// True iff bit `p` of the packed sign words is set (weight negative).
#[inline(always)]
fn neg_bit(neg: &[u64], p: usize) -> bool {
    (neg[p >> 6] >> (p & 63)) & 1 == 1
}

/// Forward column run for one path: `znext[d + bi] ±= m·max(v, 0)`.
///
/// # Safety
/// Same pointer/range contract as the scalar kernel's inner loop.
#[inline(always)]
unsafe fn fwd_columns_one_path(
    znext: *mut f32,
    zprev: *const f32,
    d: usize,
    s: usize,
    m: f32,
    neg: bool,
    c0: usize,
    c1: usize,
) {
    if neg {
        for bi in c0..c1 {
            *znext.add(d + bi) -= m * (*zprev.add(s + bi)).max(0.0);
        }
    } else {
        for bi in c0..c1 {
            *znext.add(d + bi) += m * (*zprev.add(s + bi)).max(0.0);
        }
    }
}

impl SparseKernel for SignKernel {
    fn kind(&self) -> KernelKind {
        KernelKind::Sign
    }

    fn prepare(&self, w: &[Vec<f32>], scratch: &mut KernelScratch) {
        let t_cnt = w.len();
        if scratch.mags.len() != t_cnt {
            scratch.mags.resize_with(t_cnt, Vec::new);
        }
        if scratch.neg.len() != t_cnt {
            scratch.neg.resize_with(t_cnt, Vec::new);
        }
        scratch.uniform.clear();
        for (t, wt) in w.iter().enumerate() {
            let paths = wt.len();
            let words = paths.div_ceil(64);
            let negt = &mut scratch.neg[t];
            negt.clear();
            negt.resize(words, 0);
            // magnitudes are always materialized (cheap, and keeps the
            // steady state allocation-free even if a transition drifts
            // between the uniform and per-path tiers mid-training)
            let magt = &mut scratch.mags[t];
            magt.clear();
            magt.resize(paths, 0.0);
            let mut uni_bits = wt.first().map(|v| v.abs().to_bits());
            for (p, &wv) in wt.iter().enumerate() {
                let a = wv.abs();
                magt[p] = a;
                if wv.is_sign_negative() {
                    negt[p >> 6] |= 1u64 << (p & 63);
                }
                if uni_bits != Some(a.to_bits()) {
                    uni_bits = None;
                }
            }
            scratch.uniform.push(uni_bits.map(f32::from_bits));
        }
    }

    fn forward_columns(&self, ctx: &FwdCtx<'_>, c0: usize, c1: usize) {
        let b = ctx.batch;
        for t in 0..ctx.w.len() {
            let src_idx = &ctx.index[t];
            let dst_idx = &ctx.index[t + 1];
            let zprev = ctx.zptrs[t].get() as *const f32;
            let znext = ctx.zptrs[t + 1].get();
            if !ctx.bias[t].is_empty() {
                // Safety: disjoint columns of a [sizes[t+1], b] buffer.
                unsafe { init_bias_columns(&ctx.bias[t], znext, b, c0, c1) };
            }
            let negt = &ctx.scratch.neg[t];
            let magt = &ctx.scratch.mags[t];
            let uni = ctx.scratch.uniform[t];
            for p in 0..ctx.paths {
                let s = src_idx[p] as usize * b;
                let d = dst_idx[p] as usize * b;
                let m = match uni {
                    Some(mu) => mu,
                    None => magt[p],
                };
                // Safety: as in the scalar kernel.
                unsafe { fwd_columns_one_path(znext, zprev, d, s, m, neg_bit(negt, p), c0, c1) };
            }
        }
    }

    fn backward_shard(&self, ctx: &BwdCtx<'_>, c0: usize, c1: usize) {
        let b = ctx.batch;
        let t_cnt = ctx.w.len();
        let s_idx = c0 / ctx.shard_width;
        let tp = t_cnt * ctx.paths;
        // Safety: shard-exclusive shadow rows (see the scalar kernel).
        let gwb = unsafe { ctx.gw_shadow.get().add(s_idx * tp) };
        let gbb = unsafe { ctx.gb_shadow.get().add(s_idx * ctx.brow) };
        for t in (0..t_cnt).rev() {
            let gznext = ctx.gzptrs[t + 1].get() as *const f32;
            let gzprev = ctx.gzptrs[t].get();
            if !ctx.bias[t].is_empty() {
                unsafe { bias_row_sums(gznext, gbb, ctx.gb_off[t], ctx.sizes[t + 1], b, c0, c1) };
            }
            let src_idx = &ctx.index[t];
            let dst_idx = &ctx.index[t + 1];
            let zprev = &ctx.z[t];
            let negt = &ctx.scratch.neg[t];
            let magt = &ctx.scratch.mags[t];
            let uni = ctx.scratch.uniform[t];
            for p in 0..ctx.paths {
                let sb = src_idx[p] as usize * b;
                let db = dst_idx[p] as usize * b;
                let m = match uni {
                    Some(mu) => mu,
                    None => magt[p],
                };
                let neg = neg_bit(negt, p);
                let mut gacc = 0.0f32;
                // `gacc` (the ∂loss/∂w of the *signed* weight) is
                // weight-free — identical to the scalar loop; only the
                // gz_prev update gets the add/sub collapse.
                if neg {
                    for bi in c0..c1 {
                        let v = zprev[sb + bi];
                        let gate = if v > 0.0 { 1.0f32 } else { 0.0 };
                        let g = unsafe { *gznext.add(db + bi) } * gate;
                        gacc += g * v;
                        unsafe { *gzprev.add(sb + bi) -= m * g };
                    }
                } else {
                    for bi in c0..c1 {
                        let v = zprev[sb + bi];
                        let gate = if v > 0.0 { 1.0f32 } else { 0.0 };
                        let g = unsafe { *gznext.add(db + bi) } * gate;
                        gacc += g * v;
                        unsafe { *gzprev.add(sb + bi) += m * g };
                    }
                }
                unsafe { *gwb.add(t * ctx.paths + p) += gacc };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_splits_weights_into_signs_and_magnitudes() {
        let w = vec![vec![0.5f32, -0.5, 0.5, -0.5], vec![0.25, 0.75, -0.125]];
        let mut scratch = KernelScratch::default();
        SignKernel.prepare(&w, &mut scratch);
        // transition 0: uniform magnitude tier
        assert_eq!(scratch.uniform[0], Some(0.5));
        assert_eq!(scratch.mags[0], vec![0.5; 4]);
        assert!(!neg_bit(&scratch.neg[0], 0));
        assert!(neg_bit(&scratch.neg[0], 1));
        assert!(neg_bit(&scratch.neg[0], 3));
        // transition 1: per-path tier
        assert_eq!(scratch.uniform[1], None);
        assert_eq!(scratch.mags[1], vec![0.25, 0.75, 0.125]);
        assert!(neg_bit(&scratch.neg[1], 2));
        // reconstruction is exact: ±mag == w bit-for-bit
        for (t, wt) in w.iter().enumerate() {
            for (p, &wv) in wt.iter().enumerate() {
                let m = scratch.mags[t][p];
                let rec = if neg_bit(&scratch.neg[t], p) { -m } else { m };
                assert_eq!(rec.to_bits(), wv.to_bits());
            }
        }
    }

    #[test]
    fn prepare_reuses_capacity() {
        let w = vec![vec![1.0f32; 100], vec![-2.0f32; 100]];
        let mut scratch = KernelScratch::default();
        SignKernel.prepare(&w, &mut scratch);
        let caps: Vec<usize> = scratch.mags.iter().map(|m| m.capacity()).collect();
        for _ in 0..3 {
            SignKernel.prepare(&w, &mut scratch);
        }
        assert_eq!(caps, scratch.mags.iter().map(|m| m.capacity()).collect::<Vec<_>>());
    }
}
