//! The bitwise-golden scalar kernel.
//!
//! These are the pre-refactor per-path loops from `sparse.rs`,
//! extracted **verbatim**: same traversal order, same branchless
//! ReLU gating, same floating-point op order per column.  Every other
//! kernel is tested against this one (`tests/kernel_golden.rs`), and
//! the existing golden fixtures (`tests/golden_{forward,backward}.rs`)
//! pin that the extraction itself changed no bits.

use super::{bias_row_sums, init_bias_columns, BwdCtx, FwdCtx, KernelKind, SparseKernel};

/// See the [module docs](self).
pub struct ScalarKernel;

impl SparseKernel for ScalarKernel {
    fn kind(&self) -> KernelKind {
        KernelKind::Scalar
    }

    fn forward_columns(&self, ctx: &FwdCtx<'_>, c0: usize, c1: usize) {
        let b = ctx.batch;
        for t in 0..ctx.w.len() {
            let src_idx = &ctx.index[t];
            let dst_idx = &ctx.index[t + 1];
            let wt = &ctx.w[t];
            let zprev = ctx.zptrs[t].get() as *const f32;
            let znext = ctx.zptrs[t + 1].get();
            if !ctx.bias[t].is_empty() {
                // Safety: layer buffers are [sizes[t+1], b]; columns
                // [c0, c1) are exclusively this call's.
                unsafe { init_bias_columns(&ctx.bias[t], znext, b, c0, c1) };
            }
            for p in 0..ctx.paths {
                let s = src_idx[p] as usize * b;
                let d = dst_idx[p] as usize * b;
                let w = wt[p];
                // branchless ReLU gate: w·max(v,0) — vectorizes
                // cleanly (EXPERIMENTS.md §Perf)
                for bi in c0..c1 {
                    unsafe {
                        *znext.add(d + bi) += w * (*zprev.add(s + bi)).max(0.0);
                    }
                }
            }
        }
    }

    fn backward_shard(&self, ctx: &BwdCtx<'_>, c0: usize, c1: usize) {
        let b = ctx.batch;
        let t_cnt = ctx.w.len();
        let s_idx = c0 / ctx.shard_width;
        let tp = t_cnt * ctx.paths;
        // Safety: shard s_idx owns shadow rows [s_idx·tp, (s_idx+1)·tp)
        // and [s_idx·brow, (s_idx+1)·brow) exclusively.
        let gwb = unsafe { ctx.gw_shadow.get().add(s_idx * tp) };
        let gbb = unsafe { ctx.gb_shadow.get().add(s_idx * ctx.brow) };
        for t in (0..t_cnt).rev() {
            let gznext = ctx.gzptrs[t + 1].get() as *const f32;
            let gzprev = ctx.gzptrs[t].get();
            // bias gradients: per-shard row sums of gz (layer t+1)
            if !ctx.bias[t].is_empty() {
                unsafe {
                    bias_row_sums(gznext, gbb, ctx.gb_off[t], ctx.sizes[t + 1], b, c0, c1)
                };
            }
            let src_idx = &ctx.index[t];
            let dst_idx = &ctx.index[t + 1];
            let wt = &ctx.w[t];
            let zprev = &ctx.z[t];
            for p in 0..ctx.paths {
                let sb = src_idx[p] as usize * b;
                let db = dst_idx[p] as usize * b;
                let w = wt[p];
                let mut gacc = 0.0f32;
                // branchless gating: the (v > 0) indicator multiplies
                // both products, letting LLVM vectorize the loop
                for bi in c0..c1 {
                    let v = zprev[sb + bi];
                    let gate = if v > 0.0 { 1.0f32 } else { 0.0 };
                    let g = unsafe { *gznext.add(db + bi) } * gate;
                    gacc += g * v;
                    unsafe { *gzprev.add(sb + bi) += w * g };
                }
                unsafe { *gwb.add(t * ctx.paths + p) += gacc };
            }
        }
    }
}
