//! Training loop: epochs, mini-batch sampling (shuffled or
//! low-discrepancy), learning-rate schedule, optional augmentation,
//! and per-epoch evaluation — the shared driver of every experiment
//! bench.

use super::loss::{accuracy, softmax_xent_into};
use super::optim::{LrSchedule, Sgd};
use super::tensor::Tensor;
use super::Model;
use crate::data::{augment, ClassificationData};
use crate::log_debug;
use crate::qmc::{Sequence, SequenceFamily};
use crate::rng::Pcg32;
use crate::util::timer::Timer;

/// How the training loop orders samples within each epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BatchSampler {
    /// Fisher–Yates shuffle per epoch, seeded from
    /// `TrainConfig::seed` and the epoch index — the historical
    /// behavior and the default.
    #[default]
    Shuffled,
    /// Low-discrepancy index stream over the family's 1-D sequence:
    /// epoch `e` of an `n`-sample set draws sample `k` as
    /// `seq.map_to(e·n + k, 0, n)`.  Within one epoch this samples
    /// with replacement, but consecutive draws are stratified — each
    /// prefix of the stream covers the index range near-uniformly, so
    /// successive mini-batches overlap less than independent uniform
    /// draws would.
    Lds(SequenceFamily),
}

/// Training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Number of epochs.
    pub epochs: usize,
    /// Mini-batch size.
    pub batch_size: usize,
    /// Learning-rate schedule.
    pub schedule: LrSchedule,
    /// Momentum.
    pub momentum: f32,
    /// Weight decay.
    pub weight_decay: f32,
    /// Shuffling/augmentation seed.
    pub seed: u64,
    /// Apply flip + pad-crop augmentation (CNN inputs only).
    pub augment: bool,
    /// Padding for the crop augmentation.
    pub augment_pad: usize,
    /// Within-epoch sample ordering.
    pub sampler: BatchSampler,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 10,
            batch_size: 64,
            schedule: LrSchedule::paper_default(),
            momentum: 0.9,
            weight_decay: 1e-4,
            seed: 0,
            augment: false,
            augment_pad: 4,
            sampler: BatchSampler::Shuffled,
        }
    }
}

/// Per-epoch training history.
#[derive(Debug, Clone, Default)]
pub struct History {
    /// Mean training loss per epoch.
    pub train_loss: Vec<f32>,
    /// Test accuracy per epoch.
    pub test_acc: Vec<f64>,
    /// Test loss per epoch.
    pub test_loss: Vec<f32>,
    /// Wall-clock seconds of the whole run.
    pub wall_secs: f64,
}

impl History {
    /// Final test accuracy (0 if never evaluated).
    pub fn final_acc(&self) -> f64 {
        self.test_acc.last().copied().unwrap_or(0.0)
    }

    /// Best test accuracy across epochs (paper reports best of weight
    /// decay sweeps; we use best-epoch within a run).
    pub fn best_acc(&self) -> f64 {
        self.test_acc.iter().cloned().fold(0.0, f64::max)
    }

    /// Final test loss.
    pub fn final_loss(&self) -> f32 {
        self.test_loss.last().copied().unwrap_or(f32::NAN)
    }
}

/// Evaluate mean loss and accuracy over a dataset.
pub fn evaluate(model: &mut dyn Model, data: &ClassificationData, batch_size: usize) -> (f32, f64) {
    evaluate_into(model, data, batch_size, &mut Vec::new())
}

/// [`evaluate`] with a caller-held index scratch: the training loop
/// reuses one Vec across its per-epoch evaluations instead of
/// allocating `len` indices each time.
pub fn evaluate_into(
    model: &mut dyn Model,
    data: &ClassificationData,
    batch_size: usize,
    order: &mut Vec<usize>,
) -> (f32, f64) {
    order.clear();
    order.extend(0..data.len());
    let mut loss_sum = 0.0f64;
    let mut acc_sum = 0.0f64;
    let mut n = 0usize;
    // reused across batches (models with scratch allocate nothing here)
    let mut logits = Tensor::empty();
    let mut glogits = Tensor::empty();
    for (x, y) in data.batches(&order, batch_size) {
        model.forward_into(&x, false, &mut logits);
        let loss = softmax_xent_into(&logits, &y, &mut glogits);
        loss_sum += loss as f64 * y.len() as f64;
        acc_sum += accuracy(&logits, &y) * y.len() as f64;
        n += y.len();
    }
    ((loss_sum / n as f64) as f32, acc_sum / n as f64)
}

/// Train `model` on `train`, evaluating on `test` after every epoch.
pub fn train(
    model: &mut dyn Model,
    train: &ClassificationData,
    test: &ClassificationData,
    cfg: &TrainConfig,
) -> History {
    let timer = Timer::start();
    let mut hist = History::default();
    let mut aug_rng = Pcg32::seeded(cfg.seed ^ 0xAA99);
    // logits/gradient tensors and both index buffers are reused across
    // every step and epoch: together with the model-held scratch this
    // makes the steady-state epoch loop allocation-free apart from
    // batch assembly
    let mut logits = Tensor::empty();
    let mut glogits = Tensor::empty();
    let mut order: Vec<usize> = Vec::with_capacity(train.len());
    let mut eval_order: Vec<usize> = Vec::new();
    let lds_seq = match &cfg.sampler {
        BatchSampler::Shuffled => None,
        BatchSampler::Lds(fam) => Some(fam.build(1)),
    };
    for epoch in 0..cfg.epochs {
        let opt = Sgd {
            lr: cfg.schedule.lr_at(epoch, cfg.epochs),
            momentum: cfg.momentum,
            weight_decay: cfg.weight_decay,
        };
        match &lds_seq {
            None => train.epoch_order_into(cfg.seed ^ (epoch as u64) << 7, &mut order),
            Some(seq) => {
                // one continuous low-discrepancy stream across epochs:
                // epoch boundaries do not restart the sequence
                let n = train.len();
                let base = (epoch * n) as u64;
                order.clear();
                order.extend((0..n).map(|k| seq.map_to(base + k as u64, 0, n)));
            }
        }
        let mut loss_sum = 0.0f64;
        let mut n = 0usize;
        for (mut x, y) in train.batches(&order, cfg.batch_size) {
            if cfg.augment {
                augment_if_image(&mut x, cfg.augment_pad, &mut aug_rng);
            }
            model.forward_into(&x, true, &mut logits);
            let loss = softmax_xent_into(&logits, &y, &mut glogits);
            model.backward(&glogits);
            model.step(&opt);
            loss_sum += loss as f64 * y.len() as f64;
            n += y.len();
        }
        let train_loss = (loss_sum / n as f64) as f32;
        let (test_loss, test_acc) =
            evaluate_into(model, test, cfg.batch_size.max(128), &mut eval_order);
        log_debug!(
            "epoch {epoch}: lr={:.4} train_loss={train_loss:.4} test_loss={test_loss:.4} acc={test_acc:.4}",
            opt.lr
        );
        hist.train_loss.push(train_loss);
        hist.test_loss.push(test_loss);
        hist.test_acc.push(test_acc);
    }
    hist.wall_secs = timer.elapsed_secs();
    hist
}

fn augment_if_image(x: &mut Tensor, pad: usize, rng: &mut Pcg32) {
    if x.shape.len() == 4 {
        augment::augment_batch(x, pad, rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{SynthConfig, SynthMnist};
    use crate::nn::init::Init;
    use crate::nn::mlp::DenseMlp;
    use crate::nn::sparse::{SparseMlp, SparseMlpConfig};
    use crate::topology::{PathSource, TopologyBuilder};

    #[test]
    fn dense_mlp_learns_synth_mnist() {
        let (tr, te) = SynthMnist::new(512, 256, 7);
        let mut mlp = DenseMlp::new(&[784, 64, 10], Init::UniformRandom, 1);
        let cfg = TrainConfig {
            epochs: 4,
            batch_size: 64,
            schedule: LrSchedule::Constant(0.05),
            weight_decay: 0.0,
            ..Default::default()
        };
        let hist = train(&mut mlp, &tr, &te, &cfg);
        assert_eq!(hist.test_acc.len(), 4);
        assert!(
            hist.final_acc() > 0.6,
            "dense MLP should learn synth-mnist, acc={}",
            hist.final_acc()
        );
        assert!(hist.train_loss[3] < hist.train_loss[0]);
        assert!(hist.wall_secs > 0.0);
        assert!(hist.best_acc() >= hist.final_acc());
    }

    #[test]
    fn sparse_mlp_learns_synth_mnist() {
        let (tr, te) = SynthMnist::new(512, 256, 7);
        let topo = TopologyBuilder::new(&[784, 128, 10])
            .paths(2048)
            .source(PathSource::Sobol { skip_bad_dims: true, scramble_seed: None })
            .build();
        let mut net = SparseMlp::new(
            &topo,
            SparseMlpConfig { init: Init::ConstantRandomSign, seed: 3, ..Default::default() },
        );
        let cfg = TrainConfig {
            epochs: 4,
            batch_size: 64,
            schedule: LrSchedule::Constant(0.05),
            weight_decay: 0.0,
            ..Default::default()
        };
        let hist = train(&mut net, &tr, &te, &cfg);
        assert!(
            hist.final_acc() > 0.5,
            "sparse MLP should learn synth-mnist, acc={}",
            hist.final_acc()
        );
    }

    #[test]
    fn lds_sampler_learns_synth_mnist() {
        let (tr, te) = SynthMnist::new(512, 256, 7);
        let mut mlp = DenseMlp::new(&[784, 64, 10], Init::UniformRandom, 1);
        let cfg = TrainConfig {
            epochs: 4,
            batch_size: 64,
            schedule: LrSchedule::Constant(0.05),
            weight_decay: 0.0,
            sampler: BatchSampler::Lds(crate::qmc::SequenceFamily::sobol()),
            ..Default::default()
        };
        let hist = train(&mut mlp, &tr, &te, &cfg);
        assert!(
            hist.final_acc() > 0.6,
            "LDS-sampled training should learn synth-mnist, acc={}",
            hist.final_acc()
        );
    }

    #[test]
    fn lds_stream_is_deterministic_and_near_uniform() {
        // the van der Corput index stream over n slots: every epoch's
        // draw counts stay within a tight band of uniform
        let fam = crate::qmc::SequenceFamily::sobol();
        let seq = fam.build(1);
        let n = 100usize;
        let mut counts = vec![0usize; n];
        for k in 0..n as u64 {
            counts[seq.map_to(k, 0, n)] += 1;
        }
        let max = *counts.iter().max().unwrap();
        assert!(max <= 2, "one epoch never draws any sample more than twice, max={max}");
        let covered = counts.iter().filter(|&&c| c > 0).count();
        // one epoch of the stream covers most of the set (84/100 for
        // this n); a uniform-with-replacement draw covers ~63%
        assert!(covered * 4 >= n * 3, "covers ≥75% of samples per epoch, got {covered}/{n}");
    }

    #[test]
    fn evaluate_counts_whole_set() {
        let cfg = SynthConfig::mnist(1);
        let d = crate::data::synth::flatten(&crate::data::synth::generate(&cfg, 100, 0));
        let mut mlp = DenseMlp::new(&[784, 16, 10], Init::UniformRandom, 0);
        let (loss, acc) = evaluate(&mut mlp, &d, 32);
        assert!(loss.is_finite());
        assert!((0.0..=1.0).contains(&acc));
    }
}
