//! Multilayer perceptrons: the dense baseline of Fig 7 and the masked
//! dense variant of Table 3 ("Constant, random sign, 90% sparse"), built
//! from [`super::dense::Dense`] + ReLU.
//!
//! (The path-sparse MLP lives in [`super::sparse`]; this module hosts
//! the matrix-based models it is compared against.)

use super::dense::Dense;
use super::init::Init;
use super::optim::Sgd;
use super::tensor::Tensor;
use super::Model;
use crate::rng::{Pcg32, Rng};

/// Dense MLP with ReLU between layers and linear output.
#[derive(Debug, Clone)]
pub struct DenseMlp {
    /// Layer stack.
    pub layers: Vec<Dense>,
    relu_mask: Vec<Vec<f32>>,
}

impl DenseMlp {
    /// Build from layer sizes (e.g. `[784, 300, 300, 10]`).
    pub fn new(sizes: &[usize], init: Init, seed: u64) -> Self {
        assert!(sizes.len() >= 2);
        let layers = sizes
            .windows(2)
            .enumerate()
            .map(|(i, w)| Dense::new(w[0], w[1], init, seed ^ (i as u64) << 13))
            .collect();
        DenseMlp { layers, relu_mask: Vec::new() }
    }

    /// Apply random unstructured sparsity of the given density to every
    /// layer (Table 3's "Constant, random sign, 90% sparse" row:
    /// `density = 0.1`).
    pub fn randomly_sparsify(&mut self, density: f64, seed: u64) {
        let mut rng = Pcg32::seeded(seed);
        for layer in &mut self.layers {
            let mask: Vec<f32> = (0..layer.w.len())
                .map(|_| if (rng.next_f64()) < density { 1.0 } else { 0.0 })
                .collect();
            layer.set_mask(mask);
        }
    }

    /// Freeze all weight signs (Table 3 "signs fixed").
    pub fn freeze_signs(&mut self) {
        for l in &mut self.layers {
            l.freeze_signs();
        }
    }
}

impl Model for DenseMlp {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let mut h = x.clone();
        if train {
            self.relu_mask.clear();
        }
        let last = self.layers.len() - 1;
        for (i, layer) in self.layers.iter_mut().enumerate() {
            h = layer.forward(&h, train);
            if i != last {
                if train {
                    self.relu_mask.push(h.data.iter().map(|&v| (v > 0.0) as u8 as f32).collect());
                }
                h = h.relu();
            }
        }
        h
    }

    fn backward(&mut self, glogits: &Tensor) {
        let mut g = glogits.clone();
        let last = self.layers.len() - 1;
        for i in (0..self.layers.len()).rev() {
            if i != last {
                let mask = &self.relu_mask[i];
                for (gv, &m) in g.data.iter_mut().zip(mask) {
                    *gv *= m;
                }
            }
            g = self.layers[i].backward(&g);
        }
    }

    fn step(&mut self, opt: &Sgd) {
        for l in &mut self.layers {
            l.step(opt);
        }
    }

    fn nparams(&self) -> usize {
        self.layers.iter().map(|l| l.nparams()).sum()
    }

    fn nnz(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match &l.mask {
                None => l.w.len(),
                Some(m) => m.iter().filter(|&&v| v > 0.0).count(),
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::loss::{accuracy, softmax_xent};

    #[test]
    fn shapes_and_counts() {
        let mlp = DenseMlp::new(&[784, 300, 300, 10], Init::UniformRandom, 0);
        assert_eq!(mlp.nparams(), 784 * 300 + 300 + 300 * 300 + 300 + 300 * 10 + 10);
        assert_eq!(mlp.nnz(), 784 * 300 + 300 * 300 + 300 * 10);
    }

    #[test]
    fn forward_backward_run() {
        let mut mlp = DenseMlp::new(&[8, 16, 4], Init::UniformRandom, 1);
        let x = Tensor::from_vec((0..16).map(|v| v as f32 * 0.1).collect(), &[2, 8]);
        let y = mlp.forward(&x, true);
        assert_eq!(y.shape, vec![2, 4]);
        let (_, g) = softmax_xent(&y, &[0, 3]);
        mlp.backward(&g);
        mlp.step(&Sgd::default());
    }

    #[test]
    fn relu_gradient_gating() {
        // finite-difference through the whole MLP
        let mut mlp = DenseMlp::new(&[4, 6, 3], Init::UniformRandom, 5);
        let x = Tensor::from_vec(vec![0.3, -0.2, 0.8, 0.1], &[1, 4]);
        let labels = [2u32];
        let logits = mlp.forward(&x, true);
        let (_, g) = softmax_xent(&logits, &labels);
        mlp.backward(&g);
        let gw0 = mlp.layers[0].w.clone();
        let grad0: Vec<f32> = {
            // recover accumulated gradient by re-running a step with lr so
            // small it's readable: instead, access via finite difference
            let eps = 1e-3;
            (0..gw0.len())
                .map(|i| {
                    let orig = mlp.layers[0].w[i];
                    mlp.layers[0].w[i] = orig + eps;
                    let (lp, _) = softmax_xent(&mlp.forward(&x, false), &labels);
                    mlp.layers[0].w[i] = orig - eps;
                    let (lm, _) = softmax_xent(&mlp.forward(&x, false), &labels);
                    mlp.layers[0].w[i] = orig;
                    (lp - lm) / (2.0 * eps)
                })
                .collect()
        };
        // compare against a fresh backward's accumulated grads
        let logits = mlp.forward(&x, true);
        let (_, g) = softmax_xent(&logits, &labels);
        mlp.backward(&g);
        // pull grads via step with momentum 0 and lr 1: w' = w - g
        let before = mlp.layers[0].w.clone();
        mlp.step(&Sgd { lr: 1.0, momentum: 0.0, weight_decay: 0.0 });
        // note: backward was called twice without step, so grads doubled
        for (i, fd) in grad0.iter().enumerate() {
            let anal = (before[i] - mlp.layers[0].w[i]) / 2.0;
            assert!(
                (fd - anal).abs() < 2e-2 * (1.0 + fd.abs()),
                "i={i} fd={fd} anal={anal}"
            );
        }
    }

    #[test]
    fn constant_init_dense_cannot_learn() {
        // §3.1/Table 3: constant positive init on a dense net keeps all
        // neurons identical — accuracy stays at chance.
        let mut mlp = DenseMlp::new(&[8, 16, 16, 4], Init::ConstantPositive, 0);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let mut rng = Pcg32::seeded(5);
        for _ in 0..64 {
            let cls = rng.next_below(4);
            let mut v = vec![0.1f32; 8];
            v[cls as usize * 2] = 1.0;
            v[cls as usize * 2 + 1] = 1.0;
            xs.extend(v);
            ys.push(cls);
        }
        let x = Tensor::from_vec(xs, &[64, 8]);
        let opt = Sgd { lr: 0.05, momentum: 0.9, weight_decay: 0.0 };
        for _ in 0..100 {
            let logits = mlp.forward(&x, true);
            let (_, g) = softmax_xent(&logits, &ys);
            mlp.backward(&g);
            mlp.step(&opt);
        }
        let acc = accuracy(&mlp.forward(&x, false), &ys);
        assert!(acc < 0.5, "dense constant-init should stay near chance, acc={acc}");
        // hidden neurons remain identical
        let w = &mlp.layers[1].w;
        let row0: Vec<f32> = w[..16].to_vec();
        let row1: Vec<f32> = w[16..32].to_vec();
        assert_eq!(row0, row1, "identical neurons under constant init");
    }

    #[test]
    fn random_sparsify_density() {
        let mut mlp = DenseMlp::new(&[100, 100, 10], Init::ConstantRandomSign, 2);
        mlp.randomly_sparsify(0.1, 7);
        let nnz = mlp.nnz();
        let total = 100 * 100 + 100 * 10;
        let density = nnz as f64 / total as f64;
        assert!((0.07..0.13).contains(&density), "density={density}");
    }
}
