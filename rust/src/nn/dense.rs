//! Fully connected (dense) layer — the baseline the paper compares
//! against, with optional static sparsity mask (the matrix emulation of
//! a path topology, footnote 1) and optional fixed signs (Table 3).

use super::init::{w_init_magnitude, Init};
use super::matmul::{matmul_nn, matmul_nt, matmul_tn};
use super::optim::Sgd;
use super::tensor::Tensor;

/// Dense layer `y = x · wᵀ + b` with weights stored `[out][in]`.
#[derive(Debug, Clone)]
pub struct Dense {
    /// Input features.
    pub in_dim: usize,
    /// Output features.
    pub out_dim: usize,
    /// Weights `[out][in]` flattened.
    pub w: Vec<f32>,
    /// Bias `[out]`.
    pub b: Vec<f32>,
    /// Optional static 0/1 mask (same layout as `w`).
    pub mask: Option<Vec<f32>>,
    /// Optional fixed signs (same layout as `w`): training only
    /// magnitudes (paper §3.2 / Table 3).
    pub fixed_signs: Option<Vec<f32>>,
    gw: Vec<f32>,
    gb: Vec<f32>,
    mw: Vec<f32>,
    mb: Vec<f32>,
    x_cache: Tensor,
}

impl Dense {
    /// New dense layer with the given initialization.
    pub fn new(in_dim: usize, out_dim: usize, init: Init, seed: u64) -> Self {
        let mut w = vec![0.0f32; in_dim * out_dim];
        let mag = w_init_magnitude(in_dim, out_dim);
        init.fill(&mut w, mag, None, seed);
        if init == Init::ConstantAlternating {
            // paper semantics: sign alternates by output NEURON index
            for o in 0..out_dim {
                let s = if o % 2 == 0 { mag } else { -mag };
                w[o * in_dim..(o + 1) * in_dim].fill(s);
            }
        }
        Dense {
            in_dim,
            out_dim,
            w,
            b: vec![0.0; out_dim],
            mask: None,
            fixed_signs: None,
            gw: vec![0.0; in_dim * out_dim],
            gb: vec![0.0; out_dim],
            mw: vec![0.0; in_dim * out_dim],
            mb: vec![0.0; out_dim],
            x_cache: Tensor::zeros(&[0]),
        }
    }

    /// Apply a static sparsity mask (zeroes masked weights immediately;
    /// gradients are masked on every backward pass).
    pub fn set_mask(&mut self, mask: Vec<f32>) {
        assert_eq!(mask.len(), self.w.len());
        for (w, &m) in self.w.iter_mut().zip(&mask) {
            *w *= m;
        }
        self.mask = Some(mask);
    }

    /// Freeze the current weight signs (Table 3 "signs fixed, train only
    /// magnitude").
    pub fn freeze_signs(&mut self) {
        self.fixed_signs = Some(self.w.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect());
    }

    /// Forward pass; caches the input for backward when `train`.
    pub fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        assert_eq!(x.features(), self.in_dim, "dense input dim");
        let bsz = x.batch();
        let mut y = Tensor::zeros(&[bsz, self.out_dim]);
        matmul_nt(&x.data, &self.w, &mut y.data, bsz, self.in_dim, self.out_dim);
        for i in 0..bsz {
            let row = y.row_mut(i);
            for (v, &bias) in row.iter_mut().zip(&self.b) {
                *v += bias;
            }
        }
        if train {
            self.x_cache = x.clone();
        }
        y
    }

    /// Backward pass: accumulate `gw`, `gb`, return input gradient.
    pub fn backward(&mut self, gy: &Tensor) -> Tensor {
        let bsz = gy.batch();
        assert_eq!(gy.features(), self.out_dim);
        assert_eq!(self.x_cache.batch(), bsz, "forward(train=true) must precede backward");
        // gw[out][in] += gyᵀ[out,B] · x[B,in]
        matmul_tn(&gy.data, &self.x_cache.data, &mut self.gw, self.out_dim, bsz, self.in_dim);
        for i in 0..bsz {
            for (gb, &g) in self.gb.iter_mut().zip(gy.row(i)) {
                *gb += g;
            }
        }
        if let Some(mask) = &self.mask {
            for (g, &m) in self.gw.iter_mut().zip(mask) {
                *g *= m;
            }
        }
        // gx[B,in] = gy[B,out] · w[out,in]
        let mut gx = Tensor::zeros(&[bsz, self.in_dim]);
        matmul_nn(&gy.data, &self.w, &mut gx.data, bsz, self.out_dim, self.in_dim);
        gx
    }

    /// SGD update of weights and bias.
    pub fn step(&mut self, opt: &Sgd) {
        opt.update(&mut self.w, &mut self.gw, &mut self.mw, self.fixed_signs.as_deref());
        opt.update_no_decay(&mut self.b, &mut self.gb, &mut self.mb);
        if let Some(mask) = &self.mask {
            // keep masked weights at exactly zero despite weight decay
            for (w, &m) in self.w.iter_mut().zip(mask) {
                *w *= m;
            }
        }
    }

    /// Trainable parameter count (mask-aware).
    pub fn nparams(&self) -> usize {
        match &self.mask {
            None => self.w.len() + self.b.len(),
            Some(m) => m.iter().filter(|&&v| v > 0.0).count() + self.b.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fd_check(layer: &mut Dense, x: &Tensor, eps: f32) {
        // loss = sum(y); dL/dw finite difference vs backward
        let y = layer.forward(x, true);
        let gy = Tensor::from_vec(vec![1.0; y.len()], &y.shape);
        let gx = layer.backward(&gy);
        // check a few weight gradients
        for &idx in &[0usize, 1, layer.w.len() / 2, layer.w.len() - 1] {
            let orig = layer.w[idx];
            layer.w[idx] = orig + eps;
            let yp: f32 = layer.forward(x, false).data.iter().sum();
            layer.w[idx] = orig - eps;
            let ym: f32 = layer.forward(x, false).data.iter().sum();
            layer.w[idx] = orig;
            let fd = (yp - ym) / (2.0 * eps);
            assert!(
                (fd - layer.gw[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "w[{idx}] fd={fd} anal={}",
                layer.gw[idx]
            );
        }
        // input gradient: dL/dx = sum over outputs of w
        for bi in 0..x.batch() {
            for i in 0..layer.in_dim {
                let want: f32 = (0..layer.out_dim).map(|o| layer.w[o * layer.in_dim + i]).sum();
                let got = gx.row(bi)[i];
                assert!((want - got).abs() < 1e-4, "gx[{bi},{i}]");
            }
        }
    }

    #[test]
    fn forward_known_values() {
        let mut l = Dense::new(2, 2, Init::ConstantPositive, 0);
        l.w.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]); // rows: out0=[1,2], out1=[3,4]
        l.b.copy_from_slice(&[0.5, -0.5]);
        let x = Tensor::from_vec(vec![1.0, 1.0], &[1, 2]);
        let y = l.forward(&x, false);
        assert_eq!(y.data, vec![3.5, 6.5]);
    }

    #[test]
    fn gradients_match_finite_difference() {
        let mut l = Dense::new(5, 4, Init::UniformRandom, 42);
        let x = Tensor::from_vec((0..10).map(|v| v as f32 * 0.1 - 0.4).collect(), &[2, 5]);
        fd_check(&mut l, &x, 1e-2);
    }

    #[test]
    fn mask_zeroes_weights_and_grads() {
        let mut l = Dense::new(3, 2, Init::ConstantPositive, 0);
        let mask = vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        l.set_mask(mask.clone());
        for (w, &m) in l.w.iter().zip(&mask) {
            assert_eq!(*w != 0.0, m != 0.0);
        }
        let x = Tensor::from_vec(vec![1.0, 2.0, 3.0], &[1, 3]);
        let y = l.forward(&x, true);
        let gy = Tensor::from_vec(vec![1.0; 2], &y.shape);
        l.backward(&gy);
        for (g, &m) in l.gw.iter().zip(&mask) {
            if m == 0.0 {
                assert_eq!(*g, 0.0);
            }
        }
        assert_eq!(l.nparams(), 3 + 2);
        // step keeps masked weights zero
        l.step(&Sgd::default());
        for (w, &m) in l.w.iter().zip(&mask) {
            if m == 0.0 {
                assert_eq!(*w, 0.0);
            }
        }
    }

    #[test]
    fn step_moves_downhill() {
        let mut l = Dense::new(4, 3, Init::UniformRandom, 1);
        let x = Tensor::from_vec(vec![0.5; 8], &[2, 4]);
        // loss = sum(y^2)/2 → gy = y; a step should reduce it
        let mut last = f32::INFINITY;
        let opt = Sgd { lr: 0.05, momentum: 0.0, weight_decay: 0.0 };
        for _ in 0..10 {
            let y = l.forward(&x, true);
            let loss: f32 = y.data.iter().map(|v| v * v).sum::<f32>() / 2.0;
            let gy = y.clone();
            l.backward(&gy);
            l.step(&opt);
            assert!(loss <= last * 1.001, "loss increased {last} -> {loss}");
            last = loss;
        }
    }

    #[test]
    fn freeze_signs_prevents_flips() {
        let mut l = Dense::new(2, 1, Init::ConstantRandomSign, 3);
        l.freeze_signs();
        let signs: Vec<f32> = l.w.iter().map(|v| v.signum()).collect();
        let x = Tensor::from_vec(vec![5.0, -5.0], &[1, 2]);
        let opt = Sgd { lr: 1.0, momentum: 0.0, weight_decay: 0.0 };
        for _ in 0..5 {
            let y = l.forward(&x, true);
            let gy = y.clone();
            l.backward(&gy);
            l.step(&opt);
        }
        for (w, s) in l.w.iter().zip(&signs) {
            assert!(w * s >= 0.0, "sign flipped: w={w} sign={s}");
        }
    }
}
