//! The paper's CIFAR CNN (§5.2): five 3×3 convolutions with channel
//! counts `(16, 32, 32, 64, 64)·width`, each followed by BatchNorm and
//! ReLU, max-pooling between stages, global average pooling, and a
//! final fully connected softmax classifier.
//!
//! The sparse variant traces paths through the *channel* graph
//! `[c_in, 16w, 32w, 32w, 64w, 64w]` (§2.2): each path activates a full
//! `3×3` filter slice per transition — the coarse, hardware-friendly
//! sparsity the paper advocates.

use super::batchnorm::BatchNorm;
use super::conv::{Conv2d, GlobalAvgPool, MaxPool2};
use super::dense::Dense;
use super::init::Init;
use super::optim::Sgd;
use super::tensor::Tensor;
use super::Model;
use crate::topology::PathTopology;

/// CNN configuration.
#[derive(Debug, Clone)]
pub struct CnnConfig {
    /// Input channels (3 for CIFAR-like data).
    pub in_channels: usize,
    /// Conv channel counts (paper: 16, 32, 32, 64, 64).
    pub channels: Vec<usize>,
    /// Conv indices after which a 2×2 max-pool is inserted.
    pub pool_after: Vec<usize>,
    /// Output classes.
    pub classes: usize,
    /// Weight initialization scheme.
    pub init: Init,
    /// Seed for random init schemes.
    pub seed: u64,
    /// Freeze signs after init (train only magnitudes).
    pub freeze_signs: bool,
}

impl CnnConfig {
    /// Paper architecture at a given width multiplier, for `hw`-sized
    /// inputs (pooling chosen so spatial dims stay even).
    pub fn paper(width: f64, in_channels: usize, classes: usize, init: Init, seed: u64) -> Self {
        let base = [16usize, 32, 32, 64, 64];
        let channels = base.iter().map(|&c| ((c as f64 * width).round() as usize).max(1)).collect();
        CnnConfig {
            in_channels,
            channels,
            pool_after: vec![0, 2],
            classes,
            init,
            seed,
            freeze_signs: false,
        }
    }
}

/// The convolutional classifier (dense or channel-path-sparse).
#[derive(Debug, Clone)]
pub struct Cnn {
    /// Configuration used to build the network.
    pub cfg: CnnConfig,
    convs: Vec<Conv2d>,
    bns: Vec<BatchNorm>,
    pools: Vec<MaxPool2>,
    gap: GlobalAvgPool,
    fc: Dense,
    relu_masks: Vec<Vec<f32>>,
    /// Channel topology when sparse (for nnz bookkeeping).
    pub topo: Option<PathTopology>,
}

impl Cnn {
    /// Dense (fully connected channels) variant.
    pub fn dense(cfg: CnnConfig) -> Self {
        // Sign-along-path has no meaning before a topology exists: build
        // with positive constants (the magnitude is what matters) and
        // let `sparse()` stamp the per-slice signs; the dense FC gets a
        // deterministic alternating sign so it can still learn.
        let conv_init = match cfg.init {
            Init::ConstantSignAlongPath => Init::ConstantPositive,
            other => other,
        };
        let fc_init = match cfg.init {
            Init::ConstantSignAlongPath => Init::ConstantAlternating,
            other => other,
        };
        let mut convs = Vec::new();
        let mut bns = Vec::new();
        let mut prev = cfg.in_channels;
        for (i, &c) in cfg.channels.iter().enumerate() {
            let mut conv = Conv2d::new(prev, c, 3, conv_init, cfg.seed ^ (i as u64) << 9);
            if cfg.freeze_signs {
                conv.freeze_signs();
            }
            convs.push(conv);
            bns.push(BatchNorm::new(c));
            prev = c;
        }
        let mut fc = Dense::new(prev, cfg.classes, fc_init, cfg.seed ^ 0xFC);
        if cfg.freeze_signs {
            fc.freeze_signs();
        }
        let n_pools = cfg.pool_after.len();
        Cnn {
            cfg,
            convs,
            bns,
            pools: (0..n_pools).map(|_| MaxPool2::new()).collect(),
            gap: GlobalAvgPool::new(),
            fc,
            relu_masks: Vec::new(),
            topo: None,
        }
    }

    /// Sparse variant: channel masks from a path topology over
    /// `[in_channels, channels…]`.  `sign_slices` additionally fixes the
    /// sign of each filter slice to its path's sign (§5.4's cautionary
    /// configuration).
    pub fn sparse(cfg: CnnConfig, topo: &PathTopology, sign_slices: bool) -> Self {
        let mut expected = vec![cfg.in_channels];
        expected.extend_from_slice(&cfg.channels);
        assert_eq!(topo.layer_sizes, expected, "topology must match channel graph");
        let mut net = Self::dense(cfg);
        for (t, conv) in net.convs.iter_mut().enumerate() {
            let mask = topo.dense_mask(t);
            let n_in = topo.layer_sizes[t];
            let n_out = topo.layer_sizes[t + 1];
            // Signed path multiplicity per (c_out, c_in) pair.  Paper
            // footnote 1: duplicate edges coalesce by SUMMING in the
            // matrix emulation — a constant per-path weight w therefore
            // becomes multiplicity·w (or (n₊−n₋)·w with signs), which is
            // exactly what breaks the filter symmetry of constant init
            // for sparse nets (§3.1): saturated transitions get distinct
            // multiplicity patterns per filter.
            let mut signed_mult = vec![0.0f32; n_in * n_out];
            for p in 0..topo.paths {
                let ci = topo.index[t][p] as usize;
                let co = topo.index[t + 1][p] as usize;
                let s = if sign_slices {
                    topo.signs.as_ref().expect("sign_slices requires topology signs")[p]
                } else {
                    1.0
                };
                signed_mult[co * n_in + ci] += s;
            }
            conv.set_channel_mask(mask, None);
            // Constant-family inits emulate the per-path weight sum.
            let coalesce_init = matches!(
                net.cfg.init,
                Init::ConstantPositive | Init::ConstantSignAlongPath
            );
            if coalesce_init || sign_slices {
                let kk = conv.k * conv.k;
                for co in 0..n_out {
                    for ci in 0..n_in {
                        let m = signed_mult[co * n_in + ci];
                        let base = (co * n_in + ci) * kk;
                        for wv in &mut conv.w[base..base + kk] {
                            *wv = wv.abs() * m;
                        }
                    }
                }
            }
            if net.cfg.freeze_signs {
                conv.freeze_signs();
            }
        }
        net.topo = Some(topo.clone());
        net
    }

    /// Total conv weight capacity of the dense counterpart (for
    /// sparsity reporting, Fig 12 / Table 2).
    pub fn dense_conv_weights(&self) -> usize {
        let mut prev = self.cfg.in_channels;
        let mut total = 0;
        for &c in &self.cfg.channels {
            total += prev * c * 9;
            prev = c;
        }
        total + prev * self.cfg.classes
    }
}

impl Model for Cnn {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        assert_eq!(x.shape.len(), 4, "CNN input must be [B,C,H,W]");
        let mut h = x.clone();
        if train {
            self.relu_masks.clear();
        }
        let mut pool_i = 0;
        for i in 0..self.convs.len() {
            h = self.convs[i].forward(&h, train);
            h = self.bns[i].forward(&h, train);
            if train {
                self.relu_masks.push(h.data.iter().map(|&v| (v > 0.0) as u8 as f32).collect());
            }
            h = h.relu();
            if self.cfg.pool_after.contains(&i) {
                h = self.pools[pool_i].forward(&h, train);
                pool_i += 1;
            }
        }
        let pooled = self.gap.forward(&h, train);
        self.fc.forward(&pooled, train)
    }

    fn backward(&mut self, glogits: &Tensor) {
        let g = self.fc.backward(glogits);
        let mut g = self.gap.backward(&g);
        let mut pool_i = self.pools.len();
        for i in (0..self.convs.len()).rev() {
            if self.cfg.pool_after.contains(&i) {
                pool_i -= 1;
                g = self.pools[pool_i].backward(&g);
            }
            for (gv, &m) in g.data.iter_mut().zip(&self.relu_masks[i]) {
                *gv *= m;
            }
            g = self.bns[i].backward(&g);
            g = self.convs[i].backward(&g);
        }
    }

    fn step(&mut self, opt: &Sgd) {
        for c in &mut self.convs {
            c.step(opt);
        }
        for b in &mut self.bns {
            b.step(opt);
        }
        self.fc.step(opt);
    }

    fn nparams(&self) -> usize {
        self.convs.iter().map(|c| c.nparams()).sum::<usize>()
            + self.bns.iter().map(|b| b.nparams()).sum::<usize>()
            + self.fc.nparams()
    }

    fn nnz(&self) -> usize {
        self.convs.iter().map(|c| c.nnz()).sum::<usize>() + self.fc.w.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::loss::softmax_xent;
    use crate::topology::{PathSource, SignPolicy, TopologyBuilder};

    fn tiny_cfg() -> CnnConfig {
        CnnConfig {
            in_channels: 3,
            channels: vec![4, 8],
            pool_after: vec![0],
            classes: 4,
            init: Init::UniformRandom,
            seed: 1,
            freeze_signs: false,
        }
    }

    #[test]
    fn paper_architecture_params() {
        // width 1.0, 3 input channels, 10 classes:
        // convs 432+4608+9216+18432+36864 = 69552, fc 640, biases
        // 16+32+32+64+64+10 = 218, bn 2·208 = 416 → 70826 ≈ paper 70.4K
        let cnn = Cnn::dense(CnnConfig::paper(1.0, 3, 10, Init::UniformRandom, 0));
        assert_eq!(cnn.nnz(), 69552 + 640);
        let total = cnn.nparams();
        assert!((70000..71500).contains(&total), "total={total}");
    }

    #[test]
    fn width_multiplier_scales() {
        let w2 = Cnn::dense(CnnConfig::paper(2.0, 3, 10, Init::UniformRandom, 0));
        assert_eq!(w2.cfg.channels, vec![32, 64, 64, 128, 128]);
        let half = Cnn::dense(CnnConfig::paper(0.5, 3, 10, Init::UniformRandom, 0));
        assert_eq!(half.cfg.channels, vec![8, 16, 16, 32, 32]);
    }

    #[test]
    fn forward_shape_and_backward_runs() {
        let mut cnn = Cnn::dense(tiny_cfg());
        let x = Tensor::from_vec((0..2 * 3 * 8 * 8).map(|i| (i as f32 * 0.01).sin()).collect(), &[2, 3, 8, 8]);
        let y = cnn.forward(&x, true);
        assert_eq!(y.shape, vec![2, 4]);
        let (_, g) = softmax_xent(&y, &[0, 2]);
        cnn.backward(&g);
        cnn.step(&Sgd::default());
    }

    #[test]
    fn sparse_masks_reduce_nnz() {
        let cfg = tiny_cfg();
        let topo = TopologyBuilder::new(&[3, 4, 8])
            .paths(8)
            .source(PathSource::Sobol { skip_bad_dims: true, scramble_seed: None })
            .build();
        let sparse = Cnn::sparse(cfg.clone(), &topo, false);
        let dense = Cnn::dense(cfg);
        assert!(sparse.nnz() < dense.nnz(), "{} < {}", sparse.nnz(), dense.nnz());
        // nnz = unique channel pairs × 9 + fc
        let expected: usize =
            (0..2).map(|t| topo.unique_edges(t)).sum::<usize>() * 9 + sparse.fc.w.len();
        assert_eq!(sparse.nnz(), expected);
    }

    #[test]
    fn sparse_training_reduces_loss() {
        let cfg = CnnConfig {
            in_channels: 1,
            channels: vec![4, 8],
            pool_after: vec![0],
            classes: 2,
            init: Init::ConstantSignAlongPath,
            seed: 0,
            freeze_signs: false,
        };
        // random paths: signed multiplicities vary, so coalesced slices
        // start non-zero (Sobol' + alternating signs at saturated
        // capacity would cancel exactly — see EXPERIMENTS.md §Findings)
        let topo = TopologyBuilder::new(&[1, 4, 8])
            .paths(16)
            .source(PathSource::Random { seed: 5 })
            .sign_policy(SignPolicy::AlternatingPath)
            .build();
        let mut cnn = Cnn::sparse(cfg, &topo, true);
        // two-class toy: vertical vs horizontal stripes
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for k in 0..16 {
            let cls = k % 2;
            for y in 0..8 {
                for x in 0..8 {
                    let v = if cls == 0 { (x % 2) as f32 } else { (y % 2) as f32 };
                    xs.push(v + 0.05 * ((k * 64 + y * 8 + x) as f32).sin());
                }
            }
            ys.push(cls as u32);
        }
        let x = Tensor::from_vec(xs, &[16, 1, 8, 8]);
        let opt = Sgd { lr: 0.05, momentum: 0.9, weight_decay: 1e-4 };
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..60 {
            let logits = cnn.forward(&x, true);
            let (loss, g) = softmax_xent(&logits, &ys);
            if step == 0 {
                first = loss;
            }
            last = loss;
            cnn.backward(&g);
            cnn.step(&opt);
        }
        assert!(last < 0.6 * first, "sparse CNN should learn stripes: {first} -> {last}");
    }

    #[test]
    #[should_panic(expected = "topology must match channel graph")]
    fn sparse_shape_mismatch_panics() {
        let topo = TopologyBuilder::new(&[3, 5, 8]).paths(8).build();
        let _ = Cnn::sparse(tiny_cfg(), &topo, false);
    }
}
