//! The paper's Fig 3 algorithm: an MLP represented by paths, trained
//! sparse from scratch.
//!
//! Weights are stored per `(transition, path)` and streamed **linearly**
//! during both inference and backpropagation — the paper's §3/§4.4
//! memory-access argument.  Activations are held in `[neurons, batch]`
//! layout so the per-path inner loop over the batch is contiguous and
//! vectorizes.
//!
//! The ReLU is implicit exactly as in Fig 3: a path contributes only if
//! its source activation is positive.
//!
//! **Parallel training hot path.**  The `[neurons, batch]` layout makes
//! every per-path inner loop a contiguous run of batch columns, and
//! distinct columns never share an activation accumulator — so **both**
//! the forward and the backward pass shard over batch columns on the
//! persistent worker pool of [`crate::util::parallel`] (thread count:
//! `SOBOLNET_THREADS` / [`crate::util::parallel::set_num_threads`]).
//! The inner loop bodies are pluggable compute kernels
//! ([`crate::nn::kernel`]: scalar golden reference, blocked SIMD,
//! sign-only, int8), selected via [`SparseMlpConfig::kernel`] /
//! `SOBOLNET_KERNEL`; the sharding, shadow merge, and scratch
//! lifecycle described here are kernel-independent.
//!
//! * *Forward* shards via [`parallel_ranges`]: each thread owns a
//!   disjoint column range of every layer buffer and runs the whole
//!   multi-layer loop for it.  Columns are processed in exact path
//!   order, so logits are **bitwise identical** for every thread count.
//! * *Backward* shards via [`parallel_chunks`] at a **fixed** shard
//!   width that depends only on the batch size ([`bwd_shard_width`]),
//!   never on the thread count.  Column-disjoint outputs (`gz`) are
//!   written in place; the two cross-column reductions — the per-path
//!   scalar `gacc` feeding `gw`, and the per-neuron bias row-sums
//!   feeding `gb` — go to per-*shard* shadow accumulators that are
//!   merged in fixed shard order afterwards.  Because the shard
//!   partition and the merge order are pure functions of the batch
//!   size, `gw`/`gb`/`gz` are **bitwise identical** for every
//!   `SOBOLNET_THREADS` setting (asserted by `tests/golden_backward.rs`).
//!
//! **Scratch-buffer contract.**  All hot-loop buffers (per-layer
//! activations `z`, per-layer gradients `gz`, the shadow accumulators,
//! and transpose staging) live in the model and are grown on demand:
//! after a warm-up step with a given batch size, `forward_into` +
//! `backward` + `step` perform **zero heap allocation**
//! (`tests/alloc_hotpath.rs` pins this with a counting global
//! allocator).  The buffers are transient: each `forward` overwrites
//! `z` (train *and* eval), so `backward` requires the most recent
//! forward to have been `train = true` and asserts it.
//!
//! `PAR_MIN_WORK` is the edge-work level (`paths × batch ×
//! transitions`) below which a pass stays on the calling thread.  With
//! the persistent pool this no longer buys back thread *spawns* — only
//! a park/wake round-trip (~µs) — so it sits at `2^14`, an order of
//! magnitude below the `2^17` the scoped-spawn implementation needed
//! (EXPERIMENTS.md §Perf).

use super::init::{w_init_magnitude, Init};
use super::kernel::{self, KernelKind, KernelScratch};
use super::optim::Sgd;
use super::tensor::Tensor;
use super::Model;
use crate::topology::PathTopology;
use crate::util::parallel::{parallel_chunks, parallel_ranges, sequential_chunks, SendPtr};

/// Minimum `paths × batch × transitions` edge-work before a pass fans
/// out to the worker pool: below this, even a pool wake/park
/// round-trip beats the win (EXPERIMENTS.md §Perf).
const PAR_MIN_WORK: usize = 1 << 14;

/// Baseline backward shard width in batch columns (one AVX2 register of
/// f32 per inner step).
const BWD_COL_SHARD: usize = 8;

/// Upper bound on backward shards, capping shadow-buffer size and merge
/// cost for large batches.
const MAX_BWD_SHARDS: usize = 32;

/// Tile edge for the blocked transposes: a 32×32 f32 tile keeps source
/// and destination lines cache-resident instead of striding the full
/// matrix per element.
const TRANSPOSE_TILE: usize = 32;

/// Fixed backward column-shard width: a pure function of the batch
/// size — [`BWD_COL_SHARD`] columns, or `⌈b / MAX_BWD_SHARDS⌉` once the
/// batch exceeds `BWD_COL_SHARD × MAX_BWD_SHARDS` columns (shards grow,
/// their count stays ≤ [`MAX_BWD_SHARDS`]) — and **never** of the
/// thread count: the shadow partition and merge order, and therefore
/// every gradient bit, are identical for any `SOBOLNET_THREADS`.
fn bwd_shard_width(b: usize) -> usize {
    ((b + MAX_BWD_SHARDS - 1) / MAX_BWD_SHARDS).max(BWD_COL_SHARD)
}

/// Transpose `[B, n]` (tensor rows) → `[n, B]` into `out` (length
/// `n·B`), tiled [`TRANSPOSE_TILE`]² so both sides stay cache-resident;
/// element-for-element equal to the naive strided loop (unit-tested).
fn transpose_in_blocked(x: &Tensor, n: usize, out: &mut [f32]) {
    let b = x.batch();
    assert_eq!(x.features(), n);
    assert_eq!(out.len(), n * b);
    let xd = &x.data;
    let mut i0 = 0;
    while i0 < n {
        let i1 = (i0 + TRANSPOSE_TILE).min(n);
        let mut b0 = 0;
        while b0 < b {
            let b1 = (b0 + TRANSPOSE_TILE).min(b);
            for bi in b0..b1 {
                for i in i0..i1 {
                    out[i * b + bi] = xd[bi * n + i];
                }
            }
            b0 = b1;
        }
        i0 = i1;
    }
}

/// Transpose `[n, B]` → `[B, n]` into `out` (length `B·n`), tiled.
fn transpose_out_blocked(z: &[f32], n: usize, b: usize, out: &mut [f32]) {
    assert_eq!(z.len(), n * b);
    assert_eq!(out.len(), b * n);
    let mut b0 = 0;
    while b0 < b {
        let b1 = (b0 + TRANSPOSE_TILE).min(b);
        let mut i0 = 0;
        while i0 < n {
            let i1 = (i0 + TRANSPOSE_TILE).min(n);
            for bi in b0..b1 {
                for i in i0..i1 {
                    out[bi * n + i] = z[i * b + bi];
                }
            }
            i0 = i1;
        }
        b0 = b1;
    }
}

/// Reusable hot-loop buffers, grown on demand and never shrunk; their
/// contents are transient per call.  Cloning a model starts with fresh
/// (empty) scratch — the pointers cached in `zptrs`/`gzptrs` are only
/// valid within the forward/backward call that rebuilt them.
#[derive(Default)]
struct Scratch {
    /// Per-layer activation buffer pointers for the forward fan-out.
    zptrs: Vec<SendPtr<f32>>,
    /// Per-layer gradient buffers `gz[l]` in `[sizes[l], B]` layout.
    gz: Vec<Vec<f32>>,
    /// Per-layer gradient buffer pointers for the backward fan-out.
    gzptrs: Vec<SendPtr<f32>>,
    /// Per-shard `gw` shadows, `[shards][transitions][paths]` flat.
    gw_shadow: Vec<f32>,
    /// Per-shard `gb` shadows, `[shards][Σ sizes[1..]]` flat.
    gb_shadow: Vec<f32>,
    /// Offset of transition `t`'s bias segment inside one `gb` shadow
    /// row (layer `t+1`, length `sizes[t+1]`).
    gb_off: Vec<usize>,
    /// Derived weight representations for the active compute kernel
    /// (sign split, int8 codes), rebuilt each pass into reused buffers.
    kernel: KernelScratch,
}

impl Clone for Scratch {
    fn clone(&self) -> Self {
        Scratch::default()
    }
}

impl std::fmt::Debug for Scratch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Scratch { .. }")
    }
}

/// Configuration for [`SparseMlp`].
#[derive(Debug, Clone, Copy)]
pub struct SparseMlpConfig {
    /// Weight initialization scheme (Table 3).
    pub init: Init,
    /// Seed for random initialization schemes.
    pub seed: u64,
    /// Use per-neuron biases (`bias[i]` in Fig 3).
    pub bias: bool,
    /// Freeze the initial signs and train only magnitudes (§3.2).
    pub freeze_signs: bool,
    /// Compute kernel for the forward/backward hot loops
    /// ([`crate::nn::kernel`]).  [`KernelKind::Auto`] resolves the
    /// `SOBOLNET_KERNEL` environment variable at build time (default:
    /// the bitwise-golden scalar kernel).
    pub kernel: KernelKind,
}

impl Default for SparseMlpConfig {
    fn default() -> Self {
        SparseMlpConfig {
            init: Init::ConstantPositive,
            seed: 0,
            bias: true,
            freeze_signs: false,
            kernel: KernelKind::Auto,
        }
    }
}

/// Path-sparse multilayer perceptron (paper Fig 3).
#[derive(Debug, Clone)]
pub struct SparseMlp {
    /// The path topology (owns `index[][]`).
    pub topo: PathTopology,
    /// Path weights `w[t][p]` — streamed linearly.
    pub w: Vec<Vec<f32>>,
    /// Per-neuron biases of layers 1..=L (empty vecs when disabled).
    pub bias: Vec<Vec<f32>>,
    /// Fixed signs per weight (set when `freeze_signs`).
    pub fixed_signs: Option<Vec<Vec<f32>>>,
    gw: Vec<Vec<f32>>,
    mw: Vec<Vec<f32>>,
    gb: Vec<Vec<f32>>,
    mb: Vec<Vec<f32>>,
    /// Cached pre-activations per layer in `[n, B]` layout; `z[0]` is
    /// the raw input.  Overwritten by every forward (train and eval).
    z: Vec<Vec<f32>>,
    zbatch: usize,
    /// True iff the most recent forward ran with `train = true` (the
    /// precondition for `backward`).
    z_train: bool,
    /// Resolved compute kernel (never [`KernelKind::Auto`]); see
    /// [`SparseMlp::kernel`].
    kernel: KernelKind,
    scratch: Scratch,
}

impl SparseMlp {
    /// Build a sparse MLP over `topo` with the given config.
    pub fn new(topo: &PathTopology, cfg: SparseMlpConfig) -> Self {
        let t_cnt = topo.transitions();
        let p = topo.paths;
        let mut w: Vec<Vec<f32>> = Vec::with_capacity(t_cnt);
        for t in 0..t_cnt {
            let mut wt = vec![0.0f32; p];
            // magnitude from the average valence of this transition
            let fan_in = (p as f32 / topo.layer_sizes[t + 1] as f32).max(1.0) as usize;
            let fan_out = (p as f32 / topo.layer_sizes[t] as f32).max(1.0) as usize;
            let mag = w_init_magnitude(fan_in, fan_out);
            let signs_per_weight: Option<Vec<f32>> =
                topo.signs.as_ref().map(|s| s.to_vec());
            cfg.init.fill(
                &mut wt,
                mag,
                signs_per_weight.as_deref(),
                cfg.seed ^ (t as u64) << 17,
            );
            if cfg.init == Init::ConstantAlternating {
                // paper semantics: sign by destination NEURON index
                for (p, wv) in wt.iter_mut().enumerate() {
                    let dst = topo.index[t + 1][p];
                    *wv = if dst % 2 == 0 { mag } else { -mag };
                }
            }
            w.push(wt);
        }
        let bias: Vec<Vec<f32>> = (1..topo.layer_sizes.len())
            .map(|l| if cfg.bias { vec![0.0; topo.layer_sizes[l]] } else { Vec::new() })
            .collect();
        let fixed_signs = if cfg.freeze_signs {
            Some(
                w.iter()
                    .map(|wt| wt.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect())
                    .collect(),
            )
        } else {
            None
        };
        let gw = w.iter().map(|wt| vec![0.0; wt.len()]).collect();
        let mw = w.iter().map(|wt| vec![0.0; wt.len()]).collect();
        let gb = bias.iter().map(|b| vec![0.0; b.len()]).collect();
        let mb = bias.iter().map(|b| vec![0.0; b.len()]).collect();
        SparseMlp {
            topo: topo.clone(),
            w,
            bias,
            fixed_signs,
            gw,
            mw,
            gb,
            mb,
            z: Vec::new(),
            zbatch: 0,
            z_train: false,
            kernel: cfg.kernel.resolve(),
            scratch: Scratch::default(),
        }
    }

    /// The compute kernel configured for this model (resolved, never
    /// `Auto`).  The kind that actually runs may still downgrade per
    /// [`KernelKind::effective`]: `Sign` requires frozen signs.
    pub fn kernel(&self) -> KernelKind {
        self.kernel
    }

    /// Accumulated weight gradients `gw[t][p]` (cleared by
    /// [`Model::step`]).
    pub fn weight_grads(&self) -> &[Vec<f32>] {
        &self.gw
    }

    /// Accumulated bias gradients `gb[t][i]` (empty vecs when biases
    /// are disabled; cleared by [`Model::step`]).
    pub fn bias_grads(&self) -> &[Vec<f32>] {
        &self.gb
    }

    /// Gradient w.r.t. the *input* activations in `[n_in, B]` layout,
    /// as propagated by the most recent [`Model::backward`] call
    /// (`None` before any backward; overwritten by the next one).
    pub fn input_grad(&self) -> Option<&[f32]> {
        self.scratch.gz.first().map(|v| v.as_slice()).filter(|v| !v.is_empty())
    }

    /// The paper's Fig 3 inference loop, scalar and literal, for a
    /// single input — used as the correctness oracle in tests.
    pub fn fig3_reference(&self, input: &[f32]) -> Vec<f32> {
        let sizes = &self.topo.layer_sizes;
        let total: usize = sizes.iter().sum();
        let mut a = vec![0.0f32; total];
        a[..sizes[0]].copy_from_slice(input);
        // offsets of each layer in the flat activation array
        let mut off = vec![0usize; sizes.len()];
        for l in 1..sizes.len() {
            off[l] = off[l - 1] + sizes[l - 1];
            // biases (Fig 3: "or bias[i], if bias terms are used")
            if !self.bias[l - 1].is_empty() {
                for (i, &b) in self.bias[l - 1].iter().enumerate() {
                    a[off[l] + i] = b;
                }
            }
        }
        for l in 1..sizes.len() {
            for p in 0..self.topo.paths {
                let prev = off[l - 1] + self.topo.index[l - 1][p] as usize;
                if a[prev] > 0.0 {
                    let cur = off[l] + self.topo.index[l][p] as usize;
                    a[cur] += self.w[l - 1][p] * a[prev];
                }
            }
        }
        a[off[sizes.len() - 1]..].to_vec()
    }
}

impl Model for SparseMlp {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let mut out = Tensor::empty();
        self.forward_into(x, train, &mut out);
        out
    }

    fn forward_into(&mut self, x: &Tensor, train: bool, out: &mut Tensor) {
        let b = x.batch();
        let t_cnt = self.topo.transitions();
        let paths = self.topo.paths;
        let n_layers = self.topo.layer_sizes.len();

        // (re)shape the per-layer activation scratch; at steady state
        // (same batch size) these keep their capacity — no allocation
        if self.z.len() != n_layers {
            self.z = vec![Vec::new(); n_layers];
        }
        for l in 0..n_layers {
            let len = self.topo.layer_sizes[l] * b;
            let zl = &mut self.z[l];
            zl.clear();
            zl.resize(len, 0.0);
        }
        transpose_in_blocked(x, self.topo.layer_sizes[0], &mut self.z[0]);

        {
            // Column-sharded execution: each thread owns a disjoint
            // range [c0, c1) of batch columns of EVERY layer buffer and
            // runs the whole multi-layer loop for it — one pool fan-out
            // per forward, no barriers between transitions.  The inner
            // per-transition/per-path loops belong to the selected
            // compute kernel; every kernel computes each column with a
            // fixed op order, so logits stay bitwise identical for
            // every thread count.
            self.scratch.zptrs.clear();
            for zl in self.z.iter_mut() {
                self.scratch.zptrs.push(SendPtr::new(zl.as_mut_ptr()));
            }
            let kern = self.kernel.effective(self.fixed_signs.is_some()).instance();
            kern.prepare(&self.w, &mut self.scratch.kernel);
            let ctx = kernel::FwdCtx {
                zptrs: &self.scratch.zptrs,
                index: &self.topo.index,
                w: &self.w,
                bias: &self.bias,
                batch: b,
                paths,
                scratch: &self.scratch.kernel,
            };
            let columns = |c0: usize, c1: usize| kern.forward_columns(&ctx, c0, c1);
            // below the work threshold run inline (min_chunk = b makes
            // parallel_ranges take its sequential path)
            let min_chunk = if paths * b * t_cnt >= PAR_MIN_WORK { 1 } else { b.max(1) };
            parallel_ranges(b, min_chunk, columns);
        }

        let classes = self.topo.layer_sizes[n_layers - 1];
        out.shape.clear();
        out.shape.push(b);
        out.shape.push(classes);
        // no clear: the transpose overwrites every element
        out.data.resize(b * classes, 0.0);
        transpose_out_blocked(self.z.last().unwrap(), classes, b, &mut out.data);
        self.zbatch = b;
        self.z_train = train;
    }

    fn backward(&mut self, glogits: &Tensor) {
        let b = self.zbatch;
        assert!(
            self.z_train,
            "backward requires the most recent forward to have run with train=true \
             (forward overwrites the activation scratch)"
        );
        assert_eq!(glogits.batch(), b, "forward(train=true) must precede backward");
        let t_cnt = self.topo.transitions();
        let paths = self.topo.paths;
        let n_layers = self.topo.layer_sizes.len();
        let classes = self.topo.layer_sizes[n_layers - 1];
        assert_eq!(glogits.features(), classes);

        // fixed column-shard partition (independent of thread count)
        let width = bwd_shard_width(b);
        let shards = (b + width - 1) / width;
        let tp = t_cnt * paths;
        let brow: usize = self.topo.layer_sizes[1..].iter().sum();

        // (re)shape the per-layer gradient scratch
        if self.scratch.gz.len() != n_layers {
            self.scratch.gz = vec![Vec::new(); n_layers];
        }
        for l in 0..n_layers {
            let len = self.topo.layer_sizes[l] * b;
            let gzl = &mut self.scratch.gz[l];
            gzl.clear();
            gzl.resize(len, 0.0);
        }
        transpose_in_blocked(glogits, classes, &mut self.scratch.gz[n_layers - 1]);

        if self.scratch.gb_off.len() != t_cnt {
            self.scratch.gb_off.clear();
            let mut off = 0usize;
            for &sz in &self.topo.layer_sizes[1..] {
                self.scratch.gb_off.push(off);
                off += sz;
            }
        }

        // zeroed per-shard shadow accumulators (capacity reused)
        self.scratch.gw_shadow.clear();
        self.scratch.gw_shadow.resize(shards * tp, 0.0);
        self.scratch.gb_shadow.clear();
        self.scratch.gb_shadow.resize(shards * brow, 0.0);

        {
            self.scratch.gzptrs.clear();
            for gzl in self.scratch.gz.iter_mut() {
                self.scratch.gzptrs.push(SendPtr::new(gzl.as_mut_ptr()));
            }
            // One shard = one fixed chunk of batch columns.  The shard
            // runs the whole reversed multi-transition loop for its
            // columns (no barriers): gz writes are column-disjoint, and
            // the cross-column reductions go to this shard's shadows.
            // The loop bodies belong to the selected compute kernel;
            // the shard partition and merge order stay here, pure
            // functions of the batch size.
            let kern = self.kernel.effective(self.fixed_signs.is_some()).instance();
            kern.prepare(&self.w, &mut self.scratch.kernel);
            let gw_sh = SendPtr::new(self.scratch.gw_shadow.as_mut_ptr());
            let gb_sh = SendPtr::new(self.scratch.gb_shadow.as_mut_ptr());
            let ctx = kernel::BwdCtx {
                gzptrs: &self.scratch.gzptrs,
                z: &self.z,
                index: &self.topo.index,
                w: &self.w,
                bias: &self.bias,
                sizes: &self.topo.layer_sizes,
                gb_off: &self.scratch.gb_off,
                gw_shadow: gw_sh,
                gb_shadow: gb_sh,
                shard_width: width,
                brow,
                batch: b,
                paths,
                scratch: &self.scratch.kernel,
            };
            let shard = |c0: usize, c1: usize| kern.backward_shard(&ctx, c0, c1);
            if paths * b * t_cnt >= PAR_MIN_WORK {
                parallel_chunks(b, width, &shard);
            } else {
                // identical chunk boundaries, inline
                sequential_chunks(b, width, &shard);
            }
        }

        // Fixed-order shadow reduction: shards merge in index order
        // 0, 1, 2, … regardless of which threads computed them, so the
        // accumulated gradients are bitwise thread-invariant.
        for s in 0..shards {
            let base = s * tp;
            for t in 0..t_cnt {
                let sh = &self.scratch.gw_shadow[base + t * paths..base + (t + 1) * paths];
                let gwt = &mut self.gw[t];
                for (gp, &sv) in gwt.iter_mut().zip(sh) {
                    *gp += sv;
                }
            }
        }
        for s in 0..shards {
            let base = s * brow;
            for t in 0..t_cnt {
                if self.gb[t].is_empty() {
                    continue;
                }
                let off = self.scratch.gb_off[t];
                let n_t = self.topo.layer_sizes[t + 1];
                let sh = &self.scratch.gb_shadow[base + off..base + off + n_t];
                let gbt = &mut self.gb[t];
                for (gp, &sv) in gbt.iter_mut().zip(sh) {
                    *gp += sv;
                }
            }
        }
    }

    fn step(&mut self, opt: &Sgd) {
        for t in 0..self.w.len() {
            let signs = self.fixed_signs.as_ref().map(|s| s[t].as_slice());
            opt.update(&mut self.w[t], &mut self.gw[t], &mut self.mw[t], signs);
            if !self.bias[t].is_empty() {
                opt.update_no_decay(&mut self.bias[t], &mut self.gb[t], &mut self.mb[t]);
            }
        }
    }

    fn set_kernel(&mut self, kernel: KernelKind) -> bool {
        self.kernel = kernel.resolve();
        true
    }

    fn nparams(&self) -> usize {
        self.w.iter().map(|w| w.len()).sum::<usize>()
            + self.bias.iter().map(|b| b.len()).sum::<usize>()
    }

    fn nnz(&self) -> usize {
        self.topo.nnz()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::loss::softmax_xent;
    use crate::topology::{PathSource, SignPolicy, TopologyBuilder};

    fn topo(sizes: &[usize], paths: usize) -> PathTopology {
        TopologyBuilder::new(sizes)
            .paths(paths)
            .source(PathSource::Sobol { skip_bad_dims: true, scramble_seed: None })
            .build()
    }

    #[test]
    fn forward_matches_fig3_reference() {
        let t = topo(&[8, 16, 16, 4], 64);
        let mut net = SparseMlp::new(
            &t,
            SparseMlpConfig { init: Init::UniformRandom, seed: 3, ..Default::default() },
        );
        // non-trivial biases
        for bl in net.bias.iter_mut() {
            for (i, v) in bl.iter_mut().enumerate() {
                *v = 0.01 * i as f32 - 0.02;
            }
        }
        let input: Vec<f32> = (0..8).map(|i| (i as f32 * 0.37).sin()).collect();
        let x = Tensor::from_vec(input.clone(), &[1, 8]);
        let batched = net.forward(&x, false);
        let reference = net.fig3_reference(&input);
        for (a, b) in batched.row(0).iter().zip(&reference) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn batching_is_consistent_with_single() {
        let t = topo(&[6, 8, 4], 32);
        let mut net = SparseMlp::new(
            &t,
            SparseMlpConfig { init: Init::UniformRandom, seed: 1, ..Default::default() },
        );
        let xs: Vec<Vec<f32>> = (0..5)
            .map(|k| (0..6).map(|i| ((i + k) as f32 * 0.31).cos()).collect())
            .collect();
        let flat: Vec<f32> = xs.iter().flatten().cloned().collect();
        let batch = net.forward(&Tensor::from_vec(flat, &[5, 6]), false);
        for (k, xrow) in xs.iter().enumerate() {
            let single = net.forward(&Tensor::from_vec(xrow.clone(), &[1, 6]), false);
            for (a, b) in batch.row(k).iter().zip(single.row(0)) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn blocked_transposes_match_naive() {
        // deliberately not multiples of the tile size
        let (n, b) = (37usize, 53usize);
        let x = Tensor::from_vec(
            (0..b * n).map(|i| (i as f32 * 0.123).sin()).collect(),
            &[b, n],
        );
        let mut blocked = vec![0.0f32; n * b];
        transpose_in_blocked(&x, n, &mut blocked);
        for bi in 0..b {
            for i in 0..n {
                assert_eq!(
                    blocked[i * b + bi].to_bits(),
                    x.data[bi * n + i].to_bits(),
                    "transpose_in ({bi},{i})"
                );
            }
        }
        let mut back = vec![0.0f32; b * n];
        transpose_out_blocked(&blocked, n, b, &mut back);
        for (got, want) in back.iter().zip(&x.data) {
            assert_eq!(got.to_bits(), want.to_bits(), "transpose_out roundtrip");
        }
    }

    #[test]
    fn scratch_buffers_are_reused_across_steps() {
        // capacity/pointer stability = no steady-state reallocation
        // (the cross-crate allocation count lives in
        // tests/alloc_hotpath.rs; this pins the mechanism in-unit)
        let t = topo(&[16, 32, 32, 8], 512);
        let mut net = SparseMlp::new(
            &t,
            SparseMlpConfig { init: Init::UniformRandom, seed: 5, ..Default::default() },
        );
        let b = 24usize;
        let x = Tensor::from_vec(
            (0..b * 16).map(|i| ((i as f32) * 0.05).sin()).collect(),
            &[b, 16],
        );
        let glogits = Tensor::from_vec(vec![0.01f32; b * 8], &[b, 8]);
        let opt = Sgd { lr: 0.01, momentum: 0.9, weight_decay: 0.0 };
        let mut out = Tensor::empty();
        // warm-up sizes everything
        net.forward_into(&x, true, &mut out);
        net.backward(&glogits);
        net.step(&opt);
        let z_ptrs: Vec<*const f32> = net.z.iter().map(|v| v.as_ptr()).collect();
        let z_caps: Vec<usize> = net.z.iter().map(|v| v.capacity()).collect();
        let gz_caps: Vec<usize> = net.scratch.gz.iter().map(|v| v.capacity()).collect();
        let gw_sh_cap = net.scratch.gw_shadow.capacity();
        let out_cap = out.data.capacity();
        for _ in 0..4 {
            net.forward_into(&x, true, &mut out);
            net.backward(&glogits);
            net.step(&opt);
        }
        let z_ptrs2: Vec<*const f32> = net.z.iter().map(|v| v.as_ptr()).collect();
        assert_eq!(z_ptrs, z_ptrs2, "activation buffers moved");
        assert_eq!(z_caps, net.z.iter().map(|v| v.capacity()).collect::<Vec<_>>());
        assert_eq!(gz_caps, net.scratch.gz.iter().map(|v| v.capacity()).collect::<Vec<_>>());
        assert_eq!(gw_sh_cap, net.scratch.gw_shadow.capacity());
        assert_eq!(out_cap, out.data.capacity());
    }

    #[test]
    #[should_panic(expected = "train=true")]
    fn backward_after_eval_forward_panics() {
        let t = topo(&[6, 8, 4], 32);
        let mut net = SparseMlp::new(&t, Default::default());
        let x = Tensor::from_vec(vec![0.5; 6], &[1, 6]);
        net.forward(&x, true);
        net.forward(&x, false); // overwrites the activation scratch
        let g = Tensor::from_vec(vec![0.1; 4], &[1, 4]);
        net.backward(&g);
    }

    #[test]
    fn gradients_match_finite_difference() {
        let t = topo(&[5, 7, 3], 24);
        let mut net = SparseMlp::new(
            &t,
            SparseMlpConfig { init: Init::UniformRandom, seed: 7, ..Default::default() },
        );
        let x = Tensor::from_vec(
            (0..10).map(|i| (i as f32 * 0.7).sin().abs() + 0.1).collect(),
            &[2, 5],
        );
        let labels = [1u32, 2];
        let logits = net.forward(&x, true);
        let (_, glogits) = softmax_xent(&logits, &labels);
        net.backward(&glogits);
        let eps = 1e-3f32;
        let gw: Vec<Vec<f32>> = net.weight_grads().to_vec();
        let gb: Vec<Vec<f32>> = net.bias_grads().to_vec();
        // check several weight gradients per transition
        for t_i in 0..net.w.len() {
            for &p in &[0usize, 5, 11, 23] {
                let orig = net.w[t_i][p];
                net.w[t_i][p] = orig + eps;
                let (lp, _) = softmax_xent(&net.forward(&x, false), &labels);
                net.w[t_i][p] = orig - eps;
                let (lm, _) = softmax_xent(&net.forward(&x, false), &labels);
                net.w[t_i][p] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let anal = gw[t_i][p];
                assert!(
                    (fd - anal).abs() < 2e-2 * (1.0 + fd.abs()),
                    "t={t_i} p={p} fd={fd} anal={anal}"
                );
            }
        }
        // bias gradients
        for t_i in 0..net.bias.len() {
            for i in [0usize, 1] {
                if i >= net.bias[t_i].len() {
                    continue;
                }
                let orig = net.bias[t_i][i];
                net.bias[t_i][i] = orig + eps;
                let (lp, _) = softmax_xent(&net.forward(&x, false), &labels);
                net.bias[t_i][i] = orig - eps;
                let (lm, _) = softmax_xent(&net.forward(&x, false), &labels);
                net.bias[t_i][i] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let anal = gb[t_i][i];
                assert!(
                    (fd - anal).abs() < 2e-2 * (1.0 + fd.abs()),
                    "bias t={t_i} i={i} fd={fd} anal={anal}"
                );
            }
        }
    }

    #[test]
    fn constant_init_trains_on_toy_task() {
        // §3.1: constant init works for sparse nets. Tiny binary task:
        // class = which half of the input has larger mass.
        //
        // Paths stay below the 8×16 edge capacity: at exact saturation
        // every edge exists exactly once and half/half signed constant
        // init cancels into an exact mirror symmetry (see EXPERIMENTS.md
        // §Findings — the degenerate regime behind the paper's Table 1
        // scrambling discussion); the sparse regime is the paper's
        // operating point.
        let t = TopologyBuilder::new(&[8, 16, 16, 2])
            .paths(96)
            .source(PathSource::Sobol { skip_bad_dims: true, scramble_seed: Some(1174) })
            .sign_policy(SignPolicy::FirstHalfPositive)
            .build();
        let mut net = SparseMlp::new(
            &t,
            SparseMlpConfig {
                init: Init::ConstantSignAlongPath,
                seed: 0,
                bias: true,
                freeze_signs: false,
                kernel: KernelKind::Auto,
            },
        );
        let mk = |seed: u64| {
            use crate::rng::{Pcg32, Rng};
            let mut rng = Pcg32::seeded(seed);
            let mut xs = Vec::new();
            let mut ys = Vec::new();
            for _ in 0..64 {
                let cls = rng.next_u32() & 1;
                let mut v = vec![0.1f32; 8];
                for i in 0..4 {
                    let idx = if cls == 0 { i } else { 4 + i };
                    v[idx] = 0.5 + rng.next_f32() * 0.5;
                }
                xs.extend(v);
                ys.push(cls);
            }
            (Tensor::from_vec(xs, &[64, 8]), ys)
        };
        let opt = Sgd { lr: 0.05, momentum: 0.9, weight_decay: 0.0 };
        let (xtr, ytr) = mk(1);
        let mut first_loss = 0.0;
        let mut last_loss = 0.0;
        for step in 0..150 {
            let logits = net.forward(&xtr, true);
            let (loss, g) = softmax_xent(&logits, &ytr);
            if step == 0 {
                first_loss = loss;
            }
            last_loss = loss;
            net.backward(&g);
            net.step(&opt);
        }
        assert!(
            last_loss < 0.5 * first_loss,
            "constant-init sparse net should learn: {first_loss} -> {last_loss}"
        );
        let (xte, yte) = mk(2);
        let acc = crate::nn::loss::accuracy(&net.forward(&xte, false), &yte);
        assert!(acc > 0.8, "test acc {acc}");
    }

    #[test]
    fn freeze_signs_keeps_signs() {
        let t = topo(&[6, 8, 2], 32);
        let mut net = SparseMlp::new(
            &t,
            SparseMlpConfig {
                init: Init::ConstantAlternating,
                seed: 0,
                bias: false,
                freeze_signs: true,
                kernel: KernelKind::Auto,
            },
        );
        let signs: Vec<Vec<f32>> =
            net.w.iter().map(|wt| wt.iter().map(|v| v.signum()).collect()).collect();
        let x = Tensor::from_vec(vec![0.5; 12], &[2, 6]);
        let opt = Sgd { lr: 0.5, momentum: 0.0, weight_decay: 0.0 };
        for _ in 0..20 {
            let logits = net.forward(&x, true);
            let (_, g) = softmax_xent(&logits, &[0, 1]);
            net.backward(&g);
            net.step(&opt);
        }
        for (wt, st) in net.w.iter().zip(&signs) {
            for (w, s) in wt.iter().zip(st) {
                assert!(w * s >= 0.0, "sign flipped");
            }
        }
    }

    #[test]
    fn nparams_and_nnz() {
        let t = topo(&[8, 16, 4], 64);
        let net = SparseMlp::new(&t, Default::default());
        assert_eq!(net.nparams(), 2 * 64 + 16 + 4);
        assert!(net.nnz() <= 128);
    }
}
