//! The paper's Fig 3 algorithm: an MLP represented by paths, trained
//! sparse from scratch.
//!
//! Weights are stored per `(transition, path)` and streamed **linearly**
//! during both inference and backpropagation — the paper's §3/§4.4
//! memory-access argument.  Activations are held in `[neurons, batch]`
//! layout so the per-path inner loop over the batch is contiguous and
//! vectorizes.
//!
//! The ReLU is implicit exactly as in Fig 3: a path contributes only if
//! its source activation is positive.
//!
//! **Parallel inference hot path.** The `[neurons, batch]` layout makes
//! every per-path inner loop a contiguous run of batch columns, and
//! distinct columns never share an accumulator — so the forward pass
//! shards conflict-free over batch columns via
//! [`crate::util::parallel::parallel_ranges`] (thread count:
//! `SOBOLNET_THREADS` / [`crate::util::parallel::set_num_threads`]).
//! Each column is still processed in exact path order, so results are
//! **bitwise identical** for every thread count.

use super::init::{w_init_magnitude, Init};
use super::optim::Sgd;
use super::tensor::Tensor;
use super::Model;
use crate::topology::PathTopology;
use crate::util::parallel::{parallel_ranges, SendPtr};

/// Minimum `paths × batch × transitions` edge-work before the forward
/// pass fans out to threads: below this, scoped-thread spawn overhead
/// beats the win (EXPERIMENTS.md §Perf).
const PAR_MIN_WORK: usize = 1 << 17;

/// Configuration for [`SparseMlp`].
#[derive(Debug, Clone, Copy)]
pub struct SparseMlpConfig {
    /// Weight initialization scheme (Table 3).
    pub init: Init,
    /// Seed for random initialization schemes.
    pub seed: u64,
    /// Use per-neuron biases (`bias[i]` in Fig 3).
    pub bias: bool,
    /// Freeze the initial signs and train only magnitudes (§3.2).
    pub freeze_signs: bool,
}

impl Default for SparseMlpConfig {
    fn default() -> Self {
        SparseMlpConfig { init: Init::ConstantPositive, seed: 0, bias: true, freeze_signs: false }
    }
}

/// Path-sparse multilayer perceptron (paper Fig 3).
#[derive(Debug, Clone)]
pub struct SparseMlp {
    /// The path topology (owns `index[][]`).
    pub topo: PathTopology,
    /// Path weights `w[t][p]` — streamed linearly.
    pub w: Vec<Vec<f32>>,
    /// Per-neuron biases of layers 1..=L (empty vecs when disabled).
    pub bias: Vec<Vec<f32>>,
    /// Fixed signs per weight (set when `freeze_signs`).
    pub fixed_signs: Option<Vec<Vec<f32>>>,
    gw: Vec<Vec<f32>>,
    mw: Vec<Vec<f32>>,
    gb: Vec<Vec<f32>>,
    mb: Vec<Vec<f32>>,
    /// Cached pre-activations per layer in `[n, B]` layout (train mode);
    /// `z[0]` is the raw input.
    z: Vec<Vec<f32>>,
    zbatch: usize,
}

impl SparseMlp {
    /// Build a sparse MLP over `topo` with the given config.
    pub fn new(topo: &PathTopology, cfg: SparseMlpConfig) -> Self {
        let t_cnt = topo.transitions();
        let p = topo.paths;
        let mut w: Vec<Vec<f32>> = Vec::with_capacity(t_cnt);
        for t in 0..t_cnt {
            let mut wt = vec![0.0f32; p];
            // magnitude from the average valence of this transition
            let fan_in = (p as f32 / topo.layer_sizes[t + 1] as f32).max(1.0) as usize;
            let fan_out = (p as f32 / topo.layer_sizes[t] as f32).max(1.0) as usize;
            let mag = w_init_magnitude(fan_in, fan_out);
            let signs_per_weight: Option<Vec<f32>> =
                topo.signs.as_ref().map(|s| s.to_vec());
            cfg.init.fill(
                &mut wt,
                mag,
                signs_per_weight.as_deref(),
                cfg.seed ^ (t as u64) << 17,
            );
            if cfg.init == Init::ConstantAlternating {
                // paper semantics: sign by destination NEURON index
                for (p, wv) in wt.iter_mut().enumerate() {
                    let dst = topo.index[t + 1][p];
                    *wv = if dst % 2 == 0 { mag } else { -mag };
                }
            }
            w.push(wt);
        }
        let bias: Vec<Vec<f32>> = (1..topo.layer_sizes.len())
            .map(|l| if cfg.bias { vec![0.0; topo.layer_sizes[l]] } else { Vec::new() })
            .collect();
        let fixed_signs = if cfg.freeze_signs {
            Some(
                w.iter()
                    .map(|wt| wt.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect())
                    .collect(),
            )
        } else {
            None
        };
        let gw = w.iter().map(|wt| vec![0.0; wt.len()]).collect();
        let mw = w.iter().map(|wt| vec![0.0; wt.len()]).collect();
        let gb = bias.iter().map(|b| vec![0.0; b.len()]).collect();
        let mb = bias.iter().map(|b| vec![0.0; b.len()]).collect();
        SparseMlp {
            topo: topo.clone(),
            w,
            bias,
            fixed_signs,
            gw,
            mw,
            gb,
            mb,
            z: Vec::new(),
            zbatch: 0,
        }
    }

    /// Transpose `[B, n]` → `[n, B]`.
    fn transpose_in(x: &Tensor, n: usize) -> Vec<f32> {
        let b = x.batch();
        assert_eq!(x.features(), n);
        let mut out = vec![0.0f32; n * b];
        for bi in 0..b {
            let row = x.row(bi);
            for (i, &v) in row.iter().enumerate() {
                out[i * b + bi] = v;
            }
        }
        out
    }

    /// Transpose `[n, B]` → `[B, n]` tensor.
    fn transpose_out(z: &[f32], n: usize, b: usize) -> Tensor {
        let mut t = Tensor::zeros(&[b, n]);
        for i in 0..n {
            for bi in 0..b {
                t.data[bi * n + i] = z[i * b + bi];
            }
        }
        t
    }

    /// The paper's Fig 3 inference loop, scalar and literal, for a
    /// single input — used as the correctness oracle in tests.
    pub fn fig3_reference(&self, input: &[f32]) -> Vec<f32> {
        let sizes = &self.topo.layer_sizes;
        let total: usize = sizes.iter().sum();
        let mut a = vec![0.0f32; total];
        a[..sizes[0]].copy_from_slice(input);
        // offsets of each layer in the flat activation array
        let mut off = vec![0usize; sizes.len()];
        for l in 1..sizes.len() {
            off[l] = off[l - 1] + sizes[l - 1];
            // biases (Fig 3: "or bias[i], if bias terms are used")
            if !self.bias[l - 1].is_empty() {
                for (i, &b) in self.bias[l - 1].iter().enumerate() {
                    a[off[l] + i] = b;
                }
            }
        }
        for l in 1..sizes.len() {
            for p in 0..self.topo.paths {
                let prev = off[l - 1] + self.topo.index[l - 1][p] as usize;
                if a[prev] > 0.0 {
                    let cur = off[l] + self.topo.index[l][p] as usize;
                    a[cur] += self.w[l - 1][p] * a[prev];
                }
            }
        }
        a[off[sizes.len() - 1]..].to_vec()
    }
}

impl Model for SparseMlp {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let sizes = &self.topo.layer_sizes;
        let b = x.batch();
        let t_cnt = self.topo.transitions();
        let paths = self.topo.paths;
        let mut z: Vec<Vec<f32>> = Vec::with_capacity(sizes.len());
        z.push(Self::transpose_in(x, sizes[0]));
        for t in 0..t_cnt {
            z.push(vec![0.0f32; sizes[t + 1] * b]);
        }
        {
            // Column-sharded execution: each thread owns a disjoint
            // range [c0, c1) of batch columns of EVERY layer buffer and
            // runs the whole multi-layer loop for it — one thread fan-out
            // per forward, no barriers between transitions.
            let ptrs: Vec<SendPtr<f32>> =
                z.iter_mut().map(|zl| SendPtr::new(zl.as_mut_ptr())).collect();
            let index = &self.topo.index;
            let ws = &self.w;
            let biases = &self.bias;
            let columns = |c0: usize, c1: usize| {
                for t in 0..t_cnt {
                    let src_idx = &index[t];
                    let dst_idx = &index[t + 1];
                    let wt = &ws[t];
                    let zprev = ptrs[t].get() as *const f32;
                    let znext = ptrs[t + 1].get();
                    if !biases[t].is_empty() {
                        for (i, &bv) in biases[t].iter().enumerate() {
                            for bi in c0..c1 {
                                unsafe { *znext.add(i * b + bi) = bv };
                            }
                        }
                    }
                    for p in 0..paths {
                        let s = src_idx[p] as usize * b;
                        let d = dst_idx[p] as usize * b;
                        let w = wt[p];
                        // branchless ReLU gate: w·max(v,0) — vectorizes
                        // cleanly (EXPERIMENTS.md §Perf)
                        for bi in c0..c1 {
                            unsafe {
                                *znext.add(d + bi) += w * (*zprev.add(s + bi)).max(0.0);
                            }
                        }
                    }
                }
            };
            // below the work threshold run inline (min_chunk = b makes
            // parallel_ranges take its sequential path)
            let min_chunk = if paths * b * t_cnt >= PAR_MIN_WORK { 1 } else { b.max(1) };
            parallel_ranges(b, min_chunk, columns);
        }
        let logits = Self::transpose_out(z.last().unwrap(), sizes[sizes.len() - 1], b);
        if train {
            self.z = z;
            self.zbatch = b;
        }
        logits
    }

    fn backward(&mut self, glogits: &Tensor) {
        let sizes = &self.topo.layer_sizes;
        let b = self.zbatch;
        assert_eq!(glogits.batch(), b, "forward(train=true) must precede backward");
        let mut gz = Self::transpose_in(glogits, sizes[sizes.len() - 1]);
        for t in (0..self.topo.transitions()).rev() {
            // bias gradients: row sums of gz (layer t+1)
            if !self.bias[t].is_empty() {
                for i in 0..sizes[t + 1] {
                    let mut s = 0.0f32;
                    for bi in 0..b {
                        s += gz[i * b + bi];
                    }
                    self.gb[t][i] += s;
                }
            }
            let src_idx = &self.topo.index[t];
            let dst_idx = &self.topo.index[t + 1];
            let wt = &self.w[t];
            let gwt = &mut self.gw[t];
            let zprev = &self.z[t];
            let mut gprev = vec![0.0f32; sizes[t] * b];
            for p in 0..self.topo.paths {
                let s = src_idx[p] as usize * b;
                let d = dst_idx[p] as usize * b;
                let w = wt[p];
                let mut gacc = 0.0f32;
                let (src, gout) = (&zprev[s..s + b], &gz[d..d + b]);
                let gsrc = &mut gprev[s..s + b];
                // branchless gating: the (v > 0) indicator multiplies
                // both products, letting LLVM vectorize the loop
                for bi in 0..b {
                    let v = src[bi];
                    let gate = if v > 0.0 { 1.0f32 } else { 0.0 };
                    let g = gout[bi] * gate;
                    gacc += g * v;
                    gsrc[bi] += w * g;
                }
                gwt[p] += gacc;
            }
            gz = gprev;
        }
    }

    fn step(&mut self, opt: &Sgd) {
        for t in 0..self.w.len() {
            let signs = self.fixed_signs.as_ref().map(|s| s[t].as_slice());
            opt.update(&mut self.w[t], &mut self.gw[t], &mut self.mw[t], signs);
            if !self.bias[t].is_empty() {
                opt.update_no_decay(&mut self.bias[t], &mut self.gb[t], &mut self.mb[t]);
            }
        }
    }

    fn nparams(&self) -> usize {
        self.w.iter().map(|w| w.len()).sum::<usize>()
            + self.bias.iter().map(|b| b.len()).sum::<usize>()
    }

    fn nnz(&self) -> usize {
        self.topo.nnz()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::loss::softmax_xent;
    use crate::topology::{PathSource, SignPolicy, TopologyBuilder};

    fn topo(sizes: &[usize], paths: usize) -> PathTopology {
        TopologyBuilder::new(sizes)
            .paths(paths)
            .source(PathSource::Sobol { skip_bad_dims: true, scramble_seed: None })
            .build()
    }

    #[test]
    fn forward_matches_fig3_reference() {
        let t = topo(&[8, 16, 16, 4], 64);
        let mut net = SparseMlp::new(
            &t,
            SparseMlpConfig { init: Init::UniformRandom, seed: 3, bias: true, freeze_signs: false },
        );
        // non-trivial biases
        for bl in net.bias.iter_mut() {
            for (i, v) in bl.iter_mut().enumerate() {
                *v = 0.01 * i as f32 - 0.02;
            }
        }
        let input: Vec<f32> = (0..8).map(|i| (i as f32 * 0.37).sin()).collect();
        let x = Tensor::from_vec(input.clone(), &[1, 8]);
        let batched = net.forward(&x, false);
        let reference = net.fig3_reference(&input);
        for (a, b) in batched.row(0).iter().zip(&reference) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn batching_is_consistent_with_single() {
        let t = topo(&[6, 8, 4], 32);
        let mut net = SparseMlp::new(
            &t,
            SparseMlpConfig { init: Init::UniformRandom, seed: 1, ..Default::default() },
        );
        let xs: Vec<Vec<f32>> = (0..5)
            .map(|k| (0..6).map(|i| ((i + k) as f32 * 0.31).cos()).collect())
            .collect();
        let flat: Vec<f32> = xs.iter().flatten().cloned().collect();
        let batch = net.forward(&Tensor::from_vec(flat, &[5, 6]), false);
        for (k, xrow) in xs.iter().enumerate() {
            let single = net.forward(&Tensor::from_vec(xrow.clone(), &[1, 6]), false);
            for (a, b) in batch.row(k).iter().zip(single.row(0)) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn gradients_match_finite_difference() {
        let t = topo(&[5, 7, 3], 24);
        let mut net = SparseMlp::new(
            &t,
            SparseMlpConfig { init: Init::UniformRandom, seed: 7, bias: true, freeze_signs: false },
        );
        let x = Tensor::from_vec(
            (0..10).map(|i| (i as f32 * 0.7).sin().abs() + 0.1).collect(),
            &[2, 5],
        );
        let labels = [1u32, 2];
        let logits = net.forward(&x, true);
        let (_, glogits) = softmax_xent(&logits, &labels);
        net.backward(&glogits);
        let eps = 1e-3f32;
        // check several weight gradients per transition
        for t_i in 0..net.w.len() {
            for &p in &[0usize, 5, 11, 23] {
                let orig = net.w[t_i][p];
                net.w[t_i][p] = orig + eps;
                let (lp, _) = softmax_xent(&net.forward(&x, false), &labels);
                net.w[t_i][p] = orig - eps;
                let (lm, _) = softmax_xent(&net.forward(&x, false), &labels);
                net.w[t_i][p] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let anal = net.gw[t_i][p];
                assert!(
                    (fd - anal).abs() < 2e-2 * (1.0 + fd.abs()),
                    "t={t_i} p={p} fd={fd} anal={anal}"
                );
            }
        }
        // bias gradients
        for t_i in 0..net.bias.len() {
            for i in [0usize, 1] {
                if i >= net.bias[t_i].len() {
                    continue;
                }
                let orig = net.bias[t_i][i];
                net.bias[t_i][i] = orig + eps;
                let (lp, _) = softmax_xent(&net.forward(&x, false), &labels);
                net.bias[t_i][i] = orig - eps;
                let (lm, _) = softmax_xent(&net.forward(&x, false), &labels);
                net.bias[t_i][i] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let anal = net.gb[t_i][i];
                assert!(
                    (fd - anal).abs() < 2e-2 * (1.0 + fd.abs()),
                    "bias t={t_i} i={i} fd={fd} anal={anal}"
                );
            }
        }
    }

    #[test]
    fn constant_init_trains_on_toy_task() {
        // §3.1: constant init works for sparse nets. Tiny binary task:
        // class = which half of the input has larger mass.
        //
        // Paths stay below the 8×16 edge capacity: at exact saturation
        // every edge exists exactly once and half/half signed constant
        // init cancels into an exact mirror symmetry (see EXPERIMENTS.md
        // §Findings — the degenerate regime behind the paper's Table 1
        // scrambling discussion); the sparse regime is the paper's
        // operating point.
        let t = TopologyBuilder::new(&[8, 16, 16, 2])
            .paths(96)
            .source(PathSource::Sobol { skip_bad_dims: true, scramble_seed: Some(1174) })
            .sign_policy(SignPolicy::FirstHalfPositive)
            .build();
        let mut net = SparseMlp::new(
            &t,
            SparseMlpConfig {
                init: Init::ConstantSignAlongPath,
                seed: 0,
                bias: true,
                freeze_signs: false,
            },
        );
        let mk = |seed: u64| {
            use crate::rng::{Pcg32, Rng};
            let mut rng = Pcg32::seeded(seed);
            let mut xs = Vec::new();
            let mut ys = Vec::new();
            for _ in 0..64 {
                let cls = rng.next_u32() & 1;
                let mut v = vec![0.1f32; 8];
                for i in 0..4 {
                    let idx = if cls == 0 { i } else { 4 + i };
                    v[idx] = 0.5 + rng.next_f32() * 0.5;
                }
                xs.extend(v);
                ys.push(cls);
            }
            (Tensor::from_vec(xs, &[64, 8]), ys)
        };
        let opt = Sgd { lr: 0.05, momentum: 0.9, weight_decay: 0.0 };
        let (xtr, ytr) = mk(1);
        let mut first_loss = 0.0;
        let mut last_loss = 0.0;
        for step in 0..150 {
            let logits = net.forward(&xtr, true);
            let (loss, g) = softmax_xent(&logits, &ytr);
            if step == 0 {
                first_loss = loss;
            }
            last_loss = loss;
            net.backward(&g);
            net.step(&opt);
        }
        assert!(
            last_loss < 0.5 * first_loss,
            "constant-init sparse net should learn: {first_loss} -> {last_loss}"
        );
        let (xte, yte) = mk(2);
        let acc = crate::nn::loss::accuracy(&net.forward(&xte, false), &yte);
        assert!(acc > 0.8, "test acc {acc}");
    }

    #[test]
    fn freeze_signs_keeps_signs() {
        let t = topo(&[6, 8, 2], 32);
        let mut net = SparseMlp::new(
            &t,
            SparseMlpConfig {
                init: Init::ConstantAlternating,
                seed: 0,
                bias: false,
                freeze_signs: true,
            },
        );
        let signs: Vec<Vec<f32>> =
            net.w.iter().map(|wt| wt.iter().map(|v| v.signum()).collect()).collect();
        let x = Tensor::from_vec(vec![0.5; 12], &[2, 6]);
        let opt = Sgd { lr: 0.5, momentum: 0.0, weight_decay: 0.0 };
        for _ in 0..20 {
            let logits = net.forward(&x, true);
            let (_, g) = softmax_xent(&logits, &[0, 1]);
            net.backward(&g);
            net.step(&opt);
        }
        for (wt, st) in net.w.iter().zip(&signs) {
            for (w, s) in wt.iter().zip(st) {
                assert!(w * s >= 0.0, "sign flipped");
            }
        }
    }

    #[test]
    fn nparams_and_nnz() {
        let t = topo(&[8, 16, 4], 64);
        let net = SparseMlp::new(&t, Default::default());
        assert_eq!(net.nparams(), 2 * 64 + 16 + 4);
        assert!(net.nnz() <= 128);
    }
}
