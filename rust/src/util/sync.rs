//! Poison-immune synchronization helpers.
//!
//! The engine's long-lived serving contract is that one panicking
//! thread must not cascade `PoisonError` panics into every other
//! thread that later touches the same lock: all the state guarded by
//! these locks (pool bookkeeping, admission queues, metrics rings,
//! EWMA cells) is maintained to a consistent snapshot *before* any
//! caller code can run, so recovering the guard from a poisoned mutex
//! is always sound.  Every lock/wait in the serving and pool layers
//! goes through these helpers (or inlines the same
//! `unwrap_or_else(|e| e.into_inner())` where a typed wrapper doesn't
//! fit, e.g. `Condvar::wait_timeout`'s tuple payload).

use std::sync::{Condvar, Mutex, MutexGuard};

/// Lock `m`, recovering the guard if some other thread panicked while
/// holding it.
pub fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Wait on `cv`, recovering the guard from a poisoned mutex exactly
/// like [`plock`].
pub fn cwait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn plock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison (expected in this test)");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*plock(&m), 7, "state behind the poisoned lock is intact");
        *plock(&m) = 8;
        assert_eq!(*plock(&m), 8);
    }

    #[test]
    fn cwait_wakes_through_poison() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        // poison the mutex first
        let p2 = pair.clone();
        let _ = std::thread::spawn(move || {
            let _g = p2.0.lock().unwrap();
            panic!("poison (expected in this test)");
        })
        .join();
        let p3 = pair.clone();
        let waker = std::thread::spawn(move || {
            *plock(&p3.0) = true;
            p3.1.notify_all();
        });
        let mut done = plock(&pair.0);
        while !*done {
            done = cwait(&pair.1, done);
        }
        waker.join().expect("waker");
    }
}
