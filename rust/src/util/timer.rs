//! Wall-clock timing helpers for the bench harness and trainers.

use std::time::Instant;

/// Simple stopwatch.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start a new stopwatch.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Restart and return the elapsed seconds of the previous lap.
    pub fn lap(&mut self) -> f64 {
        let e = self.elapsed_secs();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.elapsed_secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances() {
        let mut t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let lap = t.lap();
        assert!(lap >= 0.004, "lap was {lap}");
        // after lap() the clock restarts
        assert!(t.elapsed_secs() < lap + 0.5);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
