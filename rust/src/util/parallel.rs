//! Data-parallel helpers (the `rayon` substrate) backed by a
//! **persistent worker pool**: long-lived threads parked on a condvar,
//! woken per dispatch, with chunk claiming under a mutex.
//!
//! Earlier revisions spawned a fresh `std::thread::scope` per call,
//! which put ~tens of microseconds of spawn/join cost on every forward
//! pass and forced the sparse engine to gate parallelism behind a large
//! `PAR_MIN_WORK` threshold.  The pool amortizes that cost to a
//! wake/park round-trip, so small-batch serving and the backward pass
//! profit from threads too.
//!
//! Used by the matmul kernel, the conv/batch loops, and the
//! column-sharded forward/backward of [`crate::nn::sparse`].  Thread
//! count defaults to the machine parallelism, capped by
//! `SOBOLNET_THREADS` and overridable at runtime via
//! [`set_num_threads`] (the pool grows on demand and never shrinks;
//! each dispatch admits at most `threads − 1` workers, so surplus
//! workers park through it and a lowered thread target is honored even
//! when chunks outnumber threads).  A chunk panic on a worker is
//! re-raised on the dispatching thread once the region completes, like
//! the scoped-thread implementation it replaces.
//!
//! Guarantees relied on elsewhere:
//!
//! * **Exact chunk boundaries.**  [`parallel_chunks`] partitions `0..n`
//!   at multiples of `chunk` regardless of the thread count, and the
//!   sequential fallback iterates the *same* boundaries — callers can
//!   key per-chunk shadow buffers off `start / chunk` and get
//!   bitwise-deterministic reductions for every `SOBOLNET_THREADS`.
//! * **Nested calls run inline.**  A `parallel_*` call from inside a
//!   worker (or from the dispatching thread while it helps execute
//!   chunks) degrades to the sequential path instead of deadlocking on
//!   the single job slot.
//! * **Zero work is safe.**  `n == 0` dispatches nothing.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, OnceLock};

static CACHED_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Number of worker threads to use.
pub fn num_threads() -> usize {
    let c = CACHED_THREADS.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("SOBOLNET_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, |n| n.get()));
    CACHED_THREADS.store(n, Ordering::Relaxed);
    n
}

/// Override the worker-thread count at runtime (wins over the
/// `SOBOLNET_THREADS` environment variable).  Used by benches and tests
/// to sweep thread scaling within one process; clamped to ≥ 1.  The
/// pool resizes lazily: the next dispatch spawns missing workers.
pub fn set_num_threads(n: usize) {
    CACHED_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Raw mutable pointer that may cross thread boundaries.
///
/// Safety contract: every thread must write only to index ranges
/// disjoint from all other threads' (the [`parallel_ranges`] pattern:
/// the caller partitions `0..n` and derives offsets from its range).
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Wrap a raw pointer.
    pub fn new(p: *mut T) -> Self {
        SendPtr(p)
    }

    /// The wrapped pointer.
    pub fn get(&self) -> *mut T {
        self.0
    }
}

impl<T> std::fmt::Debug for SendPtr<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SendPtr({:p})", self.0)
    }
}

// ---------------------------------------------------------------------------
// The pool
// ---------------------------------------------------------------------------

/// One dispatched parallel region: a type-erased `Fn(usize, usize)`
/// living on the dispatcher's stack.  Valid only while that dispatch is
/// active — the dispatcher does not return (or unwind) past its
/// [`ActiveJob`] guard until every claimed chunk has finished.
#[derive(Clone, Copy)]
struct Job {
    call: unsafe fn(*const (), usize, usize),
    data: *const (),
    n: usize,
    chunk: usize,
}

// Safety: `data` is only dereferenced through `call` while the
// dispatching thread keeps the closure alive (see `ActiveJob`), and the
// closure itself is required to be `Sync` by the public entry points.
unsafe impl Send for Job {}

struct PoolState {
    /// Monotone dispatch generation; workers remember the last one they
    /// looked at so a stale worker never claims chunks of a new job.
    gen: u64,
    /// The single active job slot (`None` between dispatches).
    job: Option<Job>,
    /// Next unclaimed index (multiple of `job.chunk` from 0).
    next: usize,
    /// Claimed-but-unfinished chunks.
    remaining: usize,
    /// Workers that joined the current generation (capped by `limit`,
    /// so a dispatch never runs wider than its thread target even when
    /// the pool holds more parked workers).
    joined: usize,
    /// Max workers allowed to join the current generation
    /// (thread target − 1; the dispatcher itself is the +1).
    limit: usize,
    /// A chunk of the current dispatch panicked on a worker; re-raised
    /// on the dispatcher after completion.
    panicked: bool,
    /// Worker threads alive (dispatchers are not counted).
    spawned: usize,
    /// Completed dispatches (observability / tests).
    dispatches: u64,
}

struct Pool {
    state: Mutex<PoolState>,
    /// Workers park here waiting for a new generation.
    work_cv: Condvar,
    /// Dispatchers park here waiting for `remaining == 0` (and queued
    /// dispatchers wait here for the job slot to free up).
    done_cv: Condvar,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| Pool {
        state: Mutex::new(PoolState {
            gen: 0,
            job: None,
            next: 0,
            remaining: 0,
            joined: 0,
            limit: 0,
            panicked: false,
            spawned: 0,
            dispatches: 0,
        }),
        work_cv: Condvar::new(),
        done_cv: Condvar::new(),
    })
}

/// Poison-immune lock: a worker can only panic inside caller code while
/// *not* holding the state lock, but be robust anyway.
fn lock(p: &Pool) -> MutexGuard<'_, PoolState> {
    p.state.lock().unwrap_or_else(|e| e.into_inner())
}

thread_local! {
    /// True while this thread is executing chunks of a parallel region
    /// (worker, or dispatcher helping).  Nested `parallel_*` calls then
    /// run inline instead of re-entering the pool.
    static IN_PARALLEL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

fn in_parallel() -> bool {
    IN_PARALLEL.with(|c| c.get())
}

/// Restores the thread-local nesting flag even if a chunk panics.
struct ParallelFlagGuard;

impl ParallelFlagGuard {
    fn enter() -> ParallelFlagGuard {
        IN_PARALLEL.with(|c| c.set(true));
        ParallelFlagGuard
    }
}

impl Drop for ParallelFlagGuard {
    fn drop(&mut self) {
        IN_PARALLEL.with(|c| c.set(false));
    }
}

/// Marks one claimed chunk finished on drop — including on unwind, so a
/// panicking chunk cannot strand the dispatcher in its completion wait.
struct ChunkDoneGuard(&'static Pool);

impl Drop for ChunkDoneGuard {
    fn drop(&mut self) {
        let mut st = lock(self.0);
        if std::thread::panicking() {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            self.0.done_cv.notify_all();
        }
    }
}

/// Dispatcher-side guard: waits out stragglers and frees the job slot,
/// on the normal path and on unwind alike, so `Job::data` never
/// outlives the closure it points into.
struct ActiveJob(&'static Pool);

impl Drop for ActiveJob {
    fn drop(&mut self) {
        let mut st = lock(self.0);
        // Cancel chunks nobody has claimed yet.  On the normal path the
        // dispatcher's help loop already drained them (no-op); on the
        // unwind path this prevents waiting forever on work no thread
        // will ever take (e.g. worker spawn failed entirely).
        if let Some(j) = st.job {
            if st.next < j.n {
                let unclaimed = (j.n - st.next + j.chunk - 1) / j.chunk;
                st.next = j.n;
                st.remaining -= unclaimed;
            }
        }
        while st.remaining > 0 {
            st = self.0.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
        st.dispatches += 1;
        // wake dispatchers queued on the job slot
        self.0.done_cv.notify_all();
    }
}

fn worker_main() {
    let pool = pool();

    /// Keeps `spawned` truthful if a chunk panic kills this worker, so
    /// a later dispatch spawns a replacement.
    struct Alive(&'static Pool);
    impl Drop for Alive {
        fn drop(&mut self) {
            lock(self.0).spawned -= 1;
        }
    }
    let _alive = Alive(pool);

    let mut seen = 0u64;
    loop {
        let mut st = lock(pool);
        loop {
            if st.gen != seen {
                match st.job {
                    // join only while the dispatch is below its thread
                    // target — surplus parked workers sit this one out
                    Some(j) if st.next < j.n && st.joined < st.limit => {
                        st.joined += 1;
                        break;
                    }
                    _ => seen = st.gen, // nothing (left) for us here
                }
            }
            st = pool.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        seen = st.gen;
        let job = st.job.expect("claimable job");
        let _flag = ParallelFlagGuard::enter();
        loop {
            // claim under the lock; generations guard against claiming
            // chunks of a newer job with this job's closure
            if st.gen != seen || st.next >= job.n {
                break;
            }
            let start = st.next;
            let end = (start + job.chunk).min(job.n);
            st.next = end;
            drop(st);
            {
                let _done = ChunkDoneGuard(pool);
                unsafe { (job.call)(job.data, start, end) };
            }
            st = lock(pool);
        }
        drop(st);
    }
}

unsafe fn invoke<F: Fn(usize, usize)>(data: *const (), start: usize, end: usize) {
    (*(data as *const F))(start, end)
}

/// Dispatch `f` over `0..n` in `chunk`-sized pieces on the pool.  The
/// calling thread installs the job, helps execute chunks, then waits
/// for stragglers.  Requires `threads ≥ 2`, `n ≥ 1`, `chunk ≥ 1`.
fn run_pool<F: Fn(usize, usize) + Sync>(n: usize, chunk: usize, threads: usize, f: &F) {
    let pool = pool();
    let job = Job { call: invoke::<F>, data: f as *const F as *const (), n, chunk };
    let nchunks = (n + chunk - 1) / chunk;

    let mut st = lock(pool);
    // single job slot: queue behind any active dispatch
    while st.job.is_some() {
        st = pool.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
    }
    // grow the pool to the requested width (never shrinks; surplus
    // workers claim nothing and park again)
    let want = threads.saturating_sub(1);
    while st.spawned < want {
        let name = format!("sobolnet-pool-{}", st.spawned);
        match std::thread::Builder::new().name(name).spawn(worker_main) {
            Ok(handle) => {
                drop(handle); // detached; lives for the process
                st.spawned += 1;
            }
            Err(_) => break, // resource limit: proceed with what we have
        }
    }
    st.gen = st.gen.wrapping_add(1);
    st.job = Some(job);
    st.next = 0;
    st.remaining = nchunks;
    st.joined = 0;
    st.limit = want;
    st.panicked = false;
    pool.work_cv.notify_all();

    // From here on the job slot MUST be cleaned up exactly once, even
    // if `f` panics on this thread — ActiveJob's drop waits for the
    // workers and frees the slot.
    let active = ActiveJob(pool);
    {
        let _flag = ParallelFlagGuard::enter();
        loop {
            if st.next >= n {
                break;
            }
            let start = st.next;
            let end = (start + chunk).min(n);
            st.next = end;
            drop(st);
            {
                let _done = ChunkDoneGuard(pool);
                f(start, end);
            }
            st = lock(pool);
        }
        drop(st);
    }
    // Normal path: wait out stragglers while the slot is still ours so
    // a worker-side chunk panic can be re-raised here (ActiveJob's drop
    // stays the unwind path and must not panic).
    let worker_panicked = {
        let mut st = lock(pool);
        while st.remaining > 0 {
            st = pool.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.panicked
    };
    drop(active); // clear the slot, count the dispatch
    if worker_panicked {
        panic!("worker pool: a parallel chunk panicked on a worker thread; results are incomplete");
    }
}

/// Run `f(start, end)` over disjoint chunks of `0..n` on the worker
/// pool.  `f` must be `Sync` (it receives disjoint ranges, so data
/// writes should be pre-partitioned by the caller, e.g. via
/// `chunks_mut` or [`SendPtr`]).  Chunk sizes derive from the current
/// thread count; when the *values* computed depend on chunk boundaries
/// (reductions), use [`parallel_chunks`] instead.
///
/// Runs inline when `n <= min_chunk`, when only one thread is
/// configured, or when called from inside another parallel region.
pub fn parallel_ranges<F: Fn(usize, usize) + Sync>(n: usize, min_chunk: usize, f: F) {
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= min_chunk || in_parallel() {
        f(0, n);
        return;
    }
    let chunk = ((n + threads - 1) / threads).max(min_chunk).max(1);
    run_pool(n, chunk, threads, &f);
}

/// Run `f(start, end)` over **fixed** `chunk`-aligned pieces of `0..n`:
/// every call sees `start % chunk == 0` and `end - start <= chunk`,
/// independent of the thread count, and the single-thread/nested
/// fallback iterates the exact same boundaries in order.
///
/// This is the deterministic-reduction primitive: callers may index
/// per-chunk shadow accumulators by `start / chunk` and merge them in
/// fixed chunk order, making the result bitwise identical for every
/// `SOBOLNET_THREADS` setting (see `SparseMlp::backward`).
pub fn parallel_chunks<F: Fn(usize, usize) + Sync>(n: usize, chunk: usize, f: F) {
    assert!(chunk > 0, "chunk must be positive");
    if n == 0 {
        return;
    }
    let threads = num_threads();
    if threads <= 1 || n <= chunk || in_parallel() {
        sequential_chunks(n, chunk, &f);
        return;
    }
    run_pool(n, chunk, threads, &f);
}

/// Iterate `f(start, end)` over the exact same `chunk`-aligned
/// boundaries as [`parallel_chunks`], on the calling thread.  The
/// single source of truth for chunk geometry: callers that gate
/// parallelism themselves (work thresholds) use this for the inline
/// path so both paths see identical boundaries.
pub fn sequential_chunks<F: FnMut(usize, usize)>(n: usize, chunk: usize, mut f: F) {
    assert!(chunk > 0, "chunk must be positive");
    let mut start = 0;
    while start < n {
        let end = (start + chunk).min(n);
        f(start, end);
        start = end;
    }
}

/// Map over mutable row-chunks of `data` (each of `row_len` floats) in
/// parallel: `f(row_index, row_slice)`.
pub fn parallel_rows<F: Fn(usize, &mut [f32]) + Sync>(data: &mut [f32], row_len: usize, f: F) {
    assert!(row_len > 0 && data.len() % row_len == 0);
    let rows = data.len() / row_len;
    if rows == 0 {
        return;
    }
    let p = SendPtr::new(data.as_mut_ptr());
    parallel_ranges(rows, 1, |r0, r1| {
        for r in r0..r1 {
            // Safety: disjoint row ranges per chunk; `data` is borrowed
            // mutably for the whole call.
            let row =
                unsafe { std::slice::from_raw_parts_mut(p.get().add(r * row_len), row_len) };
            f(r, row);
        }
    });
}

/// Pool observability for tests and benches: `(worker threads alive,
/// completed dispatches)`.  Both are process-global; `spawned` is
/// monotone while no worker panics and is bounded by the largest thread
/// target any dispatch has used, minus one (the dispatcher itself).
pub fn pool_stats() -> (usize, u64) {
    let st = lock(pool());
    (st.spawned, st.dispatches)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    /// Serializes every test that mutates the process-global thread
    /// count or asserts on `pool_stats` (other tests in this binary may
    /// dispatch concurrently, but they leave the thread count alone).
    static POOL_SHAPE_LOCK: Mutex<()> = Mutex::new(());

    /// A thread target no concurrent test exceeds: every other dispatch
    /// in this binary uses at most the machine parallelism (or small
    /// explicit overrides ≤ 8).
    fn max_target() -> usize {
        std::thread::available_parallelism().map_or(1, |n| n.get()).max(8)
    }

    #[test]
    fn ranges_cover_everything_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_ranges(1000, 16, |a, b| {
            for i in a..b {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn small_n_runs_inline() {
        let hits = AtomicU64::new(0);
        parallel_ranges(3, 16, |a, b| {
            hits.fetch_add((b - a) as u64, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn rows_see_correct_indices() {
        let mut data = vec![0.0f32; 64 * 8];
        parallel_rows(&mut data, 8, |r, row| {
            for v in row.iter_mut() {
                *v = r as f32;
            }
        });
        for (r, row) in data.chunks(8).enumerate() {
            assert!(row.iter().all(|&v| v == r as f32));
        }
    }

    #[test]
    fn num_threads_at_least_one() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn set_num_threads_overrides_and_clamps() {
        let _guard = POOL_SHAPE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let before = num_threads();
        set_num_threads(3);
        assert_eq!(num_threads(), 3);
        set_num_threads(0); // clamped
        assert_eq!(num_threads(), 1);
        set_num_threads(before);
        assert_eq!(num_threads(), before);
    }

    #[test]
    fn send_ptr_disjoint_writes() {
        let mut data = vec![0u32; 256];
        let p = SendPtr::new(data.as_mut_ptr());
        parallel_ranges(256, 16, |a, b| {
            for i in a..b {
                unsafe { *p.get().add(i) = i as u32 };
            }
        });
        assert!(data.iter().enumerate().all(|(i, &v)| v == i as u32));
    }

    #[test]
    fn pool_reuses_threads_across_dispatches() {
        let _guard = POOL_SHAPE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let ambient = num_threads();
        // grow the pool to the binary-wide max once, then verify that
        // further dispatches reuse the same threads
        set_num_threads(max_target());
        let sink = AtomicU64::new(0);
        let work = |a: usize, b: usize| {
            sink.fetch_add((b - a) as u64, Ordering::Relaxed);
        };
        parallel_ranges(1 << 12, 1, work);
        let (spawned_warm, dispatches_warm) = pool_stats();
        assert!(spawned_warm >= max_target() - 1, "pool grew to the target width");
        for _ in 0..8 {
            parallel_ranges(1 << 12, 1, work);
        }
        let (spawned_after, dispatches_after) = pool_stats();
        assert_eq!(spawned_after, spawned_warm, "no re-spawn on later dispatches");
        assert!(dispatches_after >= dispatches_warm + 8, "dispatches counted");
        set_num_threads(ambient);
    }

    #[test]
    fn resize_mid_process_takes_effect() {
        let _guard = POOL_SHAPE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let ambient = num_threads();
        let run = |n: usize| {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            parallel_ranges(n, 1, |a, b| {
                for i in a..b {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        };
        set_num_threads(2);
        run(4096);
        set_num_threads(6);
        run(4096);
        let (spawned, _) = pool_stats();
        assert!(spawned >= 5, "pool grew after set_num_threads(6), spawned={spawned}");
        set_num_threads(1);
        run(64); // sequential path still covers everything
        set_num_threads(ambient);
    }

    #[test]
    fn nested_calls_run_inline_without_deadlock() {
        let hits: Vec<AtomicU64> = (0..64 * 64).map(|_| AtomicU64::new(0)).collect();
        let hits = &hits;
        parallel_ranges(64, 1, |a, b| {
            for outer in a..b {
                // nested: must run inline on this thread, not re-enter
                // the single job slot
                parallel_ranges(64, 1, |c, d| {
                    for inner in c..d {
                        hits[outer * 64 + inner].fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn zero_work_is_a_noop() {
        let hits = AtomicU64::new(0);
        parallel_ranges(0, 4, |a, b| {
            hits.fetch_add((b - a) as u64, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 0);
        parallel_chunks(0, 4, |_, _| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 0);
        parallel_rows(&mut [], 8, |_, _| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn fixed_chunks_have_stable_boundaries() {
        let _guard = POOL_SHAPE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let ambient = num_threads();
        let collect = |threads: usize| {
            set_num_threads(threads);
            let seen = Mutex::new(Vec::new());
            parallel_chunks(103, 8, |a, b| {
                seen.lock().unwrap().push((a, b));
            });
            let mut v = seen.into_inner().unwrap();
            v.sort_unstable();
            v
        };
        let one = collect(1);
        for threads in [2usize, 4, 8] {
            assert_eq!(collect(threads), one, "threads={threads}");
        }
        set_num_threads(ambient);
        assert_eq!(one.len(), 13); // ceil(103 / 8)
        for (i, &(a, b)) in one.iter().enumerate() {
            assert_eq!(a, i * 8);
            assert_eq!(b, ((i + 1) * 8).min(103));
        }
    }

    #[test]
    fn chunk_dispatch_respects_thread_cap() {
        let _guard = POOL_SHAPE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let ambient = num_threads();
        // make sure the pool already holds more workers than the cap
        set_num_threads(max_target());
        parallel_ranges(1 << 12, 1, |_, _| {});
        // a 2-thread dispatch with many more chunks than threads must
        // still run on at most 2 distinct threads
        set_num_threads(2);
        let ids = Mutex::new(std::collections::HashSet::new());
        parallel_chunks(256, 1, |_, _| {
            ids.lock().unwrap().insert(std::thread::current().id());
        });
        let n = ids.into_inner().unwrap().len();
        assert!(n <= 2, "2-thread dispatch ran on {n} distinct threads");
        set_num_threads(ambient);
    }

    #[test]
    #[should_panic]
    fn chunk_panic_propagates_to_dispatcher() {
        let _guard = POOL_SHAPE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_num_threads(4);
        parallel_ranges(1 << 10, 1, |a, _| {
            if a == 0 {
                panic!("boom");
            }
        });
    }
}
