//! Minimal data-parallel helper (the `rayon` substrate): split a range
//! of work items across `std::thread::scope` threads.
//!
//! Used by the matmul kernel and the batch loops of the pure-rust
//! engine.  Thread count defaults to the machine parallelism, capped by
//! `SOBOLNET_THREADS`.

use std::sync::atomic::{AtomicUsize, Ordering};

static CACHED_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Number of worker threads to use.
pub fn num_threads() -> usize {
    let c = CACHED_THREADS.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("SOBOLNET_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, |n| n.get()));
    CACHED_THREADS.store(n, Ordering::Relaxed);
    n
}

/// Override the worker-thread count at runtime (wins over the
/// `SOBOLNET_THREADS` environment variable).  Used by benches and tests
/// to sweep thread scaling within one process; clamped to ≥ 1.
pub fn set_num_threads(n: usize) {
    CACHED_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Raw mutable pointer that may cross scoped-thread boundaries.
///
/// Safety contract: every thread must write only to index ranges
/// disjoint from all other threads' (the [`parallel_ranges`] pattern:
/// the caller partitions `0..n` and derives offsets from its range).
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Wrap a raw pointer.
    pub fn new(p: *mut T) -> Self {
        SendPtr(p)
    }

    /// The wrapped pointer.
    pub fn get(&self) -> *mut T {
        self.0
    }
}

/// Run `f(start, end)` over disjoint chunks of `0..n` on worker threads.
/// `f` must be `Sync` (it receives disjoint ranges, so data writes should
/// be pre-partitioned by the caller, e.g. via `chunks_mut`).
pub fn parallel_ranges<F: Fn(usize, usize) + Sync>(n: usize, min_chunk: usize, f: F) {
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= min_chunk {
        f(0, n);
        return;
    }
    let chunk = (n + threads - 1) / threads;
    let chunk = chunk.max(min_chunk);
    std::thread::scope(|s| {
        let f = &f;
        let mut start = 0;
        while start < n {
            let end = (start + chunk).min(n);
            s.spawn(move || f(start, end));
            start = end;
        }
    });
}

/// Map over mutable row-chunks of `data` (each of `row_len` floats) in
/// parallel: `f(row_index, row_slice)`.
pub fn parallel_rows<F: Fn(usize, &mut [f32]) + Sync>(data: &mut [f32], row_len: usize, f: F) {
    assert!(row_len > 0 && data.len() % row_len == 0);
    let rows = data.len() / row_len;
    let threads = num_threads().min(rows.max(1));
    if threads <= 1 {
        for (r, row) in data.chunks_mut(row_len).enumerate() {
            f(r, row);
        }
        return;
    }
    let per = (rows + threads - 1) / threads;
    std::thread::scope(|s| {
        let f = &f;
        for (t, block) in data.chunks_mut(per * row_len).enumerate() {
            s.spawn(move || {
                for (i, row) in block.chunks_mut(row_len).enumerate() {
                    f(t * per + i, row);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn ranges_cover_everything_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_ranges(1000, 16, |a, b| {
            for i in a..b {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn small_n_runs_inline() {
        let hits = AtomicU64::new(0);
        parallel_ranges(3, 16, |a, b| {
            hits.fetch_add((b - a) as u64, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn rows_see_correct_indices() {
        let mut data = vec![0.0f32; 64 * 8];
        parallel_rows(&mut data, 8, |r, row| {
            for v in row.iter_mut() {
                *v = r as f32;
            }
        });
        for (r, row) in data.chunks(8).enumerate() {
            assert!(row.iter().all(|&v| v == r as f32));
        }
    }

    #[test]
    fn num_threads_at_least_one() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn set_num_threads_overrides_and_clamps() {
        let before = num_threads();
        set_num_threads(3);
        assert_eq!(num_threads(), 3);
        set_num_threads(0); // clamped
        assert_eq!(num_threads(), 1);
        set_num_threads(before);
        assert_eq!(num_threads(), before);
    }

    #[test]
    fn send_ptr_disjoint_writes() {
        let mut data = vec![0u32; 256];
        let p = SendPtr::new(data.as_mut_ptr());
        parallel_ranges(256, 16, |a, b| {
            for i in a..b {
                unsafe { *p.get().add(i) = i as u32 };
            }
        });
        assert!(data.iter().enumerate().all(|(i, &v)| v == i as u32));
    }
}
