//! Data-parallel helpers (the `rayon` substrate) backed by a
//! **persistent multi-job worker pool**: long-lived threads parked on a
//! condvar, a bounded queue of concurrently active jobs, and chunk
//! claiming under one mutex.
//!
//! Earlier revisions spawned a fresh `std::thread::scope` per call
//! (tens of microseconds of spawn/join on every forward pass), and the
//! first pooled revision ran **one dispatch at a time**: N engine
//! shards doing small-batch forwards queued on a single job slot, so
//! concurrent serving serialized exactly where the paper promises
//! parallel hardware stays busy.  The pool now holds up to
//! [`MAX_ACTIVE_JOBS`] live jobs at once:
//!
//! * parked workers claim chunks from **any** live job (work stealing
//!   across jobs, bounded per job by its thread target), and
//! * a dispatcher that has drained its own job's unclaimed chunks but
//!   is still waiting on stragglers **helps drain other live jobs**
//!   instead of idling on the completion condvar (its foreign chunks
//!   run under `catch_unwind`, so another job's panic is recorded
//!   against *that* job and never unwinds into an innocent caller).
//!   Stealing is chunk-granular and the dispatcher re-checks its own
//!   job's completion between stolen chunks, so the latency a steal
//!   can add to the stealer's own return is bounded by **one** foreign
//!   chunk — chunks are the pool's unit of work everywhere and are
//!   sized small (≈ `n / threads` or the caller's fixed reduction
//!   width), which keeps that bound far below a straggler wait that
//!   would have idled anyway.
//!
//! Used by the matmul kernel, the conv/batch loops, and the
//! column-sharded forward/backward of [`crate::nn::sparse`].  Thread
//! count defaults to the machine parallelism, capped by
//! `SOBOLNET_THREADS` and overridable at runtime via
//! [`set_num_threads`] (the pool grows on demand and never shrinks;
//! each job admits at most `threads − 1` pool workers, so surplus
//! workers park through it and a lowered thread target is honored even
//! when chunks outnumber threads — a *dispatcher* of another job may
//! transiently lend a hand on top, but it is a thread that was already
//! awake and would otherwise spin-wait).  A chunk panic on a worker is
//! re-raised on that job's dispatching thread once the region
//! completes, like the scoped-thread implementation this replaces.
//!
//! Guarantees relied on elsewhere — all of them **per job**, and all of
//! them independent of how many jobs are in flight:
//!
//! * **Exact chunk boundaries.**  [`parallel_chunks`] partitions `0..n`
//!   at multiples of `chunk` regardless of the thread count, the number
//!   of concurrent jobs, or which thread (worker, own dispatcher,
//!   foreign dispatcher) executes a chunk — and the sequential fallback
//!   iterates the *same* boundaries.  Callers can key per-chunk shadow
//!   buffers off `start / chunk` and get bitwise-deterministic
//!   reductions for every `SOBOLNET_THREADS`, even while other jobs
//!   run (`tests/pool_contention.rs`, `tests/golden_backward.rs`).
//! * **Nested calls run inline.**  A `parallel_*` call from inside a
//!   chunk (worker, or a dispatcher helping any job) degrades to the
//!   sequential path instead of re-entering the pool.
//! * **Zero work is safe.**  `n == 0` dispatches nothing.
//! * **Steady state allocates nothing.**  The job queue is
//!   pre-allocated at [`MAX_ACTIVE_JOBS`]; dispatching, claiming,
//!   stealing, and completing all run allocation-free once the worker
//!   threads exist (`tests/alloc_hotpath.rs` pins this under
//!   concurrent dispatch).

use crate::util::sync::{cwait, plock};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, OnceLock};

/// Upper bound on concurrently active jobs.  A dispatcher arriving at a
/// full queue waits for a slot (the pre-multi-job behavior, generalized
/// from 1 slot to this many).  Far above any realistic shard count, and
/// small enough that the pre-allocated queue is trivial.
pub const MAX_ACTIVE_JOBS: usize = 32;

static CACHED_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Number of worker threads to use.
pub fn num_threads() -> usize {
    let c = CACHED_THREADS.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("SOBOLNET_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, |n| n.get()));
    CACHED_THREADS.store(n, Ordering::Relaxed);
    n
}

/// Override the worker-thread count at runtime (wins over the
/// `SOBOLNET_THREADS` environment variable).  Used by benches and tests
/// to sweep thread scaling within one process; clamped to ≥ 1.  The
/// pool resizes lazily: the next dispatch spawns missing workers.
pub fn set_num_threads(n: usize) {
    CACHED_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Raw mutable pointer that may cross thread boundaries.
///
/// Safety contract: every thread must write only to index ranges
/// disjoint from all other threads' (the [`parallel_ranges`] pattern:
/// the caller partitions `0..n` and derives offsets from its range).
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Wrap a raw pointer.
    pub fn new(p: *mut T) -> Self {
        SendPtr(p)
    }

    /// The wrapped pointer.
    pub fn get(&self) -> *mut T {
        self.0
    }
}

impl<T> std::fmt::Debug for SendPtr<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SendPtr({:p})", self.0)
    }
}

// ---------------------------------------------------------------------------
// The pool
// ---------------------------------------------------------------------------

/// One dispatched parallel region: a type-erased `Fn(usize, usize)`
/// living on the dispatcher's stack.  Valid only while that dispatch is
/// active — the dispatcher does not return (or unwind) past its
/// [`ActiveJob`] guard until every claimed chunk has finished, and a
/// chunk can only be claimed while the job is still in the active
/// queue, which it leaves strictly before the guard releases.
#[derive(Clone, Copy)]
struct Job {
    call: unsafe fn(*const (), usize, usize),
    data: *const (),
    n: usize,
    chunk: usize,
}

// Safety: `data` is only dereferenced through `call` while the
// dispatching thread keeps the closure alive (see `ActiveJob`), and the
// closure itself is required to be `Sync` by the public entry points.
unsafe impl Send for Job {}

/// Bookkeeping of one live job in the active queue.
struct JobState {
    /// Queue-unique id; chunk claims and completions are keyed by it so
    /// a stale reference can never touch a newer job's state.
    id: u64,
    job: Job,
    /// Next unclaimed index (multiple of `job.chunk` from 0).
    next: usize,
    /// Chunks not yet finished (claimed-but-running + unclaimed).
    remaining: usize,
    /// Pool workers that joined this job (capped by `limit`, so a job
    /// never runs wider than its thread target even when the pool
    /// holds more parked workers).
    joined: usize,
    /// Max pool workers allowed to join (thread target − 1; the
    /// dispatcher itself is the +1).  Foreign dispatchers stealing
    /// chunks while they wait on their own stragglers are not counted:
    /// they are threads that were already awake.
    limit: usize,
    /// A chunk of this job panicked on a worker (or was caught on a
    /// stealing dispatcher); re-raised on this job's dispatcher after
    /// completion.
    panicked: bool,
}

struct PoolState {
    /// Monotone id source for [`JobState::id`].
    next_id: u64,
    /// Live jobs, at most [`MAX_ACTIVE_JOBS`]; pre-allocated so the
    /// dispatch path never allocates.
    jobs: Vec<JobState>,
    /// Worker threads alive (dispatchers are not counted).
    spawned: usize,
    /// Completed dispatches (observability / tests).
    dispatches: u64,
    /// Chunks executed by a dispatcher on behalf of *another* job
    /// while waiting out its own stragglers (observability / benches).
    steals: u64,
}

struct Pool {
    state: Mutex<PoolState>,
    /// Workers park here waiting for a claimable job.
    work_cv: Condvar,
    /// Dispatchers park here waiting for their job's `remaining == 0`
    /// (when no other job has chunks to steal), and for a free slot in
    /// the active queue.
    done_cv: Condvar,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| Pool {
        state: Mutex::new(PoolState {
            next_id: 0,
            jobs: Vec::with_capacity(MAX_ACTIVE_JOBS),
            spawned: 0,
            dispatches: 0,
            steals: 0,
        }),
        work_cv: Condvar::new(),
        done_cv: Condvar::new(),
    })
}

/// Poison-immune lock: a worker can only panic inside caller code while
/// *not* holding the state lock, but be robust anyway.
fn lock(p: &Pool) -> MutexGuard<'_, PoolState> {
    plock(&p.state)
}

thread_local! {
    /// True while this thread is executing chunks of a parallel region
    /// (worker, or dispatcher executing own/stolen chunks).  Nested
    /// `parallel_*` calls then run inline instead of re-entering the
    /// pool.
    static IN_PARALLEL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

fn in_parallel() -> bool {
    IN_PARALLEL.with(|c| c.get())
}

/// Restores the thread-local nesting flag even if a chunk panics.
struct ParallelFlagGuard;

impl ParallelFlagGuard {
    fn enter() -> ParallelFlagGuard {
        IN_PARALLEL.with(|c| c.set(true));
        ParallelFlagGuard
    }
}

impl Drop for ParallelFlagGuard {
    fn drop(&mut self) {
        IN_PARALLEL.with(|c| c.set(false));
    }
}

/// Marks one claimed chunk of job `id` finished on drop — including on
/// unwind, so a panicking chunk cannot strand its dispatcher in the
/// completion wait.
struct ChunkDoneGuard {
    pool: &'static Pool,
    id: u64,
}

impl Drop for ChunkDoneGuard {
    fn drop(&mut self) {
        finish_chunk(self.pool, self.id, std::thread::panicking());
    }
}

/// Mark one claimed chunk of job `id` finished: record a panic against
/// the job, decrement its outstanding-chunk count, and wake its
/// dispatcher at zero.  The single completion protocol shared by
/// workers/dispatchers ([`ChunkDoneGuard`]) and the stealing path
/// (whose panic bit comes from a caught `Result`, not the unwinding
/// thread state).
fn finish_chunk(pool: &Pool, id: u64, panicked: bool) {
    let mut st = lock(pool);
    if let Some(j) = st.jobs.iter_mut().find(|j| j.id == id) {
        if panicked {
            j.panicked = true;
        }
        j.remaining -= 1;
        if j.remaining == 0 {
            pool.done_cv.notify_all();
        }
    }
}

/// Claim the next chunk of job `id` under the lock.  `None` when the
/// job has left the queue or has no unclaimed chunks.
fn claim_chunk(st: &mut PoolState, id: u64) -> Option<(usize, usize)> {
    let j = st.jobs.iter_mut().find(|j| j.id == id)?;
    if j.next >= j.job.n {
        return None;
    }
    let start = j.next;
    let end = (start + j.job.chunk).min(j.job.n);
    j.next = end;
    Some((start, end))
}

/// Dispatcher-side guard: waits out stragglers and removes the job
/// from the active queue, on the normal path and on unwind alike, so
/// `Job::data` never outlives the closure it points into.
struct ActiveJob {
    pool: &'static Pool,
    id: u64,
}

impl Drop for ActiveJob {
    fn drop(&mut self) {
        let mut st = lock(self.pool);
        // Cancel chunks nobody has claimed yet.  On the normal path the
        // dispatcher's help loop already drained them (no-op); on the
        // unwind path this prevents waiting forever on work no thread
        // will ever take (e.g. worker spawn failed entirely).
        if let Some(j) = st.jobs.iter_mut().find(|j| j.id == self.id) {
            if j.next < j.job.n {
                let unclaimed = (j.job.n - j.next + j.job.chunk - 1) / j.job.chunk;
                j.next = j.job.n;
                j.remaining -= unclaimed;
            }
        }
        loop {
            let remaining =
                st.jobs.iter().find(|j| j.id == self.id).map_or(0, |j| j.remaining);
            if remaining == 0 {
                break;
            }
            st = cwait(&self.pool.done_cv, st);
        }
        if let Some(pos) = st.jobs.iter().position(|j| j.id == self.id) {
            st.jobs.swap_remove(pos);
        }
        st.dispatches += 1;
        // wake dispatchers queued on a full active-job queue
        self.pool.done_cv.notify_all();
    }
}

fn worker_main() {
    let pool = pool();

    /// Keeps `spawned` truthful if a chunk panic kills this worker, so
    /// a later dispatch spawns a replacement.
    struct Alive(&'static Pool);
    impl Drop for Alive {
        fn drop(&mut self) {
            lock(self.0).spawned -= 1;
        }
    }
    let _alive = Alive(pool);

    let mut st = lock(pool);
    loop {
        // join any live job that still has unclaimed chunks and room
        // under its per-job worker cap
        let Some(pos) =
            st.jobs.iter().position(|j| j.next < j.job.n && j.joined < j.limit)
        else {
            st = cwait(&pool.work_cv, st);
            continue;
        };
        st.jobs[pos].joined += 1;
        let id = st.jobs[pos].id;
        let job = st.jobs[pos].job;
        let flag = ParallelFlagGuard::enter();
        while let Some((start, end)) = claim_chunk(&mut st, id) {
            drop(st);
            {
                let _done = ChunkDoneGuard { pool, id };
                unsafe { (job.call)(job.data, start, end) };
            }
            st = lock(pool);
        }
        drop(flag);
        // loop around (lock still held): another live job may have
        // claimable chunks — steal into it before parking
    }
}

unsafe fn invoke<F: Fn(usize, usize)>(data: *const (), start: usize, end: usize) {
    (*(data as *const F))(start, end)
}

/// Dispatch `f` over `0..n` in `chunk`-sized pieces on the pool.  The
/// calling thread installs the job, helps execute its chunks, then
/// drains *other* live jobs while waiting for stragglers.  Requires
/// `threads ≥ 2`, `n ≥ 1`, `chunk ≥ 1`.
fn run_pool<F: Fn(usize, usize) + Sync>(n: usize, chunk: usize, threads: usize, f: &F) {
    let pool = pool();
    let job = Job { call: invoke::<F>, data: f as *const F as *const (), n, chunk };
    let nchunks = (n + chunk - 1) / chunk;

    let mut st = lock(pool);
    // bounded active queue: wait for a free slot (jobs always complete
    // because each one's dispatcher drives it even with zero workers)
    while st.jobs.len() >= MAX_ACTIVE_JOBS {
        st = cwait(&pool.done_cv, st);
    }
    // grow the pool to the requested width (never shrinks; surplus
    // workers claim nothing and park again)
    let want = threads.saturating_sub(1);
    while st.spawned < want {
        let name = format!("sobolnet-pool-{}", st.spawned);
        match std::thread::Builder::new().name(name).spawn(worker_main) {
            Ok(handle) => {
                drop(handle); // detached; lives for the process
                st.spawned += 1;
            }
            Err(_) => break, // resource limit: proceed with what we have
        }
    }
    let id = st.next_id;
    st.next_id = st.next_id.wrapping_add(1);
    st.jobs.push(JobState {
        id,
        job,
        next: 0,
        remaining: nchunks,
        joined: 0,
        limit: want,
        panicked: false,
    });
    pool.work_cv.notify_all();
    // dispatchers parked in their straggler wait can steal from us too
    pool.done_cv.notify_all();

    // From here on the job MUST be cleaned up exactly once, even if `f`
    // panics on this thread — ActiveJob's drop waits for the workers
    // and removes the job from the queue.
    let active = ActiveJob { pool, id };
    {
        let _flag = ParallelFlagGuard::enter();
        // drain our own job first
        while let Some((start, end)) = claim_chunk(&mut st, id) {
            drop(st);
            {
                let _done = ChunkDoneGuard { pool, id };
                f(start, end);
            }
            st = lock(pool);
        }
        // straggler phase: our chunks are all claimed but some are
        // still running on workers.  Instead of idling on done_cv,
        // help drain any other live job; foreign chunks run under
        // catch_unwind so another job's panic is recorded against that
        // job (its own dispatcher re-raises it) and never unwinds into
        // our caller.
        loop {
            let remaining = st.jobs.iter().find(|j| j.id == id).map_or(0, |j| j.remaining);
            if remaining == 0 {
                break;
            }
            let stolen = st
                .jobs
                .iter_mut()
                .find(|j| j.id != id && j.next < j.job.n)
                .map(|j| {
                    let start = j.next;
                    let end = (start + j.job.chunk).min(j.job.n);
                    j.next = end;
                    (j.id, j.job, start, end)
                });
            match stolen {
                Some((sid, sjob, start, end)) => {
                    st.steals += 1;
                    drop(st);
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || unsafe { (sjob.call)(sjob.data, start, end) },
                    ));
                    finish_chunk(pool, sid, result.is_err());
                    st = lock(pool);
                }
                None => {
                    st = cwait(&pool.done_cv, st);
                }
            }
        }
        // read the panic flag while the job is still ours (ActiveJob's
        // drop stays the unwind path and must not panic)
        let worker_panicked =
            st.jobs.iter().find(|j| j.id == id).is_some_and(|j| j.panicked);
        drop(st);
        drop(active); // remove the job, count the dispatch
        if worker_panicked {
            panic!(
                "worker pool: a parallel chunk panicked on another thread; results are incomplete"
            );
        }
    }
}

/// Run `f(start, end)` over disjoint chunks of `0..n` on the worker
/// pool.  `f` must be `Sync` (it receives disjoint ranges, so data
/// writes should be pre-partitioned by the caller, e.g. via
/// `chunks_mut` or [`SendPtr`]).  Chunk sizes derive from the current
/// thread count; when the *values* computed depend on chunk boundaries
/// (reductions), use [`parallel_chunks`] instead.
///
/// Runs inline when `n <= min_chunk`, when only one thread is
/// configured, or when called from inside another parallel region.
/// Concurrent callers do not serialize: each call is its own job in
/// the pool's active queue (see the [module docs](self)).
pub fn parallel_ranges<F: Fn(usize, usize) + Sync>(n: usize, min_chunk: usize, f: F) {
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= min_chunk || in_parallel() {
        f(0, n);
        return;
    }
    let chunk = ((n + threads - 1) / threads).max(min_chunk).max(1);
    run_pool(n, chunk, threads, &f);
}

/// Run `f(start, end)` over **fixed** `chunk`-aligned pieces of `0..n`:
/// every call sees `start % chunk == 0` and `end - start <= chunk`,
/// independent of the thread count, the number of concurrently live
/// jobs, or which thread executes a chunk — and the
/// single-thread/nested fallback iterates the exact same boundaries in
/// order.
///
/// This is the deterministic-reduction primitive: callers may index
/// per-chunk shadow accumulators by `start / chunk` and merge them in
/// fixed chunk order, making the result bitwise identical for every
/// `SOBOLNET_THREADS` setting (see `SparseMlp::backward`) — including
/// under concurrent dispatch from many engine shards
/// (`tests/pool_contention.rs`).
pub fn parallel_chunks<F: Fn(usize, usize) + Sync>(n: usize, chunk: usize, f: F) {
    assert!(chunk > 0, "chunk must be positive");
    if n == 0 {
        return;
    }
    let threads = num_threads();
    if threads <= 1 || n <= chunk || in_parallel() {
        sequential_chunks(n, chunk, &f);
        return;
    }
    run_pool(n, chunk, threads, &f);
}

/// Iterate `f(start, end)` over the exact same `chunk`-aligned
/// boundaries as [`parallel_chunks`], on the calling thread.  The
/// single source of truth for chunk geometry: callers that gate
/// parallelism themselves (work thresholds) use this for the inline
/// path so both paths see identical boundaries.
pub fn sequential_chunks<F: FnMut(usize, usize)>(n: usize, chunk: usize, mut f: F) {
    assert!(chunk > 0, "chunk must be positive");
    let mut start = 0;
    while start < n {
        let end = (start + chunk).min(n);
        f(start, end);
        start = end;
    }
}

/// Map over mutable row-chunks of `data` (each of `row_len` floats) in
/// parallel: `f(row_index, row_slice)`.
pub fn parallel_rows<F: Fn(usize, &mut [f32]) + Sync>(data: &mut [f32], row_len: usize, f: F) {
    assert!(row_len > 0 && data.len() % row_len == 0);
    let rows = data.len() / row_len;
    if rows == 0 {
        return;
    }
    let p = SendPtr::new(data.as_mut_ptr());
    parallel_ranges(rows, 1, |r0, r1| {
        for r in r0..r1 {
            // Safety: disjoint row ranges per chunk; `data` is borrowed
            // mutably for the whole call.
            let row =
                unsafe { std::slice::from_raw_parts_mut(p.get().add(r * row_len), row_len) };
            f(r, row);
        }
    });
}

/// Pool observability for tests and benches: `(worker threads alive,
/// completed dispatches)`.  Both are process-global; `spawned` is
/// monotone while no worker panics and is bounded by the largest thread
/// target any dispatch has used, minus one (the dispatcher itself).
pub fn pool_stats() -> (usize, u64) {
    let st = lock(pool());
    (st.spawned, st.dispatches)
}

/// Chunks executed by a dispatcher on behalf of **another** live job
/// while waiting out its own stragglers (process-global, monotone).
/// The direct observable of the multi-job pool's work stealing.
pub fn pool_steals() -> u64 {
    lock(pool()).steals
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    /// Serializes every test that mutates the process-global thread
    /// count or asserts on `pool_stats` (other tests in this binary may
    /// dispatch concurrently, but they leave the thread count alone).
    static POOL_SHAPE_LOCK: Mutex<()> = Mutex::new(());

    /// A thread target no concurrent test exceeds: every other dispatch
    /// in this binary uses at most the machine parallelism (or small
    /// explicit overrides ≤ 8).
    fn max_target() -> usize {
        std::thread::available_parallelism().map_or(1, |n| n.get()).max(8)
    }

    #[test]
    fn ranges_cover_everything_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_ranges(1000, 16, |a, b| {
            for i in a..b {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn small_n_runs_inline() {
        let hits = AtomicU64::new(0);
        parallel_ranges(3, 16, |a, b| {
            hits.fetch_add((b - a) as u64, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn rows_see_correct_indices() {
        let mut data = vec![0.0f32; 64 * 8];
        parallel_rows(&mut data, 8, |r, row| {
            for v in row.iter_mut() {
                *v = r as f32;
            }
        });
        for (r, row) in data.chunks(8).enumerate() {
            assert!(row.iter().all(|&v| v == r as f32));
        }
    }

    #[test]
    fn num_threads_at_least_one() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn set_num_threads_overrides_and_clamps() {
        let _guard = POOL_SHAPE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let before = num_threads();
        set_num_threads(3);
        assert_eq!(num_threads(), 3);
        set_num_threads(0); // clamped
        assert_eq!(num_threads(), 1);
        set_num_threads(before);
        assert_eq!(num_threads(), before);
    }

    #[test]
    fn send_ptr_disjoint_writes() {
        let mut data = vec![0u32; 256];
        let p = SendPtr::new(data.as_mut_ptr());
        parallel_ranges(256, 16, |a, b| {
            for i in a..b {
                unsafe { *p.get().add(i) = i as u32 };
            }
        });
        assert!(data.iter().enumerate().all(|(i, &v)| v == i as u32));
    }

    #[test]
    fn pool_reuses_threads_across_dispatches() {
        let _guard = POOL_SHAPE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let ambient = num_threads();
        // grow the pool to the binary-wide max once, then verify that
        // further dispatches reuse the same threads
        set_num_threads(max_target());
        let sink = AtomicU64::new(0);
        let work = |a: usize, b: usize| {
            sink.fetch_add((b - a) as u64, Ordering::Relaxed);
        };
        parallel_ranges(1 << 12, 1, work);
        let (spawned_warm, dispatches_warm) = pool_stats();
        assert!(spawned_warm >= max_target() - 1, "pool grew to the target width");
        for _ in 0..8 {
            parallel_ranges(1 << 12, 1, work);
        }
        let (spawned_after, dispatches_after) = pool_stats();
        assert_eq!(spawned_after, spawned_warm, "no re-spawn on later dispatches");
        assert!(dispatches_after >= dispatches_warm + 8, "dispatches counted");
        set_num_threads(ambient);
    }

    #[test]
    fn resize_mid_process_takes_effect() {
        let _guard = POOL_SHAPE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let ambient = num_threads();
        let run = |n: usize| {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            parallel_ranges(n, 1, |a, b| {
                for i in a..b {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        };
        set_num_threads(2);
        run(4096);
        set_num_threads(6);
        run(4096);
        let (spawned, _) = pool_stats();
        assert!(spawned >= 5, "pool grew after set_num_threads(6), spawned={spawned}");
        set_num_threads(1);
        run(64); // sequential path still covers everything
        set_num_threads(ambient);
    }

    #[test]
    fn nested_calls_run_inline_without_deadlock() {
        let hits: Vec<AtomicU64> = (0..64 * 64).map(|_| AtomicU64::new(0)).collect();
        let hits = &hits;
        parallel_ranges(64, 1, |a, b| {
            for outer in a..b {
                // nested: must run inline on this thread, not re-enter
                // the pool
                parallel_ranges(64, 1, |c, d| {
                    for inner in c..d {
                        hits[outer * 64 + inner].fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn zero_work_is_a_noop() {
        let hits = AtomicU64::new(0);
        parallel_ranges(0, 4, |a, b| {
            hits.fetch_add((b - a) as u64, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 0);
        parallel_chunks(0, 4, |_, _| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 0);
        parallel_rows(&mut [], 8, |_, _| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn fixed_chunks_have_stable_boundaries() {
        let _guard = POOL_SHAPE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let ambient = num_threads();
        let collect = |threads: usize| {
            set_num_threads(threads);
            let seen = Mutex::new(Vec::new());
            parallel_chunks(103, 8, |a, b| {
                seen.lock().unwrap().push((a, b));
            });
            let mut v = seen.into_inner().unwrap();
            v.sort_unstable();
            v
        };
        let one = collect(1);
        for threads in [2usize, 4, 8] {
            assert_eq!(collect(threads), one, "threads={threads}");
        }
        set_num_threads(ambient);
        assert_eq!(one.len(), 13); // ceil(103 / 8)
        for (i, &(a, b)) in one.iter().enumerate() {
            assert_eq!(a, i * 8);
            assert_eq!(b, ((i + 1) * 8).min(103));
        }
    }

    /// Pool *workers* honor the per-job thread cap: a 2-thread dispatch
    /// admits at most 1 pool worker no matter how many are parked.  (A
    /// concurrent test's dispatcher may transiently steal a chunk —
    /// that is the multi-job contract — so the assertion counts
    /// distinct `sobolnet-pool-*` threads, not all threads.)
    #[test]
    fn chunk_dispatch_respects_thread_cap() {
        let _guard = POOL_SHAPE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let ambient = num_threads();
        // make sure the pool already holds more workers than the cap
        set_num_threads(max_target());
        parallel_ranges(1 << 12, 1, |_, _| {});
        // a 2-thread dispatch with many more chunks than threads must
        // admit at most 1 distinct pool worker
        set_num_threads(2);
        let ids = Mutex::new(std::collections::HashSet::new());
        parallel_chunks(256, 1, |_, _| {
            let t = std::thread::current();
            if t.name().is_some_and(|n| n.starts_with("sobolnet-pool-")) {
                ids.lock().unwrap().insert(t.id());
            }
        });
        let n = ids.into_inner().unwrap().len();
        assert!(n <= 1, "2-thread dispatch admitted {n} distinct pool workers");
        set_num_threads(ambient);
    }

    #[test]
    #[should_panic]
    fn chunk_panic_propagates_to_dispatcher() {
        let _guard = POOL_SHAPE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_num_threads(4);
        parallel_ranges(1 << 10, 1, |a, _| {
            if a == 0 {
                panic!("boom");
            }
        });
    }
}
