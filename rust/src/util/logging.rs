//! Minimal leveled logger (the `log`/`env_logger` substrate).
//!
//! Controlled by `SOBOLNET_LOG` (`error|warn|info|debug|trace`, default
//! `info`).  Thread-safe via an atomic level; output goes to stderr so
//! benchmark tables on stdout stay machine-parsable.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ordered from most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Suspicious conditions that do not abort the run.
    Warn = 1,
    /// High-level progress (default).
    Info = 2,
    /// Per-step diagnostics.
    Debug = 3,
    /// Everything.
    Trace = 4,
}

impl Level {
    fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn current_level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let lvl = std::env::var("SOBOLNET_LOG")
            .ok()
            .and_then(|s| Level::parse(&s))
            .unwrap_or(Level::Info);
        LEVEL.store(lvl as u8, Ordering::Relaxed);
        return lvl;
    }
    // Safety: only valid discriminants are ever stored.
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the log level programmatically (tests, CLI `--log-level`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// `true` if a message at `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    level <= current_level()
}

/// Emit a log line (used by the macros; prefer those).
pub fn log(level: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{} {}] {}", level.tag(), module, args);
    }
}

/// Log at error level.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

/// Log at warn level.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

/// Log at info level.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

/// Log at debug level.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
    }
}
