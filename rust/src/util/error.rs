//! Minimal error-context substrate (the `anyhow` substitute): a string
//! error type, a [`Context`] extension trait for `Result`/`Option`, and
//! the [`crate::ensure!`] / [`crate::bail!`] / [`crate::err!`] macros.
//!
//! Exists in-tree because the crate builds with zero external
//! dependencies (see `rust/Cargo.toml`); the API mirrors the `anyhow`
//! surface the runtime/coordinator layers use, so swapping the real
//! crate back is a one-line import change.

/// A boxed-string error carrying its accumulated context chain.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    /// Wrap a message into an [`Error`].
    pub fn msg<M: std::fmt::Display>(m: M) -> Error {
        Error(m.to_string())
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error(s.to_string())
    }
}

/// Crate-wide result type (defaults the error to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach human-readable context to failures, `anyhow`-style.
pub trait Context<T> {
    /// Replace/prefix the error with `ctx` (keeps the cause message).
    fn context<C: std::fmt::Display>(self, ctx: C) -> Result<T, Error>;

    /// Lazily-built variant of [`Context::context`].
    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: std::fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error(format!("{ctx}: {e}")))
    }

    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: std::fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error(ctx.to_string()))
    }

    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Build an [`Error`](crate::util::error::Error) from a format string
/// (the `anyhow::anyhow!` substrate).
#[macro_export]
macro_rules! err {
    ($($arg:tt)+) => {
        $crate::util::error::Error::msg(format!($($arg)+))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::err!($($arg)+).into())
    };
}

/// Return early with an error when a condition does not hold (the
/// `anyhow::ensure!` substrate).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::err!($($arg)+).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(Error::msg("boom"))
    }

    #[test]
    fn context_chains_messages() {
        let e = fails().context("stage A").unwrap_err();
        assert_eq!(e.to_string(), "stage A: boom");
        let e = fails().with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "step 3: boom");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        assert_eq!(Some(7).context("x").unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        fn guarded(v: usize) -> Result<usize> {
            crate::ensure!(v < 10, "value {v} out of range");
            if v == 9 {
                crate::bail!("nine is reserved");
            }
            Ok(v)
        }
        assert_eq!(guarded(3).unwrap(), 3);
        assert_eq!(guarded(12).unwrap_err().to_string(), "value 12 out of range");
        assert_eq!(guarded(9).unwrap_err().to_string(), "nine is reserved");
        assert_eq!(crate::err!("code {}", 42).to_string(), "code 42");
    }

    #[test]
    fn boxes_into_std_error() {
        let b: Box<dyn std::error::Error> = Error::msg("x").into();
        assert_eq!(b.to_string(), "x");
    }
}
