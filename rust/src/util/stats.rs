//! Streaming statistics and simple summaries used by the benchmark
//! harness, the metrics module, and the experiment tables.

/// Online mean/variance accumulator (Welford's algorithm) — numerically
/// stable for long benchmark runs.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Fresh accumulator.
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 for empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Unbiased sample variance (0 for n < 2).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Minimum observation (+inf for empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum observation (-inf for empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Half-width of a ~95% normal confidence interval of the mean.
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            1.96 * self.std() / (self.n as f64).sqrt()
        }
    }
}

/// Percentile over a *sorted* slice using linear interpolation
/// (`q` in [0,1]).  Returns `NaN` for empty input.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Sort a copy and return (p50, p90, p99).
pub fn latency_percentiles(samples: &[f64]) -> (f64, f64, f64) {
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (
        percentile_sorted(&v, 0.50),
        percentile_sorted(&v, 0.90),
        percentile_sorted(&v, 0.99),
    )
}

/// Arithmetic mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Exponential moving average helper for loss curves.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    /// `alpha` is the smoothing factor in (0, 1]; larger = faster.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0);
        Ema { alpha, value: None }
    }

    /// Fold in one observation, returning the smoothed value.
    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    /// Current smoothed value, if any observation was pushed.
    pub fn value(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - m).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
        assert_eq!(w.count(), 5);
        assert!(w.ci95() > 0.0);
    }

    #[test]
    fn welford_empty_and_single() {
        let mut w = Welford::new();
        assert_eq!(w.variance(), 0.0);
        w.push(3.0);
        assert_eq!(w.mean(), 3.0);
        assert_eq!(w.variance(), 0.0);
        assert_eq!(w.ci95(), 0.0);
    }

    #[test]
    fn percentiles() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 1.0), 4.0);
        assert!((percentile_sorted(&v, 0.5) - 2.5).abs() < 1e-12);
        assert!(percentile_sorted(&[], 0.5).is_nan());
        let (p50, p90, p99) = latency_percentiles(&[4.0, 1.0, 3.0, 2.0]);
        assert!((p50 - 2.5).abs() < 1e-12);
        assert!(p90 <= p99 && p50 <= p90);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.value(), None);
        e.push(0.0);
        for _ in 0..64 {
            e.push(1.0);
        }
        assert!((e.value().unwrap() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn mean_of_slice() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }
}
