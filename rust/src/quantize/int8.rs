//! Symmetric per-transition int8 weight quantization — the arithmetic
//! behind the `int8` compute kernel ([`crate::nn::kernel`]).
//!
//! One scale per transition: `scale = amax/127` with
//! `amax = max |w[t][p]|`, weights rounded to the nearest int8 and
//! clamped to `±127` (the `-128` slot is unused, keeping the code
//! symmetric).  Accumulation stays in f32: the kernel dequantizes each
//! path weight once per column run (`q as f32 · scale` — exact, both
//! factors are representable) and then runs the standard loops, so the
//! int8 kernel is **bitwise identical** to the scalar kernel running
//! on the dequantized weights (pinned by `tests/kernel_golden.rs`),
//! and within quantization tolerance — per-weight error ≤ `scale/2 =
//! amax/254` — of the full-precision net.
//!
//! Degenerate transitions are safe by construction: an all-zero (or
//! all-NaN) transition gets `scale = 0` and all-zero codes, which
//! dequantize to exactly `0.0`.

/// Largest finite `|w|` in a transition; NaN entries are ignored
/// (they fail every `>` comparison) rather than poisoning the scale.
pub fn amax(w: &[f32]) -> f32 {
    let mut m = 0.0f32;
    for &v in w {
        let a = v.abs();
        if a.is_finite() && a > m {
            m = a;
        }
    }
    m
}

/// Symmetric scale mapping `[-amax, amax]` onto `[-127, 127]`.
pub fn scale_for(amax: f32) -> f32 {
    amax / 127.0
}

/// Quantize a transition's weights into `out` (cleared and refilled;
/// capacity is reused, so the call is allocation-free once warm).
/// Non-finite weights and a non-positive scale quantize to `0`.
pub fn quantize_into(w: &[f32], scale: f32, out: &mut Vec<i8>) {
    out.clear();
    if scale <= 0.0 || scale.is_nan() || scale.is_infinite() {
        out.resize(w.len(), 0);
        return;
    }
    out.extend(w.iter().map(|&v| {
        let q = (v / scale).round();
        if q.is_nan() {
            0
        } else {
            q.clamp(-127.0, 127.0) as i8
        }
    }));
}

/// Dequantize one code: exact in f32 (both factors are representable).
#[inline(always)]
pub fn dequant(q: i8, scale: f32) -> f32 {
    q as f32 * scale
}

/// Round-trip a transition through int8: the weights the `int8` kernel
/// actually computes with (test/oracle helper).
pub fn dequantized(w: &[f32]) -> Vec<f32> {
    let scale = scale_for(amax(w));
    let mut q = Vec::new();
    quantize_into(w, scale, &mut q);
    q.iter().map(|&qi| dequant(qi, scale)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_error_is_bounded_by_half_a_step() {
        let w: Vec<f32> = (0..257).map(|i| ((i as f32) * 0.7).sin() * 3.0).collect();
        let a = amax(&w);
        let scale = scale_for(a);
        let dq = dequantized(&w);
        for (orig, got) in w.iter().zip(&dq) {
            assert!(
                (orig - got).abs() <= scale * 0.5 + 1e-7,
                "{orig} → {got} (scale {scale})"
            );
        }
    }

    #[test]
    fn extremes_hit_the_full_code_range() {
        let w = [3.0f32, -3.0, 0.0];
        let scale = scale_for(amax(&w));
        let mut q = Vec::new();
        quantize_into(&w, scale, &mut q);
        assert_eq!(q, vec![127, -127, 0]);
        assert!((dequant(q[0], scale) - 3.0).abs() <= 0.5 * scale);
        assert!((dequant(q[1], scale) + 3.0).abs() <= 0.5 * scale);
    }

    #[test]
    fn degenerate_transitions_quantize_to_zero() {
        for w in [vec![0.0f32; 5], vec![f32::NAN; 5], Vec::new()] {
            let scale = scale_for(amax(&w));
            let mut q = Vec::new();
            quantize_into(&w, scale, &mut q);
            assert_eq!(q.len(), w.len());
            assert!(q.iter().all(|&qi| qi == 0));
            assert!(dequantized(&w).iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn quantize_into_reuses_capacity() {
        let w = vec![1.0f32; 64];
        let mut q = Vec::new();
        quantize_into(&w, 0.5, &mut q);
        let cap = q.capacity();
        let ptr = q.as_ptr();
        for _ in 0..3 {
            quantize_into(&w, 0.5, &mut q);
        }
        assert_eq!(cap, q.capacity());
        assert_eq!(ptr, q.as_ptr());
    }
}
