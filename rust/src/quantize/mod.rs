//! Quantization of trained dense networks by sampling paths (paper §2.1,
//! Fig 2).
//!
//! A trained dense ReLU network is compressed by tracing paths from the
//! outputs back to the inputs, sampling each step proportionally to the
//! L1-normalized absolute weights of the neuron.  Because sampling is an
//! unbiased discretization of the weight distribution, keeping only the
//! sampled fraction of connections preserves test accuracy until the
//! fraction gets small (Fig 2).
//!
//! The sampler supports both a PRNG and — in the spirit of the paper —
//! a low discrepancy sequence driving the inverse-CDF selection.
//!
//! The [`int8`] submodule carries the symmetric per-transition int8
//! weight quantization behind the `int8` compute kernel
//! ([`crate::nn::kernel`]).

pub mod int8;

use crate::nn::dense::Dense;
use crate::nn::mlp::DenseMlp;
use crate::nn::Model;
use crate::qmc::sobol::Sobol;
use crate::qmc::Sequence;
use crate::rng::{Pcg32, Rng};

/// Driver of the per-step uniform samples.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SampleDriver {
    /// PCG32 pseudo-random sampling.
    Random(u64),
    /// Sobol' sequence: path i uses component (i, layer-dim).
    Sobol,
}

/// Build the cumulative distribution of `|w|` for one output neuron
/// row.  NaN magnitudes count as zero mass (a NaN entry must never
/// poison the row, and `select` must never land on it).  An all-zero
/// (degenerate) row gets the **uniform** CDF `(i+1)/n`, so `select`
/// samples it uniformly from `u` instead of deterministically
/// collapsing to one index.
fn row_cdf(w: &[f32]) -> Vec<f32> {
    let mut cdf = Vec::with_capacity(w.len());
    let mut acc = 0.0f32;
    for &v in w {
        let a = v.abs();
        acc += if a.is_nan() { 0.0 } else { a };
        cdf.push(acc);
    }
    if acc > 0.0 {
        for c in &mut cdf {
            *c /= acc;
        }
    } else {
        let n = cdf.len() as f32;
        for (i, c) in cdf.iter_mut().enumerate() {
            *c = (i + 1) as f32 / n;
        }
    }
    cdf
}

/// Inverse-CDF selection: the first index whose cdf ≥ u **and** whose
/// entry carries probability mass.
///
/// `partition_point` returns the *first* index reaching `u`; a
/// zero-weight edge never strictly increases the CDF, so a duplicated
/// cumulative value (e.g. `[0.5, 0.5, 1.0]` from weights
/// `[2, 0, 2]`) can never be selected even when `u` lands exactly on
/// the repeated value — unlike `binary_search_by`, which may return
/// any of the equal entries (and whose `partial_cmp().unwrap()`
/// panicked on NaN).  `u` is clamped strictly positive so `u = 0`
/// (the first point of an unscrambled Sobol' sequence) cannot pick a
/// leading zero-mass entry, and the result is clamped to the last
/// index so `u ≥ cdf[n-1]` (round-off or NaN `u`) stays in range.
fn select(cdf: &[f32], u: f32) -> usize {
    let u = u.max(f32::MIN_POSITIVE);
    cdf.partition_point(|&c| c < u).min(cdf.len().saturating_sub(1))
}

/// Quantize a trained [`DenseMlp`] by tracing `paths_per_output` paths
/// backwards from every output neuron.  Returns a masked copy where only
/// sampled connections survive (duplicates coalesce, paper footnote 1).
pub fn quantize_mlp(
    net: &DenseMlp,
    paths_per_output: usize,
    driver: SampleDriver,
) -> DenseMlp {
    let mut masks: Vec<Vec<f32>> =
        net.layers.iter().map(|l| vec![0.0f32; l.w.len()]).collect();
    // Pre-compute the per-neuron CDFs of every layer.
    let cdfs: Vec<Vec<Vec<f32>>> = net
        .layers
        .iter()
        .map(|l| (0..l.out_dim).map(|o| row_cdf(&l.w[o * l.in_dim..(o + 1) * l.in_dim])).collect())
        .collect();
    let mut rng = match driver {
        SampleDriver::Random(seed) => Some(Pcg32::seeded(seed)),
        SampleDriver::Sobol => None,
    };
    // One Sobol' dimension per layer, capped at MAX_DIMS: nets deeper
    // than MAX_DIMS wrap the dimension index (`li % dims` below),
    // trading some cross-layer decorrelation for correctness — the
    // uncapped `li` indexed past the driver's direction numbers and
    // panicked on deep nets.
    let dims = net.layers.len().min(crate::qmc::sobol::MAX_DIMS);
    let sobol = Sobol::new(dims);
    let outputs = net.layers.last().unwrap().out_dim;
    let mut path_i = 0u64;
    for out in 0..outputs {
        for _ in 0..paths_per_output {
            // trace from this output back to the inputs
            let mut cur = out;
            for (li, layer) in net.layers.iter().enumerate().rev() {
                let u = match &mut rng {
                    Some(r) => r.next_f32(),
                    None => sobol.component(path_i, li % dims) as f32,
                };
                let src = select(&cdfs[li][cur], u);
                masks[li][cur * layer.in_dim + src] = 1.0;
                cur = src;
            }
            path_i += 1;
        }
    }
    let mut q = net.clone();
    for (l, m) in q.layers.iter_mut().zip(masks) {
        l.set_mask(m);
    }
    q
}

/// Fraction of dense connections kept by a quantized network.
pub fn kept_fraction(q: &DenseMlp) -> f64 {
    let kept: usize = q.nnz();
    let total: usize = q.layers.iter().map(|l| l.w.len()).sum();
    kept as f64 / total as f64
}

/// ReLU-invariance normalization of §2.1: scale each neuron's incoming
/// weights to unit L1 norm and push the factor into the *outgoing*
/// weights of the next layer — output logits are unchanged (biases must
/// be absent or zero for exactness; asserted).
pub fn l1_normalize_forward(net: &mut DenseMlp) {
    for li in 0..net.layers.len() - 1 {
        assert!(
            net.layers[li].b.iter().all(|&b| b == 0.0),
            "L1 forward-propagation requires zero biases"
        );
        let (head, tail) = net.layers.split_at_mut(li + 1);
        let cur: &mut Dense = &mut head[li];
        let next: &mut Dense = &mut tail[0];
        for o in 0..cur.out_dim {
            let row = &mut cur.w[o * cur.in_dim..(o + 1) * cur.in_dim];
            let norm: f32 = row.iter().map(|v| v.abs()).sum();
            if norm > 0.0 {
                for v in row.iter_mut() {
                    *v /= norm;
                }
                // scale the o-th *input column* of the next layer
                for no in 0..next.out_dim {
                    next.w[no * next.in_dim + o] *= norm;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::init::Init;
    use crate::nn::tensor::Tensor;

    fn trained_like_net(seed: u64) -> DenseMlp {
        // random weights act as a stand-in for a trained net in unit
        // tests; the bench trains a real one.
        DenseMlp::new(&[16, 32, 32, 4], Init::UniformRandom, seed)
    }

    #[test]
    fn cdf_and_select() {
        let cdf = row_cdf(&[1.0, -1.0, 2.0]);
        assert!((cdf[2] - 1.0).abs() < 1e-6);
        assert_eq!(select(&cdf, 0.1), 0);
        assert_eq!(select(&cdf, 0.3), 1);
        assert_eq!(select(&cdf, 0.9), 2);
        assert_eq!(select(&cdf, 1.0), 2);
    }

    #[test]
    fn zero_row_selects_uniformly() {
        // degenerate all-zero row: uniform CDF, so `u` spreads the
        // selection over every index instead of collapsing to the last
        let cdf = row_cdf(&[0.0, 0.0, 0.0, 0.0]);
        assert_eq!(cdf, vec![0.25, 0.5, 0.75, 1.0]);
        assert_eq!(select(&cdf, 0.1), 0);
        assert_eq!(select(&cdf, 0.3), 1);
        assert_eq!(select(&cdf, 0.6), 2);
        assert_eq!(select(&cdf, 0.9), 3);
        // boundaries stay in range
        assert_eq!(select(&cdf, 0.0), 0);
        assert_eq!(select(&cdf, 1.0), 3);
    }

    #[test]
    fn duplicated_cdf_never_selects_zero_weight_edge() {
        // interior zero-weight entry bracketed by equal cumulative
        // values: [2, 0, 2] → cdf [0.5, 0.5, 1.0].  The old
        // binary_search_by could return index 1 (a dead edge) when u
        // landed exactly on the repeated 0.5.
        let cdf = row_cdf(&[2.0, 0.0, 2.0]);
        assert_eq!(cdf, vec![0.5, 0.5, 1.0]);
        assert_eq!(select(&cdf, 0.5), 0, "u on the repeated value must take the live edge");
        for k in 0..=64 {
            let u = k as f32 / 64.0;
            assert_ne!(select(&cdf, u), 1, "u={u} selected the zero-weight edge");
        }
    }

    #[test]
    fn nan_weights_are_ignored_not_fatal() {
        // the old partial_cmp().unwrap() panicked here
        let cdf = row_cdf(&[1.0, f32::NAN, 3.0]);
        assert_eq!(cdf, vec![0.25, 0.25, 1.0]);
        for k in 0..=64 {
            let u = k as f32 / 64.0;
            let i = select(&cdf, u);
            assert!(i < 3);
            assert_ne!(i, 1, "u={u} selected the NaN edge");
        }
        assert_eq!(select(&cdf, f32::NAN), 0, "NaN u stays in range");
    }

    #[test]
    fn sobol_driver_survives_nets_deeper_than_max_dims() {
        // regression: the Sobol' driver was built with
        // min(layers, MAX_DIMS) dims but indexed by the raw layer
        // index — out of bounds (panic) for > MAX_DIMS layers
        let deep: Vec<usize> = vec![4; crate::qmc::sobol::MAX_DIMS + 5];
        let net = DenseMlp::new(&deep, Init::UniformRandom, 13);
        assert!(net.layers.len() > crate::qmc::sobol::MAX_DIMS);
        let q = quantize_mlp(&net, 2, SampleDriver::Sobol);
        assert!(kept_fraction(&q) > 0.0);
    }

    #[test]
    fn quantize_keeps_subset_monotone_in_paths() {
        let net = trained_like_net(3);
        let q_small = quantize_mlp(&net, 4, SampleDriver::Random(1));
        let q_large = quantize_mlp(&net, 64, SampleDriver::Random(1));
        let f_small = kept_fraction(&q_small);
        let f_large = kept_fraction(&q_large);
        assert!(f_small > 0.0 && f_small < 1.0);
        assert!(f_large > f_small, "{f_large} > {f_small}");
        // kept weights identical to original where mask=1
        for (lo, lq) in net.layers.iter().zip(&q_small.layers) {
            let mask = lq.mask.as_ref().unwrap();
            for i in 0..lo.w.len() {
                if mask[i] > 0.0 {
                    assert_eq!(lq.w[i], lo.w[i]);
                } else {
                    assert_eq!(lq.w[i], 0.0);
                }
            }
        }
    }

    #[test]
    fn sobol_driver_works() {
        let net = trained_like_net(5);
        let q = quantize_mlp(&net, 16, SampleDriver::Sobol);
        assert!(kept_fraction(&q) > 0.0);
    }

    #[test]
    fn every_output_neuron_keeps_an_edge() {
        let net = trained_like_net(7);
        let q = quantize_mlp(&net, 2, SampleDriver::Random(9));
        let last = q.layers.last().unwrap();
        let mask = last.mask.as_ref().unwrap();
        for o in 0..last.out_dim {
            let row = &mask[o * last.in_dim..(o + 1) * last.in_dim];
            assert!(row.iter().any(|&m| m > 0.0), "output {o} lost all edges");
        }
    }

    #[test]
    fn l1_normalization_preserves_logits() {
        let mut net = trained_like_net(11);
        // zero all biases for exact invariance
        for l in &mut net.layers {
            l.b.iter_mut().for_each(|b| *b = 0.0);
        }
        let x = Tensor::from_vec((0..32).map(|i| (i as f32 * 0.17).sin()).collect(), &[2, 16]);
        let before = net.forward(&x, false);
        l1_normalize_forward(&mut net);
        let after = net.forward(&x, false);
        assert!(
            before.max_abs_diff(&after) < 1e-4,
            "ReLU scaling invariance violated: {}",
            before.max_abs_diff(&after)
        );
        // hidden rows now have unit L1 norm
        for l in &net.layers[..net.layers.len() - 1] {
            for o in 0..l.out_dim {
                let s: f32 = l.w[o * l.in_dim..(o + 1) * l.in_dim].iter().map(|v| v.abs()).sum();
                assert!((s - 1.0).abs() < 1e-4 || s == 0.0);
            }
        }
    }
}
