//! Shared experiment drivers for the table/figure benches: dataset
//! construction with the paper's preprocessing, and one-call trainers
//! for the MLP and CNN variants.  Budgets are scaled down from the
//! paper's 182-epoch runs (DESIGN.md §Substitutions) but keep the
//! schedule *shape* (SGD + momentum 0.9, step-decayed lr, weight decay,
//! flips + pad-crop for CIFAR).

use crate::data::synth::{self, SynthConfig};
use crate::data::{augment, ClassificationData};
use crate::nn::cnn::{Cnn, CnnConfig};
use crate::nn::init::Init;
use crate::nn::mlp::DenseMlp;
use crate::nn::optim::LrSchedule;
use crate::nn::sparse::{SparseMlp, SparseMlpConfig};
use crate::nn::trainer::{train, History, TrainConfig};
use crate::nn::Model;
use crate::topology::PathTopology;

/// Standard reduced experiment budget.
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    /// Training samples.
    pub n_train: usize,
    /// Test samples.
    pub n_test: usize,
    /// Epochs.
    pub epochs: usize,
}

impl Budget {
    /// MLP experiments (Fig 7, Fig 2).
    pub fn mlp() -> Budget {
        Budget { n_train: 4096, n_test: 1024, epochs: 4 }
    }

    /// CNN experiments (Fig 8/10-12, Tables 1-3).  Calibrated against
    /// the synthetic CIFAR difficulty so the dense baseline lands in the
    /// 60–85% band (as in the paper) rather than at ceiling.
    pub fn cnn() -> Budget {
        Budget { n_train: 768, n_test: 384, epochs: 3 }
    }

    /// Smoke-scale (honours `SOBOLNET_BENCH_FAST=1`).
    pub fn apply_env(mut self) -> Budget {
        if std::env::var("SOBOLNET_BENCH_FAST").as_deref() == Ok("1") {
            self.n_train /= 4;
            self.n_test /= 4;
            self.epochs = self.epochs.min(2);
        }
        self
    }
}

/// Flattened, normalized MNIST-like pair.
pub fn mnist_data(b: Budget, seed: u64) -> (ClassificationData, ClassificationData) {
    synth::SynthMnist::new(b.n_train, b.n_test, seed)
}

/// Flattened, normalized Fashion-like pair.
pub fn fashion_data(b: Budget, seed: u64) -> (ClassificationData, ClassificationData) {
    let cfg = SynthConfig::fashion(seed);
    let (mut tr, mut te) = synth::train_test(&cfg, b.n_train, b.n_test);
    augment::normalize_pair(&mut tr, &mut te);
    (synth::flatten(&tr), synth::flatten(&te))
}

/// CIFAR-like `[N,3,H,W]` pair, normalized.
pub fn cifar_data(b: Budget, seed: u64) -> (ClassificationData, ClassificationData) {
    let cfg = SynthConfig::cifar(seed);
    let (mut tr, mut te) = synth::train_test(&cfg, b.n_train, b.n_test);
    augment::normalize_pair(&mut tr, &mut te);
    (tr, te)
}

/// The paper's training configuration shape at a reduced budget
/// (CNN experiments; BN stabilizes the paper's base lr 0.1).
pub fn paper_train_config(epochs: usize, augment: bool) -> TrainConfig {
    TrainConfig {
        epochs,
        batch_size: 64,
        schedule: LrSchedule::StepDecay { base: 0.1, factor: 0.1, milestones: vec![0.5, 0.75] },
        momentum: 0.9,
        weight_decay: 1e-4,
        seed: 0,
        augment,
        augment_pad: 2,
        ..TrainConfig::default()
    }
}

/// MLP variant: same schedule shape at base lr 0.05 — the BN-free MLPs
/// diverge at 0.1 with momentum 0.9 on the noisier synthetic data.
pub fn mlp_train_config(epochs: usize) -> TrainConfig {
    TrainConfig {
        schedule: LrSchedule::StepDecay { base: 0.05, factor: 0.1, milestones: vec![0.5, 0.75] },
        ..paper_train_config(epochs, false)
    }
}

/// Train a sparse MLP over a topology; returns (history, params).
pub fn run_sparse_mlp(
    topo: &PathTopology,
    init: Init,
    tr: &ClassificationData,
    te: &ClassificationData,
    epochs: usize,
) -> (History, usize) {
    let mut net = SparseMlp::new(topo, SparseMlpConfig { init, seed: 0, ..Default::default() });
    let hist = train(&mut net, tr, te, &mlp_train_config(epochs));
    let n = net.nparams();
    (hist, n)
}

/// Train the dense MLP baseline.
pub fn run_dense_mlp(
    sizes: &[usize],
    tr: &ClassificationData,
    te: &ClassificationData,
    epochs: usize,
) -> (History, usize) {
    let mut net = DenseMlp::new(sizes, Init::UniformRandom, 0);
    let hist = train(&mut net, tr, te, &mlp_train_config(epochs));
    let n = net.nparams();
    (hist, n)
}

/// Train a CNN (dense or sparse) and report (history, nnz, params).
pub fn run_cnn(
    mut cnn: Cnn,
    tr: &ClassificationData,
    te: &ClassificationData,
    epochs: usize,
) -> (History, usize, usize) {
    let hist = train(&mut cnn, tr, te, &paper_train_config(epochs, true));
    let nnz = cnn.nnz();
    let params = cnn.nparams();
    (hist, nnz, params)
}

/// The paper's CNN channel graph for a width multiplier.
pub fn cnn_channel_sizes(width: f64, in_channels: usize) -> Vec<usize> {
    let cfg = CnnConfig::paper(width, in_channels, 10, Init::UniformRandom, 0);
    let mut sizes = vec![in_channels];
    sizes.extend(cfg.channels);
    sizes
}
