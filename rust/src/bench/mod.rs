//! In-tree micro-benchmark harness (the `criterion` substrate) plus
//! table formatting for the experiment benches.
//!
//! Design goals: warmup, multiple timed samples, mean ± CI and
//! throughput reporting, machine-greppable one-line results so
//! `cargo bench | tee bench_output.txt` archives every table/figure,
//! and a machine-readable [`BenchReport`] (results + named metrics such
//! as thread-scaling ratios) serialized as JSON — the perf-hotpath
//! bench writes `BENCH_hotpath.json` at the repo root so the
//! throughput trajectory is tracked across PRs.

pub mod exp;

use crate::config::json::JsonValue;
use crate::util::stats::Welford;
use crate::util::timer::Timer;
use crate::util::{fmt_count, fmt_secs};
use std::collections::BTreeMap;

/// A configured micro-benchmark runner.
#[derive(Debug, Clone)]
pub struct Bench {
    /// Benchmark group name (printed as prefix).
    pub group: String,
    /// Warmup iterations.
    pub warmup: usize,
    /// Timed samples.
    pub samples: usize,
    /// Minimum total measured time; samples are added until reached.
    pub min_time_secs: f64,
}

impl Bench {
    /// New runner with sensible defaults.
    pub fn new(group: &str) -> Self {
        Bench { group: group.to_string(), warmup: 3, samples: 10, min_time_secs: 0.2 }
    }

    /// Builder: warmup iterations.
    pub fn warmup(mut self, w: usize) -> Self {
        self.warmup = w;
        self
    }

    /// Builder: sample count.
    pub fn samples(mut self, s: usize) -> Self {
        self.samples = s;
        self
    }

    /// Run a closure repeatedly and report stats.  `work_units` scales
    /// the throughput line (e.g. elements processed per call).
    pub fn run<F: FnMut()>(&self, name: &str, work_units: usize, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut w = Welford::new();
        let total = Timer::start();
        let mut i = 0usize;
        while i < self.samples || total.elapsed_secs() < self.min_time_secs {
            let t = Timer::start();
            f();
            w.push(t.elapsed_secs());
            i += 1;
            if i > self.samples * 100 {
                break; // pathological fast function; enough samples
            }
        }
        let r = BenchResult {
            group: self.group.clone(),
            name: name.to_string(),
            mean_secs: w.mean(),
            ci95: w.ci95(),
            min_secs: w.min(),
            samples: w.count() as usize,
            work_units,
        };
        println!("{r}");
        r
    }
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Group name.
    pub group: String,
    /// Case name.
    pub name: String,
    /// Mean seconds per call.
    pub mean_secs: f64,
    /// 95% CI half-width.
    pub ci95: f64,
    /// Fastest sample.
    pub min_secs: f64,
    /// Number of samples.
    pub samples: usize,
    /// Work units per call for throughput.
    pub work_units: usize,
}

impl BenchResult {
    /// Work units per second at the mean.
    pub fn throughput(&self) -> f64 {
        if self.mean_secs > 0.0 {
            self.work_units as f64 / self.mean_secs
        } else {
            f64::INFINITY
        }
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "bench {}/{}: {} ±{} (min {}, n={})",
            self.group,
            self.name,
            fmt_secs(self.mean_secs),
            fmt_secs(self.ci95),
            fmt_secs(self.min_secs),
            self.samples
        )?;
        if self.work_units > 0 {
            write!(f, " | {}/s", fmt_count(self.throughput() as usize))?;
        }
        Ok(())
    }
}

/// Machine-readable run report: accumulates [`BenchResult`]s plus named
/// scalar metrics (e.g. thread-scaling ratios) and serializes them to
/// compact JSON for cross-PR perf tracking.
#[derive(Debug, Clone, Default)]
pub struct BenchReport {
    /// All recorded results, in run order.
    pub results: Vec<BenchResult>,
    /// Named scalar metrics, in record order.
    pub metrics: Vec<(String, f64)>,
}

/// JSON number that is always valid JSON (non-finite values clamp to 0).
fn json_num(v: f64) -> JsonValue {
    JsonValue::Number(if v.is_finite() { v } else { 0.0 })
}

impl BenchReport {
    /// Empty report.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one bench result.
    pub fn push(&mut self, r: &BenchResult) {
        self.results.push(r.clone());
    }

    /// Record a named scalar metric (e.g. `"sparse_bwd_scaling_4t"`).
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.push((name.to_string(), value));
    }

    /// JSON form: `{"version", "results": [...], "metrics": {...}}`.
    pub fn to_json(&self) -> JsonValue {
        let results: Vec<JsonValue> = self
            .results
            .iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("group".to_string(), JsonValue::String(r.group.clone()));
                m.insert("name".to_string(), JsonValue::String(r.name.clone()));
                m.insert("mean_secs".to_string(), json_num(r.mean_secs));
                m.insert("ci95_secs".to_string(), json_num(r.ci95));
                m.insert("min_secs".to_string(), json_num(r.min_secs));
                m.insert("samples".to_string(), json_num(r.samples as f64));
                m.insert("work_units".to_string(), json_num(r.work_units as f64));
                m.insert("throughput_per_sec".to_string(), json_num(r.throughput()));
                JsonValue::Object(m)
            })
            .collect();
        let mut metrics = BTreeMap::new();
        for (k, v) in &self.metrics {
            metrics.insert(k.clone(), json_num(*v));
        }
        let mut top = BTreeMap::new();
        top.insert("version".to_string(), JsonValue::String(crate::VERSION.to_string()));
        top.insert("results".to_string(), JsonValue::Array(results));
        top.insert("metrics".to_string(), JsonValue::Object(metrics));
        JsonValue::Object(top)
    }

    /// Write compact JSON to `path`.
    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_compact())
    }
}

/// Simple aligned-column table printer for experiment outputs
/// (the rows the paper's tables/figures report).
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header count).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n=== {} ===\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:w$} | "));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bench::new("test").warmup(1).samples(3);
        let mut counter = 0u64;
        let r = b.run("noop", 100, || {
            counter += 1;
        });
        assert!(counter >= 4, "warmup + samples");
        assert!(r.samples >= 3);
        assert!(r.mean_secs >= 0.0);
        assert!(r.throughput() > 0.0);
        let s = format!("{r}");
        assert!(s.contains("test/noop"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer-name".into(), "2.50".into()]);
        let s = t.render();
        assert!(s.contains("=== Demo ==="));
        assert!(s.contains("longer-name"));
        let lines: Vec<&str> = s.lines().filter(|l| l.starts_with('|')).collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[1].len(), lines[2].len(), "rows aligned");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_checks_width() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn report_serializes_valid_json() {
        let mut rep = BenchReport::new();
        rep.push(&BenchResult {
            group: "g".into(),
            name: "case a".into(),
            mean_secs: 0.002,
            ci95: 0.0001,
            min_secs: 0.0018,
            samples: 10,
            work_units: 4096,
        });
        rep.metric("scaling_4t", 3.1);
        let text = rep.to_json().to_string_compact();
        // must round-trip through the in-tree parser
        let v = crate::config::json::parse(&text).expect("valid JSON");
        let results = v.get("results").and_then(|r| r.as_array()).expect("results");
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").and_then(|n| n.as_str()), Some("case a"));
        assert_eq!(results[0].get("work_units").and_then(|n| n.as_usize()), Some(4096));
        let tp = results[0].get("throughput_per_sec").and_then(|n| n.as_f64()).unwrap();
        assert!((tp - 4096.0 / 0.002).abs() / tp < 1e-9);
        let m = v.get("metrics").expect("metrics");
        assert_eq!(m.get("scaling_4t").and_then(|n| n.as_f64()), Some(3.1));
    }

    #[test]
    fn report_clamps_non_finite_numbers() {
        let mut rep = BenchReport::new();
        rep.push(&BenchResult {
            group: "g".into(),
            name: "instant".into(),
            mean_secs: 0.0, // throughput would be +inf
            ci95: 0.0,
            min_secs: 0.0,
            samples: 1,
            work_units: 10,
        });
        let text = rep.to_json().to_string_compact();
        let v = crate::config::json::parse(&text).expect("still valid JSON");
        let results = v.get("results").and_then(|r| r.as_array()).unwrap();
        assert_eq!(
            results[0].get("throughput_per_sec").and_then(|n| n.as_f64()),
            Some(0.0)
        );
    }
}
