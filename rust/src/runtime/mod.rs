//! PJRT runtime: load AOT-compiled HLO artifacts (produced by
//! `python/compile/aot.py`) and execute them from rust — the bridge
//! between the L3 coordinator and the L2/L1 JAX+Pallas compute.
//!
//! Interchange format is **HLO text** (not serialized protos): jax≥0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while
//! the text parser reassigns ids cleanly (see /opt/xla-example).
//!
//! Python never runs at request time: artifacts are compiled once by
//! `make artifacts`, and every invocation here is pure rust → PJRT.

pub mod artifact;
pub mod client;
pub mod xla_stub;

pub use artifact::{ArtifactManifest, ArtifactSpec};
pub use client::{Executable, Runtime};
