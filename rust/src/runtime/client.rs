//! PJRT client wrapper over the `xla` crate surface.
//!
//! In the offline build `xla` resolves to the in-tree host stub
//! ([`crate::runtime::xla_stub`]); swap the import below to the real
//! crate to target actual PJRT hardware.

use super::xla_stub as xla;
use crate::util::error::{Context, Result};

/// A PJRT client (CPU in this environment).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Platform name reported by PJRT.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of addressable devices.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO **text** artifact and compile it.
    pub fn load_hlo_text(&self, path: &str) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {path}"))?;
        Ok(Executable { exe, name: path.to_string() })
    }

}

/// A compiled executable ready to run.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Source path (diagnostics).
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened tuple outputs.
    ///
    /// `aot.py` lowers every artifact with `return_tuple=True`, so the
    /// single device output is a tuple literal which we decompose.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute(inputs).context("execute")?;
        let mut out = result[0][0].to_literal_sync().context("device → host transfer")?;
        let tuple = out.decompose_tuple().context("decomposing output tuple")?;
        Ok(tuple)
    }
}

/// Build an f32 literal of the given dimensions.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    crate::ensure!(n == data.len(), "literal_f32 size mismatch: {} vs {:?}", data.len(), dims);
    let lit = xla::Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims_i64).context("reshape literal")
}

/// Build an i32 literal of the given dimensions.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    crate::ensure!(n == data.len(), "literal_i32 size mismatch");
    let lit = xla::Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims_i64).context("reshape literal")
}

/// Extract an f32 vector from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("literal to f32 vec")
}

/// Extract a scalar f32.
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = to_vec_f32(lit)?;
    crate::ensure!(v.len() == 1, "expected scalar, got {} elements", v.len());
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    //! Runtime tests that need compiled artifacts live in
    //! `rust/tests/aot_integration.rs` (they require `make artifacts`).
    use super::*;

    #[test]
    fn literal_helpers_validate_sizes() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_i32(&[1, 2, 3], &[2]).is_err());
    }

    #[test]
    fn literal_roundtrip() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        let back = to_vec_f32(&lit).unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn scalar_extraction() {
        let lit = literal_f32(&[7.5], &[1]).unwrap();
        assert_eq!(to_scalar_f32(&lit).unwrap(), 7.5);
        let lit2 = literal_f32(&[1.0, 2.0], &[2]).unwrap();
        assert!(to_scalar_f32(&lit2).is_err());
    }
}
