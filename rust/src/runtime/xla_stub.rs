//! Host-side stand-in for the `xla` (PJRT) crate.
//!
//! The offline build carries no `xla_extension` shared library, so the
//! runtime layer compiles against this stub instead of the real crate:
//! [`Literal`] is a fully functional host literal (shape + typed data +
//! tuples — enough for every literal helper and its tests), while the
//! client/compile/execute surface exists but reports PJRT as
//! unavailable.  `runtime/client.rs` and `coordinator/train.rs` import
//! this module as `xla`; pointing those imports back at the real crate
//! (and adding the dependency) restores hardware execution without any
//! other code change.

use crate::util::error::{Error, Result};

/// Element types the stub literal can hold.
pub trait NativeType: Copy {
    /// Build a rank-1 literal from a data vector.
    fn literal_from(data: Vec<Self>) -> Literal;
    /// Extract the flat data if the literal holds this element type.
    fn literal_to(lit: &Literal) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn literal_from(data: Vec<f32>) -> Literal {
        let dims = vec![data.len() as i64];
        Literal::F32 { data, dims }
    }

    fn literal_to(lit: &Literal) -> Option<Vec<f32>> {
        match lit {
            Literal::F32 { data, .. } => Some(data.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn literal_from(data: Vec<i32>) -> Literal {
        let dims = vec![data.len() as i64];
        Literal::I32 { data, dims }
    }

    fn literal_to(lit: &Literal) -> Option<Vec<i32>> {
        match lit {
            Literal::I32 { data, .. } => Some(data.clone()),
            _ => None,
        }
    }
}

/// A host literal: typed flat data plus dimensions, or a tuple.
#[derive(Debug, Clone)]
pub enum Literal {
    /// f32 array.
    F32 {
        /// Flat row-major data.
        data: Vec<f32>,
        /// Dimension sizes.
        dims: Vec<i64>,
    },
    /// i32 array.
    I32 {
        /// Flat row-major data.
        data: Vec<i32>,
        /// Dimension sizes.
        dims: Vec<i64>,
    },
    /// Tuple of literals (executable outputs).
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a slice (mirrors `xla::Literal::vec1`).
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::literal_from(data.to_vec())
    }

    /// Element count of an array literal.
    fn element_count(&self) -> Result<usize> {
        match self {
            Literal::F32 { data, .. } => Ok(data.len()),
            Literal::I32 { data, .. } => Ok(data.len()),
            Literal::Tuple(_) => Err(Error::msg("tuple literal has no element count")),
        }
    }

    /// Return a copy with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let have = self.element_count()?;
        if n as usize != have {
            return Err(crate::err!("reshape {dims:?} does not match {have} elements"));
        }
        Ok(match self {
            Literal::F32 { data, .. } => Literal::F32 { data: data.clone(), dims: dims.to_vec() },
            Literal::I32 { data, .. } => Literal::I32 { data: data.clone(), dims: dims.to_vec() },
            Literal::Tuple(_) => unreachable!("element_count rejected tuples"),
        })
    }

    /// Flat host copy of the data (mirrors `xla::Literal::to_vec`).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::literal_to(self).ok_or_else(|| Error::msg("literal element type mismatch"))
    }

    /// Split a tuple literal into its elements.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(elems) => Ok(std::mem::take(elems)),
            _ => Err(Error::msg("not a tuple literal")),
        }
    }
}

const UNAVAILABLE: &str =
    "PJRT unavailable: built against the in-tree xla stub (no xla_extension in this environment)";

/// Parsed HLO module (stub: never constructed successfully).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file — always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::msg(UNAVAILABLE))
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client — always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::msg(UNAVAILABLE))
    }

    /// Platform name.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Addressable device count.
    pub fn device_count(&self) -> usize {
        0
    }

    /// Compile a computation — unreachable (no client can exist).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::msg(UNAVAILABLE))
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Device → host transfer — unreachable (no buffer can exist).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::msg(UNAVAILABLE))
    }
}

/// Loaded executable (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal inputs — unreachable (cannot be compiled).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::msg(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_and_extract() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[3]).is_err());
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn tuple_decomposes_once() {
        let mut t = Literal::Tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2.0f32])]);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        let mut arr = Literal::vec1(&[1.0f32]);
        assert!(arr.decompose_tuple().is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
