//! Artifact manifest: `python/compile/aot.py` writes
//! `artifacts/manifest.json` describing every lowered HLO module (name,
//! file, input/output shapes and the model hyperparameters baked into
//! it); the coordinator reads it to wire inputs without hardcoding
//! shapes in two languages.

use crate::config::json::{self, JsonValue};
use std::path::{Path, PathBuf};

/// One lowered module.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    /// Logical name, e.g. `sparse_train_step`.
    pub name: String,
    /// HLO text file (relative to the manifest).
    pub file: String,
    /// Input tensor shapes, in call order.
    pub inputs: Vec<Vec<usize>>,
    /// Output tensor shapes, in tuple order.
    pub outputs: Vec<Vec<usize>>,
    /// Free-form metadata (layer sizes, paths, batch…).
    pub meta: JsonValue,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    /// Directory containing the manifest (files resolve against it).
    pub dir: PathBuf,
    /// All artifacts by name.
    pub artifacts: Vec<ArtifactSpec>,
}

fn parse_shape_list(v: &JsonValue) -> Result<Vec<Vec<usize>>, String> {
    v.as_array()
        .ok_or("shape list must be an array")?
        .iter()
        .map(|s| {
            s.as_array()
                .ok_or("shape must be an array")?
                .iter()
                .map(|d| d.as_usize().ok_or("dim must be int".to_string()))
                .collect()
        })
        .collect()
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<ArtifactManifest, String> {
        let dir = PathBuf::from(dir);
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<ArtifactManifest, String> {
        let root = json::parse(text)?;
        let arr = root
            .get("artifacts")
            .and_then(|a| a.as_array())
            .ok_or("manifest must contain an 'artifacts' array")?;
        let mut artifacts = Vec::new();
        for a in arr {
            artifacts.push(ArtifactSpec {
                name: a
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or("artifact.name")?
                    .to_string(),
                file: a
                    .get("file")
                    .and_then(|v| v.as_str())
                    .ok_or("artifact.file")?
                    .to_string(),
                inputs: parse_shape_list(a.get("inputs").ok_or("artifact.inputs")?)?,
                outputs: parse_shape_list(a.get("outputs").ok_or("artifact.outputs")?)?,
                meta: a.get("meta").cloned().unwrap_or(JsonValue::Null),
            });
        }
        Ok(ArtifactManifest { dir, artifacts })
    }

    /// Find an artifact by name.
    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// True if every artifact file exists on disk.
    pub fn complete(&self) -> bool {
        self.artifacts.iter().all(|a| Path::new(&self.path_of(a)).exists())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [
        {
          "name": "sparse_train_step",
          "file": "sparse_train_step.hlo.txt",
          "inputs": [[2048], [2048], [64, 784], [64]],
          "outputs": [[2048], [2048], [1]],
          "meta": {"paths": 2048, "batch": 64}
        },
        {
          "name": "sparse_forward",
          "file": "sparse_forward.hlo.txt",
          "inputs": [[2048], [64, 784]],
          "outputs": [[64, 10]]
        }
      ]
    }"#;

    #[test]
    fn parse_and_lookup() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/tmp/art")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let ts = m.find("sparse_train_step").unwrap();
        assert_eq!(ts.inputs.len(), 4);
        assert_eq!(ts.inputs[2], vec![64, 784]);
        assert_eq!(ts.meta.get("paths").unwrap().as_usize(), Some(2048));
        assert_eq!(
            m.path_of(ts),
            PathBuf::from("/tmp/art/sparse_train_step.hlo.txt")
        );
        assert!(m.find("nope").is_none());
        assert!(!m.complete(), "files do not exist");
    }

    #[test]
    fn missing_fields_error() {
        assert!(ArtifactManifest::parse(r#"{"artifacts": [{"name": "x"}]}"#, ".".into()).is_err());
        assert!(ArtifactManifest::parse(r#"{}"#, ".".into()).is_err());
    }
}
