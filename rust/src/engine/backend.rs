//! Backend contract of the engine: anything that can classify a
//! fixed-size batch, plus the blanket adapter for pure-rust models.
//! (Moved here from `serve`; `serve` re-exports both names.)

use super::ticket::RejectReason;

/// Something that can classify a fixed-size batch.
///
/// Implemented by the AOT executable wrapper (see
/// `coordinator::train::AotForward`) and by the pure-rust models (via
/// [`ModelBackend`]), so the same engine fronts both.
///
/// Backends need not be `Send`: workers construct them *on* their own
/// thread via a factory (PJRT handles are `Rc`-based and cannot cross
/// threads).
pub trait InferenceBackend {
    /// Static batch capacity of one execution.
    fn batch_capacity(&self) -> usize;

    /// Features per sample.
    fn features(&self) -> usize;

    /// Classes per sample.
    fn classes(&self) -> usize;

    /// Run on a `[capacity × features]` buffer (padded rows arbitrary);
    /// returns `[capacity × classes]` logits.
    fn infer_batch(&mut self, x: &[f32]) -> Vec<f32>;

    /// Run on the first `rows` real rows of a `[capacity × features]`
    /// buffer (the tail is padding).  The engine worker calls this; the
    /// default forwards to [`InferenceBackend::infer_batch`], which
    /// computes the padded rows too and returns `capacity × classes`
    /// logits — callers must only read the first `rows × classes`.
    /// Backends that can exploit the real row count override it: the
    /// remote transport ships (and has the worker process compute)
    /// only the real rows, so worker-side counters and latency
    /// histograms count requests, never padding.
    fn infer_rows(&mut self, x: &[f32], rows: usize) -> Vec<f32> {
        let _ = rows;
        self.infer_batch(x)
    }

    /// Multi-tenant entry point: run `rows` rows against the model
    /// pinned as `(model_id, version)`.  `(0, 0)` is the default
    /// (builder-configured) model and must behave exactly like
    /// [`InferenceBackend::infer_rows`].  The default implementation
    /// serves *only* the default model — any other key is rejected with
    /// [`RejectReason::UnknownModel`] — which is correct for legacy
    /// single-model backends.  Backends that can route by model
    /// override it: the remote transport ships the key in the request
    /// frame so the worker *process* resolves it against its own
    /// registry cache, and engine workers with local tenancy intercept
    /// non-default keys before this method via their per-shard
    /// [`ModelCache`](crate::registry::cache::ModelCache).
    fn infer_rows_model(
        &mut self,
        model_id: u64,
        version: u64,
        x: &[f32],
        rows: usize,
    ) -> Result<Vec<f32>, RejectReason> {
        if (model_id, version) != (0, 0) {
            return Err(RejectReason::UnknownModel { model_id, version });
        }
        Ok(self.infer_rows(x, rows))
    }
}

/// Blanket adapter for pure-rust [`crate::nn::Model`]s.
///
/// Holds reusable input/output tensors, so on the serve hot path each
/// batch costs one forward pass plus a single logits copy — the model's
/// own scratch (e.g. `SparseMlp`) allocates nothing once warm, and the
/// forward fans out on the shared process-wide worker pool of
/// [`crate::util::parallel`].
pub struct ModelBackend<M: crate::nn::Model + Send> {
    /// Wrapped model.
    pub model: M,
    /// Fixed batch capacity to emulate.
    pub capacity: usize,
    /// Input features.
    pub features: usize,
    /// Output classes.
    pub classes: usize,
    /// Reused `[capacity, features]` input staging tensor.
    xbuf: crate::nn::tensor::Tensor,
    /// Reused logits tensor.
    obuf: crate::nn::tensor::Tensor,
}

impl<M: crate::nn::Model + Send> ModelBackend<M> {
    /// Wrap `model` behind a fixed `[capacity × features] →
    /// [capacity × classes]` serving contract.
    pub fn new(model: M, capacity: usize, features: usize, classes: usize) -> Self {
        ModelBackend {
            model,
            capacity,
            features,
            classes,
            xbuf: crate::nn::tensor::Tensor::empty(),
            obuf: crate::nn::tensor::Tensor::empty(),
        }
    }
}

impl<M: crate::nn::Model + Send> InferenceBackend for ModelBackend<M> {
    fn batch_capacity(&self) -> usize {
        self.capacity
    }

    fn features(&self) -> usize {
        self.features
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn infer_batch(&mut self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.capacity * self.features, "infer_batch input shape");
        self.xbuf.shape.clear();
        self.xbuf.shape.push(self.capacity);
        self.xbuf.shape.push(self.features);
        self.xbuf.data.clear();
        self.xbuf.data.extend_from_slice(x);
        self.model.forward_into(&self.xbuf, false, &mut self.obuf);
        self.obuf.data.clone()
    }
}
