//! One worker shard: a dedicated OS thread owning a backend instance,
//! draining its private bounded admission queue through the adaptive
//! [`Batcher`](super::batcher::Batcher).
//!
//! The backend is constructed *on* the worker thread via a factory, so
//! non-`Send` backends (PJRT handles are `Rc`-based) work unchanged.
//! Each worker keeps its own [`Metrics`] sized to the engine's sample
//! window (the engine merges them on read — see
//! `Metrics::merged_percentiles`; the window keeps a long-lived shard's
//! sample storage O(window), not O(requests served)), bumps the
//! engine-wide aggregate counters, maintains the in-flight gauge the
//! dispatcher reads, and reports each completion latency back to the
//! [`DispatchPolicy`](super::dispatch::DispatchPolicy) so learning
//! policies (EWMA) can adapt.
//!
//! Each worker thread is also a *dispatcher* into
//! [`util::parallel`](crate::util::parallel)'s multi-job pool: the
//! backend's column-sharded forward runs as its own pool job, so K
//! shards doing small-batch forwards execute concurrently instead of
//! queueing on a single job slot (pre-multi-job pools serialized
//! exactly here).  Determinism is unaffected — chunk geometry and
//! merge order are job-local properties.

use super::admission::BoundedQueue;
use super::batcher::Batcher;
use super::dispatch::DispatchPolicy;
use super::ticket::{RejectReason, ReplyTx};
use super::InferenceBackend;
use crate::coordinator::metrics::Metrics;
use crate::util::timer::Timer;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// One queued inference request (a single sample).
pub(crate) struct EngineRequest {
    /// Flattened input features.
    pub x: Vec<f32>,
    /// Where the outcome goes.
    pub reply: ReplyTx,
    /// End-to-end latency stopwatch, started at submit.
    pub t_start: Timer,
}

/// Handle to a running worker shard.
pub(crate) struct Shard {
    /// Bounded admission queue (`close()` begins shutdown).
    pub queue: Arc<BoundedQueue<EngineRequest>>,
    /// Requests dispatched to this shard but not yet answered.
    pub inflight: Arc<AtomicUsize>,
    /// This worker's own metrics, including its `shed` counter (the
    /// engine merges these on read).
    pub metrics: Arc<Metrics>,
    /// Worker thread handle.
    pub join: Option<JoinHandle<()>>,
}

/// Closes and drains the shard queue when the worker thread exits —
/// normally or by **panic** — so queued tickets resolve to
/// [`RejectReason::WorkerFailed`] instead of hanging forever and
/// submitters blocked on a full queue wake up (they get
/// `ShuttingDown`).  Without this, a panicking backend would strand
/// every queued request and deadlock `Block`-admission producers.
struct QueueGuard {
    queue: Arc<BoundedQueue<EngineRequest>>,
}

impl Drop for QueueGuard {
    fn drop(&mut self) {
        self.queue.close();
        while let Some(req) = self.queue.pop_block() {
            req.reply.send_rejected(RejectReason::WorkerFailed);
        }
    }
}

/// Spawn a worker shard.  Returns the shard handle plus a one-shot
/// channel carrying `(features, classes, batch_capacity)` once the
/// backend is constructed on the worker thread.
pub(crate) fn spawn<F>(
    worker_id: usize,
    factory: F,
    max_wait: Duration,
    queue_bound: usize,
    metrics_window: usize,
    aggregate: Arc<Metrics>,
    dispatch: Arc<dyn DispatchPolicy>,
) -> (Shard, Receiver<(usize, usize, usize)>)
where
    F: FnOnce() -> Box<dyn InferenceBackend> + Send + 'static,
{
    let queue = Arc::new(BoundedQueue::new(queue_bound));
    let (meta_tx, meta_rx) = channel();
    let metrics = Arc::new(Metrics::with_window(metrics_window));
    let inflight = Arc::new(AtomicUsize::new(0));
    let own = metrics.clone();
    let gauge = inflight.clone();
    let q = queue.clone();
    let join = std::thread::Builder::new()
        .name(format!("sobolnet-engine-{worker_id}"))
        .spawn(move || {
            let _guard = QueueGuard { queue: q.clone() };
            let mut backend = factory();
            let cap = backend.batch_capacity();
            let feat = backend.features();
            let classes = backend.classes();
            let _ = meta_tx.send((feat, classes, cap));
            let batcher = Batcher { capacity: cap, max_wait };
            let mut xbuf = vec![0.0f32; cap * feat];
            while let Some(batch) = batcher.next_batch(&*q) {
                // assemble the padded batch: real rows are overwritten,
                // only the tail padding needs (re)zeroing
                for (i, r) in batch.iter().enumerate() {
                    xbuf[i * feat..(i + 1) * feat].copy_from_slice(&r.x);
                }
                for v in &mut xbuf[batch.len() * feat..] {
                    *v = 0.0;
                }
                let logits = backend.infer_rows(&xbuf, batch.len());
                own.record_batch(batch.len(), cap);
                aggregate.record_batch(batch.len(), cap);
                for (i, r) in batch.into_iter().enumerate() {
                    let out = logits[i * classes..(i + 1) * classes].to_vec();
                    let secs = r.t_start.elapsed_secs();
                    // latency samples live only in the per-worker
                    // metrics; the engine merges them before computing
                    // aggregate percentiles, so the per-request cost
                    // here is one uncontended lock, not two
                    own.record_latency(secs);
                    aggregate.completed.fetch_add(1, Ordering::Relaxed);
                    dispatch.observe(worker_id, secs);
                    gauge.fetch_sub(1, Ordering::Relaxed);
                    r.reply.send_logits(out);
                }
            }
        })
        .expect("spawn engine worker thread");
    (Shard { queue, inflight, metrics, join: Some(join) }, meta_rx)
}
