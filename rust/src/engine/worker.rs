//! One worker shard: a dedicated OS thread owning a backend instance,
//! draining its private bounded admission queue through the adaptive
//! [`Batcher`](super::batcher::Batcher).
//!
//! The backend is constructed *on* the worker thread via a factory, so
//! non-`Send` backends (PJRT handles are `Rc`-based) work unchanged.
//! Each worker keeps its own [`Metrics`] sized to the engine's sample
//! window (the engine merges them on read — see
//! `Metrics::merged_percentiles`; the window keeps a long-lived shard's
//! sample storage O(window), not O(requests served)), bumps the
//! engine-wide aggregate counters, maintains the in-flight gauge the
//! dispatcher reads, and reports each completion latency back to the
//! [`DispatchPolicy`](super::dispatch::DispatchPolicy) so learning
//! policies (EWMA) can adapt.
//!
//! Each worker thread is also a *dispatcher* into
//! [`util::parallel`](crate::util::parallel)'s multi-job pool: the
//! backend's column-sharded forward runs as its own pool job, so K
//! shards doing small-batch forwards execute concurrently instead of
//! queueing on a single job slot (pre-multi-job pools serialized
//! exactly here).  Determinism is unaffected — chunk geometry and
//! merge order are job-local properties.
//!
//! Workers are **ensemble-agnostic**: a fan-out request arrives as an
//! ordinary [`EngineRequest`] whose `reply` is a member-tagged
//! [`ReplyTx::Member`], and the worker answers it exactly like any
//! other — the member tag rides along in the reply channel, and all
//! merge bookkeeping lives in the ticket
//! ([`super::ticket::Ticket`]) and [`super::ensemble`].

use super::admission::BoundedQueue;
use super::batcher::{homogeneous_runs, Batcher};
use super::dispatch::DispatchPolicy;
use super::ticket::{RejectReason, ReplyTx};
use super::InferenceBackend;
use crate::coordinator::metrics::Metrics;
use crate::registry::cache::ModelCache;
use crate::registry::Registry;
use crate::util::timer::Timer;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// One queued inference request (a single sample).
pub(crate) struct EngineRequest {
    /// Flattened input features.
    pub x: Vec<f32>,
    /// Tenant model this request was admitted against (`0` = the
    /// builder-configured default model).
    pub model_id: u64,
    /// Snapshot version pinned **at admission** — the worker never
    /// re-resolves it, so a publish racing this request cannot change
    /// which weights answer it.
    pub version: u64,
    /// Where the outcome goes.
    pub reply: ReplyTx,
    /// End-to-end latency stopwatch, started at submit.
    pub t_start: Timer,
}

/// Multi-tenant wiring handed to each worker shard: the shared
/// registry to cold-load from, plus the per-shard cache bound.  The
/// worker builds its own [`ModelCache`] (single-owner, no lock) once
/// it knows the backend's batch capacity.
pub(crate) struct Tenancy {
    /// Shared model registry (specs + versioned snapshots).
    pub registry: Arc<Registry>,
    /// Max built tenant backends resident per shard.
    pub cache_cap: usize,
}

/// Handle to a running worker shard.
pub(crate) struct Shard {
    /// Bounded admission queue (`close()` begins shutdown).
    pub queue: Arc<BoundedQueue<EngineRequest>>,
    /// Requests dispatched to this shard but not yet answered.
    pub inflight: Arc<AtomicUsize>,
    /// This worker's own metrics, including its `shed` counter (the
    /// engine merges these on read).
    pub metrics: Arc<Metrics>,
    /// Worker thread handle.
    pub join: Option<JoinHandle<()>>,
}

/// Closes and drains the shard queue when the worker thread exits —
/// normally or by **panic** — so queued tickets resolve to
/// [`RejectReason::WorkerFailed`] instead of hanging forever and
/// submitters blocked on a full queue wake up (they get
/// `ShuttingDown`).  Without this, a panicking backend would strand
/// every queued request and deadlock `Block`-admission producers.
struct QueueGuard {
    queue: Arc<BoundedQueue<EngineRequest>>,
}

impl Drop for QueueGuard {
    fn drop(&mut self) {
        self.queue.close();
        while let Some(req) = self.queue.pop_block() {
            req.reply.send_rejected(RejectReason::WorkerFailed);
        }
    }
}

/// Spawn a worker shard.  Returns the shard handle plus a one-shot
/// channel carrying `(features, classes, batch_capacity)` once the
/// backend is constructed on the worker thread.
pub(crate) fn spawn<F>(
    worker_id: usize,
    factory: F,
    max_wait: Duration,
    queue_bound: usize,
    metrics_window: usize,
    aggregate: Arc<Metrics>,
    dispatch: Arc<dyn DispatchPolicy>,
    tenancy: Option<Tenancy>,
) -> (Shard, Receiver<(usize, usize, usize)>)
where
    F: FnOnce() -> Box<dyn InferenceBackend> + Send + 'static,
{
    let queue = Arc::new(BoundedQueue::new(queue_bound));
    let (meta_tx, meta_rx) = channel();
    let metrics = Arc::new(Metrics::with_window(metrics_window));
    let inflight = Arc::new(AtomicUsize::new(0));
    let own = metrics.clone();
    let gauge = inflight.clone();
    let q = queue.clone();
    let join = std::thread::Builder::new()
        .name(format!("sobolnet-engine-{worker_id}"))
        .spawn(move || {
            let _guard = QueueGuard { queue: q.clone() };
            let mut backend = factory();
            let cap = backend.batch_capacity();
            let feat = backend.features();
            let classes = backend.classes();
            let _ = meta_tx.send((feat, classes, cap));
            // per-shard tenant cache, bounded and single-owner; built
            // here because the batch capacity comes from the backend
            let mut tenants: Option<(Arc<Registry>, ModelCache)> =
                tenancy.map(|t| (t.registry, ModelCache::new(t.cache_cap, cap)));
            let batcher = Batcher { capacity: cap, max_wait };
            let mut xbuf = vec![0.0f32; cap * feat];
            while let Some(batch) = batcher.next_batch(&*q) {
                // one drained batch may mix tenants; each backend
                // execution serves one (model_id, version), so split
                // into consecutive homogeneous runs (arrival order is
                // preserved — a boundary costs one extra execution,
                // never a reorder)
                let runs = homogeneous_runs(&batch, |r| (r.model_id, r.version));
                let mut remaining = batch.into_iter();
                for (s, e) in runs {
                    let run: Vec<EngineRequest> = remaining.by_ref().take(e - s).collect();
                    let rows = run.len();
                    // assemble the padded run: real rows are
                    // overwritten, only the tail needs (re)zeroing
                    for (i, r) in run.iter().enumerate() {
                        xbuf[i * feat..(i + 1) * feat].copy_from_slice(&r.x);
                    }
                    for v in &mut xbuf[rows * feat..] {
                        *v = 0.0;
                    }
                    let key = (run[0].model_id, run[0].version);
                    let result: Result<Vec<f32>, RejectReason> = if key == (0, 0) {
                        Ok(backend.infer_rows(&xbuf, rows))
                    } else if let Some((reg, cache)) = tenants.as_mut() {
                        // the version was pinned at admission; the
                        // cache key includes it, so a concurrent
                        // publish can never swap weights under this run
                        match cache.get_or_load(reg, key.0, key.1, &own) {
                            Ok(b) => Ok(b.infer_rows(&xbuf, rows)),
                            Err(_) => Err(RejectReason::UnknownModel {
                                model_id: key.0,
                                version: key.1,
                            }),
                        }
                    } else {
                        // no local tenancy: the backend itself may
                        // route by model (the remote transport ships
                        // the key to the worker process)
                        backend.infer_rows_model(key.0, key.1, &xbuf, rows)
                    };
                    own.record_batch(rows, cap);
                    aggregate.record_batch(rows, cap);
                    match result {
                        Ok(logits) => {
                            for (i, r) in run.into_iter().enumerate() {
                                let out = logits[i * classes..(i + 1) * classes].to_vec();
                                let secs = r.t_start.elapsed_secs();
                                // latency samples live only in the
                                // per-worker metrics; the engine merges
                                // them before computing aggregate
                                // percentiles, so the per-request cost
                                // here is one uncontended lock, not two
                                own.record_latency(secs);
                                aggregate.completed.fetch_add(1, Ordering::Relaxed);
                                dispatch.observe(worker_id, secs);
                                gauge.fetch_sub(1, Ordering::Relaxed);
                                r.reply.send_logits(out);
                            }
                        }
                        Err(reason) => {
                            for r in run {
                                gauge.fetch_sub(1, Ordering::Relaxed);
                                r.reply.send_rejected(reason);
                            }
                        }
                    }
                }
            }
        })
        .expect("spawn engine worker thread");
    (Shard { queue, inflight, metrics, join: Some(join) }, meta_rx)
}
