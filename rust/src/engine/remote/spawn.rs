//! Child-process management for coordinator-spawned worker shards.
//!
//! [`spawn_shards`] launches `n` copies of the `sobolnet shard-worker`
//! subcommand (or any program speaking the wire protocol), each
//! listening on its own fresh Unix socket, and waits until every
//! child completes a `Hello` handshake — a child that merely *binds*
//! its socket but wedges before serving (slow model build gone wrong)
//! fails readiness at `ready_timeout` with an error naming the
//! address, instead of hanging `build_remote`.  The returned
//! [`SpawnedShards`] owns
//! the `Child` handles: dropping it kills and reaps every process that
//! is still alive, so an `Engine` built over spawned shards cannot
//! leak children — and tests can [`SpawnedShards::kill`] one shard to
//! exercise the `WorkerFailed` path.

use super::transport::Addr;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Monotonic counter so concurrent spawns (parallel tests) never
/// collide on a socket path.
static SPAWN_SEQ: AtomicU64 = AtomicU64::new(0);

/// How to launch a worker shard process.
#[derive(Debug, Clone)]
pub struct SpawnSpec {
    /// Program to run; defaults to the current executable (the normal
    /// case: a `sobolnet` coordinator spawning `sobolnet shard-worker`
    /// children).  Tests point this at `env!("CARGO_BIN_EXE_sobolnet")`.
    pub program: PathBuf,
    /// Extra arguments appended after `shard-worker --listen <addr>` —
    /// the model/topology spec the child builds its replica from
    /// (`--sizes`, `--paths`, `--seed`, …), plus multi-tenant knobs
    /// (`--registry <dir>`, `--model-cache <n>`) when the coordinator
    /// will `Publish` tenant snapshots to the children.
    pub shard_args: Vec<String>,
    /// Directory for the per-shard Unix sockets.
    pub socket_dir: PathBuf,
    /// How long to wait for every child to start listening.
    pub ready_timeout: Duration,
}

impl Default for SpawnSpec {
    fn default() -> Self {
        SpawnSpec {
            program: std::env::current_exe().unwrap_or_else(|_| PathBuf::from("sobolnet")),
            shard_args: Vec::new(),
            socket_dir: std::env::temp_dir(),
            ready_timeout: Duration::from_secs(30),
        }
    }
}

impl SpawnSpec {
    /// Default spec with the given model/topology arguments.
    pub fn with_args<S: Into<String>, I: IntoIterator<Item = S>>(args: I) -> Self {
        SpawnSpec { shard_args: args.into_iter().map(Into::into).collect(), ..Default::default() }
    }

    /// The `--seed` value in `shard_args`, if present and parseable.
    pub fn seed_arg(&self) -> Option<u64> {
        let i = self.shard_args.iter().position(|a| a == "--seed")?;
        self.shard_args.get(i + 1)?.parse().ok()
    }

    /// Clone of this spec with the child's `--seed` replaced (appended
    /// when absent) — the per-member spawn path of ensemble engines,
    /// where member `m`'s children build from `member_seed(base, m)`.
    pub fn with_seed(&self, seed: u64) -> SpawnSpec {
        let mut spec = self.clone();
        match spec.shard_args.iter().position(|a| a == "--seed") {
            Some(i) if i + 1 < spec.shard_args.len() => {
                spec.shard_args[i + 1] = seed.to_string();
            }
            Some(_) => spec.shard_args.push(seed.to_string()),
            None => {
                spec.shard_args.push("--seed".into());
                spec.shard_args.push(seed.to_string());
            }
        }
        spec
    }
}

/// Handle to a set of spawned worker-shard processes.
pub struct SpawnedShards {
    addrs: Vec<String>,
    children: Vec<Option<Child>>,
    socket_paths: Vec<PathBuf>,
}

impl SpawnedShards {
    /// Shard addresses, in shard order (feed these to
    /// `EngineBuilder::remote`).
    pub fn addrs(&self) -> &[String] {
        &self.addrs
    }

    /// Number of shards spawned.
    pub fn len(&self) -> usize {
        self.addrs.len()
    }

    /// `true` when no shards were spawned.
    pub fn is_empty(&self) -> bool {
        self.addrs.is_empty()
    }

    /// Absorb another spawned set: addresses, child handles, and socket
    /// paths concatenate in spawn order (ensemble spawning launches one
    /// set per member, then folds them into a single handle whose
    /// address order is member-major).  `other` is left empty, so its
    /// `Drop` kills nothing.
    pub fn append(&mut self, mut other: SpawnedShards) {
        self.addrs.append(&mut other.addrs);
        self.children.append(&mut other.children);
        self.socket_paths.append(&mut other.socket_paths);
    }

    /// Hard-kill one worker process (tests of the `WorkerFailed`
    /// path).  Returns `false` if it was already reaped.
    pub fn kill(&mut self, idx: usize) -> bool {
        match self.children[idx].take() {
            Some(mut child) => {
                let _ = child.kill();
                let _ = child.wait();
                true
            }
            None => false,
        }
    }
}

impl Drop for SpawnedShards {
    fn drop(&mut self) {
        for child in self.children.iter_mut() {
            if let Some(mut c) = child.take() {
                // graceful exit already happened if the coordinator
                // sent Shutdown; kill() on an exited child is a no-op
                let _ = c.kill();
                let _ = c.wait();
            }
        }
        for p in &self.socket_paths {
            let _ = std::fs::remove_file(p);
        }
    }
}

/// Spawn `n` worker shards per `spec` and wait until each one listens.
/// On any failure every already-spawned child is killed before the
/// error returns.
pub fn spawn_shards(n: usize, spec: &SpawnSpec) -> std::io::Result<SpawnedShards> {
    assert!(n > 0, "spawn at least one shard");
    let mut shards = SpawnedShards {
        addrs: Vec::with_capacity(n),
        children: Vec::with_capacity(n),
        socket_paths: Vec::with_capacity(n),
    };
    for i in 0..n {
        let seq = SPAWN_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = spec
            .socket_dir
            .join(format!("sobolnet-shard-{}-{}-{}.sock", std::process::id(), seq, i));
        let addr = format!("unix:{}", path.display());
        let child = Command::new(&spec.program)
            .arg("shard-worker")
            .arg("--listen")
            .arg(&addr)
            .args(&spec.shard_args)
            // fault injection is a coordinator-side harness: a child
            // inheriting the plan would garble its own Hello frames and
            // make worker startup nondeterministic — worker-process
            // faults are exercised by killing real processes instead
            .env_remove("SOBOLNET_FAULTS")
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()?;
        shards.addrs.push(addr);
        shards.children.push(Some(child));
        shards.socket_paths.push(path);
    }
    // readiness: a full Hello handshake per shard, not a bare connect —
    // binding the socket proves nothing about the serve loop (the
    // worker binds before its possibly slow model build), and a child
    // wedged between bind and serve must fail readiness, not hang the
    // caller.  Each probe attempt is bounded; the probe connection is
    // dropped immediately (the worker just loops back to accept).
    let deadline = Instant::now() + spec.ready_timeout;
    for i in 0..n {
        let addr = Addr::parse(&shards.addrs[i])
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidInput, e))?;
        loop {
            if let Some(child) = shards.children[i].as_mut() {
                if let Ok(Some(status)) = child.try_wait() {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::BrokenPipe,
                        format!("shard-worker {i} exited during startup: {status}"),
                    ));
                }
            }
            // bound each attempt so the loop re-checks the child and
            // the deadline even against a bound-but-wedged socket
            let left = deadline.saturating_duration_since(Instant::now());
            let attempt = left.min(Duration::from_millis(250)).max(Duration::from_millis(10));
            match super::client::RemoteBackend::probe(&addr, attempt) {
                Ok(_shape) => break,
                Err(_) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        format!(
                            "shard-worker {i} at {} not ready within {:?}: \
                             no Hello handshake ({e})",
                            shards.addrs[i], spec.ready_timeout
                        ),
                    ));
                }
            }
        }
    }
    Ok(shards)
}
