//! Coordinator-side remote backend: an [`InferenceBackend`] whose
//! compute lives in another OS process, reached over a socket.
//!
//! [`RemoteBackend`] slots into the engine exactly where an in-process
//! model backend would: each engine worker shard owns one, so
//! admission, dispatch, batching, and backpressure behave **identically
//! to the in-process path** — the only change is that inference
//! serializes the batch's real rows (padding never crosses the wire)
//! into a [`Frame::Request`] and resolves them from the matching
//! [`Frame::Response`].
//!
//! Failure contract:
//!
//! * transient socket errors trigger **reconnect with exponential
//!   backoff** (the exchange is retried — inference is idempotent, so a
//!   batch resent after a reconnect cannot corrupt state);
//! * a shard whose process is gone (retries exhausted) **panics** on
//!   the engine worker thread, which is precisely the engine's
//!   worker-death path: queued and in-flight tickets resolve to
//!   [`RejectReason::WorkerFailed`](crate::engine::RejectReason) and
//!   the engine routes new requests to the surviving shards
//!   (`tests/remote_shard.rs`).
//!
//! Shared-nothing metrics: every `stats_every` batches the backend
//! sends a [`Frame::StatsRequest`] and folds the worker's reply — its
//! **raw** latency samples plus counters — into the per-shard metrics
//! slot the coordinator merges through `Metrics::merged_percentiles`.
//! Raw samples cross the wire so percentiles are merged, never
//! averaged.  A final poll runs at backend drop, so after a graceful
//! `Engine::shutdown` the folded stats are complete.

use super::frame::{read_frame, write_frame, Frame};
use super::transport::{Addr, Stream};
use crate::coordinator::metrics::Metrics;
use crate::engine::InferenceBackend;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Knobs of the remote transport (per shard connection).
#[derive(Debug, Clone)]
pub struct RemoteOptions {
    /// Budget for the *initial* connect + `Hello` handshake, per dial
    /// attempt (covers worker process startup — including its model
    /// build/train — when the coordinator spawns its own shards; also
    /// bounds each TCP connect so a blackholed host fails fast).
    pub connect_timeout: Duration,
    /// Reconnect attempts per failed exchange before the shard is
    /// declared dead.
    pub retry_attempts: u32,
    /// Base backoff between reconnect attempts; doubles per attempt.
    pub retry_backoff: Duration,
    /// Poll worker stats every N batches (`0` disables periodic polls;
    /// the final poll at drop still runs).
    pub stats_every: u64,
}

impl Default for RemoteOptions {
    fn default() -> Self {
        RemoteOptions {
            connect_timeout: Duration::from_secs(30),
            retry_attempts: 3,
            retry_backoff: Duration::from_millis(50),
            stats_every: 8,
        }
    }
}

/// [`InferenceBackend`] proxying to a `shard-worker` process.
pub struct RemoteBackend {
    addr: Addr,
    opts: RemoteOptions,
    stream: Option<Stream>,
    features: usize,
    classes: usize,
    capacity: usize,
    next_id: u64,
    batches: u64,
    /// Coordinator-side slot the worker's stats frames fold into; the
    /// engine merges these across shards on read.
    slot: Arc<Metrics>,
}

impl RemoteBackend {
    /// Dial `addr` (string form, `unix:…`/`tcp:…`), retrying with
    /// backoff until [`RemoteOptions::connect_timeout`], and perform
    /// the `Hello` handshake.  Runs on the engine worker thread via the
    /// backend factory.
    pub fn connect(addr: &str, opts: RemoteOptions, slot: Arc<Metrics>) -> Result<Self, String> {
        let addr = Addr::parse(addr)?;
        let deadline = Instant::now() + opts.connect_timeout;
        let mut backoff = opts.retry_backoff.max(Duration::from_millis(1));
        // the connect budget also bounds each dial's TCP connect and
        // Hello read: a blackholed host or a child that accepted but
        // never starts serving cannot hang the builder
        let (stream, features, classes, capacity) = loop {
            match Self::dial(&addr, opts.connect_timeout) {
                Ok(ok) => break ok,
                Err(e) => {
                    if Instant::now() + backoff > deadline {
                        return Err(format!("connect {addr}: {e}"));
                    }
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(500));
                }
            }
        };
        Ok(RemoteBackend {
            addr,
            opts,
            stream: Some(stream),
            features,
            classes,
            capacity,
            next_id: 0,
            batches: 0,
            slot,
        })
    }

    /// One dial + handshake attempt, fully bounded by `timeout`: it
    /// caps the TCP connect (a blackholed host fails fast) and the
    /// `Hello` read — a worker binds its listener before a possibly
    /// slow model build, so a connect succeeding does not prove the
    /// serve loop is running, and no caller may block on it forever.
    /// The read timeout is cleared again after the handshake:
    /// exchange reads must block while the worker computes.
    fn dial(addr: &Addr, timeout: Duration) -> Result<(Stream, usize, usize, usize), String> {
        let mut stream = addr.connect_timeout(timeout).map_err(|e| e.to_string())?;
        let _ = stream.set_read_timeout(Some(timeout));
        match read_frame(&mut stream) {
            Ok(Frame::Hello { features, classes, batch_capacity }) => {
                let _ = stream.set_read_timeout(None);
                Ok((stream, features as usize, classes as usize, batch_capacity as usize))
            }
            Ok(other) => Err(format!("expected hello, got {} frame", other.name())),
            Err(e) => Err(format!("hello: {e}")),
        }
    }

    /// Bounded handshake probe: dial, read the `Hello`, drop the
    /// connection (the worker just loops back to `accept`).  The
    /// builder pre-flights every shard with this so operator mistakes
    /// — mismatched `--sizes`/`--batch` across workers — surface as a
    /// clean error naming the offending address instead of a
    /// cross-thread assert panic.
    pub(crate) fn probe(addr: &Addr, timeout: Duration) -> Result<(usize, usize, usize), String> {
        Self::dial(addr, timeout).map(|(_stream, f, c, cap)| (f, c, cap))
    }

    /// Reconnect and re-validate the handshake against the shape this
    /// backend was built with.  The dial is bounded: a wedged worker
    /// must fail the retry ladder (→ `WorkerFailed`), not hang the
    /// shard forever.
    fn reconnect(&mut self) -> Result<(), String> {
        let (stream, features, classes, capacity) =
            Self::dial(&self.addr, Duration::from_secs(5))?;
        if (features, classes, capacity) != (self.features, self.classes, self.capacity) {
            return Err(format!(
                "worker at {} changed shape: {}x{} cap {} (was {}x{} cap {})",
                self.addr, features, classes, capacity, self.features, self.classes, self.capacity
            ));
        }
        self.stream = Some(stream);
        Ok(())
    }

    /// One request/response exchange of `rows` real rows on the live
    /// stream.
    fn exchange(&mut self, id: u64, x: &[f32], rows: usize) -> Result<Vec<f32>, String> {
        let stream = self.stream.as_mut().ok_or("not connected")?;
        let req = Frame::Request {
            id,
            rows: rows as u32,
            features: self.features as u32,
            data: x[..rows * self.features].to_vec(),
        };
        write_frame(stream, &req).map_err(|e| e.to_string())?;
        match read_frame(stream) {
            Ok(Frame::Response { id: rid, rows: rrows, classes, data }) => {
                if rid != id {
                    return Err(format!("response id {rid} != request id {id}"));
                }
                if (rrows as usize, classes as usize) != (rows, self.classes)
                    || data.len() != rows * self.classes
                {
                    return Err(format!(
                        "response shape {}x{} ({} values) != {}x{}",
                        rrows,
                        classes,
                        data.len(),
                        rows,
                        self.classes
                    ));
                }
                Ok(data)
            }
            Ok(Frame::Reject { reason, .. }) => Err(format!("worker rejected batch: {reason}")),
            Ok(other) => Err(format!("expected response, got {} frame", other.name())),
            Err(e) => Err(e.to_string()),
        }
    }

    /// Ask the worker for its raw metrics and fold them into the
    /// coordinator-side slot.  Stats frames carry cumulative counters
    /// plus a bounded window of recent raw samples, so the fold
    /// replaces rather than appends.
    fn poll_stats(&mut self) -> Result<(), String> {
        let stream = self.stream.as_mut().ok_or("not connected")?;
        write_frame(stream, &Frame::StatsRequest).map_err(|e| e.to_string())?;
        match read_frame(stream) {
            Ok(Frame::Stats { completed, shed, batches, latencies }) => {
                self.slot.fold_remote(completed, shed, batches, &latencies);
                Ok(())
            }
            Ok(other) => Err(format!("expected stats, got {} frame", other.name())),
            Err(e) => Err(e.to_string()),
        }
    }
}

impl InferenceBackend for RemoteBackend {
    fn batch_capacity(&self) -> usize {
        self.capacity
    }

    fn features(&self) -> usize {
        self.features
    }

    fn classes(&self) -> usize {
        self.classes
    }

    /// Full-capacity path of the backend contract: ships every row
    /// (padding included) and pads the reply back out.  The engine
    /// worker uses [`InferenceBackend::infer_rows`] instead, which
    /// skips the padding on the wire.
    fn infer_batch(&mut self, x: &[f32]) -> Vec<f32> {
        self.infer_rows(x, self.capacity)
    }

    /// Ship the real rows of the batch to the worker process; panic
    /// once the shard is unreachable (the engine's worker-death path
    /// turns that into `WorkerFailed` tickets + routing around this
    /// shard).  Returns `rows × classes` logits — exactly what the
    /// engine worker reads.
    fn infer_rows(&mut self, x: &[f32], rows: usize) -> Vec<f32> {
        assert_eq!(x.len(), self.capacity * self.features, "remote infer input shape");
        assert!(rows <= self.capacity, "rows within batch capacity");
        let id = self.next_id;
        self.next_id += 1;
        let mut last_err = String::new();
        for attempt in 0..=self.opts.retry_attempts {
            if attempt > 0 {
                // reconnect-with-backoff: drop the broken stream, wait,
                // redial, revalidate the handshake
                self.stream = None;
                let backoff = self.opts.retry_backoff.max(Duration::from_millis(1))
                    * 2u32.pow((attempt - 1).min(4));
                std::thread::sleep(backoff.min(Duration::from_millis(500)));
            }
            if self.stream.is_none() {
                if let Err(e) = self.reconnect() {
                    last_err = e;
                    continue;
                }
            }
            match self.exchange(id, x, rows) {
                Ok(logits) => {
                    self.batches += 1;
                    if self.opts.stats_every > 0 && self.batches % self.opts.stats_every == 0 {
                        // periodic stats ride the same connection; a
                        // failed poll only drops the stream — the next
                        // batch reconnects
                        if self.poll_stats().is_err() {
                            self.stream = None;
                        }
                    }
                    return logits;
                }
                Err(e) => last_err = e,
            }
        }
        panic!(
            "remote shard {} unreachable after {} attempts: {last_err}",
            self.addr,
            self.opts.retry_attempts + 1
        );
    }
}

impl Drop for RemoteBackend {
    /// Best-effort closing handshake: a final stats poll (bounded by a
    /// read timeout so a wedged worker cannot hang shutdown) and a
    /// `Shutdown` frame telling a spawned worker process to exit.
    /// Never panics — drop also runs while unwinding a dead shard.
    fn drop(&mut self) {
        if self.stream.is_none() {
            // a transient failure may have dropped the stream mid-run;
            // one quick redial so the closing handshake (final stats
            // fold + Shutdown for the worker process) still happens.
            // The dial is bounded end to end, so neither a dead
            // address nor a wedged worker can hang shutdown.
            if let Ok((stream, f, c, cap)) = Self::dial(&self.addr, Duration::from_millis(500)) {
                if (f, c, cap) == (self.features, self.classes, self.capacity) {
                    self.stream = Some(stream);
                }
            }
        }
        match self.stream.as_ref() {
            Some(stream) => {
                let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
            }
            None => return,
        }
        let _ = self.poll_stats();
        if let Some(stream) = self.stream.as_mut() {
            let _ = write_frame(stream, &Frame::Shutdown);
        }
    }
}
