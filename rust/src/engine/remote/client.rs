//! Coordinator-side remote backend: an [`InferenceBackend`] whose
//! compute lives in another OS process, reached over a socket.
//!
//! [`RemoteBackend`] slots into the engine exactly where an in-process
//! model backend would: each engine worker shard owns one, so
//! admission, dispatch, batching, and backpressure behave **identically
//! to the in-process path** — the only change is that inference
//! serializes the batch's real rows (padding never crosses the wire)
//! into a [`Frame::Request`] and resolves them from the matching
//! [`Frame::Response`].
//!
//! Failure contract:
//!
//! * transient socket errors trigger **reconnect with exponential
//!   backoff** (the exchange is retried — inference is idempotent, so a
//!   batch resent after a reconnect cannot corrupt state);
//! * with replica siblings configured ([`RemoteBackend::with_group`]),
//!   an exchange that fails hard (reset/refused — the killed-worker
//!   case) **fails over**: the same request is re-fired at the next
//!   sibling in fixed order, and only when every replica is
//!   unreachable does the ladder give up;
//! * with a hedge deadline configured ([`RemoteOptions::hedge_after`]),
//!   an exchange whose response exceeds the deadline (the larger of
//!   the configured floor and twice this backend's recent p99
//!   estimate) is **hedged**: the primary connection is severed — a
//!   late reply must never desync the strict request/response stream —
//!   and the request re-fired at a sibling, first answer wins.
//!   Duplicates are safe twice over: inference is pure, and the
//!   worker-side reply cache answers a true resend without
//!   recomputing.  Replicas are bitwise-interchangeable, so hedging
//!   never changes an output bit;
//! * a shard whose process is gone (retries and siblings exhausted)
//!   **panics** on the engine worker thread, which is precisely the
//!   engine's worker-death path: queued and in-flight tickets resolve
//!   to [`RejectReason::WorkerFailed`](crate::engine::RejectReason)
//!   and the engine routes new requests to the surviving shards
//!   (`tests/remote_shard.rs`, `tests/chaos.rs`).
//!
//! Shared-nothing metrics: every `stats_every` batches the backend
//! sends a [`Frame::StatsRequest`] and folds the worker's reply — its
//! **raw** latency samples plus counters — into the per-shard metrics
//! slot the coordinator merges through `Metrics::merged_percentiles`.
//! Raw samples cross the wire so percentiles are merged, never
//! averaged.  A final poll runs at backend drop, so after a graceful
//! `Engine::shutdown` the folded stats are complete.

use super::frame::{read_frame, write_frame, Frame};
use super::health::HealthBoard;
use super::transport::{Addr, FaultPlan, Stream};
use crate::coordinator::metrics::Metrics;
use crate::engine::ticket::RejectReason;
use crate::engine::InferenceBackend;
use crate::registry::{ModelSpec, Snapshot};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The one backoff cap of the remote transport: every exponential
/// ladder (initial connect, per-exchange reconnect) tops out here.
pub const BACKOFF_CAP: Duration = Duration::from_millis(500);

/// How long a hedged or failed-over exchange waits for the sibling's
/// answer.  Generous relative to any hedge deadline — the sibling is
/// doing real compute — but bounded, so a sick sibling falls through
/// to the retry ladder instead of hanging the shard.
pub const SIBLING_READ_TIMEOUT: Duration = Duration::from_secs(5);

/// Exponential backoff delay for 0-based `attempt`:
/// `base · 2^attempt`, with `base` floored at 1 ms, the exponent
/// clamped (so large attempt counts cannot overflow), and the result
/// capped at [`BACKOFF_CAP`].
pub fn backoff_delay(base: Duration, attempt: u32) -> Duration {
    (base.max(Duration::from_millis(1)) * 2u32.pow(attempt.min(16))).min(BACKOFF_CAP)
}

/// Knobs of the remote transport (per shard connection).
#[derive(Debug, Clone)]
pub struct RemoteOptions {
    /// Budget for the *initial* connect + `Hello` handshake, per dial
    /// attempt (covers worker process startup — including its model
    /// build/train — when the coordinator spawns its own shards; also
    /// bounds each TCP connect so a blackholed host fails fast).
    pub connect_timeout: Duration,
    /// Reconnect attempts per failed exchange before the shard is
    /// declared dead.
    pub retry_attempts: u32,
    /// Base backoff between reconnect attempts; doubles per attempt
    /// (capped at [`BACKOFF_CAP`]).
    pub retry_backoff: Duration,
    /// Poll worker stats every N batches (`0` disables periodic polls;
    /// the final poll at drop still runs).
    pub stats_every: u64,
    /// Hedge deadline floor: an exchange not answered within
    /// `max(hedge_after, 2 × recent p99)` is re-fired at a sibling
    /// replica.  `None` disables hedging (exchanges block until the
    /// worker answers or the connection breaks).  Only effective when
    /// the backend has siblings ([`RemoteBackend::with_group`]).
    pub hedge_after: Option<Duration>,
    /// Cadence of the coordinator-side health prober
    /// (`Duration::ZERO` disables it).
    pub probe_interval: Duration,
    /// Deterministic fault plan injected into this backend's data
    /// connections (chaos testing).  `None` falls back to the
    /// process-wide `SOBOLNET_FAULTS` plan, if any.
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for RemoteOptions {
    fn default() -> Self {
        RemoteOptions {
            connect_timeout: Duration::from_secs(30),
            retry_attempts: 3,
            retry_backoff: Duration::from_millis(50),
            stats_every: 8,
            hedge_after: None,
            probe_interval: Duration::from_millis(250),
            faults: None,
        }
    }
}

/// How an exchange failed: past the hedge deadline (the sibling path
/// may still win the request) or hard (broken stream, reject, shape
/// mismatch — reconnect/failover territory).
enum ExchangeFail {
    /// The response did not arrive within the hedge deadline.
    Timeout(String),
    /// The exchange is unrecoverable on this connection.
    Hard(String),
    /// The worker answered with a *definitive* reject that no retry,
    /// hedge, or failover can change (it doesn't know the pinned
    /// `(model_id, version)`) — propagate it to the ticket instead of
    /// burning the ladder.
    Rejected(RejectReason),
}

impl ExchangeFail {
    fn msg(self) -> String {
        match self {
            ExchangeFail::Timeout(m) | ExchangeFail::Hard(m) => m,
            ExchangeFail::Rejected(r) => format!("worker rejected batch: {r}"),
        }
    }
}

/// [`InferenceBackend`] proxying to a `shard-worker` process.
pub struct RemoteBackend {
    addr: Addr,
    opts: RemoteOptions,
    stream: Option<Stream>,
    features: usize,
    classes: usize,
    capacity: usize,
    next_id: u64,
    batches: u64,
    /// Coordinator-side slot the worker's stats frames fold into; the
    /// engine merges these across shards on read.
    slot: Arc<Metrics>,
    /// Sibling replica addresses (same group, fixed order) — the hedge
    /// and failover targets.  Empty for ungrouped backends.
    siblings: Vec<Addr>,
    /// Shared hedge/failover counters (`None` for standalone use
    /// outside an engine).
    board: Option<Arc<HealthBoard>>,
    /// Resolved fault plan (options override, else `SOBOLNET_FAULTS`).
    faults: Option<Arc<FaultPlan>>,
    /// EWMA of successful exchange latency (seconds) feeding the
    /// adaptive hedge deadline.
    lat_mean: f64,
    lat_var: f64,
    lat_n: u64,
}

impl RemoteBackend {
    /// Dial `addr` (string form, `unix:…`/`tcp:…`), retrying with
    /// backoff until [`RemoteOptions::connect_timeout`], and perform
    /// the `Hello` handshake.  Runs on the engine worker thread via the
    /// backend factory.
    pub fn connect(addr: &str, opts: RemoteOptions, slot: Arc<Metrics>) -> Result<Self, String> {
        let addr = Addr::parse(addr)?;
        let faults = opts.faults.clone().or_else(FaultPlan::from_env);
        let deadline = Instant::now() + opts.connect_timeout;
        let mut attempt = 0u32;
        // the connect budget also bounds each dial's TCP connect and
        // Hello read: a blackholed host or a child that accepted but
        // never starts serving cannot hang the builder
        let (stream, features, classes, capacity) = loop {
            match Self::dial(&addr, opts.connect_timeout, faults.as_ref()) {
                Ok(ok) => break ok,
                Err(e) => {
                    let backoff = backoff_delay(opts.retry_backoff, attempt);
                    attempt += 1;
                    if Instant::now() + backoff > deadline {
                        return Err(format!("connect {addr}: {e}"));
                    }
                    std::thread::sleep(backoff);
                }
            }
        };
        Ok(RemoteBackend {
            addr,
            opts,
            stream: Some(stream),
            features,
            classes,
            capacity,
            next_id: 0,
            batches: 0,
            slot,
            siblings: Vec::new(),
            board: None,
            faults,
            lat_mean: 0.0,
            lat_var: 0.0,
            lat_n: 0,
        })
    }

    /// Attach this backend to its replica group: `siblings` are the
    /// other replicas' addresses (fixed order — hedges and failovers
    /// try them in exactly this order, which keeps recovery behavior
    /// reproducible), `board` the engine-wide hedge/failover counters.
    pub fn with_group(
        mut self,
        siblings: &[String],
        board: Arc<HealthBoard>,
    ) -> Result<Self, String> {
        self.siblings =
            siblings.iter().map(|s| Addr::parse(s)).collect::<Result<Vec<_>, String>>()?;
        self.board = Some(board);
        Ok(self)
    }

    /// One dial + handshake attempt, fully bounded by `timeout`: it
    /// caps the TCP connect (a blackholed host fails fast) and the
    /// `Hello` read — a worker binds its listener before a possibly
    /// slow model build, so a connect succeeding does not prove the
    /// serve loop is running, and no caller may block on it forever.
    /// The read timeout is cleared again after the handshake:
    /// exchange reads must block while the worker computes.
    /// `faults`, when present, wraps the data connection in the
    /// deterministic chaos layer.
    fn dial(
        addr: &Addr,
        timeout: Duration,
        faults: Option<&Arc<FaultPlan>>,
    ) -> Result<(Stream, usize, usize, usize), String> {
        let mut stream = addr.connect_timeout(timeout).map_err(|e| e.to_string())?;
        if let Some(plan) = faults {
            stream = plan.wrap(stream);
        }
        let _ = stream.set_read_timeout(Some(timeout));
        match read_frame(&mut stream) {
            Ok(Frame::Hello { features, classes, batch_capacity }) => {
                let _ = stream.set_read_timeout(None);
                Ok((stream, features as usize, classes as usize, batch_capacity as usize))
            }
            Ok(other) => Err(format!("expected hello, got {} frame", other.name())),
            Err(e) => Err(format!("hello: {e}")),
        }
    }

    /// Bounded handshake probe: dial, read the `Hello`, drop the
    /// connection (the worker just loops back to `accept`).  The
    /// builder pre-flights every shard with this so operator mistakes
    /// — mismatched `--sizes`/`--batch` across workers — surface as a
    /// clean error naming the offending address instead of a
    /// cross-thread assert panic.  Probes never inject faults: they
    /// answer "is the worker there", not "does recovery work".
    pub(crate) fn probe(addr: &Addr, timeout: Duration) -> Result<(usize, usize, usize), String> {
        Self::dial(addr, timeout, None).map(|(_stream, f, c, cap)| (f, c, cap))
    }

    /// Reconnect and re-validate the handshake against the shape this
    /// backend was built with.  The dial is bounded: a wedged worker
    /// must fail the retry ladder (→ `WorkerFailed`), not hang the
    /// shard forever.
    fn reconnect(&mut self) -> Result<(), String> {
        let (stream, features, classes, capacity) =
            Self::dial(&self.addr, Duration::from_secs(5), self.faults.as_ref())?;
        if (features, classes, capacity) != (self.features, self.classes, self.capacity) {
            return Err(format!(
                "worker at {} changed shape: {}x{} cap {} (was {}x{} cap {})",
                self.addr, features, classes, capacity, self.features, self.classes, self.capacity
            ));
        }
        self.stream = Some(stream);
        Ok(())
    }

    /// Effective hedge deadline for the next exchange: the configured
    /// floor, raised to twice the recent p99 estimate once enough
    /// samples exist (a cold backend must not hedge off noise).
    /// `None` — hedging off or no siblings to hedge to — leaves the
    /// response read unbounded.
    fn hedge_deadline(&self) -> Option<Duration> {
        let floor = self.opts.hedge_after?;
        if self.siblings.is_empty() {
            return None;
        }
        if self.lat_n >= 8 {
            let p99 = self.lat_mean + 2.33 * self.lat_var.max(0.0).sqrt();
            let adaptive = Duration::from_secs_f64((2.0 * p99).max(0.0));
            Some(floor.max(adaptive))
        } else {
            Some(floor)
        }
    }

    /// Fold a successful exchange latency into the hedge-deadline EWMA.
    fn observe_latency(&mut self, d: Duration) {
        const ALPHA: f64 = 0.2;
        let x = d.as_secs_f64();
        if self.lat_n == 0 {
            self.lat_mean = x;
            self.lat_var = 0.0;
        } else {
            let delta = x - self.lat_mean;
            self.lat_mean += ALPHA * delta;
            self.lat_var = (1.0 - ALPHA) * (self.lat_var + ALPHA * delta * delta);
        }
        self.lat_n += 1;
    }

    /// Read and validate one `Response` for `id` (pinned to
    /// `(model_id, version)`) from `stream`.
    fn read_response(
        stream: &mut Stream,
        id: u64,
        model_id: u64,
        version: u64,
        rows: usize,
        classes: usize,
    ) -> Result<Vec<f32>, ExchangeFail> {
        match read_frame(stream) {
            Ok(Frame::Response {
                id: rid,
                model_id: rmodel,
                version: rversion,
                rows: rrows,
                classes: rclasses,
                data,
            }) => {
                if rid != id {
                    return Err(ExchangeFail::Hard(format!(
                        "response id {rid} != request id {id}"
                    )));
                }
                if (rmodel, rversion) != (model_id, version) {
                    // a worker that re-resolved the version would break
                    // admission-time pinning — treat it as corruption
                    return Err(ExchangeFail::Hard(format!(
                        "response model {rmodel} v{rversion} != pinned model {model_id} \
                         v{version}"
                    )));
                }
                if (rrows as usize, rclasses as usize) != (rows, classes)
                    || data.len() != rows * classes
                {
                    return Err(ExchangeFail::Hard(format!(
                        "response shape {}x{} ({} values) != {}x{}",
                        rrows,
                        rclasses,
                        data.len(),
                        rows,
                        classes
                    )));
                }
                Ok(data)
            }
            Ok(Frame::Reject { reason: reason @ RejectReason::UnknownModel { .. }, .. }) => {
                Err(ExchangeFail::Rejected(reason))
            }
            Ok(Frame::Reject { reason, .. }) => {
                Err(ExchangeFail::Hard(format!("worker rejected batch: {reason}")))
            }
            Ok(other) => {
                Err(ExchangeFail::Hard(format!("expected response, got {} frame", other.name())))
            }
            Err(super::frame::FrameError::Io(e))
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                Err(ExchangeFail::Timeout(e.to_string()))
            }
            Err(e) => Err(ExchangeFail::Hard(e.to_string())),
        }
    }

    /// One request/response exchange of `rows` real rows on the live
    /// stream.  With hedging active, the response read is bounded by
    /// the hedge deadline; a deadline miss surfaces as
    /// [`ExchangeFail::Timeout`] for the caller to hedge on.
    fn exchange(
        &mut self,
        id: u64,
        key: (u64, u64),
        x: &[f32],
        rows: usize,
    ) -> Result<Vec<f32>, ExchangeFail> {
        let deadline = self.hedge_deadline();
        let classes = self.classes;
        let stream =
            self.stream.as_mut().ok_or_else(|| ExchangeFail::Hard("not connected".into()))?;
        let req = Frame::Request {
            id,
            model_id: key.0,
            version: key.1,
            rows: rows as u32,
            features: self.features as u32,
            data: x[..rows * self.features].to_vec(),
        };
        write_frame(stream, &req).map_err(|e| ExchangeFail::Hard(e.to_string()))?;
        let _ = stream.set_read_timeout(deadline);
        let started = Instant::now();
        let res = Self::read_response(stream, id, key.0, key.1, rows, classes);
        let _ = stream.set_read_timeout(None);
        if res.is_ok() {
            self.observe_latency(started.elapsed());
        }
        res
    }

    /// Re-fire request `id` at the sibling replicas, fixed order, on a
    /// fresh one-shot connection each.  Replicas are
    /// bitwise-interchangeable, so whichever sibling answers first
    /// returns the exact bits the primary would have.  Every step is
    /// bounded: dial by [`BACKOFF_CAP`], the response read by
    /// [`SIBLING_READ_TIMEOUT`].
    fn exchange_via_sibling(
        &mut self,
        id: u64,
        key: (u64, u64),
        x: &[f32],
        rows: usize,
    ) -> Result<Vec<f32>, ExchangeFail> {
        let mut last = ExchangeFail::Hard(String::from("no sibling replicas"));
        for i in 0..self.siblings.len() {
            let sib = self.siblings[i].clone();
            let (mut stream, f, c, cap) = match Self::dial(&sib, BACKOFF_CAP, self.faults.as_ref())
            {
                Ok(ok) => ok,
                Err(e) => {
                    last = ExchangeFail::Hard(format!("sibling {sib}: {e}"));
                    continue;
                }
            };
            if (f, c, cap) != (self.features, self.classes, self.capacity) {
                last = ExchangeFail::Hard(format!("sibling {sib}: shape mismatch {f}x{c} cap {cap}"));
                continue;
            }
            let req = Frame::Request {
                id,
                model_id: key.0,
                version: key.1,
                rows: rows as u32,
                features: self.features as u32,
                data: x[..rows * self.features].to_vec(),
            };
            if let Err(e) = write_frame(&mut stream, &req) {
                last = ExchangeFail::Hard(format!("sibling {sib}: {e}"));
                continue;
            }
            let _ = stream.set_read_timeout(Some(SIBLING_READ_TIMEOUT));
            match Self::read_response(&mut stream, id, key.0, key.1, rows, self.classes) {
                Ok(data) => return Ok(data),
                // a definitive reject from a bitwise-interchangeable
                // sibling is definitive for the group
                Err(r @ ExchangeFail::Rejected(_)) => return Err(r),
                Err(e) => {
                    last = ExchangeFail::Hard(format!("sibling {sib}: {}", e.msg()));
                    continue;
                }
            }
        }
        Err(last)
    }

    /// Hard-failure failover: try the siblings, count a failover on
    /// success.  `Some(Err(_))` is a definitive reject (no point
    /// continuing the ladder); `None` means the siblings couldn't help.
    fn try_failover(
        &mut self,
        id: u64,
        key: (u64, u64),
        x: &[f32],
        rows: usize,
    ) -> Option<Result<Vec<f32>, RejectReason>> {
        if self.siblings.is_empty() {
            return None;
        }
        match self.exchange_via_sibling(id, key, x, rows) {
            Ok(data) => {
                if let Some(board) = &self.board {
                    board.failovers.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
                Some(Ok(data))
            }
            Err(ExchangeFail::Rejected(r)) => Some(Err(r)),
            Err(_) => None,
        }
    }

    /// Ask the worker for its raw metrics and fold them into the
    /// coordinator-side slot.  Stats frames carry cumulative counters
    /// plus a bounded window of recent raw samples, so the fold
    /// replaces rather than appends.
    fn poll_stats(&mut self) -> Result<(), String> {
        let stream = self.stream.as_mut().ok_or("not connected")?;
        write_frame(stream, &Frame::StatsRequest).map_err(|e| e.to_string())?;
        match read_frame(stream) {
            Ok(Frame::Stats { completed, shed, batches, latencies }) => {
                self.slot.fold_remote(completed, shed, batches, &latencies);
                Ok(())
            }
            Ok(other) => Err(format!("expected stats, got {} frame", other.name())),
            Err(e) => Err(e.to_string()),
        }
    }
}

impl InferenceBackend for RemoteBackend {
    fn batch_capacity(&self) -> usize {
        self.capacity
    }

    fn features(&self) -> usize {
        self.features
    }

    fn classes(&self) -> usize {
        self.classes
    }

    /// Full-capacity path of the backend contract: ships every row
    /// (padding included) and pads the reply back out.  The engine
    /// worker uses [`InferenceBackend::infer_rows`] instead, which
    /// skips the padding on the wire.
    fn infer_batch(&mut self, x: &[f32]) -> Vec<f32> {
        self.infer_rows(x, self.capacity)
    }

    /// Ship the real rows of the batch to the worker process; panic
    /// once the shard is unreachable (the engine's worker-death path
    /// turns that into `WorkerFailed` tickets + routing around this
    /// shard).  Returns `rows × classes` logits — exactly what the
    /// engine worker reads.
    fn infer_rows(&mut self, x: &[f32], rows: usize) -> Vec<f32> {
        match self.infer_keyed(0, 0, x, rows) {
            Ok(logits) => logits,
            // the default model always exists on the worker — a reject
            // here is a protocol violation, handled like worker death
            Err(r) => panic!("remote shard {} rejected default-model batch: {r}", self.addr),
        }
    }

    /// Tenant path: ship the key with the batch; the worker process
    /// resolves it against its own registry cache.  A worker that
    /// doesn't know the pinned `(model_id, version)` answers with a
    /// definitive [`RejectReason::UnknownModel`], which propagates to
    /// the tickets instead of burning the retry ladder.
    fn infer_rows_model(
        &mut self,
        model_id: u64,
        version: u64,
        x: &[f32],
        rows: usize,
    ) -> Result<Vec<f32>, RejectReason> {
        self.infer_keyed(model_id, version, x, rows)
    }
}

impl RemoteBackend {
    /// The retry/hedge/failover ladder shared by the default and
    /// tenant paths.  `Err` carries only *definitive* rejects; every
    /// transient failure either recovers inside the ladder or panics
    /// the shard (the engine's worker-death path).
    fn infer_keyed(
        &mut self,
        model_id: u64,
        version: u64,
        x: &[f32],
        rows: usize,
    ) -> Result<Vec<f32>, RejectReason> {
        assert_eq!(x.len(), self.capacity * self.features, "remote infer input shape");
        assert!(rows <= self.capacity, "rows within batch capacity");
        let key = (model_id, version);
        let id = self.next_id;
        self.next_id += 1;
        let mut last_err = String::new();
        for attempt in 0..=self.opts.retry_attempts {
            if attempt > 0 {
                // reconnect-with-backoff: drop the broken stream, wait,
                // redial, revalidate the handshake
                self.stream = None;
                std::thread::sleep(backoff_delay(self.opts.retry_backoff, attempt - 1));
            }
            if self.stream.is_none() {
                if let Err(e) = self.reconnect() {
                    last_err = e;
                    // primary unreachable (killed worker): a sibling
                    // replica can answer with identical bits — route
                    // around the corpse before burning backoff on it
                    if let Some(outcome) = self.try_failover(id, key, x, rows) {
                        self.batches += 1;
                        return outcome;
                    }
                    continue;
                }
            }
            match self.exchange(id, key, x, rows) {
                Ok(logits) => {
                    self.batches += 1;
                    if self.opts.stats_every > 0 && self.batches % self.opts.stats_every == 0 {
                        // periodic stats ride the same connection; a
                        // failed poll only drops the stream — the next
                        // batch reconnects
                        if self.poll_stats().is_err() {
                            self.stream = None;
                        }
                    }
                    return Ok(logits);
                }
                Err(ExchangeFail::Rejected(r)) => return Err(r),
                Err(ExchangeFail::Timeout(e)) => {
                    // hedge: sever the primary first — its late reply
                    // must never desync the strict request/response
                    // stream — then re-fire at a sibling, first answer
                    // wins (bitwise identical either way)
                    self.stream = None;
                    if let Some(board) = &self.board {
                        board.hedges.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                    match self.exchange_via_sibling(id, key, x, rows) {
                        Ok(logits) => {
                            self.batches += 1;
                            return Ok(logits);
                        }
                        Err(ExchangeFail::Rejected(r)) => return Err(r),
                        Err(e2) => {
                            last_err = format!("hedge after timeout ({e}): {}", e2.msg())
                        }
                    }
                }
                Err(ExchangeFail::Hard(e)) => {
                    last_err = e;
                    self.stream = None;
                    if let Some(outcome) = self.try_failover(id, key, x, rows) {
                        self.batches += 1;
                        return outcome;
                    }
                }
            }
        }
        panic!(
            "remote shard {} unreachable after {} attempts: {last_err}",
            self.addr,
            self.opts.retry_attempts + 1
        );
    }
}

/// Push one snapshot into a worker process over a **fresh** connection
/// (Hello handshake → `Publish` → `PublishAck`), never the live
/// exchange stream — a publish racing an in-flight request must not
/// interleave with the strict request/response conversation.  Bounded
/// end to end by [`RemoteOptions::connect_timeout`] on the dial and on
/// the ack read.  Publish connections are deliberately not
/// fault-injected: chaos plans exercise the data path, and a
/// half-applied publish would make every later bitwise assertion
/// meaningless.
pub fn publish_to(
    addr: &str,
    opts: &RemoteOptions,
    model_id: u64,
    spec: &ModelSpec,
    snap: &Snapshot,
) -> Result<(), String> {
    let addr = Addr::parse(addr)?;
    let (mut stream, _f, _c, _cap) = RemoteBackend::dial(&addr, opts.connect_timeout, None)?;
    let frame = Frame::Publish {
        model_id,
        version: snap.version,
        spec: spec.clone(),
        w: snap.w.clone(),
        bias: snap.bias.clone(),
    };
    write_frame(&mut stream, &frame).map_err(|e| format!("publish to {addr}: {e}"))?;
    let _ = stream.set_read_timeout(Some(opts.connect_timeout));
    match read_frame(&mut stream) {
        Ok(Frame::PublishAck { model_id: am, version: av }) => {
            if (am, av) != (model_id, snap.version) {
                return Err(format!(
                    "{addr} acked model {am} v{av}, expected model {model_id} v{}",
                    snap.version
                ));
            }
            Ok(())
        }
        Ok(Frame::Reject { reason, .. }) => Err(format!("{addr} refused publish: {reason}")),
        Ok(other) => Err(format!("{addr}: expected publish-ack, got {} frame", other.name())),
        Err(e) => Err(format!("{addr}: publish-ack: {e}")),
    }
}

impl Drop for RemoteBackend {
    /// Best-effort closing handshake: a final stats poll (bounded by a
    /// read timeout so a wedged worker cannot hang shutdown) and a
    /// `Shutdown` frame telling a spawned worker process to exit.
    /// Never panics — drop also runs while unwinding a dead shard.
    fn drop(&mut self) {
        if self.stream.is_none() {
            // a transient failure may have dropped the stream mid-run;
            // one quick redial so the closing handshake (final stats
            // fold + Shutdown for the worker process) still happens.
            // The dial is bounded end to end, so neither a dead
            // address nor a wedged worker can hang shutdown.
            if let Ok((stream, f, c, cap)) =
                Self::dial(&self.addr, Duration::from_millis(500), self.faults.as_ref())
            {
                if (f, c, cap) == (self.features, self.classes, self.capacity) {
                    self.stream = Some(stream);
                }
            }
        }
        match self.stream.as_ref() {
            Some(stream) => {
                let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
            }
            None => return,
        }
        let _ = self.poll_stats();
        if let Some(stream) = self.stream.as_mut() {
            let _ = write_frame(stream, &Frame::Shutdown);
        }
    }
}
