//! Replica health tracking: the coordinator-side up/down board and the
//! prober thread that maintains it.
//!
//! Every engine owns a [`HealthBoard`] — one up/down flag per physical
//! shard plus the fault-tolerance counters (hedges, failovers, health
//! marks).  In-process engines never mark anything down (a thread that
//! dies closes its queue, which the admit path already filters);
//! remote engines with a probe interval also run a [`Prober`]: a
//! background thread that dials each worker between requests, speaks
//! the `Health` probe exchange, and flips the board so dispatch stops
//! routing into a corpse *before* a data exchange has to fail.
//!
//! The counters live here — not in the per-shard metrics slots —
//! deliberately: worker `Stats` folds replace slot counters wholesale
//! (`Metrics::fold_remote`), so a coordinator-side count stored there
//! would be clobbered by the next stats frame.

use super::frame::{read_frame, write_frame, Frame, HEALTH_PROBE, HEALTH_SERVING};
use super::transport::Addr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Per-shard liveness flags plus fault-tolerance counters, shared by
/// the admit path, the remote backends, and the prober.
pub struct HealthBoard {
    up: Vec<AtomicBool>,
    /// Exchanges re-fired at a sibling replica after the hedge
    /// deadline expired.
    pub hedges: AtomicU64,
    /// Exchanges answered by a sibling replica after the primary
    /// failed hard (reset/refused), before the retry ladder gave up.
    pub failovers: AtomicU64,
    /// Up→down transitions recorded by the prober.
    pub marks_down: AtomicU64,
    /// Down→up transitions recorded by the prober.
    pub marks_up: AtomicU64,
}

/// Snapshot of a [`HealthBoard`] for reports and test assertions.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HealthCounters {
    /// Hedged exchanges (see [`HealthBoard::hedges`]).
    pub hedges: u64,
    /// Failed-over exchanges (see [`HealthBoard::failovers`]).
    pub failovers: u64,
    /// Up→down prober transitions.
    pub marks_down: u64,
    /// Down→up prober transitions.
    pub marks_up: u64,
    /// Shards currently marked down.
    pub down_now: u64,
}

impl HealthBoard {
    /// All-up board for `shards` physical shards.
    pub fn new(shards: usize) -> Arc<HealthBoard> {
        Arc::new(HealthBoard {
            up: (0..shards).map(|_| AtomicBool::new(true)).collect(),
            hedges: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            marks_down: AtomicU64::new(0),
            marks_up: AtomicU64::new(0),
        })
    }

    /// Is `shard` currently marked serving?  Unknown shard ids read as
    /// up — the board only ever *narrows* routing.
    pub fn is_up(&self, shard: usize) -> bool {
        self.up.get(shard).map(|f| f.load(Ordering::Acquire)).unwrap_or(true)
    }

    /// Record a probe verdict, counting only transitions.
    pub fn mark(&self, shard: usize, up: bool) {
        if let Some(flag) = self.up.get(shard) {
            let was = flag.swap(up, Ordering::AcqRel);
            if was != up {
                if up {
                    self.marks_up.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.marks_down.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// Counter snapshot plus the number of shards currently down.
    pub fn snapshot(&self) -> HealthCounters {
        HealthCounters {
            hedges: self.hedges.load(Ordering::Relaxed),
            failovers: self.failovers.load(Ordering::Relaxed),
            marks_down: self.marks_down.load(Ordering::Relaxed),
            marks_up: self.marks_up.load(Ordering::Relaxed),
            down_now: self.up.iter().filter(|f| !f.load(Ordering::Acquire)).count() as u64,
        }
    }
}

/// One bounded health-probe exchange: dial, read the `Hello`, send a
/// `Health` probe, read the state reply.  Every read is bounded by
/// `timeout`, so a wedged worker answers "down", never a hang.
pub fn probe_health(addr: &Addr, timeout: Duration) -> Result<u8, String> {
    let mut stream = addr.connect_timeout(timeout).map_err(|e| e.to_string())?;
    let _ = stream.set_read_timeout(Some(timeout));
    match read_frame(&mut stream) {
        Ok(Frame::Hello { .. }) => {}
        Ok(other) => return Err(format!("expected hello, got {} frame", other.name())),
        Err(e) => return Err(format!("hello: {e}")),
    }
    write_frame(&mut stream, &Frame::Health { state: HEALTH_PROBE }).map_err(|e| e.to_string())?;
    match read_frame(&mut stream) {
        Ok(Frame::Health { state }) => Ok(state),
        Ok(other) => Err(format!("expected health, got {} frame", other.name())),
        Err(e) => Err(format!("health: {e}")),
    }
}

/// Handle to the prober thread; stopping joins it.
pub struct Prober {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Prober {
    /// Start probing `addrs` (shard *i* ↔ `addrs[i]`) every `interval`,
    /// each probe bounded by `timeout`, flipping `board` marks.
    pub fn spawn(
        addrs: Vec<Addr>,
        board: Arc<HealthBoard>,
        interval: Duration,
        timeout: Duration,
    ) -> Prober {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("sobolnet-prober".into())
            .spawn(move || {
                while !stop2.load(Ordering::Acquire) {
                    for (i, addr) in addrs.iter().enumerate() {
                        if stop2.load(Ordering::Acquire) {
                            return;
                        }
                        let serving = matches!(probe_health(addr, timeout), Ok(HEALTH_SERVING));
                        board.mark(i, serving);
                    }
                    // sleep in short slices so stop() never waits a
                    // whole interval
                    let mut left = interval;
                    while !left.is_zero() && !stop2.load(Ordering::Acquire) {
                        let step = left.min(Duration::from_millis(10));
                        std::thread::sleep(step);
                        left -= step;
                    }
                }
            })
            .expect("spawn prober thread");
        Prober { stop, handle: Some(handle) }
    }

    /// Signal the thread and join it (idempotent).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Prober {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn board_counts_transitions_not_reaffirmations() {
        let b = HealthBoard::new(3);
        assert!(b.is_up(0) && b.is_up(2));
        assert!(b.is_up(99), "unknown shards read as up");
        b.mark(1, true); // reaffirmation: no transition
        b.mark(1, false);
        b.mark(1, false); // reaffirmation: no transition
        b.mark(1, true);
        b.mark(2, false);
        let s = b.snapshot();
        assert_eq!(s.marks_down, 2);
        assert_eq!(s.marks_up, 1);
        assert_eq!(s.down_now, 1);
        assert!(!b.is_up(2));
        b.mark(99, false); // out of range: ignored, no panic
        assert_eq!(b.snapshot().marks_down, 2);
    }

    #[test]
    fn probe_against_dead_address_is_bounded_error() {
        let addr = Addr::Unix(std::path::PathBuf::from("/nonexistent/sobolnet-probe.sock"));
        let start = std::time::Instant::now();
        assert!(probe_health(&addr, Duration::from_millis(200)).is_err());
        assert!(start.elapsed() < Duration::from_secs(2));
    }
}
