//! Wire frame codec of the multi-process transport.
//!
//! This file is the *implementation* of the frame format; the
//! normative byte-level specification lives in `docs/ARCHITECTURE.md`
//! (§Wire protocol) at the repository root — keep the two in sync.
//!
//! Every frame is
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"SBN3" (protocol version is the last byte)
//! 4       1     type   tag (see the `TYPE_*` constants)
//! 5       4     len    payload length, u32 little-endian, ≤ MAX_PAYLOAD
//! 9       len   payload (fields little-endian, f32/f64 as IEEE-754 bits)
//! ```
//!
//! **Protocol version 2** (the multi-tenant registry PR) added a
//! `model_id + version` pair to `Request`/`Response`, widened the
//! `Reject` detail fields to u64 (they now carry model ids), and
//! introduced the `Publish`/`PublishAck` frames for hot snapshot
//! publication.  **Protocol version 3** (the `SequenceFamily`
//! unification) appended the spec's sequence descriptor — kind byte,
//! flags byte, u64 scramble/seed parameter — to the `Publish` spec
//! header, so a remote worker rebuilds a non-default topology (Owen-
//! scrambled Sobol', Halton, PRNG baseline) bitwise-identically.
//! These are *silent* layout changes — an older peer would misparse
//! the frames — so the magic's version byte was bumped each time and
//! a peer speaking any other `SBN*` version is rejected with the
//! descriptive [`FrameError::VersionMismatch`] instead of the generic
//! bad-magic error.
//!
//! f32 payloads are carried as raw little-endian IEEE-754 bits
//! (`to_le_bytes`/`from_le_bytes`), so a value crosses the wire
//! **bitwise intact** — the property `tests/remote_shard.rs` pins when
//! it compares a multi-process engine against the sequential
//! single-process reference.
//!
//! Decoding is total: any malformed input — wrong magic, unknown type,
//! oversize length, a frame cut short mid-read, a payload whose length
//! disagrees with its declared row/sample counts — surfaces as a typed
//! [`FrameError`], never a panic and never an unbounded allocation
//! (the length is validated against [`MAX_PAYLOAD`] *before* any
//! buffer is reserved).

use crate::engine::RejectReason;
use crate::nn::kernel::KernelKind;
use crate::qmc::{SequenceFamily, SequenceKind};
use crate::registry::ModelSpec;
use std::io::{Read, Write};

/// Frame magic; the trailing byte is the protocol version (`'3'`
/// since the sequence descriptor entered the `Publish` spec header —
/// see the module docs).
pub const MAGIC: [u8; 4] = *b"SBN3";

/// Hard cap on a frame payload (64 MiB): a corrupt or hostile length
/// header is rejected *before* allocation.
pub const MAX_PAYLOAD: u32 = 64 * 1024 * 1024;

const TYPE_HELLO: u8 = 1;
const TYPE_REQUEST: u8 = 2;
const TYPE_RESPONSE: u8 = 3;
const TYPE_REJECT: u8 = 4;
const TYPE_STATS_REQUEST: u8 = 5;
const TYPE_STATS: u8 = 6;
const TYPE_SHUTDOWN: u8 = 7;
const TYPE_HEALTH: u8 = 8;
const TYPE_DRAIN: u8 = 9;
const TYPE_PUBLISH: u8 = 10;
const TYPE_PUBLISH_ACK: u8 = 11;

/// `Health` state: coordinator → worker probe (asks "how are you?").
pub const HEALTH_PROBE: u8 = 0;
/// `Health` state: worker → coordinator, accepting traffic.
pub const HEALTH_SERVING: u8 = 1;
/// `Health` state: worker → coordinator, draining — still answering
/// in-flight requests but asking for no new traffic.
pub const HEALTH_DRAINING: u8 = 2;

/// Typed decode/transport failure.  Every malformed input maps to one
/// of these — the codec never panics and never hangs on bad bytes.
#[derive(Debug)]
pub enum FrameError {
    /// Underlying socket/pipe error.
    Io(std::io::Error),
    /// The peer closed the connection cleanly at a frame boundary.
    Closed,
    /// Stream ended (or errored with `UnexpectedEof`) mid-frame.
    Truncated,
    /// First four bytes were not [`MAGIC`] and not an `SBN*` prefix at
    /// all — noise, not a sobolnet peer.
    BadMagic([u8; 4]),
    /// The peer *is* a sobolnet process, but speaks a different
    /// protocol version (first three bytes matched `b"SBN"`, the
    /// version byte did not) — e.g. an old SBN2 worker answering an
    /// SBN3 coordinator.  Split from [`FrameError::BadMagic`] so
    /// operators see "upgrade that peer", not "garbage on the wire".
    VersionMismatch {
        /// The peer's version byte (the 4th magic byte).
        got: u8,
    },
    /// Unknown frame type tag.
    UnknownType(u8),
    /// Declared payload length exceeds [`MAX_PAYLOAD`].
    TooLarge {
        /// Declared length.
        len: u32,
        /// The cap it exceeded.
        max: u32,
    },
    /// Payload length disagrees with the frame's declared counts.
    BadPayloadLen {
        /// Frame type name.
        frame: &'static str,
        /// Bytes the declared counts require.
        expected: usize,
        /// Bytes actually present.
        got: usize,
    },
    /// Reject frame carried an unknown reason code.
    BadReason(u8),
    /// Health frame carried an unknown state code.
    BadHealthState(u8),
    /// Publish frame carried an unknown kernel code.
    BadKernelCode(u8),
    /// Publish frame carried an unknown sequence-family kind code.
    BadSequenceCode(u8),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "frame i/o: {e}"),
            FrameError::Closed => write!(f, "connection closed"),
            FrameError::Truncated => write!(f, "frame truncated mid-read"),
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:?} (want {MAGIC:?})"),
            FrameError::VersionMismatch { got } => write!(
                f,
                "peer speaks wire protocol version '{}' but this build requires \
                 version '{}' (magic {}) — upgrade the older side",
                *got as char,
                MAGIC[3] as char,
                std::str::from_utf8(&MAGIC).unwrap_or("SBN?"),
            ),
            FrameError::UnknownType(t) => write!(f, "unknown frame type {t}"),
            FrameError::TooLarge { len, max } => {
                write!(f, "frame payload {len} exceeds cap {max}")
            }
            FrameError::BadPayloadLen { frame, expected, got } => {
                write!(f, "{frame} payload length {got} != expected {expected}")
            }
            FrameError::BadReason(c) => write!(f, "unknown reject reason code {c}"),
            FrameError::BadHealthState(s) => write!(f, "unknown health state code {s}"),
            FrameError::BadKernelCode(k) => write!(f, "unknown kernel code {k}"),
            FrameError::BadSequenceCode(k) => {
                write!(f, "unknown sequence family code {k}")
            }
        }
    }
}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> Self {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            FrameError::Truncated
        } else {
            FrameError::Io(e)
        }
    }
}

/// One protocol frame.  `Hello` flows worker → coordinator once per
/// connection; `Request`/`StatsRequest`/`Shutdown` flow coordinator →
/// worker; `Response`/`Reject`/`Stats` are the worker's replies.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Worker self-description, sent immediately after `accept`.
    Hello {
        /// Features per sample.
        features: u32,
        /// Classes per sample.
        classes: u32,
        /// The worker backend's fixed batch capacity.
        batch_capacity: u32,
    },
    /// One inference batch (row-major `[rows × features]`, raw f32 bits).
    Request {
        /// Request id, echoed by the matching `Response`/`Reject`.
        id: u64,
        /// Tenant model this batch runs against (`0` = the worker's
        /// default model, the single-tenant path).
        model_id: u64,
        /// Snapshot version the batch is **pinned** to — resolved by
        /// the coordinator at admission, never re-resolved by the
        /// worker, so a publish racing this request cannot change
        /// which weights answer it (`0` = default model, unversioned).
        version: u64,
        /// Rows in the batch (zero is legal: the reply is an empty
        /// `Response`).
        rows: u32,
        /// Features per row (must match the worker's `Hello`).
        features: u32,
        /// `rows × features` values.
        data: Vec<f32>,
    },
    /// Logits for a served request (row-major `[rows × classes]`).
    Response {
        /// Id of the request this answers.
        id: u64,
        /// Model that produced these logits (echoes the request).
        model_id: u64,
        /// Snapshot version that produced these logits (echoes the
        /// request) — lets the coordinator verify the pin survived.
        version: u64,
        /// Rows answered.
        rows: u32,
        /// Classes per row.
        classes: u32,
        /// `rows × classes` values.
        data: Vec<f32>,
    },
    /// The request was not served.
    Reject {
        /// Id of the request this answers.
        id: u64,
        /// Why (codes mirror [`RejectReason`]).
        reason: RejectReason,
    },
    /// Coordinator asks for the worker's raw metrics.
    StatsRequest,
    /// Shared-nothing stats: the worker's **raw** latency samples plus
    /// counters, cumulative since worker start.  The coordinator folds
    /// the samples through `Metrics::merged_percentiles` — raw samples
    /// cross the wire precisely so percentiles are merged, never
    /// averaged.
    Stats {
        /// Requests this worker answered with logits.
        completed: u64,
        /// Requests shed by this worker's admission control.
        shed: u64,
        /// Batches this worker executed.
        batches: u64,
        /// Raw end-to-end latency samples, seconds.
        latencies: Vec<f64>,
    },
    /// Coordinator tells the worker process to exit.
    Shutdown,
    /// Health probe/report.  Coordinator → worker with
    /// [`HEALTH_PROBE`]; the worker answers with [`HEALTH_SERVING`] or
    /// [`HEALTH_DRAINING`].
    Health {
        /// One of the `HEALTH_*` codes.
        state: u8,
    },
    /// Coordinator asks the worker to stop advertising itself as
    /// serving: in-flight requests still complete, but subsequent
    /// `Health` probes answer [`HEALTH_DRAINING`] so the prober routes
    /// new traffic elsewhere.
    Drain,
    /// Hot snapshot publish: push a new weight version of a tenant
    /// model into a live worker.  Carries the full deterministic spec
    /// so a worker that has never seen the model can register it, plus
    /// the weight payload at a coordinator-assigned version (the
    /// coordinator's registry is authoritative for version numbers).
    /// The worker stores the snapshot and answers [`Frame::PublishAck`];
    /// requests already in flight keep resolving against the version
    /// they were admitted under.
    Publish {
        /// Tenant model being published.
        model_id: u64,
        /// Coordinator-assigned snapshot version (1-based).
        version: u64,
        /// Deterministic rebuild spec
        /// (sizes/paths/seed/kernel/sequence).
        spec: ModelSpec,
        /// Per-transition path weights, `w[t][p]`.
        w: Vec<Vec<f32>>,
        /// Per-layer biases (empty vecs when bias is disabled).
        bias: Vec<Vec<f32>>,
    },
    /// Worker's acknowledgement of a [`Frame::Publish`]: the snapshot
    /// is stored and every request admitted from now on may resolve to
    /// it.
    PublishAck {
        /// Model id echoed from the publish.
        model_id: u64,
        /// Version echoed from the publish.
        version: u64,
    },
}

impl Frame {
    /// Frame type name (diagnostics).
    pub fn name(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "hello",
            Frame::Request { .. } => "request",
            Frame::Response { .. } => "response",
            Frame::Reject { .. } => "reject",
            Frame::StatsRequest => "stats-request",
            Frame::Stats { .. } => "stats",
            Frame::Shutdown => "shutdown",
            Frame::Health { .. } => "health",
            Frame::Drain => "drain",
            Frame::Publish { .. } => "publish",
            Frame::PublishAck { .. } => "publish-ack",
        }
    }
}

// reject detail fields are u64 since protocol version 2: code 5
// carries a model id + version, which do not fit the old u32 pair
fn reason_code(r: RejectReason) -> (u8, u64, u64) {
    match r {
        RejectReason::QueueFull => (1, 0, 0),
        RejectReason::ShuttingDown => (2, 0, 0),
        RejectReason::BadShape { expected, got } => (3, expected as u64, got as u64),
        RejectReason::WorkerFailed => (4, 0, 0),
        RejectReason::UnknownModel { model_id, version } => (5, model_id, version),
    }
}

fn reason_from_code(code: u8, a: u64, b: u64) -> Result<RejectReason, FrameError> {
    match code {
        1 => Ok(RejectReason::QueueFull),
        2 => Ok(RejectReason::ShuttingDown),
        3 => Ok(RejectReason::BadShape { expected: a as usize, got: b as usize }),
        4 => Ok(RejectReason::WorkerFailed),
        5 => Ok(RejectReason::UnknownModel { model_id: a, version: b }),
        other => Err(FrameError::BadReason(other)),
    }
}

/// Wire code of a [`KernelKind`] (Publish frames carry the spec's
/// kernel as one byte).
fn kernel_code(k: KernelKind) -> u8 {
    match k {
        KernelKind::Auto => 0,
        KernelKind::Scalar => 1,
        KernelKind::Simd => 2,
        KernelKind::Sign => 3,
        KernelKind::Int8 => 4,
    }
}

fn kernel_from_code(code: u8) -> Result<KernelKind, FrameError> {
    match code {
        0 => Ok(KernelKind::Auto),
        1 => Ok(KernelKind::Scalar),
        2 => Ok(KernelKind::Simd),
        3 => Ok(KernelKind::Sign),
        4 => Ok(KernelKind::Int8),
        other => Err(FrameError::BadKernelCode(other)),
    }
}

/// Wire form of a [`SequenceFamily`] (protocol version 3): kind byte
/// (1 = Sobol', 2 = Halton, 3 = PRNG), flags byte (bit 0 = scramble
/// present, bit 1 = Sobol' bad-dimension skipping), u64 scramble/seed
/// parameter (0 when absent).
fn sequence_code(f: &SequenceFamily) -> (u8, u8, u64) {
    let kind = match f.kind {
        SequenceKind::Sobol => 1,
        SequenceKind::Halton => 2,
        SequenceKind::Prng => 3,
    };
    let mut flags = 0u8;
    if f.scramble.is_some() {
        flags |= 1;
    }
    if f.skip_bad_dims {
        flags |= 2;
    }
    (kind, flags, f.scramble.unwrap_or(0))
}

fn sequence_from_code(kind: u8, flags: u8, param: u64) -> Result<SequenceFamily, FrameError> {
    let kind = match kind {
        1 => SequenceKind::Sobol,
        2 => SequenceKind::Halton,
        3 => SequenceKind::Prng,
        other => return Err(FrameError::BadSequenceCode(other)),
    };
    let scramble = if flags & 1 != 0 { Some(param) } else { None };
    Ok(SequenceFamily { kind, scramble, skip_bad_dims: flags & 2 != 0 })
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    out.reserve(vs.len() * 4);
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_f64s(out: &mut Vec<u8>, vs: &[f64]) {
    out.reserve(vs.len() * 8);
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Length-prefixed list of length-prefixed f32 vectors (the weight /
/// bias payloads of a `Publish`).
fn put_f32_vecs(out: &mut Vec<u8>, vs: &[Vec<f32>]) {
    put_u32(out, vs.len() as u32);
    for v in vs {
        put_u32(out, v.len() as u32);
        put_f32s(out, v);
    }
}

/// Bounds-checked little-endian payload reader.
struct Cur<'a> {
    frame: &'static str,
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(frame: &'static str, buf: &'a [u8]) -> Self {
        Cur { frame, buf, pos: 0 }
    }

    fn short(&self, needed: usize) -> FrameError {
        FrameError::BadPayloadLen {
            frame: self.frame,
            expected: self.pos.saturating_add(needed),
            got: self.buf.len(),
        }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], FrameError> {
        if self.buf.len() - self.pos < n {
            return Err(self.short(n));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, FrameError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, FrameError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, FrameError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32s(&mut self, count: usize) -> Result<Vec<f32>, FrameError> {
        let n = match count.checked_mul(4) {
            Some(n) => n,
            None => return Err(self.short(usize::MAX)),
        };
        let b = self.take(n)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    fn f64s(&mut self, count: usize) -> Result<Vec<f64>, FrameError> {
        let n = match count.checked_mul(8) {
            Some(n) => n,
            None => return Err(self.short(usize::MAX)),
        };
        let b = self.take(n)?;
        Ok(b.chunks_exact(8)
            .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect())
    }

    /// Length-prefixed list of length-prefixed f32 vectors.  Counts
    /// are untrusted: nothing is preallocated from them — every
    /// element read is bounds-checked against the remaining payload,
    /// so a hostile count fails with `BadPayloadLen` before any
    /// oversized buffer exists.
    fn f32_vecs(&mut self) -> Result<Vec<Vec<f32>>, FrameError> {
        let n = self.u32()? as usize;
        let mut vs = Vec::new();
        for _ in 0..n {
            let len = self.u32()? as usize;
            vs.push(self.f32s(len)?);
        }
        Ok(vs)
    }

    /// Error unless the payload was consumed exactly.
    fn finish(self) -> Result<(), FrameError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(FrameError::BadPayloadLen {
                frame: self.frame,
                expected: self.pos,
                got: self.buf.len(),
            })
        }
    }
}

/// Serialize `frame` to `w` (one `write_all` per header field plus the
/// payload, then `flush`).
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<(), FrameError> {
    let (tag, payload) = encode_payload(frame);
    if payload.len() as u64 > MAX_PAYLOAD as u64 {
        return Err(FrameError::TooLarge { len: payload.len() as u32, max: MAX_PAYLOAD });
    }
    w.write_all(&MAGIC).map_err(FrameError::Io)?;
    w.write_all(&[tag]).map_err(FrameError::Io)?;
    w.write_all(&(payload.len() as u32).to_le_bytes()).map_err(FrameError::Io)?;
    w.write_all(&payload).map_err(FrameError::Io)?;
    w.flush().map_err(FrameError::Io)?;
    Ok(())
}

fn encode_payload(frame: &Frame) -> (u8, Vec<u8>) {
    let mut p = Vec::new();
    let tag = match frame {
        Frame::Hello { features, classes, batch_capacity } => {
            put_u32(&mut p, *features);
            put_u32(&mut p, *classes);
            put_u32(&mut p, *batch_capacity);
            TYPE_HELLO
        }
        Frame::Request { id, model_id, version, rows, features, data } => {
            put_u64(&mut p, *id);
            put_u64(&mut p, *model_id);
            put_u64(&mut p, *version);
            put_u32(&mut p, *rows);
            put_u32(&mut p, *features);
            put_f32s(&mut p, data);
            TYPE_REQUEST
        }
        Frame::Response { id, model_id, version, rows, classes, data } => {
            put_u64(&mut p, *id);
            put_u64(&mut p, *model_id);
            put_u64(&mut p, *version);
            put_u32(&mut p, *rows);
            put_u32(&mut p, *classes);
            put_f32s(&mut p, data);
            TYPE_RESPONSE
        }
        Frame::Reject { id, reason } => {
            let (code, a, b) = reason_code(*reason);
            put_u64(&mut p, *id);
            p.push(code);
            put_u64(&mut p, a);
            put_u64(&mut p, b);
            TYPE_REJECT
        }
        Frame::StatsRequest => TYPE_STATS_REQUEST,
        Frame::Stats { completed, shed, batches, latencies } => {
            put_u64(&mut p, *completed);
            put_u64(&mut p, *shed);
            put_u64(&mut p, *batches);
            put_u32(&mut p, latencies.len() as u32);
            put_f64s(&mut p, latencies);
            TYPE_STATS
        }
        Frame::Shutdown => TYPE_SHUTDOWN,
        Frame::Health { state } => {
            p.push(*state);
            TYPE_HEALTH
        }
        Frame::Drain => TYPE_DRAIN,
        Frame::Publish { model_id, version, spec, w, bias } => {
            put_u64(&mut p, *model_id);
            put_u64(&mut p, *version);
            put_u32(&mut p, spec.sizes.len() as u32);
            for s in &spec.sizes {
                put_u32(&mut p, *s as u32);
            }
            put_u32(&mut p, spec.paths as u32);
            put_u64(&mut p, spec.seed);
            p.push(kernel_code(spec.kernel));
            let (kind, flags, param) = sequence_code(&spec.sequence);
            p.push(kind);
            p.push(flags);
            put_u64(&mut p, param);
            put_f32_vecs(&mut p, w);
            put_f32_vecs(&mut p, bias);
            TYPE_PUBLISH
        }
        Frame::PublishAck { model_id, version } => {
            put_u64(&mut p, *model_id);
            put_u64(&mut p, *version);
            TYPE_PUBLISH_ACK
        }
    };
    (tag, p)
}

/// Read one frame from `r`.  Blocks until a full frame arrives (socket
/// read timeouts surface as [`FrameError::Io`]).  A peer that closed
/// cleanly at a frame boundary yields [`FrameError::Closed`]; anything
/// cut short mid-frame yields [`FrameError::Truncated`].
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, FrameError> {
    // first byte read separately: zero bytes here is a clean close,
    // not a truncation
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Err(FrameError::Closed),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::from(e)),
        }
    }
    let mut magic = [0u8; 4];
    magic[0] = first[0];
    r.read_exact(&mut magic[1..])?;
    if magic != MAGIC {
        // an `SBN`-prefixed magic with the wrong version byte is a
        // sobolnet peer of another protocol generation — tell the
        // operator to upgrade it rather than reporting wire garbage
        return if magic[..3] == MAGIC[..3] {
            Err(FrameError::VersionMismatch { got: magic[3] })
        } else {
            Err(FrameError::BadMagic(magic))
        };
    }
    let mut head = [0u8; 5];
    r.read_exact(&mut head)?;
    let tag = head[0];
    let len = u32::from_le_bytes([head[1], head[2], head[3], head[4]]);
    // validation order is normative (ARCHITECTURE.md): magic, type,
    // length cap — all before the payload buffer is allocated or read
    if !(TYPE_HELLO..=TYPE_PUBLISH_ACK).contains(&tag) {
        return Err(FrameError::UnknownType(tag));
    }
    if len > MAX_PAYLOAD {
        return Err(FrameError::TooLarge { len, max: MAX_PAYLOAD });
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    decode_payload(tag, &payload)
}

fn decode_payload(tag: u8, payload: &[u8]) -> Result<Frame, FrameError> {
    match tag {
        TYPE_HELLO => {
            let mut c = Cur::new("hello", payload);
            let features = c.u32()?;
            let classes = c.u32()?;
            let batch_capacity = c.u32()?;
            c.finish()?;
            Ok(Frame::Hello { features, classes, batch_capacity })
        }
        TYPE_REQUEST => {
            let mut c = Cur::new("request", payload);
            let id = c.u64()?;
            let model_id = c.u64()?;
            let version = c.u64()?;
            let rows = c.u32()?;
            let features = c.u32()?;
            let data = c.f32s(rows as usize * features as usize)?;
            c.finish()?;
            Ok(Frame::Request { id, model_id, version, rows, features, data })
        }
        TYPE_RESPONSE => {
            let mut c = Cur::new("response", payload);
            let id = c.u64()?;
            let model_id = c.u64()?;
            let version = c.u64()?;
            let rows = c.u32()?;
            let classes = c.u32()?;
            let data = c.f32s(rows as usize * classes as usize)?;
            c.finish()?;
            Ok(Frame::Response { id, model_id, version, rows, classes, data })
        }
        TYPE_REJECT => {
            let mut c = Cur::new("reject", payload);
            let id = c.u64()?;
            let code = c.u8()?;
            let a = c.u64()?;
            let b = c.u64()?;
            c.finish()?;
            Ok(Frame::Reject { id, reason: reason_from_code(code, a, b)? })
        }
        TYPE_STATS_REQUEST => {
            Cur::new("stats-request", payload).finish()?;
            Ok(Frame::StatsRequest)
        }
        TYPE_STATS => {
            let mut c = Cur::new("stats", payload);
            let completed = c.u64()?;
            let shed = c.u64()?;
            let batches = c.u64()?;
            let n = c.u32()?;
            let latencies = c.f64s(n as usize)?;
            c.finish()?;
            Ok(Frame::Stats { completed, shed, batches, latencies })
        }
        TYPE_SHUTDOWN => {
            Cur::new("shutdown", payload).finish()?;
            Ok(Frame::Shutdown)
        }
        TYPE_HEALTH => {
            let mut c = Cur::new("health", payload);
            let state = c.u8()?;
            c.finish()?;
            if !(HEALTH_PROBE..=HEALTH_DRAINING).contains(&state) {
                return Err(FrameError::BadHealthState(state));
            }
            Ok(Frame::Health { state })
        }
        TYPE_DRAIN => {
            Cur::new("drain", payload).finish()?;
            Ok(Frame::Drain)
        }
        TYPE_PUBLISH => {
            let mut c = Cur::new("publish", payload);
            let model_id = c.u64()?;
            let version = c.u64()?;
            let n_sizes = c.u32()? as usize;
            let mut sizes = Vec::new();
            for _ in 0..n_sizes {
                sizes.push(c.u32()? as usize);
            }
            let paths = c.u32()? as usize;
            let seed = c.u64()?;
            let kernel = kernel_from_code(c.u8()?)?;
            let seq_kind = c.u8()?;
            let seq_flags = c.u8()?;
            let seq_param = c.u64()?;
            let sequence = sequence_from_code(seq_kind, seq_flags, seq_param)?;
            let w = c.f32_vecs()?;
            let bias = c.f32_vecs()?;
            c.finish()?;
            let spec = ModelSpec { sizes, paths, seed, kernel, sequence };
            Ok(Frame::Publish { model_id, version, spec, w, bias })
        }
        TYPE_PUBLISH_ACK => {
            let mut c = Cur::new("publish-ack", payload);
            let model_id = c.u64()?;
            let version = c.u64()?;
            c.finish()?;
            Ok(Frame::PublishAck { model_id, version })
        }
        other => Err(FrameError::UnknownType(other)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(f: &Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, f).expect("encode");
        read_frame(&mut Cursor::new(buf)).expect("decode")
    }

    fn encode(f: &Frame) -> Vec<u8> {
        let mut buf = Vec::new();
        write_frame(&mut buf, f).expect("encode");
        buf
    }

    fn test_spec() -> ModelSpec {
        ModelSpec {
            sizes: vec![8, 16, 4],
            paths: 32,
            seed: 5,
            kernel: KernelKind::Scalar,
            sequence: SequenceFamily::default(),
        }
    }

    #[test]
    fn every_frame_type_round_trips() {
        let frames = [
            Frame::Hello { features: 784, classes: 10, batch_capacity: 64 },
            Frame::Request {
                id: 7,
                model_id: 3,
                version: 2,
                rows: 2,
                features: 3,
                data: vec![1.0, -2.5, 0.0, 4.0, 5.0, -0.125],
            },
            Frame::Response {
                id: 7,
                model_id: 3,
                version: 2,
                rows: 2,
                classes: 2,
                data: vec![0.5, -0.5, 1.5, 2.5],
            },
            Frame::Reject { id: 9, reason: RejectReason::QueueFull },
            Frame::Reject { id: 9, reason: RejectReason::BadShape { expected: 784, got: 3 } },
            Frame::Reject { id: 1, reason: RejectReason::ShuttingDown },
            Frame::Reject { id: 2, reason: RejectReason::WorkerFailed },
            Frame::Reject {
                id: 3,
                reason: RejectReason::UnknownModel { model_id: u64::MAX, version: 17 },
            },
            Frame::StatsRequest,
            Frame::Stats {
                completed: 100,
                shed: 3,
                batches: 25,
                latencies: vec![0.001, 0.002, 0.101],
            },
            Frame::Shutdown,
            Frame::Health { state: HEALTH_PROBE },
            Frame::Health { state: HEALTH_SERVING },
            Frame::Health { state: HEALTH_DRAINING },
            Frame::Drain,
            Frame::Publish {
                model_id: 11,
                version: 4,
                spec: test_spec(),
                w: vec![vec![0.5, -0.25, 1.0e-9], vec![]],
                bias: vec![vec![0.125; 16], vec![]],
            },
            // non-default sequence families must survive the wire so
            // remote workers rebuild the same topology
            Frame::Publish {
                model_id: 12,
                version: 1,
                spec: ModelSpec {
                    sequence: SequenceFamily::halton_scrambled(9),
                    ..test_spec()
                },
                w: vec![vec![1.0]],
                bias: vec![vec![0.0]],
            },
            Frame::Publish {
                model_id: 13,
                version: 1,
                spec: ModelSpec { sequence: SequenceFamily::prng(3), ..test_spec() },
                w: vec![vec![1.0]],
                bias: vec![vec![0.0]],
            },
            Frame::PublishAck { model_id: 11, version: 4 },
        ];
        for f in &frames {
            assert_eq!(&roundtrip(f), f, "{} round-trip", f.name());
        }
    }

    #[test]
    fn unknown_health_state_is_typed_error() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.push(8); // health
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.push(42); // bogus state code
        match read_frame(&mut Cursor::new(bytes)) {
            Err(FrameError::BadHealthState(42)) => {}
            other => panic!("expected BadHealthState, got {other:?}"),
        }
    }

    #[test]
    fn type_beyond_drain_is_still_unknown() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.push(12); // one past the last assigned tag
        bytes.extend_from_slice(&0u32.to_le_bytes());
        match read_frame(&mut Cursor::new(bytes)) {
            Err(FrameError::UnknownType(12)) => {}
            other => panic!("expected UnknownType, got {other:?}"),
        }
    }

    #[test]
    fn f32_payloads_cross_bitwise() {
        // values with tricky bit patterns: -0.0, subnormal, NaN payload
        let vals = vec![-0.0f32, f32::MIN_POSITIVE / 2.0, f32::NAN, f32::INFINITY, -1.0e-38];
        let req = Frame::Request {
            id: 1,
            model_id: 0,
            version: 0,
            rows: 1,
            features: 5,
            data: vals.clone(),
        };
        let got = match roundtrip(&req) {
            Frame::Request { data, .. } => data,
            other => panic!("wrong frame {other:?}"),
        };
        for (a, b) in vals.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits(), "bitwise-identical across the wire");
        }
    }

    #[test]
    fn zero_length_batch_is_legal() {
        let f = Frame::Request { id: 3, model_id: 0, version: 0, rows: 0, features: 784, data: vec![] };
        assert_eq!(roundtrip(&f), f);
        let r = Frame::Response { id: 3, model_id: 0, version: 0, rows: 0, classes: 10, data: vec![] };
        assert_eq!(roundtrip(&r), r);
    }

    #[test]
    fn garbage_magic_is_typed_error() {
        let mut bytes = encode(&Frame::Shutdown);
        bytes[..4].copy_from_slice(b"XXXX");
        match read_frame(&mut Cursor::new(bytes)) {
            Err(FrameError::BadMagic(m)) => assert_eq!(&m, b"XXXX"),
            other => panic!("expected BadMagic, got {other:?}"),
        }
        // pure noise, not even a header
        match read_frame(&mut Cursor::new(b"hello sobolnet".to_vec())) {
            Err(FrameError::BadMagic(_)) => {}
            other => panic!("expected BadMagic, got {other:?}"),
        }
    }

    #[test]
    fn truncation_at_every_boundary_is_typed_error() {
        let full = encode(&Frame::Request {
            id: 5,
            model_id: 2,
            version: 1,
            rows: 1,
            features: 4,
            data: vec![1.0; 4],
        });
        // cut the stream at every possible byte offset: each must be a
        // typed error (Closed at offset 0, Truncated elsewhere), never
        // a panic or a bogus frame
        for cut in 0..full.len() {
            let r = read_frame(&mut Cursor::new(full[..cut].to_vec()));
            match (cut, r) {
                (0, Err(FrameError::Closed)) => {}
                (_, Err(FrameError::Truncated)) => {}
                (c, other) => panic!("cut at {c}: expected typed error, got {other:?}"),
            }
        }
        // the intact frame still decodes after all those partial reads
        assert!(read_frame(&mut Cursor::new(full)).is_ok());
    }

    #[test]
    fn oversize_header_rejected_before_allocation() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.push(2); // request
        bytes.extend_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        // no payload follows — the length check must fire before any read
        match read_frame(&mut Cursor::new(bytes)) {
            Err(FrameError::TooLarge { len, max }) => {
                assert_eq!(len, MAX_PAYLOAD + 1);
                assert_eq!(max, MAX_PAYLOAD);
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn max_size_payload_round_trips() {
        // largest request that fits the cap: payload header is 32 bytes
        // (id + model_id + version + rows + features), so
        // (MAX_PAYLOAD - 32) / 4 values exactly at the boundary
        let n = (MAX_PAYLOAD as usize - 32) / 4;
        let f = Frame::Request {
            id: 1,
            model_id: 0,
            version: 0,
            rows: 1,
            features: n as u32,
            data: vec![0.25; n],
        };
        let bytes = encode(&f);
        assert_eq!(bytes.len(), 9 + 32 + 4 * n);
        match read_frame(&mut Cursor::new(bytes)).expect("decode at the cap") {
            Frame::Request { data, .. } => assert_eq!(data.len(), n),
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn payload_count_mismatch_is_typed_error() {
        // declared 8 rows but carried only 1 row of data
        let mut bad = Vec::new();
        put_u64(&mut bad, 1);
        put_u64(&mut bad, 0); // model_id
        put_u64(&mut bad, 0); // version
        put_u32(&mut bad, 8); // rows
        put_u32(&mut bad, 4); // features
        put_f32s(&mut bad, &[0.0; 4]); // one row, not eight
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.push(2);
        bytes.extend_from_slice(&(bad.len() as u32).to_le_bytes());
        bytes.extend_from_slice(&bad);
        match read_frame(&mut Cursor::new(bytes)) {
            Err(FrameError::BadPayloadLen { frame: "request", .. }) => {}
            other => panic!("expected BadPayloadLen, got {other:?}"),
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut p = Vec::new();
        put_u32(&mut p, 1);
        put_u32(&mut p, 2);
        put_u32(&mut p, 3);
        p.push(0xFF); // one byte too many for a hello
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.push(1);
        bytes.extend_from_slice(&(p.len() as u32).to_le_bytes());
        bytes.extend_from_slice(&p);
        match read_frame(&mut Cursor::new(bytes)) {
            Err(FrameError::BadPayloadLen { frame: "hello", .. }) => {}
            other => panic!("expected BadPayloadLen, got {other:?}"),
        }
    }

    #[test]
    fn unknown_type_and_reason_are_typed_errors() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.push(99);
        bytes.extend_from_slice(&0u32.to_le_bytes());
        match read_frame(&mut Cursor::new(bytes)) {
            Err(FrameError::UnknownType(99)) => {}
            other => panic!("expected UnknownType, got {other:?}"),
        }
        let mut p = Vec::new();
        put_u64(&mut p, 1);
        p.push(77); // bogus reason code
        put_u64(&mut p, 0);
        put_u64(&mut p, 0);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.push(4);
        bytes.extend_from_slice(&(p.len() as u32).to_le_bytes());
        bytes.extend_from_slice(&p);
        match read_frame(&mut Cursor::new(bytes)) {
            Err(FrameError::BadReason(77)) => {}
            other => panic!("expected BadReason, got {other:?}"),
        }
    }

    #[test]
    fn errors_display() {
        let samples: Vec<FrameError> = vec![
            FrameError::Closed,
            FrameError::Truncated,
            FrameError::BadMagic(*b"XXXX"),
            FrameError::UnknownType(9),
            FrameError::TooLarge { len: 1, max: 0 },
            FrameError::BadPayloadLen { frame: "hello", expected: 12, got: 13 },
            FrameError::BadReason(0),
            FrameError::BadHealthState(3),
            FrameError::VersionMismatch { got: b'1' },
            FrameError::BadKernelCode(9),
            FrameError::BadSequenceCode(9),
            FrameError::Io(std::io::Error::other("boom")),
        ];
        for e in samples {
            assert!(!format!("{e}").is_empty());
        }
    }

    #[test]
    fn old_protocol_magic_is_version_mismatch_not_garbage() {
        // a protocol-1 peer sends SBN1-magic frames: the error must name
        // the version clash, not report wire garbage
        let mut bytes = encode(&Frame::Shutdown);
        bytes[..4].copy_from_slice(b"SBN1");
        match read_frame(&mut Cursor::new(bytes)) {
            Err(FrameError::VersionMismatch { got }) => assert_eq!(got, b'1'),
            other => panic!("expected VersionMismatch, got {other:?}"),
        }
        // and the display text tells the operator which side to upgrade
        let msg = format!("{}", FrameError::VersionMismatch { got: b'1' });
        assert!(msg.contains('1') && msg.contains('3'), "unhelpful message: {msg}");
    }

    #[test]
    fn publish_truncation_and_bad_kernel_are_typed_errors() {
        let full = encode(&Frame::Publish {
            model_id: 11,
            version: 4,
            spec: test_spec(),
            w: vec![vec![0.5, -0.25], vec![1.0]],
            bias: vec![vec![0.125; 16], vec![]],
        });
        for cut in 9..full.len() {
            let r = read_frame(&mut Cursor::new(full[..cut].to_vec()));
            assert!(
                matches!(r, Err(FrameError::Truncated)),
                "cut at {cut}: expected Truncated, got {r:?}"
            );
        }
        assert!(read_frame(&mut Cursor::new(full.clone())).is_ok());
        // corrupt the kernel code: u64 id + u64 version + u32 count +
        // 3 × u32 sizes + u32 paths + u64 seed = 44 bytes into the payload
        let mut bad = full.clone();
        bad[9 + 44] = 0xEE;
        match read_frame(&mut Cursor::new(bad)) {
            Err(FrameError::BadKernelCode(0xEE)) => {}
            other => panic!("expected BadKernelCode, got {other:?}"),
        }
        // the sequence kind byte sits right after the kernel code
        let mut bad = full;
        bad[9 + 45] = 0xDD;
        match read_frame(&mut Cursor::new(bad)) {
            Err(FrameError::BadSequenceCode(0xDD)) => {}
            other => panic!("expected BadSequenceCode, got {other:?}"),
        }
    }

    #[test]
    fn unknown_model_reject_round_trips_detail() {
        let f = Frame::Reject {
            id: 4,
            reason: RejectReason::UnknownModel { model_id: 7, version: 0 },
        };
        match roundtrip(&f) {
            Frame::Reject { id: 4, reason: RejectReason::UnknownModel { model_id: 7, version: 0 } } => {}
            other => panic!("detail fields lost: {other:?}"),
        }
    }
}
