//! Worker-process side of the multi-process engine: serve one engine
//! shard behind a socket listener.
//!
//! This is what the `sobolnet shard-worker` subcommand runs.  The
//! process hosts a normal (usually single-shard) [`Engine`] and
//! answers the coordinator's frames:
//!
//! * `Request`  → rows are submitted through [`Engine::try_submit`]
//!   (the same admission path every local caller uses) and the tickets
//!   awaited in row order, so a remote batch is bitwise identical to
//!   local submission of the same rows.  A request matching the
//!   previous one (same id **and** same payload fingerprint) is
//!   answered from a 1-deep reply cache that survives reconnects, so a
//!   coordinator retry after a broken connection is idempotent — no
//!   recomputation, no double-counted stats — while a restarted
//!   coordinator reusing id 0 with different data recomputes;
//! * `StatsRequest` → a `Stats` frame carrying this worker's counters
//!   (cumulative since start) and its recent **raw** latency samples
//!   (bounded by [`STATS_SAMPLE_CAP`]) — the shared-nothing half of
//!   engine-wide percentile merging;
//! * `Health` (probe) → a `Health` reply carrying the worker's state,
//!   serving or draining — the coordinator-side prober's signal;
//! * `Drain` → the worker flips to the draining state (in-flight and
//!   subsequent requests still answer, but probes now report draining
//!   so the prober routes new traffic to siblings), acked with a
//!   `Health` reply;
//! * `Publish` → the carried snapshot is registered and appended to
//!   this worker's registry at exactly the coordinator-assigned
//!   version (idempotent for an identical retry), acked with
//!   `PublishAck`; in-flight requests keep completing against the
//!   version pinned at their admission;
//! * `Shutdown` → [`serve_shard`] returns so the process can exit.
//!
//! Connections are accepted **concurrently** (one thread per
//! connection): the long-lived coordinator data connection never
//! blocks short-lived health probes out of the listener.  A dropped
//! connection (coordinator restart, transient network) is not fatal:
//! its thread ends and the listener keeps accepting, which is what
//! makes the coordinator's reconnect-with-backoff work.  Malformed
//! frames from a stray client are logged and treated as a disconnect —
//! garbage on the socket can never crash a serving shard.

use super::frame::{
    read_frame, write_frame, Frame, FrameError, HEALTH_DRAINING, HEALTH_PROBE, HEALTH_SERVING,
};
use super::transport::{Listener, Stream};
use crate::engine::{Engine, RejectReason, Response};
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Why a single connection ended.
enum ConnExit {
    /// Coordinator sent `Shutdown`: the process should exit.
    Shutdown,
    /// Peer disconnected (or sent garbage): go back to `accept`.
    Disconnected,
}

/// Serve `engine` behind `listener` until a `Shutdown` frame arrives.
/// Connections are handled on their own threads (a coordinator data
/// connection plus any number of health probes); returns `Err` only
/// for listener-level I/O failures.
pub fn serve_shard(listener: &Listener, engine: &Engine) -> Result<(), FrameError> {
    // 1-deep idempotency cache, surviving reconnects: a coordinator
    // that lost the connection mid-exchange resends the same request
    // id and gets the cached reply — a retried batch is never
    // recomputed and never double-counted in worker-side stats.  The
    // cache is keyed by (id, payload fingerprint), not id alone: a
    // *restarted* coordinator also starts its ids at 0, and an
    // id-only key would hand its first (different) batch the previous
    // coordinator's cached logits.  Shared under a mutex across
    // connection threads — request handling serializes on it, which
    // matches the protocol (one data connection per shard at a time)
    // and keeps retried-after-reconnect semantics identical to the
    // serial-accept implementation.
    let last_reply: Mutex<Option<(u64, u64, Frame)>> = Mutex::new(None);
    // serving/draining state machine: Drain flips it once, Health
    // probes report it
    let state = AtomicU8::new(HEALTH_SERVING);
    let shutdown = AtomicBool::new(false);
    listener.set_nonblocking(true).map_err(FrameError::Io)?;
    std::thread::scope(|scope| {
        loop {
            if shutdown.load(Ordering::Acquire) {
                return Ok(());
            }
            match listener.accept() {
                Ok(conn) => {
                    // the listener is nonblocking; the accepted stream
                    // must not be (inheritance is platform-dependent)
                    conn.set_nonblocking(false).map_err(FrameError::Io)?;
                    let (last_reply, state, shutdown) = (&last_reply, &state, &shutdown);
                    scope.spawn(move || {
                        let mut conn = conn;
                        match handle_conn(&mut conn, engine, last_reply, state) {
                            Ok(ConnExit::Shutdown) => shutdown.store(true, Ordering::Release),
                            Ok(ConnExit::Disconnected) => {}
                            Err(e) => {
                                // bad bytes or a mid-frame hangup: drop
                                // the connection, keep the shard serving
                                crate::log_warn!("shard-worker connection error: {e}");
                            }
                        }
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(FrameError::Io(e)),
            }
        }
    })
}

fn handle_conn(
    conn: &mut Stream,
    engine: &Engine,
    last_reply: &Mutex<Option<(u64, u64, Frame)>>,
    state: &AtomicU8,
) -> Result<ConnExit, FrameError> {
    write_frame(
        conn,
        &Frame::Hello {
            features: engine.features() as u32,
            classes: engine.classes() as u32,
            batch_capacity: engine.batch_capacity() as u32,
        },
    )?;
    loop {
        let frame = match read_frame(conn) {
            Ok(f) => f,
            Err(FrameError::Closed) => return Ok(ConnExit::Disconnected),
            Err(e) => return Err(e),
        };
        match frame {
            Frame::Request { id, model_id, version, rows, features, data } => {
                let fp = request_fingerprint(model_id, version, rows, features, &data);
                // the cache lock is held across the compute: requests
                // from racing connections (a reconnect overtaking its
                // predecessor) serialize, exactly like serial accept did
                let mut cache = crate::util::sync::plock(last_reply);
                let hit = cache
                    .as_ref()
                    .map(|(lid, lfp, _)| *lid == id && *lfp == fp)
                    .unwrap_or(false);
                if !hit {
                    let reply = answer_request(
                        engine,
                        model_id,
                        version,
                        rows as usize,
                        features as usize,
                        &data,
                        id,
                    );
                    *cache = Some((id, fp, reply));
                }
                if let Some((_, _, reply)) = cache.as_ref() {
                    write_frame(conn, reply)?;
                }
            }
            Frame::Publish { model_id, version, spec, w, bias } => {
                // hot snapshot publish into this worker's registry:
                // register the spec if first contact (idempotent for an
                // identical spec), then append the snapshot at exactly
                // the version the coordinator assigned.  Versions are
                // immutable and the tenant cache keys include them, so
                // requests already admitted against an older version
                // keep completing against its exact bits.
                let outcome = match engine.registry() {
                    Some(reg) => reg
                        .register(model_id, spec)
                        .and_then(|()| reg.publish_at(model_id, version, w, bias)),
                    None => Err("worker engine has no registry attached".to_string()),
                };
                match outcome {
                    Ok(()) => write_frame(conn, &Frame::PublishAck { model_id, version })?,
                    Err(e) => {
                        crate::log_warn!(
                            "shard-worker: refused publish of model {model_id} v{version}: {e}"
                        );
                        write_frame(
                            conn,
                            &Frame::Reject {
                                id: 0,
                                reason: RejectReason::UnknownModel { model_id, version },
                            },
                        )?;
                    }
                }
            }
            Frame::StatsRequest => {
                write_frame(conn, &stats_frame(engine))?;
            }
            Frame::Health { state: HEALTH_PROBE } => {
                write_frame(conn, &Frame::Health { state: state.load(Ordering::Acquire) })?;
            }
            Frame::Drain => {
                state.store(HEALTH_DRAINING, Ordering::Release);
                write_frame(conn, &Frame::Health { state: HEALTH_DRAINING })?;
            }
            Frame::Shutdown => return Ok(ConnExit::Shutdown),
            // a worker never expects coordinator-bound frame types;
            // treat a confused peer as a disconnect
            other => {
                crate::log_warn!(
                    "shard-worker: unexpected {} frame, dropping connection",
                    other.name()
                );
                return Ok(ConnExit::Disconnected);
            }
        }
    }
}

/// FNV-1a over `bytes`, continuing from `h`.
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Content fingerprint of a request (model key + shape + exact payload
/// bits), the second half of the reply-cache key: an id match alone is
/// not proof of a retry — a restarted coordinator reuses low ids.  The
/// `(model_id, version)` pair **must** be folded in: a retried id with
/// the same payload but a different pinned version is a different
/// request, and answering it from the stale version's cached reply
/// would silently serve old weights after a publish.
fn request_fingerprint(model_id: u64, version: u64, rows: u32, features: u32, data: &[f32]) -> u64 {
    let mut h = fnv1a(0xcbf2_9ce4_8422_2325, &model_id.to_le_bytes());
    h = fnv1a(h, &version.to_le_bytes());
    h = fnv1a(h, &rows.to_le_bytes());
    h = fnv1a(h, &features.to_le_bytes());
    for v in data {
        h = fnv1a(h, &v.to_le_bytes());
    }
    h
}

/// Submit every row of the batch through the engine's normal admission
/// path — pinned to exactly the `(model_id, version)` the coordinator
/// stamped at *its* admission, never re-resolved here — await the
/// tickets in row order, and assemble the reply.
fn answer_request(
    engine: &Engine,
    model_id: u64,
    version: u64,
    rows: usize,
    features: usize,
    data: &[f32],
    id: u64,
) -> Frame {
    if features != engine.features() {
        return Frame::Reject {
            id,
            reason: RejectReason::BadShape { expected: engine.features(), got: features },
        };
    }
    if rows == 0 {
        // zero-length batches are legal and answered in kind
        return Frame::Response {
            id,
            model_id,
            version,
            rows: 0,
            classes: engine.classes() as u32,
            data: vec![],
        };
    }
    // submit all rows first (they coalesce into the shard's batcher),
    // then await in row order so the reply layout is deterministic
    let mut tickets = Vec::with_capacity(rows);
    for r in 0..rows {
        match engine.try_submit_pinned(
            model_id,
            version,
            data[r * features..(r + 1) * features].to_vec(),
        ) {
            Ok(t) => tickets.push(t),
            Err(reason) => return Frame::Reject { id, reason },
        }
    }
    let classes = engine.classes();
    let mut out = Vec::with_capacity(rows * classes);
    for t in tickets {
        match t.wait() {
            Response::Logits(l) => out.extend_from_slice(&l),
            // a shard-worker serving an ensemble engine answers with
            // merged logits; the wire carries them like any others
            Response::Merged { logits, .. } => out.extend_from_slice(&logits),
            Response::Rejected(reason) => return Frame::Reject { id, reason },
        }
    }
    Frame::Response {
        id,
        model_id,
        version,
        rows: rows as u32,
        classes: classes as u32,
        data: out,
    }
}

/// Most recent raw latency samples a single `Stats` frame will carry.
/// Counters stay cumulative, but an unbounded sample vector would
/// outgrow the frame payload cap on a long-lived worker (and make
/// total stats traffic quadratic in request count), so each frame
/// ships a bounded tail — 64 Ki samples ≈ 512 KiB, far more than any
/// percentile needs.
pub const STATS_SAMPLE_CAP: usize = 64 * 1024;

/// Snapshot this worker's raw metrics into a `Stats` frame
/// (shared-nothing: the coordinator folds, never averages).  Counters
/// are cumulative since worker start; latency samples are the most
/// recent [`STATS_SAMPLE_CAP`] (raw, so the coordinator can merge
/// before ranking).
fn stats_frame(engine: &Engine) -> Frame {
    let mut latencies = Vec::new();
    for m in engine.worker_metrics() {
        // bounded copy: O(cap) under the metrics lock per poll, not
        // O(everything this worker ever served)
        m.extend_recent_latencies_into(&mut latencies, STATS_SAMPLE_CAP);
    }
    if latencies.len() > STATS_SAMPLE_CAP {
        latencies.drain(..latencies.len() - STATS_SAMPLE_CAP);
    }
    Frame::Stats {
        completed: engine.metrics.completed.load(Ordering::Relaxed),
        shed: engine.metrics.shed.load(Ordering::Relaxed),
        batches: engine.metrics.batches.load(Ordering::Relaxed),
        latencies,
    }
}
