//! Multi-process engine transport: worker shards in separate OS
//! processes, connected over Unix-domain or TCP sockets.
//!
//! The paper keeps a sparse network fast by keeping its weight blocks
//! contiguous and its layer hops permutations — contention-free
//! parallel hardware (§3, §4.4).  This module applies the same idea
//! one level up, in the spirit of interleaver-style partitioning
//! across compute units: worker shards become **shared-nothing
//! processes**, and the engine's existing
//! [`Ticket`](crate::engine::Ticket)/[`Response`](crate::engine::Response)/
//! [`RejectReason`](crate::engine::RejectReason) contract becomes the
//! wire protocol (PR 3 shaped it as plain data for exactly this
//! reason).
//!
//! Layering — the coordinator process keeps admission, dispatch, and
//! batching **unchanged**; only the backend crosses a process
//! boundary:
//!
//! ```text
//! coordinator process                 worker processes
//! ───────────────────                 ────────────────────────────
//! EngineBuilder::remote(addrs)        sobolnet shard-worker --listen …
//!   │  (or .spawn_workers(n, spec))     │
//!   ▼                                   ▼
//! Engine ── shard 0: RemoteBackend ◄── socket ──► single-shard Engine
//!        ── shard 1: RemoteBackend ◄── socket ──► single-shard Engine
//!        └─ shard N: …
//! ```
//!
//! * [`frame`] — the length-prefixed binary frame codec (the byte-level
//!   spec is normative in `docs/ARCHITECTURE.md` §Wire protocol);
//! * [`transport`] — `unix:`/`tcp:` address grammar, streams, listeners;
//! * [`client`] — [`RemoteBackend`], the coordinator-side
//!   [`InferenceBackend`](crate::engine::InferenceBackend) proxy with
//!   reconnect-with-backoff;
//! * [`server`] — [`serve_shard`], the worker-process request loop;
//! * [`spawn`] — [`SpawnedShards`], child-process lifecycle;
//! * [`health`] — [`HealthBoard`] (per-shard up/down flags +
//!   hedge/failover counters) and the [`Prober`] thread keeping it
//!   current between requests.
//!
//! **Fault tolerance** (docs/ARCHITECTURE.md §Fault tolerance): shards
//! can be built as **replica groups** (`EngineBuilder::replicas`) of
//! bitwise-interchangeable copies; exchanges that miss a hedge
//! deadline are re-fired at a sibling, hard failures fail over to one,
//! and a seeded [`FaultPlan`](transport::FaultPlan) injects
//! delay/drop/sever/garble faults deterministically for
//! `tests/chaos.rs`.
//!
//! **Metrics are shared-nothing**: each worker process records raw
//! latency samples locally and ships them (plus shed counters) in
//! [`Frame::Stats`](frame::Frame) replies; the coordinator folds the
//! raw samples through
//! [`Metrics::merged_percentiles`](crate::engine::Metrics::merged_percentiles).
//! Percentiles are merged from pooled samples, **never averaged**.
//!
//! **Failure semantics match the in-process engine**: a dead worker
//! process resolves its in-flight tickets as `WorkerFailed` (after
//! reconnect-with-backoff is exhausted) and the engine keeps serving
//! on the surviving shards; a full shard queue sheds per the
//! configured [`AdmissionPolicy`](crate::engine::AdmissionPolicy).
//! `tests/remote_shard.rs` pins both, plus bitwise equality of a
//! multi-process engine against the sequential single-process
//! reference.
//!
//! ```no_run
//! # fn main() -> std::io::Result<()> {
//! use sobolnet::engine::{EngineBuilder, Response, SpawnSpec};
//!
//! // four worker shards, each its own OS process with a replica built
//! // from the same deterministic spec
//! let spec = SpawnSpec::with_args([
//!     "--sizes", "784,256,256,10", "--paths", "2048", "--seed", "1",
//! ]);
//! let engine = EngineBuilder::new().spawn_workers(4, spec)?.build_remote()?;
//! match engine.infer(vec![0.0; 784]) {
//!     Response::Logits(logits) => println!("{logits:?}"),
//!     Response::Rejected(reason) => eprintln!("rejected: {reason}"),
//! }
//! engine.shutdown(); // graceful: final stats fold + Shutdown frames
//! # Ok(())
//! # }
//! ```

pub mod client;
pub mod frame;
pub mod health;
pub mod server;
pub mod spawn;
pub mod transport;

pub use client::{publish_to, RemoteBackend, RemoteOptions};
pub use frame::{Frame, FrameError};
pub use health::{HealthBoard, HealthCounters, Prober};
pub use server::serve_shard;
pub use spawn::{spawn_shards, SpawnSpec, SpawnedShards};
pub use transport::{Addr, FaultCounts, FaultPlan, Listener, Stream};
