//! Socket transport of the multi-process engine: one address grammar
//! over Unix-domain and TCP sockets.
//!
//! Addresses are strings so they travel through config files and CLI
//! flags unchanged:
//!
//! * `unix:/path/to/shard.sock` — Unix-domain socket (the default for
//!   same-host sharding: lowest latency, filesystem permissions),
//! * `tcp:host:port` — TCP socket (cross-host sharding).
//!
//! [`Addr::listen`] yields a [`Listener`], [`Addr::connect`] a
//! [`Stream`]; both are thin enums over the std types so the frame
//! codec ([`super::frame`]) reads/writes one `impl Read + Write`
//! regardless of family.
//!
//! # Deterministic fault injection
//!
//! [`FaultPlan`] wraps a connected [`Stream`] in a deterministic
//! chaos layer ([`FaultPlan::wrap`]): every I/O operation rolls a
//! pseudo-random value derived purely from `(seed, connection index,
//! operation index)` — no wall clock, no OS entropy — so a fixed seed
//! replays the identical fault schedule on every run.  Plans come from
//! the `SOBOLNET_FAULTS` env var ([`FaultPlan::from_env`], read once)
//! or programmatically via `EngineBuilder::faults`; the spec grammar is
//! documented on [`FaultPlan::parse`].  `tests/chaos.rs` is the
//! consumer.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

/// A parsed shard-worker address.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Addr {
    /// Unix-domain socket path.
    Unix(PathBuf),
    /// TCP `host:port`.
    Tcp(String),
}

impl Addr {
    /// Parse `unix:/path` or `tcp:host:port`.
    pub fn parse(s: &str) -> Result<Addr, String> {
        if let Some(path) = s.strip_prefix("unix:") {
            if path.is_empty() {
                return Err(format!("empty unix socket path in '{s}'"));
            }
            Ok(Addr::Unix(PathBuf::from(path)))
        } else if let Some(hostport) = s.strip_prefix("tcp:") {
            if !hostport.contains(':') {
                return Err(format!("tcp address '{s}' must be tcp:host:port"));
            }
            Ok(Addr::Tcp(hostport.to_string()))
        } else {
            Err(format!("address '{s}' must start with unix: or tcp:"))
        }
    }

    /// Bind a listener at this address.  For Unix sockets a stale
    /// socket file from a previous run is removed first.
    pub fn listen(&self) -> std::io::Result<Listener> {
        match self {
            Addr::Unix(path) => {
                // a leftover socket file makes bind fail with AddrInUse
                // even though nothing is listening
                let _ = std::fs::remove_file(path);
                Ok(Listener::Unix(UnixListener::bind(path)?))
            }
            Addr::Tcp(hostport) => Ok(Listener::Tcp(TcpListener::bind(hostport.as_str())?)),
        }
    }

    /// One connection attempt (no retry — the caller owns backoff).
    /// TCP uses the OS default connect timeout; prefer
    /// [`Addr::connect_timeout`] anywhere a blackholed host must not
    /// stall the caller.
    pub fn connect(&self) -> std::io::Result<Stream> {
        match self {
            Addr::Unix(path) => Ok(Stream::Unix(UnixStream::connect(path)?)),
            Addr::Tcp(hostport) => Ok(Stream::Tcp(TcpStream::connect(hostport.as_str())?)),
        }
    }

    /// One connection attempt with a per-address TCP connect timeout —
    /// a SYN-blackholed host fails within `timeout` instead of the OS
    /// default (minutes).  Unix-domain connects complete or fail
    /// immediately, so the timeout only bounds TCP (name resolution,
    /// if any, still runs untimed before it).
    pub fn connect_timeout(&self, timeout: Duration) -> std::io::Result<Stream> {
        match self {
            Addr::Unix(path) => Ok(Stream::Unix(UnixStream::connect(path)?)),
            Addr::Tcp(hostport) => {
                use std::net::ToSocketAddrs;
                let mut last: Option<std::io::Error> = None;
                for sock_addr in hostport.as_str().to_socket_addrs()? {
                    match TcpStream::connect_timeout(&sock_addr, timeout) {
                        Ok(s) => return Ok(Stream::Tcp(s)),
                        Err(e) => last = Some(e),
                    }
                }
                Err(last.unwrap_or_else(|| {
                    std::io::Error::new(
                        std::io::ErrorKind::NotFound,
                        format!("no socket addresses for {hostport}"),
                    )
                }))
            }
        }
    }
}

impl std::fmt::Display for Addr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Addr::Unix(p) => write!(f, "unix:{}", p.display()),
            Addr::Tcp(hp) => write!(f, "tcp:{hp}"),
        }
    }
}

/// A bound server socket of either family.
pub enum Listener {
    /// Unix-domain listener.
    Unix(UnixListener),
    /// TCP listener.
    Tcp(TcpListener),
}

impl Listener {
    /// Block for the next inbound connection (or return `WouldBlock`
    /// immediately when the listener is nonblocking).
    pub fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Listener::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
        }
    }

    /// Toggle nonblocking accept; the concurrent worker serve loop
    /// polls accept so it can also watch its shutdown flag.
    pub fn set_nonblocking(&self, nonblocking: bool) -> std::io::Result<()> {
        match self {
            Listener::Unix(l) => l.set_nonblocking(nonblocking),
            Listener::Tcp(l) => l.set_nonblocking(nonblocking),
        }
    }
}

/// A connected socket of either family — or one wrapped in a
/// deterministic fault-injection layer ([`FaultPlan::wrap`]).
pub enum Stream {
    /// Unix-domain stream.
    Unix(UnixStream),
    /// TCP stream.
    Tcp(TcpStream),
    /// A stream with a [`FaultPlan`] interposed on every I/O op.
    Faulty(Box<FaultStream>),
}

impl Stream {
    /// Set (or clear) the read timeout; used by best-effort paths like
    /// the final stats poll at backend drop so they cannot hang.
    pub fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Unix(s) => s.set_read_timeout(d),
            Stream::Tcp(s) => s.set_read_timeout(d),
            Stream::Faulty(f) => f.set_read_timeout(d),
        }
    }

    /// Toggle nonblocking mode (accepted connections are returned to
    /// blocking mode by the serve loop).
    pub fn set_nonblocking(&self, nonblocking: bool) -> std::io::Result<()> {
        match self {
            Stream::Unix(s) => s.set_nonblocking(nonblocking),
            Stream::Tcp(s) => s.set_nonblocking(nonblocking),
            Stream::Faulty(f) => f.inner.set_nonblocking(nonblocking),
        }
    }

    fn shutdown_both(&self) {
        match self {
            Stream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            Stream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            Stream::Faulty(f) => f.inner.shutdown_both(),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
            Stream::Faulty(f) => f.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
            Stream::Faulty(f) => f.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
            Stream::Faulty(f) => f.flush(),
        }
    }
}

/// Injected-fault totals, for chaos-test assertions and log lines.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounts {
    /// Reads delayed (including delays converted into read timeouts).
    pub delays: u64,
    /// Whole frames swallowed on the write side.
    pub drops: u64,
    /// Connections severed mid-conversation.
    pub severs: u64,
    /// Frame headers corrupted on the write side.
    pub garbles: u64,
}

/// A seeded, deterministic connection-fault schedule.
///
/// Probabilities are rolled per I/O operation from a counter-based
/// hash of `(seed, connection index, operation index)` — two runs with
/// the same seed and the same I/O sequence inject the identical
/// faults.  Fault classes:
///
/// * **delay** — sleep before a read completes; if the stream has a
///   read timeout shorter than the injected delay, the read surfaces
///   the timeout (`WouldBlock`) exactly as a slow peer would.
/// * **drop** — swallow one entire outbound frame (write-side, gated
///   on the frame-magic write so framing never desyncs).  The peer
///   simply never sees the frame; recovery therefore requires a read
///   timeout or hedge deadline on the caller, as with any lost
///   message.
/// * **sever** — shut the socket down both ways mid-conversation;
///   subsequent ops fail with `ConnectionReset`/`BrokenPipe`.
/// * **garble** — corrupt an outbound **frame header** (flip a magic
///   byte).  The receiver detects it (`BadMagic`) and drops the
///   connection per the wire spec.  Payload bytes are never garbled:
///   the protocol carries no payload checksum, so undetectable
///   payload corruption would break the bitwise-determinism contract
///   rather than exercise recovery.
pub struct FaultPlan {
    seed: u64,
    delay_prob: f64,
    delay: Duration,
    drop_prob: f64,
    sever_prob: f64,
    garble_prob: f64,
    conn_seq: AtomicU64,
    delays: AtomicU64,
    drops: AtomicU64,
    severs: AtomicU64,
    garbles: AtomicU64,
}

impl std::fmt::Debug for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultPlan")
            .field("seed", &self.seed)
            .field("delay_prob", &self.delay_prob)
            .field("delay", &self.delay)
            .field("drop_prob", &self.drop_prob)
            .field("sever_prob", &self.sever_prob)
            .field("garble_prob", &self.garble_prob)
            .finish()
    }
}

impl FaultPlan {
    /// Parse a fault spec: comma-separated `key=value` pairs.
    ///
    /// * `seed=<u64>` — schedule seed (default 0)
    /// * `delay=<prob>x<ms>` — delay reads with probability `prob`
    ///   (e.g. `delay=0.25x100`: a quarter of reads stall 100 ms)
    /// * `drop=<prob>` — swallow outbound frames
    /// * `sever=<prob>` — cut the connection (per I/O op)
    /// * `garble=<prob>` — corrupt outbound frame headers
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan {
            seed: 0,
            delay_prob: 0.0,
            delay: Duration::ZERO,
            drop_prob: 0.0,
            sever_prob: 0.0,
            garble_prob: 0.0,
            conn_seq: AtomicU64::new(0),
            delays: AtomicU64::new(0),
            drops: AtomicU64::new(0),
            severs: AtomicU64::new(0),
            garbles: AtomicU64::new(0),
        };
        let parse_prob = |key: &str, v: &str| -> Result<f64, String> {
            let p: f64 =
                v.parse().map_err(|_| format!("fault spec: {key}={v} is not a probability"))?;
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("fault spec: {key}={v} must be in [0, 1]"));
            }
            Ok(p)
        };
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| format!("fault spec: '{part}' is not key=value"))?;
            match key {
                "seed" => {
                    plan.seed = value
                        .parse()
                        .map_err(|_| format!("fault spec: seed={value} is not a u64"))?;
                }
                "delay" => {
                    let (prob, ms) = value
                        .split_once('x')
                        .ok_or_else(|| format!("fault spec: delay={value} must be <prob>x<ms>"))?;
                    plan.delay_prob = parse_prob("delay", prob)?;
                    let ms: u64 = ms
                        .parse()
                        .map_err(|_| format!("fault spec: delay={value} has a bad ms count"))?;
                    plan.delay = Duration::from_millis(ms);
                }
                "drop" => plan.drop_prob = parse_prob("drop", value)?,
                "sever" => plan.sever_prob = parse_prob("sever", value)?,
                "garble" => plan.garble_prob = parse_prob("garble", value)?,
                other => return Err(format!("fault spec: unknown key '{other}'")),
            }
        }
        Ok(plan)
    }

    /// The process-wide plan from `SOBOLNET_FAULTS`, read and parsed
    /// once.  A malformed spec panics with the parse error — a chaos
    /// run with a typo'd spec silently running fault-free would defeat
    /// the test it was meant to power.
    pub fn from_env() -> Option<Arc<FaultPlan>> {
        static PLAN: OnceLock<Option<Arc<FaultPlan>>> = OnceLock::new();
        PLAN.get_or_init(|| match std::env::var("SOBOLNET_FAULTS") {
            Ok(spec) if !spec.trim().is_empty() => match FaultPlan::parse(&spec) {
                Ok(p) => Some(Arc::new(p)),
                Err(e) => panic!("invalid SOBOLNET_FAULTS: {e}"),
            },
            _ => None,
        })
        .clone()
    }

    /// Interpose this plan on a connected stream.  Each wrapped
    /// connection gets the next connection index, so a fresh plan plus
    /// a fixed connect/IO sequence replays identically.
    pub fn wrap(self: &Arc<Self>, inner: Stream) -> Stream {
        if matches!(inner, Stream::Faulty(_)) {
            return inner;
        }
        let conn = self.conn_seq.fetch_add(1, Ordering::Relaxed);
        Stream::Faulty(Box::new(FaultStream {
            inner,
            plan: Arc::clone(self),
            conn,
            op: 0,
            read_timeout: None,
            severed: false,
            dropping: false,
        }))
    }

    /// Injected-fault totals so far.
    pub fn counts(&self) -> FaultCounts {
        FaultCounts {
            delays: self.delays.load(Ordering::Relaxed),
            drops: self.drops.load(Ordering::Relaxed),
            severs: self.severs.load(Ordering::Relaxed),
            garbles: self.garbles.load(Ordering::Relaxed),
        }
    }

    /// The schedule seed (diagnostics).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Counter-based roll in `[0, 1)`: a pure function of
    /// `(seed, conn, op, salt)`.
    fn roll(&self, conn: u64, op: u64, salt: u64) -> f64 {
        let h = splitmix(splitmix(self.seed ^ salt) ^ splitmix(conn) ^ splitmix(op ^ 0xA5A5));
        (h >> 11) as f64 / (1u64 << 53) as f64
    }
}

fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

const SALT_DELAY: u64 = 0xD1;
const SALT_DROP: u64 = 0xD2;
const SALT_SEVER: u64 = 0xD3;
const SALT_GARBLE: u64 = 0xD4;

/// A [`Stream`] with a [`FaultPlan`] interposed.  Constructed only via
/// [`FaultPlan::wrap`].
pub struct FaultStream {
    inner: Stream,
    plan: Arc<FaultPlan>,
    conn: u64,
    op: u64,
    read_timeout: Option<Duration>,
    severed: bool,
    dropping: bool,
}

impl FaultStream {
    fn set_read_timeout(&mut self, d: Option<Duration>) -> std::io::Result<()> {
        self.read_timeout = d;
        self.inner.set_read_timeout(d)
    }

    fn next_op(&mut self) -> u64 {
        let op = self.op;
        self.op += 1;
        op
    }

    fn sever(&mut self) -> std::io::Error {
        self.severed = true;
        self.inner.shutdown_both();
        self.plan.severs.fetch_add(1, Ordering::Relaxed);
        std::io::Error::new(std::io::ErrorKind::ConnectionReset, "injected fault: severed")
    }

    fn severed_err(kind: std::io::ErrorKind) -> std::io::Error {
        std::io::Error::new(kind, "injected fault: connection severed")
    }
}

impl Read for FaultStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.severed {
            return Err(Self::severed_err(std::io::ErrorKind::ConnectionReset));
        }
        let op = self.next_op();
        if self.plan.roll(self.conn, op, SALT_SEVER) < self.plan.sever_prob {
            return Err(self.sever());
        }
        if self.plan.roll(self.conn, op, SALT_DELAY) < self.plan.delay_prob {
            self.plan.delays.fetch_add(1, Ordering::Relaxed);
            match self.read_timeout {
                // a delay past the caller's read timeout behaves like a
                // slow peer: sleep out the timeout, surface WouldBlock
                Some(t) if t <= self.plan.delay => {
                    std::thread::sleep(t);
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WouldBlock,
                        "injected fault: delayed past read timeout",
                    ));
                }
                _ => std::thread::sleep(self.plan.delay),
            }
        }
        self.inner.read(buf)
    }
}

impl Write for FaultStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if self.severed {
            return Err(Self::severed_err(std::io::ErrorKind::BrokenPipe));
        }
        if self.dropping {
            // swallowing the rest of a dropped frame; `flush` ends it
            return Ok(buf.len());
        }
        let op = self.next_op();
        if self.plan.roll(self.conn, op, SALT_SEVER) < self.plan.sever_prob {
            return Err(self.sever());
        }
        // drop/garble fire only on a frame-magic write so framing on
        // the wire never silently desyncs (see the FaultPlan docs)
        if buf == super::frame::MAGIC {
            if self.plan.roll(self.conn, op, SALT_DROP) < self.plan.drop_prob {
                self.plan.drops.fetch_add(1, Ordering::Relaxed);
                self.dropping = true;
                return Ok(buf.len());
            }
            if self.plan.roll(self.conn, op, SALT_GARBLE) < self.plan.garble_prob {
                self.plan.garbles.fetch_add(1, Ordering::Relaxed);
                let mut bad = [0u8; 4];
                bad.copy_from_slice(buf);
                bad[0] ^= 0xFF;
                self.inner.write_all(&bad)?;
                return Ok(buf.len());
            }
        }
        self.inner.write(buf)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        if self.severed {
            return Err(Self::severed_err(std::io::ErrorKind::BrokenPipe));
        }
        if self.dropping {
            self.dropping = false;
            return Ok(());
        }
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_grammar() {
        assert_eq!(
            Addr::parse("unix:/tmp/shard.sock"),
            Ok(Addr::Unix(PathBuf::from("/tmp/shard.sock")))
        );
        assert_eq!(Addr::parse("tcp:127.0.0.1:7070"), Ok(Addr::Tcp("127.0.0.1:7070".into())));
        assert!(Addr::parse("/tmp/bare-path").is_err());
        assert!(Addr::parse("udp:1.2.3.4:5").is_err());
        assert!(Addr::parse("unix:").is_err());
        assert!(Addr::parse("tcp:portless").is_err());
        let a = Addr::parse("unix:/tmp/x.sock").unwrap();
        assert_eq!(Addr::parse(&a.to_string()), Ok(a), "display round-trips through parse");
    }

    #[test]
    fn unix_listen_connect_roundtrip() {
        let path = std::env::temp_dir()
            .join(format!("sobolnet-transport-test-{}.sock", std::process::id()));
        let addr = Addr::Unix(path.clone());
        let listener = addr.listen().expect("bind");
        let server = std::thread::spawn(move || {
            let mut conn = listener.accept().expect("accept");
            let mut buf = [0u8; 4];
            conn.read_exact(&mut buf).expect("read");
            conn.write_all(&buf).expect("echo");
            conn.flush().expect("flush");
        });
        let mut client = addr.connect().expect("connect");
        client.write_all(b"ping").expect("send");
        client.flush().expect("flush");
        let mut echo = [0u8; 4];
        client.read_exact(&mut echo).expect("recv");
        assert_eq!(&echo, b"ping");
        server.join().expect("server thread");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn fault_spec_grammar() {
        let p = FaultPlan::parse("seed=42,delay=0.25x100,sever=0.01,garble=0.02,drop=0.05")
            .expect("full spec");
        assert_eq!(p.seed(), 42);
        assert_eq!(p.delay, Duration::from_millis(100));
        assert_eq!(p.delay_prob, 0.25);
        assert_eq!(p.drop_prob, 0.05);
        assert_eq!(p.sever_prob, 0.01);
        assert_eq!(p.garble_prob, 0.02);
        // every field is optional; empty spec is a no-op plan
        let p = FaultPlan::parse("").expect("empty spec");
        assert_eq!(p.seed(), 0);
        assert_eq!(p.delay_prob, 0.0);
        assert!(FaultPlan::parse("seed=notanumber").is_err());
        assert!(FaultPlan::parse("delay=0.5").is_err(), "delay needs <prob>x<ms>");
        assert!(FaultPlan::parse("drop=1.5").is_err(), "probability out of range");
        assert!(FaultPlan::parse("jitter=0.1").is_err(), "unknown key");
        assert!(FaultPlan::parse("seed").is_err(), "not key=value");
    }

    #[test]
    fn fault_rolls_are_deterministic_and_distinct() {
        let a = FaultPlan::parse("seed=7").unwrap();
        let b = FaultPlan::parse("seed=7").unwrap();
        let c = FaultPlan::parse("seed=8").unwrap();
        let mut same = 0;
        for conn in 0..4u64 {
            for op in 0..64u64 {
                let ra = a.roll(conn, op, SALT_DELAY);
                assert!((0.0..1.0).contains(&ra));
                assert_eq!(ra, b.roll(conn, op, SALT_DELAY), "same seed, same schedule");
                if ra == c.roll(conn, op, SALT_DELAY) {
                    same += 1;
                }
                assert_ne!(
                    a.roll(conn, op, SALT_DELAY),
                    a.roll(conn, op, SALT_SEVER),
                    "salts decorrelate fault classes"
                );
            }
        }
        assert!(same < 4, "different seeds give different schedules");
    }

    fn fault_pair(spec: &str) -> (Stream, Stream, Arc<FaultPlan>) {
        let (a, b) = UnixStream::pair().expect("socketpair");
        let plan = Arc::new(FaultPlan::parse(spec).unwrap());
        (plan.wrap(Stream::Unix(a)), Stream::Unix(b), plan)
    }

    #[test]
    fn dropped_frames_vanish_whole_and_are_counted() {
        use crate::engine::remote::frame::{read_frame, write_frame, Frame};
        // drop=1: every frame is swallowed at the magic write
        let (mut faulty, mut peer, plan) = fault_pair("drop=1");
        write_frame(&mut faulty, &Frame::Shutdown).expect("write side reports success");
        assert_eq!(plan.counts().drops, 1);
        // the peer never sees a byte: a short read timeout trips
        peer.set_read_timeout(Some(Duration::from_millis(30))).unwrap();
        assert!(read_frame(&mut peer).is_err(), "frame was swallowed");
        // a fresh drop=0 wrap of the same plan delivers normally
        let (mut ok, mut peer2, _plan2) = fault_pair("drop=0");
        write_frame(&mut ok, &Frame::Shutdown).expect("write");
        assert!(matches!(read_frame(&mut peer2), Ok(Frame::Shutdown)));
    }

    #[test]
    fn garbled_headers_surface_as_bad_magic() {
        use crate::engine::remote::frame::{read_frame, write_frame, Frame, FrameError};
        let (mut faulty, mut peer, plan) = fault_pair("garble=1");
        write_frame(&mut faulty, &Frame::Shutdown).expect("write completes");
        assert_eq!(plan.counts().garbles, 1);
        match read_frame(&mut peer) {
            Err(FrameError::BadMagic(_)) => {}
            other => panic!("expected BadMagic from a garbled header, got {other:?}"),
        }
    }

    #[test]
    fn severed_connections_fail_both_sides() {
        let (mut faulty, mut peer, plan) = fault_pair("sever=1");
        let err = faulty.write(b"SBN1").expect_err("first op severs");
        assert_eq!(err.kind(), std::io::ErrorKind::ConnectionReset);
        assert_eq!(plan.counts().severs, 1);
        // subsequent ops fail without touching the socket
        assert!(faulty.write(b"x").is_err());
        assert!(faulty.read(&mut [0u8; 1]).is_err());
        // the peer sees EOF, not a hang
        assert_eq!(peer.read(&mut [0u8; 8]).unwrap_or(0), 0);
    }

    #[test]
    fn delay_past_read_timeout_surfaces_would_block() {
        let (mut faulty, _peer, plan) = fault_pair("delay=1x10000");
        faulty.set_read_timeout(Some(Duration::from_millis(20))).unwrap();
        let start = std::time::Instant::now();
        let err = faulty.read(&mut [0u8; 1]).expect_err("delayed past timeout");
        assert_eq!(err.kind(), std::io::ErrorKind::WouldBlock);
        assert!(start.elapsed() < Duration::from_secs(2), "slept the timeout, not the delay");
        assert_eq!(plan.counts().delays, 1);
    }

    #[test]
    fn stale_unix_socket_file_is_replaced() {
        let path = std::env::temp_dir()
            .join(format!("sobolnet-transport-stale-{}.sock", std::process::id()));
        let addr = Addr::Unix(path.clone());
        drop(addr.listen().expect("first bind"));
        // the socket file lingers after the listener drops; a rebind
        // must succeed anyway
        let _second = addr.listen().expect("rebind over stale socket file");
        let _ = std::fs::remove_file(path);
    }
}
