//! Socket transport of the multi-process engine: one address grammar
//! over Unix-domain and TCP sockets.
//!
//! Addresses are strings so they travel through config files and CLI
//! flags unchanged:
//!
//! * `unix:/path/to/shard.sock` — Unix-domain socket (the default for
//!   same-host sharding: lowest latency, filesystem permissions),
//! * `tcp:host:port` — TCP socket (cross-host sharding).
//!
//! [`Addr::listen`] yields a [`Listener`], [`Addr::connect`] a
//! [`Stream`]; both are thin enums over the std types so the frame
//! codec ([`super::frame`]) reads/writes one `impl Read + Write`
//! regardless of family.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::time::Duration;

/// A parsed shard-worker address.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Addr {
    /// Unix-domain socket path.
    Unix(PathBuf),
    /// TCP `host:port`.
    Tcp(String),
}

impl Addr {
    /// Parse `unix:/path` or `tcp:host:port`.
    pub fn parse(s: &str) -> Result<Addr, String> {
        if let Some(path) = s.strip_prefix("unix:") {
            if path.is_empty() {
                return Err(format!("empty unix socket path in '{s}'"));
            }
            Ok(Addr::Unix(PathBuf::from(path)))
        } else if let Some(hostport) = s.strip_prefix("tcp:") {
            if !hostport.contains(':') {
                return Err(format!("tcp address '{s}' must be tcp:host:port"));
            }
            Ok(Addr::Tcp(hostport.to_string()))
        } else {
            Err(format!("address '{s}' must start with unix: or tcp:"))
        }
    }

    /// Bind a listener at this address.  For Unix sockets a stale
    /// socket file from a previous run is removed first.
    pub fn listen(&self) -> std::io::Result<Listener> {
        match self {
            Addr::Unix(path) => {
                // a leftover socket file makes bind fail with AddrInUse
                // even though nothing is listening
                let _ = std::fs::remove_file(path);
                Ok(Listener::Unix(UnixListener::bind(path)?))
            }
            Addr::Tcp(hostport) => Ok(Listener::Tcp(TcpListener::bind(hostport.as_str())?)),
        }
    }

    /// One connection attempt (no retry — the caller owns backoff).
    /// TCP uses the OS default connect timeout; prefer
    /// [`Addr::connect_timeout`] anywhere a blackholed host must not
    /// stall the caller.
    pub fn connect(&self) -> std::io::Result<Stream> {
        match self {
            Addr::Unix(path) => Ok(Stream::Unix(UnixStream::connect(path)?)),
            Addr::Tcp(hostport) => Ok(Stream::Tcp(TcpStream::connect(hostport.as_str())?)),
        }
    }

    /// One connection attempt with a per-address TCP connect timeout —
    /// a SYN-blackholed host fails within `timeout` instead of the OS
    /// default (minutes).  Unix-domain connects complete or fail
    /// immediately, so the timeout only bounds TCP (name resolution,
    /// if any, still runs untimed before it).
    pub fn connect_timeout(&self, timeout: Duration) -> std::io::Result<Stream> {
        match self {
            Addr::Unix(path) => Ok(Stream::Unix(UnixStream::connect(path)?)),
            Addr::Tcp(hostport) => {
                use std::net::ToSocketAddrs;
                let mut last: Option<std::io::Error> = None;
                for sock_addr in hostport.as_str().to_socket_addrs()? {
                    match TcpStream::connect_timeout(&sock_addr, timeout) {
                        Ok(s) => return Ok(Stream::Tcp(s)),
                        Err(e) => last = Some(e),
                    }
                }
                Err(last.unwrap_or_else(|| {
                    std::io::Error::new(
                        std::io::ErrorKind::NotFound,
                        format!("no socket addresses for {hostport}"),
                    )
                }))
            }
        }
    }
}

impl std::fmt::Display for Addr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Addr::Unix(p) => write!(f, "unix:{}", p.display()),
            Addr::Tcp(hp) => write!(f, "tcp:{hp}"),
        }
    }
}

/// A bound server socket of either family.
pub enum Listener {
    /// Unix-domain listener.
    Unix(UnixListener),
    /// TCP listener.
    Tcp(TcpListener),
}

impl Listener {
    /// Block for the next inbound connection.
    pub fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Listener::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
        }
    }
}

/// A connected socket of either family.
pub enum Stream {
    /// Unix-domain stream.
    Unix(UnixStream),
    /// TCP stream.
    Tcp(TcpStream),
}

impl Stream {
    /// Set (or clear) the read timeout; used by best-effort paths like
    /// the final stats poll at backend drop so they cannot hang.
    pub fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Unix(s) => s.set_read_timeout(d),
            Stream::Tcp(s) => s.set_read_timeout(d),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_grammar() {
        assert_eq!(
            Addr::parse("unix:/tmp/shard.sock"),
            Ok(Addr::Unix(PathBuf::from("/tmp/shard.sock")))
        );
        assert_eq!(Addr::parse("tcp:127.0.0.1:7070"), Ok(Addr::Tcp("127.0.0.1:7070".into())));
        assert!(Addr::parse("/tmp/bare-path").is_err());
        assert!(Addr::parse("udp:1.2.3.4:5").is_err());
        assert!(Addr::parse("unix:").is_err());
        assert!(Addr::parse("tcp:portless").is_err());
        let a = Addr::parse("unix:/tmp/x.sock").unwrap();
        assert_eq!(Addr::parse(&a.to_string()), Ok(a), "display round-trips through parse");
    }

    #[test]
    fn unix_listen_connect_roundtrip() {
        let path = std::env::temp_dir()
            .join(format!("sobolnet-transport-test-{}.sock", std::process::id()));
        let addr = Addr::Unix(path.clone());
        let listener = addr.listen().expect("bind");
        let server = std::thread::spawn(move || {
            let mut conn = listener.accept().expect("accept");
            let mut buf = [0u8; 4];
            conn.read_exact(&mut buf).expect("read");
            conn.write_all(&buf).expect("echo");
            conn.flush().expect("flush");
        });
        let mut client = addr.connect().expect("connect");
        client.write_all(b"ping").expect("send");
        client.flush().expect("flush");
        let mut echo = [0u8; 4];
        client.read_exact(&mut echo).expect("recv");
        assert_eq!(&echo, b"ping");
        server.join().expect("server thread");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn stale_unix_socket_file_is_replaced() {
        let path = std::env::temp_dir()
            .join(format!("sobolnet-transport-stale-{}.sock", std::process::id()));
        let addr = Addr::Unix(path.clone());
        drop(addr.listen().expect("first bind"));
        // the socket file lingers after the listener drops; a rebind
        // must succeed anyway
        let _second = addr.listen().expect("rebind over stale socket file");
        let _ = std::fs::remove_file(path);
    }
}
