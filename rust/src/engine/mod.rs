//! Unified serving engine (L3): one facade over admission, dispatch,
//! batching, and worker shards.
//!
//! This is the public contract of the serving layer.  The paper's case
//! for path-sparse networks is that they keep parallel hardware
//! saturated (contiguous weight blocks, permutation-based layer hops —
//! §3, §4.4); the engine makes *admission and routing* part of that
//! contract too, so the server can shed load and route around a slow
//! shard instead of queueing unboundedly.
//!
//! ```text
//! try_submit(x) ──► DispatchPolicy (round-robin │ least-loaded │ ewma-p99)
//!       │                │                     │
//!       ▼                ▼                     ▼
//!    Ticket          shard 0    …          shard N-1
//!   (wait /       ┌───────────┐         ┌───────────┐   each: own thread,
//!    wait_timeout)│ bounded   │         │ bounded   │   own backend built
//!                 │ queue ≤ Q │         │ queue ≤ Q │   on-thread via the
//!                 │ batcher   │         │ batcher   │   factory (non-`Send`
//!                 │ backend   │         │ backend   │   PJRT works)
//!                 │ metrics   │         │ metrics   │
//!                 └───────────┘         └───────────┘
//! ```
//!
//! **Admission** ([`AdmissionPolicy`]): every shard queue has a depth
//! bound; at the bound, `Block` parks the submitter, `ShedNewest`
//! rejects the new request with [`RejectReason::QueueFull`], and
//! `ShedOldest` admits it while evicting the oldest queued request
//! (its ticket resolves to `Response::Rejected(QueueFull)`).  Queue
//! depth is therefore an invariant, not a hope — the queues track a
//! high-watermark that `tests/engine_backpressure.rs` asserts.
//!
//! **Dispatch** ([`DispatchPolicy`]): a trait object, not an enum.
//! Built-ins: strict [`RoundRobin`], in-flight-gauge [`LeastLoaded`],
//! and the p99-aware [`EwmaLatency`] which learns per-shard tail
//! latency from completion feedback and routes around slow replicas.
//!
//! **Tickets** ([`Ticket`]): `try_submit` never blocks on a full queue
//! (unless the policy is `Block`); it returns a one-shot handle whose
//! payload is plain data — which is what lets the [`remote`] transport
//! carry the same contract across process boundaries.
//!
//! **Multi-process** ([`remote`]): `EngineBuilder::remote(addrs)` /
//! `spawn_workers(n, spec)` + `build_remote()` put each worker shard
//! in its own OS process behind a Unix/TCP socket, with dispatch,
//! admission, and backpressure unchanged; a shard whose process dies
//! resolves its tickets as [`RejectReason::WorkerFailed`] and the
//! admit path routes around it.  With
//! [`EngineBuilder::replicas`], shards form **replica groups** of
//! bitwise-interchangeable workers: slow exchanges hedge to a sibling,
//! hard failures fail over to one, a background prober marks dead
//! replicas down on the engine's [`HealthBoard`] so admission stops
//! routing into them, and the engine serves a group as long as one
//! replica lives.  The engine layering and the wire
//! format are specified normatively in `docs/ARCHITECTURE.md`.
//!
//! **Ensembles** ([`ensemble`]): `EngineBuilder::ensemble(n, mode)`
//! splits the shard list into N member-major blocks, each serving a
//! distinct-seed model derived from one base spec
//! ([`crate::registry::ModelSpec::member`] — the paper's cheap-replica
//! trick); one `try_submit` fans out across the members as concurrent
//! jobs and the ticket merges their logits in fixed member order
//! (mean or majority vote), optionally returning a K-of-N partial
//! merge when stragglers blow a p99-derived deadline
//! ([`EngineBuilder::quorum`]).
//!
//! **Determinism**: batching, padding, shard choice, and thread count
//! cannot change a single output bit — each batch column is processed
//! in exact path order by the sparse engine, so an admitted request's
//! logits are bitwise identical to a sequential single-worker
//! reference (`tests/engine_backpressure.rs`,
//! `tests/serve_concurrency.rs`).  This holds under *contended*
//! dispatch too: worker shards fan their forwards out through
//! [`crate::util::parallel`]'s multi-job pool, where K shards'
//! small-batch jobs interleave on the same worker threads instead of
//! queueing on a single job slot — chunk geometry and merge order are
//! job-local, so concurrency is invisible in the bits
//! (`tests/pool_contention.rs`).
//!
//! **Long-lived serving**: metrics sample storage is a fixed ring
//! ([`EngineBuilder::metrics_window`]) and every engine-internal lock
//! recovers from poisoning, so one panicking worker or client cannot
//! leak memory without bound or cascade `PoisonError` panics into the
//! other shards' submit paths.
//!
//! This module is the only serving surface: callers build engines
//! through [`EngineBuilder`] directly (the pre-engine `ShardedServer`
//! and `coordinator::server` compatibility shims are gone).

pub mod admission;
pub mod backend;
pub mod batcher;
pub mod dispatch;
pub mod ensemble;
pub mod remote;
pub mod ticket;
pub(crate) mod worker;

pub use admission::{AdmissionPolicy, BoundedQueue};
pub use backend::{InferenceBackend, ModelBackend};
pub use batcher::{BatchSource, Batcher};
pub use dispatch::{DispatchKind, DispatchPolicy, EwmaLatency, LeastLoaded, RoundRobin, ShardView};
pub use ensemble::{EnsembleMerger, EnsembleMode};
pub use remote::{
    FaultPlan, HealthBoard, HealthCounters, RemoteBackend, RemoteOptions, SpawnSpec, SpawnedShards,
};
pub use ticket::{RejectReason, Response, Ticket};

pub use crate::coordinator::metrics::Metrics;

use crate::registry::Registry;
use ensemble::EnsembleShared;
use std::cell::RefCell;
use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;
use ticket::ReplyTx;
use worker::{EngineRequest, Shard, Tenancy};

thread_local! {
    /// Reused per-thread scratch for the dispatch load snapshot, so the
    /// submit hot path performs no heap allocation for it.
    static VIEW_SCRATCH: RefCell<Vec<ShardView>> = RefCell::new(Vec::new());
}

enum DispatchChoice {
    Kind(DispatchKind),
    Custom(Arc<dyn DispatchPolicy>),
}

/// Composes topology/model/serving knobs into a running [`Engine`].
///
/// Absorbs what used to be scattered across the pre-engine serving
/// config, `main.rs serve` flags, and ad-hoc example code:
///
/// ```no_run
/// use sobolnet::engine::{AdmissionPolicy, DispatchKind, EngineBuilder};
/// # let model: sobolnet::nn::sparse::SparseMlp = todo!();
/// let engine = EngineBuilder::new()
///     .workers(4)
///     .batch(64)
///     .max_wait(std::time::Duration::from_millis(2))
///     .queue_depth(128)
///     .admission(AdmissionPolicy::ShedNewest)
///     .dispatch(DispatchKind::EwmaP99)
///     .build_model(model, 784, 10);
/// let ticket = engine.try_submit(vec![0.0; 784]).expect("admitted");
/// let response = ticket.wait();
/// ```
pub struct EngineBuilder {
    workers: usize,
    batch: usize,
    max_wait: Duration,
    queue_depth: usize,
    metrics_window: usize,
    admission: AdmissionPolicy,
    dispatch: DispatchChoice,
    remote_addrs: Vec<String>,
    remote_opts: RemoteOptions,
    replicas: usize,
    spawned: Option<SpawnedShards>,
    kernel: Option<crate::nn::kernel::KernelKind>,
    registry: Option<Arc<Registry>>,
    model_cache: usize,
    ensemble: usize,
    ensemble_mode: EnsembleMode,
    quorum: usize,
    quorum_deadline: Duration,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        EngineBuilder {
            workers: 1,
            batch: 64,
            max_wait: Duration::from_millis(2),
            queue_depth: 1024,
            metrics_window: crate::coordinator::metrics::DEFAULT_SAMPLE_WINDOW,
            admission: AdmissionPolicy::Block,
            dispatch: DispatchChoice::Kind(DispatchKind::LeastLoaded),
            remote_addrs: Vec::new(),
            remote_opts: RemoteOptions::default(),
            replicas: 1,
            spawned: None,
            kernel: None,
            registry: None,
            model_cache: 8,
            ensemble: 1,
            ensemble_mode: EnsembleMode::Mean,
            quorum: 0,
            quorum_deadline: Duration::from_millis(25),
        }
    }
}

impl EngineBuilder {
    /// New builder with defaults: 1 worker, batch 64, 2 ms max wait,
    /// queue depth 1024, `Block` admission, `LeastLoaded` dispatch.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of worker shards (each owns one backend instance).
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Batch capacity used by [`EngineBuilder::build_model`] backends.
    pub fn batch(mut self, capacity: usize) -> Self {
        self.batch = capacity.max(1);
        self
    }

    /// Max time a worker waits for a full batch before flushing.
    pub fn max_wait(mut self, d: Duration) -> Self {
        self.max_wait = d;
        self
    }

    /// Per-shard admission queue depth bound (`0` = unbounded).
    pub fn queue_depth(mut self, q: usize) -> Self {
        self.queue_depth = q;
        self
    }

    /// What happens when a request meets a full shard queue.
    pub fn admission(mut self, p: AdmissionPolicy) -> Self {
        self.admission = p;
        self
    }

    /// Max latency/batch-size samples each metrics registry retains
    /// (per worker shard, for the aggregate, and for remote-shard fold
    /// slots; clamped to ≥ 1).  Counters stay cumulative; sample
    /// storage is a ring, so a long-lived engine holds O(window)
    /// metrics memory no matter how many requests it serves.  Default:
    /// [`crate::coordinator::metrics::DEFAULT_SAMPLE_WINDOW`].
    pub fn metrics_window(mut self, window: usize) -> Self {
        self.metrics_window = window.max(1);
        self
    }

    /// Compute kernel applied to the model handed to
    /// [`EngineBuilder::build_model`] before it is replicated across
    /// workers ([`crate::nn::kernel`]: scalar golden reference,
    /// blocked SIMD, sign-only, int8).  Each kernel keeps logits
    /// bitwise thread-invariant, so replicas answer identically under
    /// any dispatch.  Remote shards pick theirs via the `shard-worker
    /// --kernel` flag instead; models that don't support kernels
    /// ignore this.
    pub fn kernel(mut self, kind: crate::nn::kernel::KernelKind) -> Self {
        self.kernel = Some(kind);
        self
    }

    /// Attach a multi-tenant model [`Registry`]: requests submitted
    /// with [`Engine::try_submit_model`] resolve their version against
    /// it **at admission**, worker shards cold-load tenant backends
    /// from it through their bounded per-shard LRU cache
    /// ([`EngineBuilder::model_cache`]), and
    /// [`Engine::publish`] appends new weight versions into it.  All
    /// tenant specs must match the engine's feature/class/batch shape
    /// (one batch buffer serves every tenant).  Without a registry the
    /// engine serves only the default model (`model_id` 0).
    pub fn registry(mut self, registry: Arc<Registry>) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Per-shard tenant cache bound: how many *built* tenant backends
    /// each worker shard keeps resident (LRU-evicted past the bound;
    /// clamped to ≥ 1; default 8).  Evictions/hits/misses land on the
    /// worker's [`Metrics`] counters.
    pub fn model_cache(mut self, cap: usize) -> Self {
        self.model_cache = cap.max(1);
        self
    }

    /// Serve an **N-member ensemble** behind one submit: the engine's
    /// shard list splits into N equal member-major blocks (member `m`
    /// owns shards `m·per .. (m+1)·per`), [`Engine::try_submit`] fans a
    /// request out across the members as concurrent jobs, and the
    /// ticket merges the member logits in **fixed member order** per
    /// `mode` — bitwise identical for any `SOBOLNET_THREADS`, any
    /// dispatch policy, and in-process vs remote members.  Build with
    /// [`EngineBuilder::build_ensemble`] (member models derived from a
    /// base [`crate::registry::ModelSpec`] via member-indexed seeds),
    /// with [`EngineBuilder::build_members`] (explicit member models),
    /// or over spawned processes via [`EngineBuilder::spawn_workers`] +
    /// [`EngineBuilder::build_remote`] (per-member child seeds).
    /// `n = 1` is the plain engine.
    pub fn ensemble(mut self, n: usize, mode: EnsembleMode) -> Self {
        self.ensemble = n.max(1);
        self.ensemble_mode = mode;
        self
    }

    /// **K-of-N partial quorum** for ensemble waits: once `k` members
    /// answered, stragglers get until a p99-derived deadline (measured
    /// from submit; see [`EngineBuilder::quorum_deadline`]), after
    /// which `Ticket::wait` returns the fixed-order merge of whatever
    /// arrived, annotated with `members_merged`.  `0` (the default)
    /// means full quorum — wait for every member, no deadline, fully
    /// deterministic.  Values clamp to `1..=n`.
    pub fn quorum(mut self, k: usize) -> Self {
        self.quorum = k;
        self
    }

    /// Floor (and cold-start value) of the quorum straggler deadline.
    /// Once enough member latencies are observed the deadline adapts to
    /// `max(floor, 2 × p99)` over an EWMA — the same rule the remote
    /// hedge uses.  Default 25 ms.
    pub fn quorum_deadline(mut self, d: Duration) -> Self {
        self.quorum_deadline = d;
        self
    }

    /// Use a named built-in dispatch policy.
    pub fn dispatch(mut self, kind: DispatchKind) -> Self {
        self.dispatch = DispatchChoice::Kind(kind);
        self
    }

    /// Plug in a custom [`DispatchPolicy`].
    pub fn dispatch_policy(mut self, policy: Arc<dyn DispatchPolicy>) -> Self {
        self.dispatch = DispatchChoice::Custom(policy);
        self
    }

    /// Apply the `serve` section of an experiment config file
    /// (including its `"remote"` subsection: pre-started shard
    /// addresses and the stats poll cadence; a configured `spawn`
    /// count is the CLI's job — it needs a [`SpawnSpec`] naming the
    /// model arguments).
    pub fn from_config(mut self, cfg: &crate::config::ServeSection) -> Self {
        self.workers = cfg.workers.max(1);
        self.batch = cfg.batch.max(1);
        self.max_wait = Duration::from_millis(cfg.max_wait_ms);
        self.queue_depth = cfg.queue_depth;
        self.admission = cfg.admission;
        self.dispatch = DispatchChoice::Kind(cfg.dispatch);
        self.replicas = cfg.replicas.max(1);
        self.ensemble = cfg.ensemble.max(1);
        self.ensemble_mode = cfg.ensemble_mode;
        self.quorum = cfg.quorum;
        // the registry *directory* is the CLI's job (it owns the IO and
        // the error reporting); the cache bound is pure config
        self.model_cache = cfg.model_cache.max(1);
        self.remote_opts.stats_every = cfg.remote.stats_every;
        self.remote_opts.connect_timeout = Duration::from_millis(cfg.remote.connect_timeout_ms);
        self.remote_opts.retry_attempts = cfg.remote.retry_attempts;
        self.remote_opts.retry_backoff = Duration::from_millis(cfg.remote.retry_backoff_ms);
        // 0 = disabled, for both optional cadences
        self.remote_opts.hedge_after = match cfg.remote.hedge_after_ms {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        };
        self.remote_opts.probe_interval = Duration::from_millis(cfg.remote.probe_interval_ms);
        if !cfg.remote.addrs.is_empty() {
            self.remote_addrs = cfg.remote.addrs.clone();
        }
        // `Auto` is the config default and resolves identically inside
        // the model, so only an explicit concrete choice overrides a
        // kernel already set on this builder
        if cfg.kernel != crate::nn::kernel::KernelKind::Auto {
            self.kernel = Some(cfg.kernel);
        }
        self
    }

    /// Use worker shards in **other processes**: one
    /// [`RemoteBackend`] per address (`unix:/path` or
    /// `tcp:host:port`), each expected to run `sobolnet shard-worker`.
    /// Finish with [`EngineBuilder::build_remote`]; the worker count
    /// is `addrs.len()`.
    pub fn remote<S: AsRef<str>>(mut self, addrs: &[S]) -> Self {
        self.remote_addrs = addrs.iter().map(|a| a.as_ref().to_string()).collect();
        self
    }

    /// Transport knobs of the remote path (connect timeout, reconnect
    /// backoff, stats poll cadence, hedge deadline, prober cadence).
    pub fn remote_options(mut self, opts: RemoteOptions) -> Self {
        self.remote_opts = opts;
        self
    }

    /// **Replica groups** (remote path): build every shard group out of
    /// `r` bitwise-interchangeable worker copies.  The physical shard
    /// list becomes `groups × r` addresses, laid out group-major
    /// (group *g* owns addresses `g·r .. g·r+r`); each backend learns
    /// its group siblings, so a hedge or hard failure re-fires the
    /// exchange at a sibling instead of burning the retry ladder, and
    /// the engine keeps serving a group as long as **one** replica
    /// lives.  Set this *before* [`EngineBuilder::spawn_workers`] (the
    /// spawn count is `groups × r`); with explicit
    /// [`EngineBuilder::remote`] addresses, their count must divide by
    /// `r`.  In-process engines don't need this knob — every worker
    /// already is a bitwise replica; just raise
    /// [`EngineBuilder::workers`].
    pub fn replicas(mut self, r: usize) -> Self {
        self.replicas = r.max(1);
        self
    }

    /// Inject a deterministic [`FaultPlan`] into every remote data
    /// connection this engine makes (chaos testing; equivalent to the
    /// `SOBOLNET_FAULTS` environment plan, but scoped to this engine
    /// with fresh counters).
    pub fn faults(mut self, plan: Arc<FaultPlan>) -> Self {
        self.remote_opts.faults = Some(plan);
        self
    }

    /// Spawn `n × replicas` `shard-worker` child processes per `spec` —
    /// `n` shard groups of [`EngineBuilder::replicas`] interchangeable
    /// copies each — and target them (the spawned handles live inside
    /// the built engine, which kills any survivor on drop).  With
    /// [`EngineBuilder::ensemble`]`(N, _)` this spawns `N × n ×
    /// replicas` children in member-major order: member `m`'s children
    /// build from `member_seed(base, m)` of the spec's `--seed` (the
    /// `shard-worker` default, 1, when absent), so each member block is
    /// a distinct-seed replica set of the same topology.  Finish with
    /// [`EngineBuilder::build_remote`].
    pub fn spawn_workers(mut self, n: usize, spec: SpawnSpec) -> std::io::Result<Self> {
        let members = self.ensemble.max(1);
        if members > 1 {
            let base = spec.seed_arg().unwrap_or(1);
            let mut all: Option<SpawnedShards> = None;
            for m in 0..members {
                let mspec = spec.with_seed(crate::registry::member_seed(base, m));
                let batch = remote::spawn_shards(n * self.replicas, &mspec)?;
                match all.as_mut() {
                    Some(a) => a.append(batch),
                    None => all = Some(batch),
                }
            }
            let shards = all.expect("members >= 1");
            self.remote_addrs = shards.addrs().to_vec();
            self.spawned = Some(shards);
            return Ok(self);
        }
        let shards = remote::spawn_shards(n * self.replicas, &spec)?;
        self.remote_addrs = shards.addrs().to_vec();
        self.spawned = Some(shards);
        Ok(self)
    }

    /// Start the engine; every worker builds its own backend by calling
    /// a clone of `factory` on its worker thread.
    pub fn build_with<F>(self, factory: F) -> Engine
    where
        F: Fn() -> Box<dyn InferenceBackend> + Clone + Send + 'static,
    {
        let n = self.workers;
        let factories: Vec<BackendFactory> = (0..n)
            .map(|_| {
                let f = factory.clone();
                Box::new(move || f()) as BackendFactory
            })
            .collect();
        self.build_each(factories)
    }

    /// Start the engine over replicas of a cloneable pure-rust model
    /// (each worker gets its own [`ModelBackend`] at the configured
    /// batch capacity).
    pub fn build_model<M>(self, mut model: M, features: usize, classes: usize) -> Engine
    where
        M: crate::nn::Model + Clone + Send + 'static,
    {
        if let Some(kind) = self.kernel {
            model.set_kernel(kind);
        }
        let capacity = self.batch;
        self.build_with(move || -> Box<dyn InferenceBackend> {
            Box::new(ModelBackend::new(model.clone(), capacity, features, classes))
        })
    }

    /// Start an **ensemble engine** over explicit member models, one
    /// entry per member in member-index order; each member is
    /// replicated across [`EngineBuilder::workers`] shards (total
    /// shards = `members × workers`, member-major).  Overrides any
    /// earlier member count from [`EngineBuilder::ensemble`] with
    /// `models.len()` (the mode and quorum knobs are kept).
    pub fn build_members<M>(self, models: Vec<M>, features: usize, classes: usize) -> Engine
    where
        M: crate::nn::Model + Clone + Send + 'static,
    {
        assert!(!models.is_empty(), "at least one ensemble member");
        let mut this = self;
        this.ensemble = models.len();
        let per = this.workers;
        let capacity = this.batch;
        let kernel = this.kernel;
        let mut factories: Vec<BackendFactory> = Vec::with_capacity(models.len() * per);
        for mut model in models {
            if let Some(kind) = kernel {
                model.set_kernel(kind);
            }
            for _ in 0..per {
                let m = model.clone();
                factories.push(Box::new(move || {
                    Box::new(ModelBackend::new(m, capacity, features, classes))
                        as Box<dyn InferenceBackend>
                }) as BackendFactory);
            }
        }
        this.build_each(factories)
    }

    /// Start the ensemble configured by [`EngineBuilder::ensemble`]
    /// from one base [`crate::registry::ModelSpec`]: member `m` builds
    /// `spec.member(m)` — identical sizes/paths/kernel, member-indexed
    /// init seed — so the members share topology and cost but answer
    /// with different weights (the paper's cheap-replica ensemble).
    pub fn build_ensemble(self, spec: &crate::registry::ModelSpec) -> Engine {
        let members = self.ensemble.max(1);
        let models: Vec<_> = (0..members).map(|m| spec.member(m).build()).collect();
        let (features, classes) = (spec.features(), spec.classes());
        self.build_members(models, features, classes)
    }

    /// Start the engine with one explicit factory per worker (the
    /// worker count is `factories.len()`); this is the `FnOnce` path
    /// for backends that cannot be built from a cloneable factory.
    /// With [`EngineBuilder::ensemble`]`(N, _)` the factory list must
    /// split into N equal member-major blocks (`factories.len() % N ==
    /// 0`): block `m` serves member `m`.
    pub fn build_each(self, factories: Vec<BackendFactory>) -> Engine {
        assert!(!factories.is_empty(), "at least one worker factory");
        let n = factories.len();
        let dispatch = match self.dispatch {
            DispatchChoice::Kind(kind) => kind.instantiate(n),
            DispatchChoice::Custom(policy) => policy,
        };
        let metrics = Arc::new(Metrics::with_window(self.metrics_window));
        let mut shards = Vec::with_capacity(n);
        // spawn every worker first so the backends construct
        // concurrently, then collect their metadata
        let mut metas = Vec::with_capacity(n);
        for (wid, factory) in factories.into_iter().enumerate() {
            let tenancy = self.registry.as_ref().map(|r| Tenancy {
                registry: Arc::clone(r),
                cache_cap: self.model_cache,
            });
            let (shard, meta_rx) = worker::spawn(
                wid,
                factory,
                self.max_wait,
                self.queue_depth,
                self.metrics_window,
                metrics.clone(),
                dispatch.clone(),
                tenancy,
            );
            shards.push(shard);
            metas.push(meta_rx);
        }
        let mut features: Option<usize> = None;
        let mut classes: Option<usize> = None;
        let mut batch: Option<usize> = None;
        for meta_rx in metas {
            let (feat, cls, cap) = meta_rx.recv().expect("backend constructed");
            match features {
                None => features = Some(feat),
                Some(prev) => assert_eq!(prev, feat, "workers disagree on feature count"),
            }
            match classes {
                None => classes = Some(cls),
                Some(prev) => assert_eq!(prev, cls, "workers disagree on class count"),
            }
            match batch {
                None => batch = Some(cap),
                Some(prev) => assert_eq!(prev, cap, "workers disagree on batch capacity"),
            }
        }
        let features = features.expect("at least one worker");
        let classes = classes.expect("at least one worker");
        let batch = batch.expect("at least one worker");
        let members = self.ensemble.max(1);
        assert!(
            members == 1 || n % members == 0,
            "{n} worker shards cannot split evenly across {members} ensemble members"
        );
        let ensemble = if members > 1 {
            let quorum = if self.quorum == 0 { members } else { self.quorum.min(members) };
            Some(Arc::new(EnsembleShared::new(
                self.ensemble_mode,
                members,
                quorum,
                self.quorum_deadline,
                classes,
            )))
        } else {
            None
        };
        Engine {
            shards,
            dispatch,
            admission: self.admission,
            metrics,
            features,
            classes,
            batch,
            health: HealthBoard::new(n),
            remote: None,
            registry: self.registry,
            ensemble,
        }
    }

    /// Start the engine over the configured **remote** worker shards
    /// (one [`RemoteBackend`] per address from
    /// [`EngineBuilder::remote`] or [`EngineBuilder::spawn_workers`]).
    /// Dispatch, admission, and backpressure are byte-for-byte the
    /// in-process machinery — only the backend crosses a process
    /// boundary.
    ///
    /// Every shard is pre-flighted with a bounded handshake first, so
    /// an unreachable worker or a spec mismatch across workers
    /// (different `--sizes`/`--batch`) returns a descriptive error
    /// naming the offending address instead of panicking mid-build.
    pub fn build_remote(mut self) -> std::io::Result<Engine> {
        assert!(
            !self.remote_addrs.is_empty(),
            "build_remote needs .remote(addrs) or .spawn_workers(n, spec)"
        );
        let addrs = std::mem::take(&mut self.remote_addrs);
        let spawned = self.spawned.take();
        let opts = self.remote_opts.clone();
        let replicas = self.replicas;
        // remote engines route tenant keys *through the wire* (the
        // worker process owns the tenant cache); local worker-side
        // tenancy would serve tenants in-process instead of remotely,
        // so the registry is held at the engine (admission-time version
        // resolution + publish source of truth) but NOT handed to the
        // coordinator-side worker threads
        let registry = self.registry.take();
        if addrs.len() % replicas != 0 {
            return Err(std::io::Error::other(format!(
                "{} remote addresses cannot form groups of {} replicas — the address count \
                 must be a multiple of .replicas(r)",
                addrs.len(),
                replicas
            )));
        }
        // ensemble layout is member-major: the address list must split
        // into equal member blocks, and each block into whole replica
        // groups — so no replica group (whose members are assumed
        // bitwise-interchangeable) ever straddles two ensemble members
        // (which answer with *different* bits by construction)
        let members = self.ensemble.max(1);
        if members > 1 {
            if addrs.len() % members != 0 {
                return Err(std::io::Error::other(format!(
                    "{} remote addresses cannot split across {} ensemble members evenly",
                    addrs.len(),
                    members
                )));
            }
            if (addrs.len() / members) % replicas != 0 {
                return Err(std::io::Error::other(format!(
                    "{} shards per ensemble member cannot form groups of {} replicas",
                    addrs.len() / members,
                    replicas
                )));
            }
        }
        // pre-flight: one bounded handshake per shard
        let mut parsed: Vec<remote::Addr> = Vec::with_capacity(addrs.len());
        let mut shapes: Vec<(usize, usize, usize)> = Vec::with_capacity(addrs.len());
        for addr_str in &addrs {
            let addr = remote::Addr::parse(addr_str).map_err(std::io::Error::other)?;
            let shape = RemoteBackend::probe(&addr, opts.connect_timeout)
                .map_err(|e| std::io::Error::other(format!("preflight {addr_str}: {e}")))?;
            parsed.push(addr);
            shapes.push(shape);
        }
        let first = shapes[0];
        for (i, shape) in shapes.iter().enumerate() {
            if *shape != first {
                return Err(std::io::Error::other(format!(
                    "remote shards disagree on model shape: {} serves {}→{} (batch {}) but {} \
                     serves {}→{} (batch {}) — start every shard-worker with identical \
                     --sizes/--paths/--seed/--epochs/--batch",
                    addrs[0], first.0, first.1, first.2, addrs[i], shape.0, shape.1, shape.2,
                )));
            }
        }
        // one coordinator-side metrics slot per remote shard: the
        // shard's stats frames fold into it, and the engine merges the
        // slots on read (raw samples, never averaged percentiles)
        let window = self.metrics_window;
        let slots: Vec<Arc<Metrics>> =
            addrs.iter().map(|_| Arc::new(Metrics::with_window(window))).collect();
        // one health board for the whole engine: the prober flips its
        // marks, the backends count hedges/failovers on it, and the
        // admit path filters on it
        let board = HealthBoard::new(addrs.len());
        let factories: Vec<BackendFactory> = addrs
            .iter()
            .zip(&slots)
            .enumerate()
            .map(|(i, (addr, slot))| {
                let addr = addr.clone();
                let slot = slot.clone();
                let opts = opts.clone();
                // replica-group siblings: the other addresses of this
                // shard's group (group-major layout), in fixed index
                // order so hedge/failover target order is reproducible
                let group = i / replicas;
                let siblings: Vec<String> = (group * replicas..(group + 1) * replicas)
                    .filter(|&j| j != i)
                    .map(|j| addrs[j].clone())
                    .collect();
                let board = Arc::clone(&board);
                Box::new(move || {
                    let backend = RemoteBackend::connect(&addr, opts, slot)
                        .and_then(|b| b.with_group(&siblings, board))
                        .unwrap_or_else(|e| panic!("remote shard: {e}"));
                    Box::new(backend) as Box<dyn InferenceBackend>
                }) as BackendFactory
            })
            .collect();
        let prober = if opts.probe_interval.is_zero() {
            None
        } else {
            // each probe exchange is short (no compute); bound it well
            // under the data-path timeouts so a wedged worker costs the
            // prober at most one slot per round
            let timeout = opts.probe_interval.clamp(
                Duration::from_millis(50),
                Duration::from_millis(500),
            );
            Some(remote::Prober::spawn(
                parsed,
                Arc::clone(&board),
                opts.probe_interval,
                timeout,
            ))
        };
        let mut engine = self.build_each(factories);
        engine.health = Arc::clone(&board);
        engine.registry = registry;
        engine.remote = Some(RemoteShards {
            metrics: slots,
            addrs,
            replicas,
            prober,
            opts,
            _spawned: spawned,
        });
        Ok(engine)
    }
}

/// A boxed one-shot backend constructor, run on the worker's thread.
pub type BackendFactory = Box<dyn FnOnce() -> Box<dyn InferenceBackend> + Send>;

/// Snapshot of one shard's load and lifetime counters.
#[derive(Debug, Clone)]
pub struct ShardStats {
    /// Requests dispatched and not yet answered.
    pub inflight: usize,
    /// Requests queued right now.
    pub queue_depth: usize,
    /// Highest queue depth ever observed (never exceeds the bound).
    pub max_queue_depth: usize,
    /// Requests this shard answered with logits.
    pub completed: u64,
    /// Requests shed at this shard's queue (rejected or evicted).
    pub shed: u64,
}

/// Snapshot of engine-wide counters plus per-shard detail.
#[derive(Debug, Clone)]
pub struct EngineStats {
    /// Submit attempts (admitted + shed).
    pub submitted: u64,
    /// Requests answered with logits.
    pub completed: u64,
    /// Requests shed by admission control (rejected new + evicted old).
    pub shed: u64,
    /// Per-shard snapshots, shard order.
    pub shards: Vec<ShardStats>,
}

/// Coordinator-side state of a multi-process engine: per-shard metric
/// slots the workers' stats frames fold into, plus ownership of any
/// spawned child processes (killed when the engine drops).
struct RemoteShards {
    metrics: Vec<Arc<Metrics>>,
    addrs: Vec<String>,
    /// Replicas per shard group (physical shards = groups × replicas).
    replicas: usize,
    /// Health-probe thread; stopped (joined) first in `Engine::stop`.
    prober: Option<remote::Prober>,
    /// Transport knobs, kept for publish connections (each publish
    /// dials a *fresh* connection per shard so it never interleaves
    /// with the strict request/response exchange stream).
    opts: RemoteOptions,
    /// Held for its `Drop` (kill + reap children); dropped after
    /// `stop()` has joined the workers, whose backends send each child
    /// a graceful `Shutdown` frame first.
    _spawned: Option<SpawnedShards>,
}

/// A running inference engine: worker shards behind backpressure-aware
/// admission and pluggable dispatch.  See the [module docs](self).
pub struct Engine {
    shards: Vec<Shard>,
    dispatch: Arc<dyn DispatchPolicy>,
    admission: AdmissionPolicy,
    /// Engine-wide aggregate counters (latency *samples* live in the
    /// per-worker metrics and are merged on read).
    pub metrics: Arc<Metrics>,
    features: usize,
    classes: usize,
    batch: usize,
    /// Per-shard liveness + hedge/failover counters.  In-process
    /// engines never mark anything down (their all-up board exists so
    /// the admit path has one code path); remote engines share this
    /// `Arc` with their backends and prober.
    health: Arc<HealthBoard>,
    remote: Option<RemoteShards>,
    /// Multi-tenant model registry, when attached
    /// ([`EngineBuilder::registry`]): admission resolves tenant
    /// versions against it, [`Engine::publish`] appends to it.
    registry: Option<Arc<Registry>>,
    /// Ensemble state ([`EngineBuilder::ensemble`]): merge mode and
    /// scratch, member/quorum geometry, latency EWMA behind the
    /// straggler deadline.  `None` = plain single-model engine.
    ensemble: Option<Arc<EnsembleShared>>,
}

impl Engine {
    /// Number of worker shards.
    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    /// Features per sample.
    pub fn features(&self) -> usize {
        self.features
    }

    /// Classes per sample.
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Batch capacity of the worker backends.
    pub fn batch_capacity(&self) -> usize {
        self.batch
    }

    /// `true` when the worker shards live in other processes.
    pub fn is_remote(&self) -> bool {
        self.remote.is_some()
    }

    /// Replicas per shard group (`1` unless the engine was built with
    /// [`EngineBuilder::replicas`]; the shard count is
    /// `groups × replicas`).
    pub fn replicas(&self) -> usize {
        self.remote.as_ref().map(|r| r.replicas).unwrap_or(1)
    }

    /// Ensemble member count (`1` = plain single-model engine; the
    /// shard count is `members × shards-per-member`).
    pub fn ensemble_members(&self) -> usize {
        self.ensemble.as_ref().map(|e| e.members).unwrap_or(1)
    }

    /// Merge mode, when this engine serves an ensemble.
    pub fn ensemble_mode(&self) -> Option<EnsembleMode> {
        self.ensemble.as_ref().map(|e| e.mode)
    }

    /// Effective quorum K (`members` when no partial quorum was
    /// configured), when this engine serves an ensemble.
    pub fn ensemble_quorum(&self) -> Option<usize> {
        self.ensemble.as_ref().map(|e| e.quorum)
    }

    /// Snapshot of the fault-tolerance counters: hedged and
    /// failed-over exchanges, prober up/down transitions, and the
    /// number of shards currently marked down.  All zero for an
    /// in-process engine.
    pub fn health_counters(&self) -> HealthCounters {
        self.health.snapshot()
    }

    /// Remote shard addresses (shard order), if this engine is
    /// multi-process.
    pub fn remote_addrs(&self) -> Option<&[String]> {
        self.remote.as_ref().map(|r| r.addrs.as_slice())
    }

    /// Per-remote-shard metric registries (shard order), if this
    /// engine is multi-process.  Each holds the **worker-process-side**
    /// raw latency samples and counters from the shard's latest stats
    /// frame; the `Arc`s stay valid after [`Engine::shutdown`], which
    /// performs a final fold.
    pub fn remote_shard_metrics(&self) -> Option<Vec<Arc<Metrics>>> {
        self.remote.as_ref().map(|r| r.metrics.clone())
    }

    /// Worker-process-side latency percentiles `(p50, p90, p99)` in
    /// seconds, computed over the **merged** raw samples from every
    /// remote shard's stats frames (never by averaging per-shard
    /// percentiles).  `None` for an in-process engine.
    pub fn remote_percentiles(&self) -> Option<(f64, f64, f64)> {
        self.remote
            .as_ref()
            .map(|r| Metrics::merged_percentiles(r.metrics.iter().map(|m| m.as_ref())))
    }

    /// Admission policy in force.
    pub fn admission(&self) -> AdmissionPolicy {
        self.admission
    }

    /// Name of the dispatch policy in force.
    pub fn dispatch_name(&self) -> &'static str {
        self.dispatch.name()
    }

    /// Attached model registry, if any.
    pub fn registry(&self) -> Option<&Arc<Registry>> {
        self.registry.as_ref()
    }

    /// Route `x` and enqueue it under the reply channel; the common
    /// path behind both the ticket API and the legacy `submit`.  The
    /// `(model_id, version)` key is already resolved — `(0, 0)` is the
    /// default model — and is carried to the worker verbatim.
    pub(crate) fn admit(
        &self,
        model_id: u64,
        version: u64,
        x: Vec<f32>,
        reply: ReplyTx,
    ) -> Result<usize, RejectReason> {
        self.admit_within(0, self.shards.len(), model_id, version, x, reply)
    }

    /// [`Engine::admit`] restricted to the `len` shards starting at
    /// `start` — the ensemble fan-out path, where member `m`'s job may
    /// only route into member `m`'s shard block (dispatch, the health
    /// fallback, and the failover scan all stay inside the block, so a
    /// member job can never be answered by a different member's model).
    fn admit_within(
        &self,
        start: usize,
        len: usize,
        model_id: u64,
        version: u64,
        x: Vec<f32>,
        reply: ReplyTx,
    ) -> Result<usize, RejectReason> {
        if x.len() != self.features {
            return Err(RejectReason::BadShape { expected: self.features, got: x.len() });
        }
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        // load snapshot in a reused thread-local buffer: closed flag,
        // inflight, and queue depth are all plain atomic loads, so a
        // submit costs no allocation and no shard-queue lock.  Dead
        // shards (closed queues) and shards the health board marks
        // down are filtered out *before* the policy picks, so
        // survivors share a dead shard's traffic per the policy
        // instead of it all spilling onto one neighbor; each view
        // carries its engine shard `id` so learning policies stay
        // keyed correctly on the filtered list.  Health marks only
        // *advise*: if they would empty the candidate list while open
        // queues remain (a prober false-negative, or every replica
        // flapping at once), admission falls back to the open queues —
        // the backends' own hedge/failover path still covers them.
        let picked = VIEW_SCRATCH.with(|scratch| {
            let mut views = scratch.borrow_mut();
            views.clear();
            let mut open_queues = 0usize;
            for (off, s) in self.shards[start..start + len].iter().enumerate() {
                let id = start + off;
                if s.queue.is_closed() {
                    continue;
                }
                open_queues += 1;
                if !self.health.is_up(id) {
                    continue;
                }
                views.push(ShardView {
                    id,
                    inflight: s.inflight.load(Ordering::Relaxed),
                    queue_depth: s.queue.depth(),
                });
            }
            if views.is_empty() && open_queues > 0 {
                for (off, s) in self.shards[start..start + len].iter().enumerate() {
                    let id = start + off;
                    if s.queue.is_closed() {
                        continue;
                    }
                    views.push(ShardView {
                        id,
                        inflight: s.inflight.load(Ordering::Relaxed),
                        queue_depth: s.queue.depth(),
                    });
                }
            }
            if views.is_empty() {
                None
            } else {
                let k = self.dispatch.pick(&views).min(views.len() - 1);
                Some(views[k].id)
            }
        });
        let idx = match picked {
            Some(i) => i,
            // every shard queue in range is closed: nothing can serve
            None => return Err(RejectReason::ShuttingDown),
        };
        let n = len;
        // failover scan: a *closed* shard queue means its worker is
        // gone (thread panicked, remote process died) — skip it and
        // route to the next live shard so the engine keeps serving on
        // the survivors.  A *full* queue is not failed over: that is
        // backpressure, and spilling would defeat the admission bound.
        let mut req = EngineRequest {
            x,
            model_id,
            version,
            reply,
            t_start: crate::util::timer::Timer::start(),
        };
        for k in 0..n {
            let i = start + ((idx - start) + k) % n;
            let shard = &self.shards[i];
            if shard.queue.is_closed() {
                continue;
            }
            shard.inflight.fetch_add(1, Ordering::Relaxed);
            match shard.queue.admit(req, self.admission) {
                admission::Admit::Admitted => {
                    shard.metrics.requests.fetch_add(1, Ordering::Relaxed);
                    return Ok(i);
                }
                admission::Admit::Evicted(old) => {
                    // the new request is in; the oldest queued one is shed
                    shard.metrics.requests.fetch_add(1, Ordering::Relaxed);
                    shard.inflight.fetch_sub(1, Ordering::Relaxed);
                    shard.metrics.shed.fetch_add(1, Ordering::Relaxed);
                    self.metrics.shed.fetch_add(1, Ordering::Relaxed);
                    old.reply.send_rejected(RejectReason::QueueFull);
                    return Ok(i);
                }
                admission::Admit::RejectedFull(_) => {
                    shard.inflight.fetch_sub(1, Ordering::Relaxed);
                    shard.metrics.shed.fetch_add(1, Ordering::Relaxed);
                    self.metrics.shed.fetch_add(1, Ordering::Relaxed);
                    return Err(RejectReason::QueueFull);
                }
                admission::Admit::RejectedClosed(r) => {
                    // closed between the check and the admit: recover
                    // the request and try the next shard
                    shard.inflight.fetch_sub(1, Ordering::Relaxed);
                    req = r;
                }
            }
        }
        Err(RejectReason::ShuttingDown)
    }

    /// Non-blocking request path (the `Block` admission policy may
    /// still park the caller at a full queue — that is its contract).
    /// `Err` means the request was never admitted; an `Ok` ticket
    /// resolves to logits, or to a rejection if the request is later
    /// evicted (`ShedOldest`) or its worker dies.  On an ensemble
    /// engine this fans the request out across every member's shard
    /// block as concurrent jobs; the ticket resolves to the
    /// fixed-member-order [`Response::Merged`].
    pub fn try_submit(&self, x: Vec<f32>) -> Result<Ticket, RejectReason> {
        if let Some(es) = &self.ensemble {
            return self.try_submit_ensemble(es, x);
        }
        let (tx, rx) = channel();
        let shard = self.admit(0, 0, x, ReplyTx::Ticket(tx))?;
        Ok(Ticket::single(rx, shard))
    }

    /// Ensemble fan-out: one member-tagged job per member, each
    /// restricted to that member's shard block.  A member whose
    /// admission fails outright is pre-resolved on the ticket (it
    /// degrades the quorum); the submit only errs when **no** member
    /// admits.
    fn try_submit_ensemble(
        &self,
        es: &Arc<EnsembleShared>,
        x: Vec<f32>,
    ) -> Result<Ticket, RejectReason> {
        let members = es.members;
        let per = self.shards.len() / members;
        let (tx, rx) = channel();
        let mut failed: Vec<(usize, RejectReason)> = Vec::new();
        let mut first_shard: Option<usize> = None;
        let mut last_err = RejectReason::ShuttingDown;
        for m in 0..members {
            let reply = ReplyTx::Member { tx: tx.clone(), member: m };
            match self.admit_within(m * per, per, 0, 0, x.clone(), reply) {
                Ok(shard) => {
                    if first_shard.is_none() {
                        first_shard = Some(shard);
                    }
                }
                Err(r) => {
                    last_err = r;
                    failed.push((m, r));
                }
            }
        }
        // drop the submit-side sender: once every admitted member's
        // worker answered (or died), the fan-in disconnects and the
        // ticket can prove no straggler is coming
        drop(tx);
        match first_shard {
            Some(shard) => Ok(Ticket::ensemble(rx, shard, Arc::clone(es), failed)),
            None => Err(last_err),
        }
    }

    /// Submit against a registered tenant model.  The model's **latest
    /// published version is resolved here, at admission** — the
    /// returned ticket is pinned to it, so a
    /// [`Engine::publish`] that lands after this call cannot change
    /// which weights answer it (in-flight requests always complete
    /// against the version they were admitted under).  Rejections:
    /// [`RejectReason::UnknownModel`] when no registry is attached, the
    /// id is unregistered, or it has no published version (detail
    /// `version` 0); [`RejectReason::BadShape`] when the tenant's spec
    /// doesn't match the engine's feature/class shape (all tenants of
    /// one engine share its batch buffer shape).
    ///
    /// On an ensemble engine, tenant requests (`model_id != 0`) route
    /// across **all** shards unrestricted and return a plain
    /// single-model ticket: a tenant snapshot resolves to identical
    /// bits on every shard regardless of member block, and "merging" N
    /// copies of the same logits would *change* the bits (`(x+x+x)/3 ≠
    /// x` in `f32`).  Only the default model (`model_id` 0) is served
    /// as an ensemble.
    pub fn try_submit_model(&self, model_id: u64, x: Vec<f32>) -> Result<Ticket, RejectReason> {
        if model_id == 0 {
            return self.try_submit(x);
        }
        let reg = self
            .registry
            .as_ref()
            .ok_or(RejectReason::UnknownModel { model_id, version: 0 })?;
        let spec =
            reg.spec(model_id).ok_or(RejectReason::UnknownModel { model_id, version: 0 })?;
        if spec.features() != self.features {
            return Err(RejectReason::BadShape {
                expected: self.features,
                got: spec.features(),
            });
        }
        if spec.classes() != self.classes {
            return Err(RejectReason::BadShape { expected: self.classes, got: spec.classes() });
        }
        let version = reg
            .latest_version(model_id)
            .ok_or(RejectReason::UnknownModel { model_id, version: 0 })?;
        self.try_submit_pinned(model_id, version, x)
    }

    /// Submit against an **explicit** `(model_id, version)` without
    /// resolving the latest version.  This is the replay path of the
    /// remote server: a coordinator already pinned the version at its
    /// own admission, and the worker process must honor that pin — a
    /// publish between the coordinator's admit and this call must not
    /// upgrade the request.  An unknown key is rejected by the worker
    /// shard (cold-load failure), not here, so the reject carries
    /// exactly what the shard knows.
    pub fn try_submit_pinned(
        &self,
        model_id: u64,
        version: u64,
        x: Vec<f32>,
    ) -> Result<Ticket, RejectReason> {
        let (tx, rx) = channel();
        let shard = self.admit(model_id, version, x, ReplyTx::Ticket(tx))?;
        Ok(Ticket::single(rx, shard))
    }

    /// Convenience: submit and wait for the outcome.
    pub fn infer(&self, x: Vec<f32>) -> Response {
        match self.try_submit(x) {
            Ok(ticket) => ticket.wait(),
            Err(reason) => Response::Rejected(reason),
        }
    }

    /// Convenience: submit against a tenant model and wait.
    pub fn infer_model(&self, model_id: u64, x: Vec<f32>) -> Response {
        match self.try_submit_model(model_id, x) {
            Ok(ticket) => ticket.wait(),
            Err(reason) => Response::Rejected(reason),
        }
    }

    /// **Hot snapshot publish**: append `(w, bias)` as the next version
    /// of `model_id` and make it live without dropping or corrupting
    /// in-flight traffic.  Returns the new version number.
    ///
    /// Ordering is the whole contract:
    ///
    /// 1. the new version is pushed to every **remote** worker process
    ///    first (fresh connection per shard — never interleaved with
    ///    the request/response exchange stream), so no worker can be
    ///    asked for a version it has never heard of;
    /// 2. only then is it committed to the engine's local registry,
    ///    which is the instant [`Engine::try_submit_model`] starts
    ///    resolving to it.
    ///
    /// Tickets admitted before the commit carry their old pinned
    /// version and complete bitwise-identically against it (worker
    /// caches key by `(model_id, version)`; snapshots are immutable).
    /// If a remote push fails the publish returns an error and is
    /// **not** committed — already-pushed shards merely hold an unused
    /// version that admission never resolves to.
    pub fn publish(
        &self,
        model_id: u64,
        w: Vec<Vec<f32>>,
        bias: Vec<Vec<f32>>,
    ) -> Result<u64, String> {
        let reg = self.registry.as_ref().ok_or_else(|| {
            "engine has no registry attached (EngineBuilder::registry)".to_string()
        })?;
        let spec = reg
            .spec(model_id)
            .ok_or_else(|| format!("model {model_id} is not registered"))?;
        spec.validate_weights(&w, &bias)?;
        let version = reg.latest_version(model_id).map_or(1, |v| v + 1);
        if let Some(r) = &self.remote {
            let snap = crate::registry::Snapshot {
                version,
                w: w.clone(),
                bias: bias.clone(),
            };
            for addr in &r.addrs {
                remote::publish_to(addr, &r.opts, model_id, &spec, &snap)
                    .map_err(|e| format!("publish v{version} to {addr}: {e}"))?;
            }
        }
        reg.publish_at(model_id, version, w, bias)?;
        Ok(version)
    }

    /// Per-worker metrics, shard order.
    pub fn worker_metrics(&self) -> Vec<Arc<Metrics>> {
        self.shards.iter().map(|s| s.metrics.clone()).collect()
    }

    /// Engine-wide latency percentiles `(p50, p90, p99)` in seconds,
    /// computed over the **merged** per-worker latency samples (never
    /// by averaging per-worker percentiles — that is not a percentile).
    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        Metrics::merged_percentiles(self.shards.iter().map(|s| s.metrics.as_ref()))
    }

    /// Snapshot of counters and per-shard load.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            submitted: self.metrics.requests.load(Ordering::Relaxed),
            completed: self.metrics.completed.load(Ordering::Relaxed),
            shed: self.metrics.shed.load(Ordering::Relaxed),
            shards: self
                .shards
                .iter()
                .map(|s| ShardStats {
                    inflight: s.inflight.load(Ordering::Relaxed),
                    queue_depth: s.queue.depth(),
                    max_queue_depth: s.queue.max_depth(),
                    completed: s.metrics.completed.load(Ordering::Relaxed),
                    shed: s.metrics.shed.load(Ordering::Relaxed),
                })
                .collect(),
        }
    }

    /// Multi-line report: aggregate summary plus one line per shard.
    pub fn report(&self) -> String {
        let (p50, p90, p99) = self.latency_percentiles();
        let stats = self.stats();
        let mut out = format!(
            "engine ({} workers, dispatch={}, admission={}): requests={} completed={} \
             shed={} batches={} mean_batch={:.1} | p50={:.3}ms p90={:.3}ms p99={:.3}ms",
            self.shards.len(),
            self.dispatch.name(),
            self.admission.as_str(),
            stats.submitted,
            stats.completed,
            stats.shed,
            self.metrics.batches.load(Ordering::Relaxed),
            self.metrics.mean_batch_size(),
            p50 * 1e3,
            p90 * 1e3,
            p99 * 1e3,
        );
        if let Some(e) = &self.ensemble {
            out.push_str(&format!(
                "\n  ensemble: members={} mode={} quorum={} merges={} partial_merges={}",
                e.members,
                e.mode,
                e.quorum,
                e.merges.load(Ordering::Relaxed),
                e.partial_merges.load(Ordering::Relaxed),
            ));
        }
        for (i, (s, st)) in self.shards.iter().zip(&stats.shards).enumerate() {
            // the summary line already carries this shard's shed counter
            out.push_str(&format!(
                "\n  worker {i}: {} max_depth={}",
                s.metrics.summary(),
                st.max_queue_depth
            ));
        }
        if let Some(r) = &self.remote {
            let h = self.health.snapshot();
            out.push_str(&format!(
                "\n  fault tolerance: replicas={} hedges={} failovers={} marks_down={} \
                 marks_up={} down_now={}",
                r.replicas, h.hedges, h.failovers, h.marks_down, h.marks_up, h.down_now
            ));
            // worker-process-side view, folded from stats frames (the
            // lines above measure coordinator-side end-to-end latency).
            // Printed field-by-field rather than via `summary()`: the
            // fold carries completed/shed/batches + raw samples, and a
            // summary line must not show unfolded fields as zeros.
            for (i, (m, addr)) in r.metrics.iter().zip(&r.addrs).enumerate() {
                let (p50, p90, p99) = m.latency_percentiles();
                let completed = m.completed.load(Ordering::Relaxed);
                let batches = m.batches.load(Ordering::Relaxed);
                let mean_batch =
                    if batches == 0 { 0.0 } else { completed as f64 / batches as f64 };
                out.push_str(&format!(
                    "\n  remote shard {i} ({addr}): completed={completed} shed={} \
                     batches={batches} mean_batch={mean_batch:.1} \
                     p50={:.3}ms p90={:.3}ms p99={:.3}ms",
                    m.shed.load(Ordering::Relaxed),
                    p50 * 1e3,
                    p90 * 1e3,
                    p99 * 1e3,
                ));
            }
        }
        out
    }

    fn stop(&mut self) {
        // prober first: it must not dial workers that are shutting
        // down and flap the board while backends run their closing
        // handshakes
        if let Some(r) = self.remote.as_mut() {
            if let Some(p) = r.prober.as_mut() {
                p.stop();
            }
        }
        for s in self.shards.iter() {
            s.queue.close();
        }
        for s in self.shards.iter_mut() {
            if let Some(j) = s.join.take() {
                let _ = j.join();
            }
        }
    }

    /// Graceful shutdown: closes every shard queue (blocked submitters
    /// get `ShuttingDown`), drains in-flight work, joins the workers.
    pub fn shutdown(mut self) {
        self.stop();
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// Backend that sums features into class 0, optionally slowly.
    struct Echo {
        calls: Arc<AtomicUsize>,
        delay: Duration,
    }

    impl Echo {
        fn factory(
            calls: Arc<AtomicUsize>,
            delay: Duration,
        ) -> impl Fn() -> Box<dyn InferenceBackend> + Clone + Send + 'static {
            move || Box::new(Echo { calls: calls.clone(), delay }) as Box<dyn InferenceBackend>
        }
    }

    impl InferenceBackend for Echo {
        fn batch_capacity(&self) -> usize {
            4
        }
        fn features(&self) -> usize {
            3
        }
        fn classes(&self) -> usize {
            2
        }
        fn infer_batch(&mut self, x: &[f32]) -> Vec<f32> {
            if !self.delay.is_zero() {
                std::thread::sleep(self.delay);
            }
            self.calls.fetch_add(1, Ordering::Relaxed);
            let mut out = vec![0.0; 4 * 2];
            for i in 0..4 {
                out[i * 2] = x[i * 3] + x[i * 3 + 1] + x[i * 3 + 2];
                out[i * 2 + 1] = -1.0;
            }
            out
        }
    }

    fn quick_engine(workers: usize) -> Engine {
        EngineBuilder::new()
            .workers(workers)
            .max_wait(Duration::from_millis(1))
            .build_with(Echo::factory(Arc::new(AtomicUsize::new(0)), Duration::ZERO))
    }

    #[test]
    fn ticket_roundtrip() {
        let eng = quick_engine(1);
        assert_eq!(eng.features(), 3);
        assert_eq!(eng.classes(), 2);
        let t = eng.try_submit(vec![1.0, 2.0, 3.0]).expect("admitted");
        assert_eq!(t.wait(), Response::Logits(vec![6.0, -1.0]));
        let (p50, _, p99) = eng.latency_percentiles();
        assert!(p50 > 0.0 && p99 >= p50, "merged percentiles populated");
        let stats = eng.stats();
        assert_eq!((stats.submitted, stats.completed, stats.shed), (1, 1, 0));
        eng.shutdown();
    }

    #[test]
    fn bad_shape_is_rejected_immediately() {
        let eng = quick_engine(1);
        match eng.try_submit(vec![1.0]) {
            Err(RejectReason::BadShape { expected: 3, got: 1 }) => {}
            other => panic!("expected BadShape, got {:?}", other.map(|_| "ticket")),
        }
    }

    #[test]
    fn infer_convenience_matches_ticket_path() {
        let eng = quick_engine(2);
        for i in 0..8 {
            let x = vec![i as f32, 1.0, 0.0];
            assert_eq!(eng.infer(x), Response::Logits(vec![i as f32 + 1.0, -1.0]));
        }
        assert_eq!(eng.stats().completed, 8);
    }

    #[test]
    fn ensemble_engine_fans_out_and_merges() {
        let eng = EngineBuilder::new()
            .workers(2) // total shards: 2 members × 1 shard each
            .max_wait(Duration::from_millis(1))
            .ensemble(2, EnsembleMode::Mean)
            .build_with(Echo::factory(Arc::new(AtomicUsize::new(0)), Duration::ZERO));
        assert_eq!(eng.ensemble_members(), 2);
        assert_eq!(eng.ensemble_mode(), Some(EnsembleMode::Mean));
        assert_eq!(eng.ensemble_quorum(), Some(2), "quorum 0 defaults to full");
        let t = eng.try_submit(vec![1.0, 2.0, 3.0]).expect("admitted");
        match t.wait() {
            Response::Merged { logits, members_merged } => {
                assert_eq!(members_merged, 2);
                // both Echo members answer [6, -1]; (x + x) / 2 is exact
                assert_eq!(logits, vec![6.0, -1.0]);
            }
            other => panic!("expected merged response, got {other:?}"),
        }
        assert!(eng.report().contains("ensemble: members=2 mode=mean quorum=2"));
        eng.shutdown();
    }

    #[test]
    fn shed_newest_rejects_past_the_bound() {
        // one slow worker, queue bound 2, capacity-4 batches
        let eng = EngineBuilder::new()
            .workers(1)
            .queue_depth(2)
            .admission(AdmissionPolicy::ShedNewest)
            .max_wait(Duration::from_millis(1))
            .build_with(Echo::factory(Arc::new(AtomicUsize::new(0)), Duration::from_millis(20)));
        let mut tickets = Vec::new();
        let mut rejected = 0usize;
        for i in 0..32 {
            match eng.try_submit(vec![i as f32, 0.0, 0.0]) {
                Ok(t) => tickets.push((i, t)),
                Err(RejectReason::QueueFull) => rejected += 1,
                Err(other) => panic!("unexpected rejection {other:?}"),
            }
        }
        assert!(rejected > 0, "32 rapid submits at a 2-deep queue must shed");
        let stats = eng.stats();
        assert_eq!(stats.shed, rejected as u64);
        assert!(stats.shards[0].max_queue_depth <= 2, "bound held");
        for (i, t) in tickets {
            assert_eq!(
                t.wait(),
                Response::Logits(vec![i as f32, -1.0]),
                "admitted request {i} served correctly"
            );
        }
        eng.shutdown();
    }

    #[test]
    fn shed_oldest_evicts_and_resolves_old_ticket() {
        let eng = EngineBuilder::new()
            .workers(1)
            .queue_depth(1)
            .admission(AdmissionPolicy::ShedOldest)
            .max_wait(Duration::from_millis(1))
            .build_with(Echo::factory(Arc::new(AtomicUsize::new(0)), Duration::from_millis(30)));
        // first request occupies the worker; then overfill the 1-deep queue
        let mut tickets = Vec::new();
        for i in 0..6 {
            tickets.push(eng.try_submit(vec![i as f32, 0.0, 0.0]).expect("shed-oldest admits"));
        }
        let outcomes: Vec<Response> = tickets.into_iter().map(|t| t.wait()).collect();
        let served = outcomes.iter().filter(|r| matches!(r, Response::Logits(_))).count();
        let evicted = outcomes
            .iter()
            .filter(|r| matches!(r, Response::Rejected(RejectReason::QueueFull)))
            .count();
        assert_eq!(served + evicted, 6);
        assert!(evicted > 0, "overfilling a 1-deep shed-oldest queue evicts");
        assert_eq!(eng.stats().shed, evicted as u64);
        // the newest request always survives eviction
        assert!(
            matches!(outcomes.last().unwrap(), Response::Logits(_)),
            "newest request is never the eviction victim"
        );
        eng.shutdown();
    }

    #[test]
    fn builder_round_robin_spreads_exactly() {
        let eng = EngineBuilder::new()
            .workers(3)
            .dispatch(DispatchKind::RoundRobin)
            .max_wait(Duration::from_micros(200))
            .build_with(Echo::factory(Arc::new(AtomicUsize::new(0)), Duration::ZERO));
        for i in 0..12 {
            assert_eq!(
                eng.infer(vec![i as f32, 1.0, 0.0]),
                Response::Logits(vec![i as f32 + 1.0, -1.0])
            );
        }
        for (i, m) in eng.worker_metrics().iter().enumerate() {
            assert_eq!(m.completed.load(Ordering::Relaxed), 4, "worker {i}");
        }
        eng.shutdown();
    }

    #[test]
    fn worker_panic_resolves_queued_tickets_instead_of_hanging() {
        /// Backend whose every inference panics.
        struct Bomb;
        impl InferenceBackend for Bomb {
            fn batch_capacity(&self) -> usize {
                1
            }
            fn features(&self) -> usize {
                1
            }
            fn classes(&self) -> usize {
                1
            }
            fn infer_batch(&mut self, _x: &[f32]) -> Vec<f32> {
                panic!("backend exploded (expected in this test)");
            }
        }
        let eng = EngineBuilder::new()
            .workers(1)
            .queue_depth(8)
            .max_wait(Duration::from_millis(1))
            .build_with(|| Box::new(Bomb) as Box<dyn InferenceBackend>);
        // burst several requests: the first batch dies mid-inference,
        // the rest are drained by the worker's queue guard
        let tickets: Vec<_> = (0..6).filter_map(|_| eng.try_submit(vec![0.5]).ok()).collect();
        assert!(!tickets.is_empty(), "at least the first submit is admitted");
        for (i, t) in tickets.into_iter().enumerate() {
            // the contract: resolve (to WorkerFailed), never hang
            match t.wait_timeout(Duration::from_secs(10)) {
                Some(Response::Rejected(RejectReason::WorkerFailed)) => {}
                other => panic!("ticket {i}: expected WorkerFailed, got {other:?}"),
            }
        }
        // the dead shard's queue is closed: new submits are refused
        match eng.infer(vec![0.5]) {
            Response::Rejected(RejectReason::ShuttingDown | RejectReason::WorkerFailed) => {}
            other => panic!("expected rejection from dead shard, got {other:?}"),
        }
    }

    #[test]
    fn dead_shard_is_routed_around() {
        /// Same shape as `Echo`, but every inference panics.
        struct Bomb3;
        impl InferenceBackend for Bomb3 {
            fn batch_capacity(&self) -> usize {
                4
            }
            fn features(&self) -> usize {
                3
            }
            fn classes(&self) -> usize {
                2
            }
            fn infer_batch(&mut self, _x: &[f32]) -> Vec<f32> {
                panic!("backend exploded (expected in this test)");
            }
        }
        let healthy = Echo::factory(Arc::new(AtomicUsize::new(0)), Duration::ZERO);
        let factories: Vec<BackendFactory> = vec![
            Box::new(move || healthy()),
            Box::new(|| Box::new(Bomb3) as Box<dyn InferenceBackend>),
        ];
        let eng = EngineBuilder::new()
            .max_wait(Duration::from_millis(1))
            .dispatch(DispatchKind::RoundRobin)
            .build_each(factories);
        assert_eq!(eng.workers(), 2);
        // requests that land on the bomb shard before its queue closes
        // resolve to WorkerFailed; once it is closed the admit path
        // must skip it, so sustained traffic converges on all-served
        let mut consecutive_ok = 0;
        for i in 0..500 {
            match eng.infer(vec![i as f32, 1.0, 0.0]) {
                Response::Logits(l) => {
                    assert_eq!(l, vec![i as f32 + 1.0, -1.0], "served bitwise-correct");
                    consecutive_ok += 1;
                    if consecutive_ok >= 16 {
                        break;
                    }
                }
                Response::Rejected(
                    RejectReason::WorkerFailed | RejectReason::ShuttingDown,
                ) => {
                    consecutive_ok = 0;
                    std::thread::sleep(Duration::from_millis(1));
                }
                other => panic!("unexpected outcome {other:?}"),
            }
        }
        assert!(
            consecutive_ok >= 16,
            "engine must keep serving on the surviving shard after a worker death"
        );
        eng.shutdown();
    }

    /// A client thread that panics while holding a [`Ticket`] must not
    /// take the engine down: its reply channel just closes, and every
    /// later `try_submit` keeps working.
    #[test]
    fn panicked_ticket_holder_does_not_take_down_later_submits() {
        let eng = Arc::new(quick_engine(2));
        let e2 = eng.clone();
        let holder = std::thread::spawn(move || {
            let _ticket = e2.try_submit(vec![1.0, 1.0, 1.0]).expect("admitted");
            panic!("ticket holder dies (expected in this test)");
        });
        assert!(holder.join().is_err(), "holder really panicked");
        for i in 0..8 {
            let t = eng.try_submit(vec![i as f32, 1.0, 0.0]).expect("submit after panic");
            assert_eq!(t.wait(), Response::Logits(vec![i as f32 + 1.0, -1.0]));
        }
    }

    /// A dispatch policy that panics inside `pick` fails that one
    /// submit, not the engine: the submit path holds no engine lock
    /// across `pick`, so nothing is poisoned and subsequent
    /// `try_submit` calls (same thread and others) still serve.
    #[test]
    fn panicking_dispatch_policy_does_not_poison_submit_path() {
        struct PanicOnce {
            armed: std::sync::atomic::AtomicBool,
            inner: RoundRobin,
        }
        impl DispatchPolicy for PanicOnce {
            fn pick(&self, views: &[ShardView]) -> usize {
                if self.armed.swap(false, Ordering::SeqCst) {
                    panic!("policy exploded (expected in this test)");
                }
                self.inner.pick(views)
            }
            fn name(&self) -> &'static str {
                "panic-once"
            }
        }
        let eng = EngineBuilder::new()
            .workers(2)
            .max_wait(Duration::from_millis(1))
            .dispatch_policy(Arc::new(PanicOnce {
                armed: std::sync::atomic::AtomicBool::new(true),
                inner: RoundRobin::new(),
            }))
            .build_with(Echo::factory(Arc::new(AtomicUsize::new(0)), Duration::ZERO));
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            eng.try_submit(vec![0.0, 0.0, 0.0])
        }));
        assert!(boom.is_err(), "the armed pick panicked the submitting thread");
        // same thread recovers...
        let t = eng.try_submit(vec![2.0, 1.0, 0.0]).expect("submit after policy panic");
        assert_eq!(t.wait(), Response::Logits(vec![3.0, -1.0]));
        // ...and so do other threads
        let eng = Arc::new(eng);
        let e2 = eng.clone();
        let other = std::thread::spawn(move || e2.infer(vec![1.0, 1.0, 1.0]));
        assert_eq!(other.join().expect("thread ok"), Response::Logits(vec![3.0, -1.0]));
        match Arc::try_unwrap(eng) {
            Ok(e) => e.shutdown(),
            Err(_) => panic!("sole owner"),
        }
    }

    #[test]
    fn report_mentions_policies() {
        let eng = EngineBuilder::new()
            .workers(2)
            .dispatch(DispatchKind::EwmaP99)
            .admission(AdmissionPolicy::ShedNewest)
            .build_with(Echo::factory(Arc::new(AtomicUsize::new(0)), Duration::ZERO));
        let _ = eng.infer(vec![0.0, 0.0, 0.0]);
        let r = eng.report();
        assert!(r.contains("ewma-p99") && r.contains("shed-newest"), "{r}");
        assert_eq!(eng.dispatch_name(), "ewma-p99");
        assert_eq!(eng.admission(), AdmissionPolicy::ShedNewest);
    }

    #[test]
    fn health_marks_narrow_routing_but_never_brick_open_queues() {
        let eng = quick_engine(2);
        assert_eq!(eng.replicas(), 1);
        assert_eq!(eng.health_counters(), HealthCounters::default());
        // shard 0 marked down: traffic converges on shard 1
        eng.health.mark(0, false);
        for i in 0..6 {
            assert_eq!(
                eng.infer(vec![i as f32, 1.0, 0.0]),
                Response::Logits(vec![i as f32 + 1.0, -1.0])
            );
        }
        let m = eng.worker_metrics();
        assert_eq!(m[0].completed.load(Ordering::Relaxed), 0, "down shard got no traffic");
        assert_eq!(m[1].completed.load(Ordering::Relaxed), 6);
        assert_eq!(eng.health_counters().down_now, 1);
        // every shard marked down, yet queues are open: marks are
        // advisory and must fall back, not reject the world
        eng.health.mark(1, false);
        assert_eq!(eng.infer(vec![1.0, 1.0, 1.0]), Response::Logits(vec![3.0, -1.0]));
        eng.health.mark(0, true);
        assert_eq!(eng.health_counters().marks_up, 1);
        eng.shutdown();
    }
}
