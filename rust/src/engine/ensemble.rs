//! Ensemble serving: N member models behind one submit, merged in
//! **fixed member order**.
//!
//! The paper's construction makes ensemble members nearly free: the
//! same LDS-generated paths with a different init seed yield another
//! network of identical topology and cost (Baldassi et al.,
//! arXiv:1605.06444 argue such cheap-replica ensembles recover the
//! accuracy a single sparse member lacks).  [`EngineBuilder::ensemble`]
//! builds the members from one base [`ModelSpec`] via
//! [`ModelSpec::member`] (member-indexed seed derivation), `try_submit`
//! fans each request out across the member shard blocks as concurrent
//! jobs, and the ticket merges the member logits here.
//!
//! **Determinism is the whole design.**  Member responses arrive in
//! whatever order dispatch, batching, and thread scheduling produce —
//! the merge never looks at arrival order.  Arrived members are
//! combined in ascending member index (the same fixed-merge-order
//! trick that makes the sharded backward bitwise thread-invariant), so
//! an ensemble response is bitwise identical for any
//! `SOBOLNET_THREADS`, any dispatch policy, and in-process vs remote
//! members (`tests/ensemble.rs` pins all three axes).
//!
//! **Merge rules** ([`EnsembleMerger`], the normative reference):
//!
//! - [`EnsembleMode::Mean`]: sum the arrived member logit vectors
//!   element-wise in ascending member order, then divide each element
//!   by the arrived count with a single `f32` division.  A one-member
//!   merge divides by `1.0`, which is exact — an N=1 ensemble answers
//!   bitwise like the plain engine.
//! - [`EnsembleMode::Vote`]: each arrived member votes for its argmax
//!   class (intra-member ties resolve to the lowest class index); the
//!   response is a one-hot vector of the winning class.  A vote-count
//!   tie is broken by the **lowest member index**: scanning members in
//!   ascending order, the first member whose voted class holds the
//!   maximum count names the winner.
//!
//! **Partial quorum** ([`EngineBuilder::quorum`]): a K-of-N ticket
//! returns once K members arrived and the stragglers blow a
//! p99-derived deadline (`max(floor, 2 × p99)` over the member-latency
//! EWMA, the same rule the remote hedge uses), annotated with
//! `members_merged`.  A dead member resolves its slot as rejected —
//! degrading the quorum — instead of failing the ticket; see
//! [`super::ticket::Ticket::wait`].
//!
//! The merge scratch (vote tally, member argmax list) is **builder
//! held** on the shared engine state, not allocated per request —
//! `tests/alloc_hotpath.rs` pins the warm merge path at zero
//! allocations.
//!
//! [`EngineBuilder::ensemble`]: super::EngineBuilder::ensemble
//! [`EngineBuilder::quorum`]: super::EngineBuilder::quorum
//! [`ModelSpec`]: crate::registry::ModelSpec
//! [`ModelSpec::member`]: crate::registry::ModelSpec::member

use crate::util::sync::plock;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// How member logits combine into one ensemble response.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EnsembleMode {
    /// Element-wise mean over the arrived members (fixed member order;
    /// the bitwise-pinned default).
    #[default]
    Mean,
    /// Majority vote over member argmax classes; the response is a
    /// one-hot vector of the winning class.
    Vote,
}

impl EnsembleMode {
    /// Parse a mode name (`"mean"` or `"vote"`, as the CLI and config
    /// spell them).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "mean" => Ok(EnsembleMode::Mean),
            "vote" => Ok(EnsembleMode::Vote),
            other => Err(format!("unknown ensemble mode '{other}' (expected mean|vote)")),
        }
    }

    /// Canonical name (round-trips through [`EnsembleMode::parse`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            EnsembleMode::Mean => "mean",
            EnsembleMode::Vote => "vote",
        }
    }
}

impl std::fmt::Display for EnsembleMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The fixed-member-order merge, usable standalone as the sequential
/// reference (`tests/ensemble.rs` compares engine responses bitwise
/// against exactly this code run over in-process member forwards).
///
/// The vote tally and argmax scratch are held here and reused across
/// merges, so a warm merge allocates nothing: the mean path folds into
/// the first arrived member's own vector, and the vote path reuses it
/// for the one-hot output.
pub struct EnsembleMerger {
    mode: EnsembleMode,
    /// Vote tally per class (vote mode scratch; zeroed per merge).
    votes: Vec<u32>,
    /// Arrived members' voted classes, ascending member order (vote
    /// mode scratch; the tie-break scan reads it back).
    voted: Vec<u32>,
}

impl EnsembleMerger {
    /// Merger for `classes`-way logits over at most `members` members.
    pub fn new(mode: EnsembleMode, classes: usize, members: usize) -> Self {
        EnsembleMerger { mode, votes: vec![0; classes], voted: Vec::with_capacity(members) }
    }

    /// Merge the arrived member logits (slot index = member index;
    /// `None` = member never answered) in **fixed member order**,
    /// taking the vectors out of `slots`.  Returns the merged logits
    /// and the arrived-member count, or `None` when nothing arrived.
    pub fn merge(&mut self, slots: &mut [Option<Vec<f32>>]) -> Option<(Vec<f32>, usize)> {
        match self.mode {
            EnsembleMode::Mean => self.merge_mean(slots),
            EnsembleMode::Vote => self.merge_vote(slots),
        }
    }

    fn merge_mean(&mut self, slots: &mut [Option<Vec<f32>>]) -> Option<(Vec<f32>, usize)> {
        let mut acc: Option<Vec<f32>> = None;
        let mut arrived = 0usize;
        for slot in slots.iter_mut() {
            let Some(l) = slot.take() else { continue };
            arrived += 1;
            match acc.as_mut() {
                // the first arrived vector (lowest member index) is the
                // accumulator — no per-merge allocation
                None => acc = Some(l),
                Some(a) => {
                    debug_assert_eq!(a.len(), l.len(), "members disagree on class count");
                    for (ai, li) in a.iter_mut().zip(&l) {
                        *ai += *li;
                    }
                }
            }
        }
        let mut out = acc?;
        // one f32 division per element — the normative mean rule; /1.0
        // is exact, so N=1 stays bitwise-equal to the single model
        let n = arrived as f32;
        for v in out.iter_mut() {
            *v /= n;
        }
        Some((out, arrived))
    }

    fn merge_vote(&mut self, slots: &mut [Option<Vec<f32>>]) -> Option<(Vec<f32>, usize)> {
        for v in self.votes.iter_mut() {
            *v = 0;
        }
        self.voted.clear();
        let mut out: Option<Vec<f32>> = None;
        let mut arrived = 0usize;
        for slot in slots.iter_mut() {
            let Some(l) = slot.take() else { continue };
            arrived += 1;
            debug_assert_eq!(l.len(), self.votes.len(), "member logits disagree on classes");
            // member argmax; strict `>` keeps the lowest class on ties
            let mut best = 0usize;
            for (c, v) in l.iter().enumerate() {
                if *v > l[best] {
                    best = c;
                }
            }
            self.votes[best] += 1;
            self.voted.push(best as u32);
            if out.is_none() {
                out = Some(l);
            }
        }
        let mut out = out?;
        let top = *self.votes.iter().max().expect("at least one class");
        // tie-break by lowest member index: the first arrived member
        // (ascending member order) whose class holds the max count
        let winner = self
            .voted
            .iter()
            .find(|&&c| self.votes[c as usize] == top)
            .copied()
            .expect("some member voted the top class") as usize;
        for v in out.iter_mut() {
            *v = 0.0;
        }
        out[winner] = 1.0;
        Some((out, arrived))
    }
}

/// Member-completion latency EWMA feeding the straggler deadline —
/// same constants as the remote hedge deadline (`client.rs`): α = 0.2,
/// p99 ≈ mean + 2.33·σ once 8 samples exist.
struct LatencyEwma {
    mean: f64,
    var: f64,
    n: u64,
}

const ALPHA: f64 = 0.2;
const MIN_SAMPLES: u64 = 8;

/// Shared state of an ensemble engine: merge configuration, the
/// builder-held merge scratch, the member-latency EWMA behind the
/// quorum deadline, and merge counters for [`Engine::report`].
///
/// [`Engine::report`]: super::Engine::report
pub(crate) struct EnsembleShared {
    /// Merge rule.
    pub(crate) mode: EnsembleMode,
    /// Member count N (each owns an equal contiguous shard block).
    pub(crate) members: usize,
    /// Quorum K (`1..=members`; `members` = wait for everyone).
    pub(crate) quorum: usize,
    /// Deadline floor while the EWMA is cold (and lower bound after).
    deadline_floor: Duration,
    lat: Mutex<LatencyEwma>,
    merger: Mutex<EnsembleMerger>,
    /// Completed merges (full or partial).
    pub(crate) merges: AtomicU64,
    /// Merges that returned with fewer than N members.
    pub(crate) partial_merges: AtomicU64,
}

impl EnsembleShared {
    pub(crate) fn new(
        mode: EnsembleMode,
        members: usize,
        quorum: usize,
        deadline_floor: Duration,
        classes: usize,
    ) -> Self {
        EnsembleShared {
            mode,
            members,
            quorum: quorum.clamp(1, members),
            deadline_floor,
            lat: Mutex::new(LatencyEwma { mean: 0.0, var: 0.0, n: 0 }),
            merger: Mutex::new(EnsembleMerger::new(mode, classes, members)),
            merges: AtomicU64::new(0),
            partial_merges: AtomicU64::new(0),
        }
    }

    /// Record one member's submit→arrival latency.
    pub(crate) fn observe(&self, secs: f64) {
        let mut g = plock(&self.lat);
        if g.n == 0 {
            g.mean = secs;
            g.var = 0.0;
        } else {
            let d = secs - g.mean;
            g.mean += ALPHA * d;
            g.var = (1.0 - ALPHA) * (g.var + ALPHA * d * d);
        }
        g.n += 1;
    }

    /// Straggler deadline, measured from submit: `max(floor, 2 × p99)`
    /// once the EWMA holds [`MIN_SAMPLES`] observations, the bare
    /// floor before — mirroring the remote hedge deadline.
    pub(crate) fn deadline(&self) -> Duration {
        let g = plock(&self.lat);
        if g.n >= MIN_SAMPLES {
            let p99 = g.mean + 2.33 * g.var.max(0.0).sqrt();
            let adaptive = Duration::from_secs_f64((2.0 * p99).max(0.0));
            self.deadline_floor.max(adaptive)
        } else {
            self.deadline_floor
        }
    }

    /// Run the fixed-order merge over the arrived slots (shared
    /// builder-held scratch; counters updated).
    pub(crate) fn merge(&self, slots: &mut [Option<Vec<f32>>]) -> Option<(Vec<f32>, usize)> {
        let merged = plock(&self.merger).merge(slots);
        if let Some((_, arrived)) = &merged {
            self.merges.fetch_add(1, Ordering::Relaxed);
            if *arrived < self.members {
                self.partial_merges.fetch_add(1, Ordering::Relaxed);
            }
        }
        merged
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slots(v: &[Option<Vec<f32>>]) -> Vec<Option<Vec<f32>>> {
        v.to_vec()
    }

    #[test]
    fn mean_merge_is_fixed_order_sum_then_one_division() {
        let mut m = EnsembleMerger::new(EnsembleMode::Mean, 2, 3);
        let mut s = slots(&[
            Some(vec![1.0, -2.0]),
            Some(vec![3.0, 0.5]),
            Some(vec![-1.0, 0.25]),
        ]);
        let (out, n) = m.merge(&mut s).expect("merged");
        assert_eq!(n, 3);
        // the normative formula, spelled out: ((a + b) + c) / 3.0
        assert_eq!(out[0].to_bits(), (((1.0f32 + 3.0) + -1.0) / 3.0).to_bits());
        assert_eq!(out[1].to_bits(), (((-2.0f32 + 0.5) + 0.25) / 3.0).to_bits());
        assert!(s.iter().all(|x| x.is_none()), "merge takes the slots");
    }

    #[test]
    fn mean_merge_of_one_member_is_bitwise_identity() {
        let mut m = EnsembleMerger::new(EnsembleMode::Mean, 3, 1);
        let v = vec![0.1f32, -0.7, 3.3e-7];
        let mut s = slots(&[Some(v.clone())]);
        let (out, n) = m.merge(&mut s).expect("merged");
        assert_eq!(n, 1);
        for (o, w) in out.iter().zip(&v) {
            assert_eq!(o.to_bits(), w.to_bits(), "x / 1.0 must be exact");
        }
    }

    #[test]
    fn mean_merge_skips_holes_and_counts_arrived_only() {
        let mut m = EnsembleMerger::new(EnsembleMode::Mean, 1, 3);
        let mut s = slots(&[Some(vec![2.0]), None, Some(vec![4.0])]);
        let (out, n) = m.merge(&mut s).expect("merged");
        assert_eq!(n, 2);
        assert_eq!(out[0].to_bits(), 3.0f32.to_bits());
        assert!(m.merge(&mut slots(&[None, None])).is_none(), "nothing arrived");
    }

    #[test]
    fn vote_merge_majority_and_one_hot_output() {
        let mut m = EnsembleMerger::new(EnsembleMode::Vote, 3, 3);
        // members vote classes [2, 0, 0] → class 0 wins 2-1
        let mut s = slots(&[
            Some(vec![0.0, 0.1, 0.9]),
            Some(vec![0.8, 0.1, 0.0]),
            Some(vec![0.7, 0.2, 0.1]),
        ]);
        let (out, n) = m.merge(&mut s).expect("merged");
        assert_eq!(n, 3);
        assert_eq!(out, vec![1.0, 0.0, 0.0]);
    }

    #[test]
    fn vote_tie_breaks_to_lowest_member_index() {
        let mut m = EnsembleMerger::new(EnsembleMode::Vote, 2, 4);
        // votes [c1, c0, c1, c0]: 2-2 tie → member 0 voted c1 → c1 wins
        let mut s = slots(&[
            Some(vec![0.1, 0.9]),
            Some(vec![0.9, 0.1]),
            Some(vec![0.2, 0.8]),
            Some(vec![0.8, 0.2]),
        ]);
        let (out, _) = m.merge(&mut s).expect("merged");
        assert_eq!(out, vec![0.0, 1.0], "tie must resolve to member 0's class");
        // ...and NOT to the class that *reached* the tied count first:
        // votes [c1, c0, c0, c1] — member 0 still names the winner
        let mut s = slots(&[
            Some(vec![0.1, 0.9]),
            Some(vec![0.9, 0.1]),
            Some(vec![0.7, 0.3]),
            Some(vec![0.3, 0.7]),
        ]);
        let (out, _) = m.merge(&mut s).expect("merged");
        assert_eq!(out, vec![0.0, 1.0]);
    }

    #[test]
    fn vote_intra_member_argmax_tie_takes_lowest_class() {
        let mut m = EnsembleMerger::new(EnsembleMode::Vote, 3, 1);
        let mut s = slots(&[Some(vec![0.5, 0.5, 0.1])]);
        let (out, _) = m.merge(&mut s).expect("merged");
        assert_eq!(out, vec![1.0, 0.0, 0.0], "flat argmax pins the lowest class");
    }

    #[test]
    fn mode_names_round_trip_and_reject_garbage() {
        for mode in [EnsembleMode::Mean, EnsembleMode::Vote] {
            assert_eq!(EnsembleMode::parse(mode.as_str()), Ok(mode));
        }
        assert!(EnsembleMode::parse("median").is_err());
    }

    #[test]
    fn deadline_floor_holds_until_warm_then_tracks_p99() {
        let es =
            EnsembleShared::new(EnsembleMode::Mean, 3, 2, Duration::from_millis(40), 2);
        assert_eq!(es.deadline(), Duration::from_millis(40), "cold EWMA uses the floor");
        for _ in 0..16 {
            es.observe(0.100); // steady 100 ms members
        }
        let d = es.deadline();
        assert!(d >= Duration::from_millis(150), "2×p99 of ~100ms members: {d:?}");
        // a fast service keeps the floor as the lower bound
        let fast =
            EnsembleShared::new(EnsembleMode::Mean, 3, 2, Duration::from_millis(40), 2);
        for _ in 0..16 {
            fast.observe(0.001);
        }
        assert_eq!(fast.deadline(), Duration::from_millis(40));
    }

    #[test]
    fn shared_merge_counts_full_and_partial() {
        let es = EnsembleShared::new(EnsembleMode::Mean, 3, 2, Duration::from_millis(5), 1);
        let mut all = slots(&[Some(vec![1.0]), Some(vec![2.0]), Some(vec![3.0])]);
        assert_eq!(es.merge(&mut all), Some((vec![2.0], 3)));
        let mut partial = slots(&[Some(vec![1.0]), None, Some(vec![3.0])]);
        assert_eq!(es.merge(&mut partial), Some((vec![2.0], 2)));
        assert_eq!(es.merges.load(Ordering::Relaxed), 2);
        assert_eq!(es.partial_merges.load(Ordering::Relaxed), 1);
    }
}
