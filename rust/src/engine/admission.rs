//! Bounded per-shard admission queues: the backpressure primitive of
//! the engine.
//!
//! Every worker shard owns one [`BoundedQueue`].  A queue has a depth
//! bound and an [`AdmissionPolicy`] decides what happens when a request
//! arrives at a full queue:
//!
//! * [`AdmissionPolicy::Block`] — the submitting thread waits for a
//!   slot (closed-loop clients self-throttle; with an unlimited bound
//!   this is classic blocking submission),
//! * [`AdmissionPolicy::ShedNewest`] — the *new* request is rejected
//!   immediately (`try_submit` returns
//!   [`RejectReason::QueueFull`](super::ticket::RejectReason)),
//! * [`AdmissionPolicy::ShedOldest`] — the new request is admitted and
//!   the *oldest* queued request is evicted; its ticket resolves to
//!   `Response::Rejected(RejectReason::QueueFull)`.
//!
//! The queue also tracks a depth high-watermark under the same lock as
//! the push, so "in-queue depth never exceeded the bound" is a checkable
//! post-condition (`tests/engine_backpressure.rs`), not a hope.
//!
//! **Poison immunity**: every lock/wait recovers the guard from a
//! poisoned mutex ([`crate::util::sync`]).  A thread that panics
//! anywhere near a shard queue must not cascade `PoisonError` panics
//! into every other shard's submit path for the rest of the process:
//! the queue's invariants are maintained *before* any caller code can
//! run, so the state behind a poisoned lock is always consistent.

use crate::util::sync::{cwait, plock};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// What to do when a request arrives at a full shard queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Block the submitter until a slot frees (or the engine shuts down).
    #[default]
    Block,
    /// Reject the incoming request (`RejectReason::QueueFull`).
    ShedNewest,
    /// Admit the incoming request; evict the oldest queued one.
    ShedOldest,
}

impl AdmissionPolicy {
    /// Parse from a config/CLI string.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "block" => Some(AdmissionPolicy::Block),
            "shed-newest" | "shed_newest" => Some(AdmissionPolicy::ShedNewest),
            "shed-oldest" | "shed_oldest" => Some(AdmissionPolicy::ShedOldest),
            _ => None,
        }
    }

    /// Canonical config/CLI spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            AdmissionPolicy::Block => "block",
            AdmissionPolicy::ShedNewest => "shed-newest",
            AdmissionPolicy::ShedOldest => "shed-oldest",
        }
    }
}

/// Outcome of [`BoundedQueue::admit`].
pub enum Admit<T> {
    /// Item enqueued.
    Admitted,
    /// Queue full under `ShedNewest`: the item is handed back.
    RejectedFull(T),
    /// Queue closed (engine shutting down): the item is handed back.
    RejectedClosed(T),
    /// Item enqueued under `ShedOldest`; the evicted oldest is returned
    /// so the caller can resolve its ticket.
    Evicted(T),
}

/// Why a timed pop returned without an item.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PopWait {
    /// Deadline elapsed with the queue still empty.
    TimedOut,
    /// Queue closed and fully drained.
    Closed,
}

struct State<T> {
    q: VecDeque<T>,
    closed: bool,
    max_depth: usize,
}

/// A depth-bounded MPSC queue with admission policies and a depth
/// high-watermark.  `bound == 0` means unbounded (legacy behavior).
pub struct BoundedQueue<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    bound: usize,
    /// Lock-free mirror of the queue length, so dispatch policies can
    /// read [`BoundedQueue::depth`] on every submit without contending
    /// with the worker's pop path.  Updated under the state lock.
    depth: AtomicUsize,
    /// Lock-free mirror of the closed flag, so the engine's submit
    /// path can skip dead shards without taking the state lock.  Set
    /// under the state lock in [`BoundedQueue::close`].
    closed: AtomicBool,
}

impl<T> BoundedQueue<T> {
    /// New queue with the given depth bound (`0` = unbounded).
    pub fn new(bound: usize) -> Self {
        BoundedQueue {
            state: Mutex::new(State { q: VecDeque::new(), closed: false, max_depth: 0 }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            bound,
            depth: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
        }
    }

    /// Depth bound (`0` = unbounded).
    pub fn bound(&self) -> usize {
        self.bound
    }

    /// Try to enqueue `item` under `policy`.  See [`Admit`].
    pub fn admit(&self, item: T, policy: AdmissionPolicy) -> Admit<T> {
        let mut s = plock(&self.state);
        if s.closed {
            return Admit::RejectedClosed(item);
        }
        let mut evicted = None;
        if self.bound > 0 && s.q.len() >= self.bound {
            match policy {
                AdmissionPolicy::Block => {
                    while s.q.len() >= self.bound && !s.closed {
                        s = cwait(&self.not_full, s);
                    }
                    if s.closed {
                        return Admit::RejectedClosed(item);
                    }
                }
                AdmissionPolicy::ShedNewest => return Admit::RejectedFull(item),
                AdmissionPolicy::ShedOldest => evicted = s.q.pop_front(),
            }
        }
        s.q.push_back(item);
        s.max_depth = s.max_depth.max(s.q.len());
        self.depth.store(s.q.len(), Ordering::Relaxed);
        self.not_empty.notify_one();
        drop(s);
        match evicted {
            Some(old) => Admit::Evicted(old),
            None => Admit::Admitted,
        }
    }

    /// Blocking pop; `None` once the queue is closed *and* drained.
    pub fn pop_block(&self) -> Option<T> {
        let mut s = plock(&self.state);
        loop {
            if let Some(item) = s.q.pop_front() {
                self.depth.store(s.q.len(), Ordering::Relaxed);
                self.not_full.notify_one();
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = cwait(&self.not_empty, s);
        }
    }

    /// Pop with a timeout (used by the batcher's flush deadline).
    pub fn pop_timeout(&self, timeout: Duration) -> Result<T, PopWait> {
        let deadline = std::time::Instant::now() + timeout;
        let mut s = plock(&self.state);
        loop {
            if let Some(item) = s.q.pop_front() {
                self.depth.store(s.q.len(), Ordering::Relaxed);
                self.not_full.notify_one();
                return Ok(item);
            }
            if s.closed {
                return Err(PopWait::Closed);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(PopWait::TimedOut);
            }
            let (guard, _) = self
                .not_empty
                .wait_timeout(s, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            s = guard;
        }
    }

    /// Close the queue: wakes all waiters; producers get
    /// [`Admit::RejectedClosed`], the consumer drains what remains.
    pub fn close(&self) {
        let mut s = plock(&self.state);
        s.closed = true;
        self.closed.store(true, Ordering::Relaxed);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// `true` once [`BoundedQueue::close`] ran — the owning worker is
    /// gone (its queue guard closes on thread exit) or the engine is
    /// shutting down.  Lock-free (the engine's submit path reads it
    /// for every shard to skip dead ones); the authoritative check
    /// stays inside [`BoundedQueue::admit`] under the lock.
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Relaxed)
    }

    /// Current queued depth (lock-free snapshot; exact at quiescence,
    /// momentarily stale under concurrent push/pop — fine for dispatch
    /// heuristics and post-drain assertions).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Highest depth ever observed (recorded under the push lock).
    pub fn max_depth(&self) -> usize {
        plock(&self.state).max_depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn policy_strings_round_trip() {
        for p in [AdmissionPolicy::Block, AdmissionPolicy::ShedNewest, AdmissionPolicy::ShedOldest]
        {
            assert_eq!(AdmissionPolicy::parse(p.as_str()), Some(p));
        }
        assert_eq!(AdmissionPolicy::parse("drop-everything"), None);
    }

    #[test]
    fn shed_newest_bounces_at_bound() {
        let q = BoundedQueue::new(2);
        assert!(matches!(q.admit(1, AdmissionPolicy::ShedNewest), Admit::Admitted));
        assert!(matches!(q.admit(2, AdmissionPolicy::ShedNewest), Admit::Admitted));
        match q.admit(3, AdmissionPolicy::ShedNewest) {
            Admit::RejectedFull(item) => assert_eq!(item, 3),
            _ => panic!("expected RejectedFull"),
        }
        assert_eq!(q.depth(), 2);
        assert_eq!(q.max_depth(), 2);
        // FIFO order preserved for the admitted items
        assert_eq!(q.pop_block(), Some(1));
        assert_eq!(q.pop_block(), Some(2));
    }

    #[test]
    fn shed_oldest_evicts_head() {
        let q = BoundedQueue::new(2);
        q.admit(1, AdmissionPolicy::ShedOldest);
        q.admit(2, AdmissionPolicy::ShedOldest);
        match q.admit(3, AdmissionPolicy::ShedOldest) {
            Admit::Evicted(old) => assert_eq!(old, 1),
            _ => panic!("expected Evicted"),
        }
        assert_eq!(q.depth(), 2, "depth stays at the bound");
        assert_eq!(q.max_depth(), 2);
        assert_eq!(q.pop_block(), Some(2));
        assert_eq!(q.pop_block(), Some(3));
    }

    #[test]
    fn block_waits_for_a_slot() {
        let q = Arc::new(BoundedQueue::new(1));
        assert!(matches!(q.admit(10, AdmissionPolicy::Block), Admit::Admitted));
        let q2 = q.clone();
        let pusher = std::thread::spawn(move || {
            // blocks until the consumer pops
            matches!(q2.admit(11, AdmissionPolicy::Block), Admit::Admitted)
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.depth(), 1, "second push still parked");
        assert_eq!(q.pop_block(), Some(10));
        assert!(pusher.join().unwrap());
        assert_eq!(q.pop_block(), Some(11));
        assert_eq!(q.max_depth(), 1, "blocking admission never exceeded the bound");
    }

    #[test]
    fn close_unblocks_producer_and_drains_consumer() {
        let q = Arc::new(BoundedQueue::new(1));
        q.admit(1, AdmissionPolicy::Block);
        let q2 = q.clone();
        let pusher = std::thread::spawn(move || q2.admit(2, AdmissionPolicy::Block));
        std::thread::sleep(Duration::from_millis(10));
        assert!(!q.is_closed());
        q.close();
        assert!(q.is_closed());
        match pusher.join().unwrap() {
            Admit::RejectedClosed(item) => assert_eq!(item, 2),
            _ => panic!("blocked producer must be rejected on close"),
        }
        // consumer still drains the admitted item, then sees Closed
        assert_eq!(q.pop_block(), Some(1));
        assert_eq!(q.pop_block(), None);
        assert!(matches!(q.admit(9, AdmissionPolicy::Block), Admit::RejectedClosed(9)));
    }

    #[test]
    fn pop_timeout_semantics() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        assert_eq!(q.pop_timeout(Duration::from_millis(2)), Err(PopWait::TimedOut));
        q.admit(5, AdmissionPolicy::Block);
        assert_eq!(q.pop_timeout(Duration::from_millis(2)), Ok(5));
        q.close();
        assert_eq!(q.pop_timeout(Duration::from_millis(2)), Err(PopWait::Closed));
    }

    /// A thread that panics while holding the state mutex poisons it;
    /// every queue operation afterwards must recover the guard and
    /// keep working instead of cascading `PoisonError` panics into
    /// other shards' submit paths (the long-lived-serving bug this
    /// module's poison immunity exists for).
    #[test]
    fn poisoned_state_lock_recovers() {
        let q = Arc::new(BoundedQueue::new(2));
        assert!(matches!(q.admit(1, AdmissionPolicy::Block), Admit::Admitted));
        // genuinely poison the private state mutex
        let q2 = q.clone();
        let _ = std::thread::spawn(move || {
            let _guard = q2.state.lock().unwrap();
            panic!("poison the queue lock (expected in this test)");
        })
        .join();
        assert!(q.state.is_poisoned(), "the mutex really is poisoned");
        // the full surface still works on the recovered guard
        assert!(matches!(q.admit(2, AdmissionPolicy::ShedNewest), Admit::Admitted));
        match q.admit(3, AdmissionPolicy::ShedNewest) {
            Admit::RejectedFull(item) => assert_eq!(item, 3),
            _ => panic!("bound still enforced after poisoning"),
        }
        assert_eq!(q.depth(), 2);
        assert_eq!(q.max_depth(), 2);
        assert_eq!(q.pop_block(), Some(1));
        assert_eq!(q.pop_timeout(Duration::from_millis(5)), Ok(2));
        q.close();
        assert_eq!(q.pop_block(), None);
        assert!(matches!(q.admit(4, AdmissionPolicy::Block), Admit::RejectedClosed(4)));
    }

    #[test]
    fn unbounded_queue_never_sheds() {
        let q = BoundedQueue::new(0);
        for i in 0..1000 {
            assert!(matches!(q.admit(i, AdmissionPolicy::ShedNewest), Admit::Admitted));
        }
        assert_eq!(q.depth(), 1000);
        assert_eq!(q.max_depth(), 1000);
    }
}
