//! Adaptive dynamic batcher: the queue-drain policy of one worker
//! shard.
//!
//! AOT executables (and the `[neurons, batch]` sparse engine layout)
//! run fixed-capacity batches, so each worker coalesces queued
//! single-sample requests into one execution.  Policy: block for the
//! first request, then keep draining until the batch is **full** or
//! `max_wait` has elapsed since the first arrival — whichever comes
//! first.  A full batch therefore never waits, and a lone request is
//! never delayed by more than `max_wait`.
//!
//! The batcher is generic over a [`BatchSource`] so the same policy
//! drains both the engine's [`BoundedQueue`](super::admission::BoundedQueue)
//! shard queues and plain `mpsc` channels (unit tests, ad-hoc tools).
//!
//! Each flushed batch becomes one job in `util::parallel`'s multi-job
//! pool (via the backend's column-sharded forward), so K shards'
//! batchers flushing small batches at once genuinely overlap instead
//! of serializing on a single pool job slot — which is why small
//! `capacity`/`max_wait` settings stay profitable under many shards.
//!
//! Batch composition is irrelevant to ensemble determinism: member
//! shards never share a queue (the fan-out admits each member copy
//! into that member's own shard block), and each request's logits are
//! bit-identical regardless of which batch it lands in, so the merge
//! in [`super::ensemble`] sees the same member values however the
//! batcher happened to coalesce them.

use super::admission::{BoundedQueue, PopWait};
use crate::util::timer::Timer;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::Duration;

/// A blocking source of single requests the batcher can drain.
pub trait BatchSource<T> {
    /// Block for the next item; `None` once the source is closed and
    /// fully drained.
    fn recv_block(&self) -> Option<T>;

    /// Wait up to `timeout` for the next item.
    fn recv_wait(&self, timeout: Duration) -> Result<T, PopWait>;
}

impl<T> BatchSource<T> for Receiver<T> {
    fn recv_block(&self) -> Option<T> {
        self.recv().ok()
    }

    fn recv_wait(&self, timeout: Duration) -> Result<T, PopWait> {
        self.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => PopWait::TimedOut,
            RecvTimeoutError::Disconnected => PopWait::Closed,
        })
    }
}

impl<T> BatchSource<T> for BoundedQueue<T> {
    fn recv_block(&self) -> Option<T> {
        self.pop_block()
    }

    fn recv_wait(&self, timeout: Duration) -> Result<T, PopWait> {
        self.pop_timeout(timeout)
    }
}

/// The flush policy of one worker's queue.
#[derive(Debug, Clone, Copy)]
pub struct Batcher {
    /// Batch capacity of the backend (flush immediately when reached).
    pub capacity: usize,
    /// Max time to wait for a full batch after the first arrival.
    pub max_wait: Duration,
}

impl Batcher {
    /// Drain the next batch from `src`.  Blocks until at least one item
    /// arrives; returns `None` when the source is closed and empty
    /// (worker shutdown).
    pub fn next_batch<T, S: BatchSource<T>>(&self, src: &S) -> Option<Vec<T>> {
        let first = src.recv_block()?;
        let mut batch = Vec::with_capacity(self.capacity);
        batch.push(first);
        let since_first = Timer::start();
        while batch.len() < self.capacity {
            let remaining = self
                .max_wait
                .saturating_sub(Duration::from_secs_f64(since_first.elapsed_secs()));
            match src.recv_wait(remaining) {
                Ok(item) => batch.push(item),
                Err(PopWait::TimedOut) => break,
                Err(PopWait::Closed) => break,
            }
        }
        Some(batch)
    }
}

/// Split `items` into maximal consecutive runs whose `key` is equal,
/// returned as `(start, end)` index pairs covering the slice in order.
///
/// A multi-tenant worker drains one mixed batch from its queue but a
/// backend execution serves one `(model_id, version)`; this is the
/// splitting step between the two.  Runs preserve arrival order — the
/// batcher never reorders across tenants, so a run boundary costs one
/// extra backend execution, never a fairness inversion.
pub fn homogeneous_runs<T, K: PartialEq>(
    items: &[T],
    key: impl Fn(&T) -> K,
) -> Vec<(usize, usize)> {
    let mut runs = Vec::new();
    let mut start = 0;
    while start < items.len() {
        let k = key(&items[start]);
        let mut end = start + 1;
        while end < items.len() && key(&items[end]) == k {
            end += 1;
        }
        runs.push((start, end));
        start = end;
    }
    runs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::admission::AdmissionPolicy;
    use std::sync::mpsc::channel;

    #[test]
    fn homogeneous_runs_split_in_order() {
        assert!(homogeneous_runs(&[] as &[u32], |&x| x).is_empty());
        assert_eq!(homogeneous_runs(&[5], |&x| x), vec![(0, 1)]);
        assert_eq!(homogeneous_runs(&[1, 1, 1], |&x| x), vec![(0, 3)]);
        // interleaved tenants split at every boundary, in arrival order
        assert_eq!(
            homogeneous_runs(&[1, 1, 2, 1, 2, 2], |&x| x),
            vec![(0, 2), (2, 3), (3, 4), (4, 6)]
        );
        // runs cover the slice exactly
        let items = [3u32, 3, 7, 7, 7, 3];
        let runs = homogeneous_runs(&items, |&x| x);
        assert_eq!(runs.iter().map(|&(s, e)| e - s).sum::<usize>(), items.len());
        assert_eq!(runs[0], (0, 2));
        assert_eq!(runs.last(), Some(&(5, 6)));
    }

    #[test]
    fn full_batch_flushes_without_waiting() {
        let (tx, rx) = channel();
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        let b = Batcher { capacity: 4, max_wait: Duration::from_secs(3600) };
        let t = Timer::start();
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert!(t.elapsed_secs() < 1.0, "must not wait out max_wait on a full batch");
    }

    #[test]
    fn partial_batch_flushes_on_deadline() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        let b = Batcher { capacity: 8, max_wait: Duration::from_millis(5) };
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch, vec![7]);
    }

    #[test]
    fn closed_empty_channel_yields_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let b = Batcher { capacity: 4, max_wait: Duration::from_millis(1) };
        assert!(b.next_batch(&rx).is_none());
    }

    #[test]
    fn drains_remaining_items_after_close() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        let b = Batcher { capacity: 8, max_wait: Duration::from_secs(3600) };
        // disconnected channel must flush what is pending, not hang
        assert_eq!(b.next_batch(&rx).unwrap(), vec![1, 2]);
        assert!(b.next_batch(&rx).is_none());
    }

    #[test]
    fn drains_bounded_queue_the_same_way() {
        let q = BoundedQueue::new(8);
        for i in 0..3 {
            q.admit(i, AdmissionPolicy::Block);
        }
        let b = Batcher { capacity: 4, max_wait: Duration::from_millis(5) };
        // 3 queued < capacity 4: flushes on the deadline with all three
        assert_eq!(b.next_batch(&q).unwrap(), vec![0, 1, 2]);
        q.admit(9, AdmissionPolicy::Block);
        q.close();
        // closed queue still drains what is pending, then yields None
        assert_eq!(b.next_batch(&q).unwrap(), vec![9]);
        assert!(b.next_batch(&q).is_none());
    }
}
