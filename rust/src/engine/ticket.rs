//! Ticket-based request path: `try_submit` hands back a [`Ticket`]
//! immediately; the outcome — logits or a typed rejection — arrives
//! through it.
//!
//! The ticket is the unit the ROADMAP's multi-process sharding item
//! needs: it is a one-shot channel whose payload ([`Response`]) is
//! plain data, so an IPC transport can carry the same contract across
//! process boundaries without touching the engine internals.
//!
//! On an ensemble engine ([`EngineBuilder::ensemble`]) one submit fans
//! out to N member shards, and the ticket holds the merge state: member
//! responses are absorbed in arrival order but merged in **fixed member
//! order**, the quorum deadline is enforced on `wait`, and a
//! `wait_timeout` that expires mid-fan-out keeps the partial state so
//! late member responses are absorbed (exactly once) by the next wait.
//!
//! [`EngineBuilder::ensemble`]: super::EngineBuilder::ensemble

use super::ensemble::EnsembleShared;
use std::cell::RefCell;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Why a request was not (or will not be) served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The picked shard's admission queue was at its depth bound
    /// (`ShedNewest` rejects the new request, `ShedOldest` evicts the
    /// oldest queued one — both report this reason).
    QueueFull,
    /// The engine is shutting down (or already shut down).
    ShuttingDown,
    /// Input length does not match the model's feature count.
    BadShape {
        /// Expected feature count.
        expected: usize,
        /// Submitted input length.
        got: usize,
    },
    /// The worker shard died before answering (its thread panicked).
    WorkerFailed,
    /// The request named a model (or model version) the registry does
    /// not hold.  `version == 0` means the model id itself is unknown;
    /// a nonzero version means the model exists but that snapshot was
    /// never published.
    UnknownModel {
        /// Requested model id.
        model_id: u64,
        /// Requested snapshot version (`0` = id lookup failed).
        version: u64,
    },
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::QueueFull => write!(f, "admission queue full"),
            RejectReason::ShuttingDown => write!(f, "engine shutting down"),
            RejectReason::BadShape { expected, got } => {
                write!(f, "bad input shape: expected {expected} features, got {got}")
            }
            RejectReason::WorkerFailed => write!(f, "worker shard failed"),
            RejectReason::UnknownModel { model_id, version: 0 } => {
                write!(f, "unknown model id {model_id}")
            }
            RejectReason::UnknownModel { model_id, version } => {
                write!(f, "model {model_id} has no published version {version}")
            }
        }
    }
}

/// Terminal outcome of an admitted request.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Class logits for the submitted sample.
    Logits(Vec<f32>),
    /// Fixed-member-order ensemble merge.  `members_merged` counts the
    /// members whose logits made it into the merge — equal to the
    /// ensemble size on a full merge, the quorum-satisfying subset on a
    /// partial one.
    Merged {
        /// Merged class logits (mean or one-hot vote winner).
        logits: Vec<f32>,
        /// How many member responses the merge combined.
        members_merged: usize,
    },
    /// The request was admitted but later rejected (evicted by
    /// `ShedOldest`, or its worker died).
    Rejected(RejectReason),
}

impl Response {
    /// Logits if served (single-model or merged), `None` on rejection.
    pub fn logits(self) -> Option<Vec<f32>> {
        match self {
            Response::Logits(l) => Some(l),
            Response::Merged { logits, .. } => Some(logits),
            Response::Rejected(_) => None,
        }
    }

    /// Merged-member count of an ensemble response, `None` otherwise.
    pub fn members_merged(&self) -> Option<usize> {
        match self {
            Response::Merged { members_merged, .. } => Some(*members_merged),
            _ => None,
        }
    }
}

/// Merge progress of one fan-out: which members resolved (answered or
/// died), the arrived logits awaiting the fixed-order merge, and the
/// first rejection seen (reported if nothing merges).
struct MergeState {
    /// Arrived logits, slot index = member index.
    got: Vec<Option<Vec<f32>>>,
    /// Members that terminally resolved (logits or rejection) — a slot
    /// resolves at most once, so a late duplicate can't double-count.
    resolved: Vec<bool>,
    /// Members that arrived with logits.
    arrived: usize,
    /// Members resolved either way.
    resolved_n: usize,
    /// First rejection observed across members.
    first_reject: Option<RejectReason>,
    /// The merge already ran and its response was handed out.
    done: bool,
}

/// Ensemble half of a ticket: the shared fan-in channel plus the merge
/// state.  `RefCell` is fine here — `Ticket` was never `Sync` (it holds
/// an mpsc `Receiver`), and all waits go through `&self` methods.
struct EnsembleWait {
    rx: Receiver<(usize, Response)>,
    shard: usize,
    state: Arc<EnsembleShared>,
    /// Submit time; the quorum straggler deadline is measured from it.
    t0: Instant,
    merge: RefCell<MergeState>,
}

enum Inner {
    Single { rx: Receiver<Response>, shard: usize },
    Ensemble(Box<EnsembleWait>),
}

/// Handle to one in-flight request.
pub struct Ticket {
    inner: Inner,
}

impl Ticket {
    /// Ticket over a plain single-model submit.
    pub(crate) fn single(rx: Receiver<Response>, shard: usize) -> Ticket {
        Ticket { inner: Inner::Single { rx, shard } }
    }

    /// Ticket over an ensemble fan-out.  `failed` pre-resolves members
    /// whose admission already failed — they degrade the quorum instead
    /// of failing the ticket.
    pub(crate) fn ensemble(
        rx: Receiver<(usize, Response)>,
        shard: usize,
        state: Arc<EnsembleShared>,
        failed: Vec<(usize, RejectReason)>,
    ) -> Ticket {
        let members = state.members;
        let mut st = MergeState {
            got: (0..members).map(|_| None).collect(),
            resolved: vec![false; members],
            arrived: 0,
            resolved_n: 0,
            first_reject: None,
            done: false,
        };
        for (m, r) in failed {
            if m < members && !st.resolved[m] {
                st.resolved[m] = true;
                st.resolved_n += 1;
                st.first_reject.get_or_insert(r);
            }
        }
        Ticket {
            inner: Inner::Ensemble(Box::new(EnsembleWait {
                rx,
                shard,
                state,
                t0: Instant::now(),
                merge: RefCell::new(st),
            })),
        }
    }

    /// Index of the worker shard the request was dispatched to (the
    /// first member's shard on an ensemble fan-out).
    pub fn shard(&self) -> usize {
        match &self.inner {
            Inner::Single { shard, .. } => *shard,
            Inner::Ensemble(w) => w.shard,
        }
    }

    /// Block until the outcome arrives.  A dead worker resolves to
    /// [`Response::Rejected`]`(`[`RejectReason::WorkerFailed`]`)`
    /// instead of panicking.  On an ensemble ticket this blocks until
    /// the quorum is met and stragglers either arrive or blow the
    /// p99-derived deadline, then returns the fixed-order
    /// [`Response::Merged`].
    pub fn wait(self) -> Response {
        match self.inner {
            Inner::Single { rx, .. } => {
                rx.recv().unwrap_or(Response::Rejected(RejectReason::WorkerFailed))
            }
            Inner::Ensemble(w) => {
                w.resolve(None).expect("unbounded ensemble wait always resolves")
            }
        }
    }

    /// Wait up to `timeout`; `None` if no outcome arrived in time (the
    /// ticket stays valid — call again or [`Ticket::wait`]).  An
    /// ensemble ticket keeps its partial fan-in state across a timeout:
    /// members that answered are retained, and late responses are
    /// absorbed exactly once by the next wait.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Response> {
        match &self.inner {
            Inner::Single { rx, .. } => match rx.recv_timeout(timeout) {
                Ok(r) => Some(r),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => {
                    Some(Response::Rejected(RejectReason::WorkerFailed))
                }
            },
            Inner::Ensemble(w) => w.resolve(Some(timeout)),
        }
    }

    /// Non-blocking poll; `None` if the outcome is not ready yet.
    pub fn try_wait(&self) -> Option<Response> {
        match &self.inner {
            Inner::Single { rx, .. } => match rx.try_recv() {
                Ok(r) => Some(r),
                Err(TryRecvError::Empty) => None,
                Err(TryRecvError::Disconnected) => {
                    Some(Response::Rejected(RejectReason::WorkerFailed))
                }
            },
            Inner::Ensemble(w) => w.resolve(Some(Duration::ZERO)),
        }
    }
}

impl EnsembleWait {
    /// Drive the fan-in until a response is due (or `budget` runs out —
    /// `None` keeps the partial state for the next call).
    ///
    /// Quorum semantics: block until at least `quorum` members arrived
    /// or every member resolved; once the quorum is met, stragglers get
    /// until `t0 + state.deadline()` (measured from submit), after
    /// which the arrived subset merges in fixed member order.  With
    /// `quorum == members` (the default) no deadline applies and the
    /// merge is always full — fully deterministic.  A rejected member
    /// resolves its slot without arriving, so a dead member degrades
    /// the quorum instead of failing the ticket.
    fn resolve(&self, budget: Option<Duration>) -> Option<Response> {
        let mut st = self.merge.borrow_mut();
        if st.done {
            // the merge was already handed out; mirror the drained
            // single-ticket channel
            return Some(Response::Rejected(
                st.first_reject.unwrap_or(RejectReason::WorkerFailed),
            ));
        }
        let give_up = budget.map(|d| Instant::now() + d);
        let members = self.state.members;
        loop {
            if st.resolved_n == members {
                return Some(self.finish(&mut st));
            }
            let mut straggler_deadline = None;
            if st.arrived >= self.state.quorum {
                let dl = self.t0 + self.state.deadline();
                if Instant::now() >= dl {
                    return Some(self.finish(&mut st));
                }
                straggler_deadline = Some(dl);
            }
            let mut wait_until = straggler_deadline;
            if let Some(g) = give_up {
                wait_until = Some(wait_until.map_or(g, |w| w.min(g)));
            }
            let received = match wait_until {
                Some(w) => {
                    self.rx.recv_timeout(w.saturating_duration_since(Instant::now()))
                }
                None => self.rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
            };
            match received {
                Ok((member, resp)) => {
                    if member >= members || st.resolved[member] {
                        // late duplicate (or garbage index): drop it —
                        // a slot resolves exactly once
                        continue;
                    }
                    st.resolved[member] = true;
                    st.resolved_n += 1;
                    match resp {
                        Response::Logits(l) | Response::Merged { logits: l, .. } => {
                            self.state.observe(self.t0.elapsed().as_secs_f64());
                            st.got[member] = Some(l);
                            st.arrived += 1;
                        }
                        Response::Rejected(r) => {
                            st.first_reject.get_or_insert(r);
                        }
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    let now = Instant::now();
                    if let Some(dl) = straggler_deadline {
                        if now >= dl {
                            return Some(self.finish(&mut st));
                        }
                    }
                    if let Some(g) = give_up {
                        if now >= g {
                            // caller budget exhausted: keep the partial
                            // state, absorb stragglers on the next call
                            return None;
                        }
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    // every sender hung up: unresolved members are dead
                    for m in 0..members {
                        if !st.resolved[m] {
                            st.resolved[m] = true;
                            st.resolved_n += 1;
                        }
                    }
                    st.first_reject.get_or_insert(RejectReason::WorkerFailed);
                }
            }
        }
    }

    /// Run the fixed-order merge over what arrived and seal the ticket.
    fn finish(&self, st: &mut MergeState) -> Response {
        st.done = true;
        match self.state.merge(&mut st.got) {
            Some((logits, members_merged)) => Response::Merged { logits, members_merged },
            None => Response::Rejected(st.first_reject.unwrap_or(RejectReason::WorkerFailed)),
        }
    }
}

/// Reply channel of one queued request.  The engine's ticket path
/// carries a typed [`Response`]; an ensemble fan-out tags it with the
/// member index so the ticket can slot it for the fixed-order merge.
pub(crate) enum ReplyTx {
    /// `try_submit` path: typed response.
    Ticket(Sender<Response>),
    /// Ensemble fan-out: member-tagged response into the shared fan-in
    /// channel of one ticket.
    Member {
        /// Fan-in sender (cloned per member).
        tx: Sender<(usize, Response)>,
        /// Member index this job serves.
        member: usize,
    },
}

impl ReplyTx {
    /// Answer with logits (receiver may have hung up; that's fine).
    pub(crate) fn send_logits(self, logits: Vec<f32>) {
        match self {
            ReplyTx::Ticket(tx) => {
                let _ = tx.send(Response::Logits(logits));
            }
            ReplyTx::Member { tx, member } => {
                let _ = tx.send((member, Response::Logits(logits)));
            }
        }
    }

    /// Answer with a rejection.
    pub(crate) fn send_rejected(self, reason: RejectReason) {
        match self {
            ReplyTx::Ticket(tx) => {
                let _ = tx.send(Response::Rejected(reason));
            }
            ReplyTx::Member { tx, member } => {
                let _ = tx.send((member, Response::Rejected(reason)));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::ensemble::EnsembleMode;
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn ticket_waits_and_times_out() {
        let (tx, rx) = channel();
        let t = Ticket::single(rx, 3);
        assert_eq!(t.shard(), 3);
        assert!(t.try_wait().is_none());
        assert!(t.wait_timeout(Duration::from_millis(2)).is_none(), "nothing sent yet");
        tx.send(Response::Logits(vec![1.0, 2.0])).unwrap();
        assert_eq!(t.wait(), Response::Logits(vec![1.0, 2.0]));
    }

    #[test]
    fn dead_worker_resolves_to_worker_failed() {
        let (tx, rx) = channel::<Response>();
        drop(tx);
        let t = Ticket::single(rx, 0);
        assert_eq!(t.wait(), Response::Rejected(RejectReason::WorkerFailed));
    }

    #[test]
    fn response_logits_accessor() {
        assert_eq!(Response::Logits(vec![0.5]).logits(), Some(vec![0.5]));
        assert_eq!(
            Response::Merged { logits: vec![0.25], members_merged: 3 }.logits(),
            Some(vec![0.25])
        );
        assert_eq!(Response::Rejected(RejectReason::QueueFull).logits(), None);
        assert_eq!(
            Response::Merged { logits: vec![], members_merged: 2 }.members_merged(),
            Some(2)
        );
        assert_eq!(Response::Logits(vec![]).members_merged(), None);
    }

    #[test]
    fn reject_reasons_display() {
        assert!(format!("{}", RejectReason::QueueFull).contains("full"));
        assert!(format!("{}", RejectReason::BadShape { expected: 784, got: 3 }).contains("784"));
        assert!(format!("{}", RejectReason::UnknownModel { model_id: 9, version: 0 })
            .contains("unknown model id 9"));
        assert!(format!("{}", RejectReason::UnknownModel { model_id: 9, version: 4 })
            .contains("no published version 4"));
    }

    fn shared(members: usize, quorum: usize, floor_ms: u64) -> Arc<EnsembleShared> {
        Arc::new(EnsembleShared::new(
            EnsembleMode::Mean,
            members,
            quorum,
            Duration::from_millis(floor_ms),
            2,
        ))
    }

    #[test]
    fn ensemble_merges_in_member_order_not_arrival_order() {
        let (tx, rx) = channel();
        let t = Ticket::ensemble(rx, 0, shared(3, 3, 1_000), Vec::new());
        // arrival order 2, 0, 1 — merge must still run 0, 1, 2
        tx.send((2, Response::Logits(vec![4.0, 8.0]))).unwrap();
        tx.send((0, Response::Logits(vec![1.0, -1.0]))).unwrap();
        tx.send((1, Response::Logits(vec![2.0, 0.5]))).unwrap();
        let expected0 = ((1.0f32 + 2.0) + 4.0) / 3.0;
        let expected1 = ((-1.0f32 + 0.5) + 8.0) / 3.0;
        match t.wait() {
            Response::Merged { logits, members_merged } => {
                assert_eq!(members_merged, 3);
                assert_eq!(logits[0].to_bits(), expected0.to_bits());
                assert_eq!(logits[1].to_bits(), expected1.to_bits());
            }
            other => panic!("expected merged response, got {other:?}"),
        }
    }

    #[test]
    fn duplicate_member_response_is_dropped_not_double_counted() {
        let (tx, rx) = channel();
        let t = Ticket::ensemble(rx, 0, shared(2, 2, 1_000), Vec::new());
        tx.send((0, Response::Logits(vec![2.0, 2.0]))).unwrap();
        tx.send((0, Response::Logits(vec![99.0, 99.0]))).unwrap(); // hedge double-send
        tx.send((1, Response::Logits(vec![4.0, 4.0]))).unwrap();
        match t.wait() {
            Response::Merged { logits, members_merged } => {
                assert_eq!(members_merged, 2);
                assert_eq!(logits, vec![3.0, 3.0], "first slot-0 response wins");
            }
            other => panic!("expected merged response, got {other:?}"),
        }
    }

    #[test]
    fn rejected_member_degrades_quorum_instead_of_failing_ticket() {
        let (tx, rx) = channel();
        let t = Ticket::ensemble(rx, 0, shared(3, 3, 1_000), Vec::new());
        tx.send((0, Response::Logits(vec![1.0, 3.0]))).unwrap();
        tx.send((1, Response::Rejected(RejectReason::WorkerFailed))).unwrap();
        tx.send((2, Response::Logits(vec![3.0, 5.0]))).unwrap();
        match t.wait() {
            Response::Merged { logits, members_merged } => {
                assert_eq!(members_merged, 2);
                assert_eq!(logits, vec![2.0, 4.0]);
            }
            other => panic!("expected merged response, got {other:?}"),
        }
    }

    #[test]
    fn admission_failed_members_are_preresolved() {
        let (tx, rx) = channel();
        let t =
            Ticket::ensemble(rx, 0, shared(2, 2, 1_000), vec![(1, RejectReason::QueueFull)]);
        tx.send((0, Response::Logits(vec![7.0, 9.0]))).unwrap();
        match t.wait() {
            Response::Merged { logits, members_merged } => {
                assert_eq!(members_merged, 1);
                assert_eq!(logits, vec![7.0, 9.0], "mean over one member is identity");
            }
            other => panic!("expected merged response, got {other:?}"),
        }
    }

    #[test]
    fn all_members_rejected_reports_first_reason() {
        let (tx, rx) = channel();
        let t = Ticket::ensemble(rx, 0, shared(2, 2, 1_000), Vec::new());
        tx.send((1, Response::Rejected(RejectReason::QueueFull))).unwrap();
        tx.send((0, Response::Rejected(RejectReason::WorkerFailed))).unwrap();
        assert_eq!(
            t.wait(),
            Response::Rejected(RejectReason::QueueFull),
            "first rejection seen (arrival order) is reported"
        );
    }

    #[test]
    fn disconnected_fanout_resolves_to_worker_failed() {
        let (tx, rx) = channel::<(usize, Response)>();
        drop(tx);
        let t = Ticket::ensemble(rx, 0, shared(3, 3, 1_000), Vec::new());
        assert_eq!(t.wait(), Response::Rejected(RejectReason::WorkerFailed));
    }

    #[test]
    fn quorum_returns_partial_merge_after_deadline() {
        let (tx, rx) = channel();
        // K=1 of 3, 5 ms straggler floor; members 1 and 2 never answer
        let t = Ticket::ensemble(rx, 0, shared(3, 1, 5), Vec::new());
        tx.send((0, Response::Logits(vec![6.0, 10.0]))).unwrap();
        let t0 = Instant::now();
        match t.wait() {
            Response::Merged { logits, members_merged } => {
                assert_eq!(members_merged, 1);
                assert_eq!(logits, vec![6.0, 10.0]);
            }
            other => panic!("expected merged response, got {other:?}"),
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "quorum must not block unboundedly");
    }

    #[test]
    fn wait_timeout_keeps_partial_state_and_absorbs_stragglers_once() {
        let (tx, rx) = channel();
        let t = Ticket::ensemble(rx, 0, shared(2, 2, 10_000), Vec::new());
        tx.send((0, Response::Logits(vec![2.0, 6.0]))).unwrap();
        assert!(
            t.wait_timeout(Duration::from_millis(5)).is_none(),
            "quorum of 2 not met: times out, state retained"
        );
        tx.send((1, Response::Logits(vec![4.0, 2.0]))).unwrap();
        match t.wait_timeout(Duration::from_secs(30)) {
            Some(Response::Merged { logits, members_merged }) => {
                assert_eq!(members_merged, 2);
                assert_eq!(logits, vec![3.0, 4.0]);
            }
            other => panic!("expected merged response, got {other:?}"),
        }
    }

    #[test]
    fn try_wait_polls_ensemble_without_blocking() {
        let (tx, rx) = channel();
        let t = Ticket::ensemble(rx, 0, shared(2, 2, 10_000), Vec::new());
        assert!(t.try_wait().is_none());
        tx.send((0, Response::Logits(vec![1.0, 1.0]))).unwrap();
        assert!(t.try_wait().is_none(), "one of two members is not a quorum");
        tx.send((1, Response::Logits(vec![3.0, 5.0]))).unwrap();
        match t.try_wait() {
            Some(Response::Merged { logits, members_merged }) => {
                assert_eq!(members_merged, 2);
                assert_eq!(logits, vec![2.0, 3.0]);
            }
            other => panic!("expected merged response, got {other:?}"),
        }
    }
}
