//! Ticket-based request path: `try_submit` hands back a [`Ticket`]
//! immediately; the outcome — logits or a typed rejection — arrives
//! through it.
//!
//! The ticket is the unit the ROADMAP's multi-process sharding item
//! needs: it is a one-shot channel whose payload ([`Response`]) is
//! plain data, so an IPC transport can carry the same contract across
//! process boundaries without touching the engine internals.

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::time::Duration;

/// Why a request was not (or will not be) served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The picked shard's admission queue was at its depth bound
    /// (`ShedNewest` rejects the new request, `ShedOldest` evicts the
    /// oldest queued one — both report this reason).
    QueueFull,
    /// The engine is shutting down (or already shut down).
    ShuttingDown,
    /// Input length does not match the model's feature count.
    BadShape {
        /// Expected feature count.
        expected: usize,
        /// Submitted input length.
        got: usize,
    },
    /// The worker shard died before answering (its thread panicked).
    WorkerFailed,
    /// The request named a model (or model version) the registry does
    /// not hold.  `version == 0` means the model id itself is unknown;
    /// a nonzero version means the model exists but that snapshot was
    /// never published.
    UnknownModel {
        /// Requested model id.
        model_id: u64,
        /// Requested snapshot version (`0` = id lookup failed).
        version: u64,
    },
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::QueueFull => write!(f, "admission queue full"),
            RejectReason::ShuttingDown => write!(f, "engine shutting down"),
            RejectReason::BadShape { expected, got } => {
                write!(f, "bad input shape: expected {expected} features, got {got}")
            }
            RejectReason::WorkerFailed => write!(f, "worker shard failed"),
            RejectReason::UnknownModel { model_id, version: 0 } => {
                write!(f, "unknown model id {model_id}")
            }
            RejectReason::UnknownModel { model_id, version } => {
                write!(f, "model {model_id} has no published version {version}")
            }
        }
    }
}

/// Terminal outcome of an admitted request.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Class logits for the submitted sample.
    Logits(Vec<f32>),
    /// The request was admitted but later rejected (evicted by
    /// `ShedOldest`, or its worker died).
    Rejected(RejectReason),
}

impl Response {
    /// Logits if served, `None` on rejection.
    pub fn logits(self) -> Option<Vec<f32>> {
        match self {
            Response::Logits(l) => Some(l),
            Response::Rejected(_) => None,
        }
    }
}

/// Handle to one in-flight request.
pub struct Ticket {
    pub(crate) rx: Receiver<Response>,
    pub(crate) shard: usize,
}

impl Ticket {
    /// Index of the worker shard the request was dispatched to.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Block until the outcome arrives.  A dead worker resolves to
    /// [`Response::Rejected`]`(`[`RejectReason::WorkerFailed`]`)`
    /// instead of panicking.
    pub fn wait(self) -> Response {
        self.rx.recv().unwrap_or(Response::Rejected(RejectReason::WorkerFailed))
    }

    /// Wait up to `timeout`; `None` if no outcome arrived in time (the
    /// ticket stays valid — call again or [`Ticket::wait`]).
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Response> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Some(r),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => {
                Some(Response::Rejected(RejectReason::WorkerFailed))
            }
        }
    }

    /// Non-blocking poll; `None` if the outcome is not ready yet.
    pub fn try_wait(&self) -> Option<Response> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                Some(Response::Rejected(RejectReason::WorkerFailed))
            }
        }
    }
}

/// Reply channel of one queued request.  The engine's ticket path
/// carries a typed [`Response`]; the legacy `ShardedServer::submit`
/// path carries bare logits (rejections there surface as a closed
/// channel, matching the historical behavior).
pub(crate) enum ReplyTx {
    /// `try_submit` path: typed response.
    Ticket(Sender<Response>),
    /// Legacy `submit` path: bare logits.
    Legacy(Sender<Vec<f32>>),
}

impl ReplyTx {
    /// Answer with logits (receiver may have hung up; that's fine).
    pub(crate) fn send_logits(self, logits: Vec<f32>) {
        match self {
            ReplyTx::Ticket(tx) => {
                let _ = tx.send(Response::Logits(logits));
            }
            ReplyTx::Legacy(tx) => {
                let _ = tx.send(logits);
            }
        }
    }

    /// Answer with a rejection (legacy receivers just see the channel
    /// close).
    pub(crate) fn send_rejected(self, reason: RejectReason) {
        match self {
            ReplyTx::Ticket(tx) => {
                let _ = tx.send(Response::Rejected(reason));
            }
            ReplyTx::Legacy(_) => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn ticket_waits_and_times_out() {
        let (tx, rx) = channel();
        let t = Ticket { rx, shard: 3 };
        assert_eq!(t.shard(), 3);
        assert!(t.try_wait().is_none());
        assert!(t.wait_timeout(Duration::from_millis(2)).is_none(), "nothing sent yet");
        tx.send(Response::Logits(vec![1.0, 2.0])).unwrap();
        assert_eq!(t.wait(), Response::Logits(vec![1.0, 2.0]));
    }

    #[test]
    fn dead_worker_resolves_to_worker_failed() {
        let (tx, rx) = channel::<Response>();
        drop(tx);
        let t = Ticket { rx, shard: 0 };
        assert_eq!(t.wait(), Response::Rejected(RejectReason::WorkerFailed));
    }

    #[test]
    fn response_logits_accessor() {
        assert_eq!(Response::Logits(vec![0.5]).logits(), Some(vec![0.5]));
        assert_eq!(Response::Rejected(RejectReason::QueueFull).logits(), None);
    }

    #[test]
    fn reject_reasons_display() {
        assert!(format!("{}", RejectReason::QueueFull).contains("full"));
        assert!(format!("{}", RejectReason::BadShape { expected: 784, got: 3 }).contains("784"));
        assert!(format!("{}", RejectReason::UnknownModel { model_id: 9, version: 0 })
            .contains("unknown model id 9"));
        assert!(format!("{}", RejectReason::UnknownModel { model_id: 9, version: 4 })
            .contains("no published version 4"));
    }
}
