//! Pluggable shard dispatch: how the engine picks the worker shard for
//! an incoming request.
//!
//! Replaces the old hardcoded dispatch enum with a
//! [`DispatchPolicy`] trait object plus three built-ins:
//!
//! * [`RoundRobin`] — strict rotation (deterministic spread, the
//!   interleaver of the serving layer),
//! * [`LeastLoaded`] — fewest in-flight requests, rotating tie-break,
//! * [`EwmaLatency`] — p99-aware: per-shard EWMA of observed request
//!   latency and its variance estimate a tail latency
//!   (`mean + 2.33·σ` ≈ p99 under a normal approximation); the score
//!   is that tail estimate scaled by the shard's current occupancy, so
//!   a shard that has gone slow (e.g. a cold replica, a noisy
//!   neighbor) is routed around instead of piling up queue depth.
//!
//! Workers feed completions back through [`DispatchPolicy::observe`];
//! policies that don't learn ignore it.
//!
//! **Replica groups** need no special casing here: every replica is a
//! physical shard with its own view, so under `EwmaLatency` traffic
//! flows to the replica with the best learned p99, and the engine's
//! candidate filter (closed queues + health-board marks, see
//! [`crate::engine::Engine`]) removes dead replicas before `pick`
//! ever sees them.
//!
//! **Ensemble fan-out** also needs no special casing: the engine calls
//! the policy once per member over that member's shard-block views
//! only (`admit_within`), so `pick` can never route a member's copy of
//! a request onto another member's shards, and — because merge order
//! is fixed by member index, not by completion order — no policy
//! choice can perturb ensemble response bits.
//!
//! Like the admission queues, the learning policies' internal locks
//! are **poison-immune** ([`crate::util::sync::plock`]): a worker
//! thread that panics right after reporting a completion must not
//! leave every future `pick` panicking on a `PoisonError` — the EWMA
//! state is a pair of floats and a counter, consistent at every
//! instruction boundary.

use crate::util::sync::plock;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Instantaneous load view of one shard, passed to [`DispatchPolicy::pick`].
#[derive(Debug, Clone, Copy)]
pub struct ShardView {
    /// Engine shard index this view describes.  The engine filters
    /// dead (closed-queue) shards out of the candidate list before
    /// `pick`, so positions in the slice shift — policies that keep
    /// per-shard state (e.g. [`EwmaLatency`], whose `observe` feedback
    /// is keyed by shard index) must look their state up by this `id`,
    /// never by slice position.
    pub id: usize,
    /// Requests dispatched to the shard and not yet answered
    /// (queued + in execution).
    pub inflight: usize,
    /// Requests sitting in the shard's admission queue right now.
    pub queue_depth: usize,
}

/// A shard-selection strategy.  Implementations must be cheap: `pick`
/// runs on every submit.
pub trait DispatchPolicy: Send + Sync {
    /// Pick a position in `0..views.len()` (`views` is never empty —
    /// it lists the live shards; the engine maps the position back to
    /// an engine shard through [`ShardView::id`]).
    fn pick(&self, views: &[ShardView]) -> usize;

    /// Feedback: a request dispatched to `shard` completed with the
    /// given end-to-end latency.  Default: ignored.
    fn observe(&self, shard: usize, latency_secs: f64) {
        let _ = (shard, latency_secs);
    }

    /// Short policy name for reports/JSON.
    fn name(&self) -> &'static str;
}

/// Strict rotation over the shards.
#[derive(Default)]
pub struct RoundRobin {
    next: AtomicUsize,
}

impl RoundRobin {
    /// New rotation starting at shard 0.
    pub fn new() -> Self {
        Self::default()
    }
}

impl DispatchPolicy for RoundRobin {
    fn pick(&self, views: &[ShardView]) -> usize {
        self.next.fetch_add(1, Ordering::Relaxed) % views.len()
    }

    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// Shard with the fewest in-flight requests; ties break by a rotating
/// start offset so equal shards share the load.
#[derive(Default)]
pub struct LeastLoaded {
    rr: AtomicUsize,
}

impl LeastLoaded {
    /// New policy.
    pub fn new() -> Self {
        Self::default()
    }
}

impl DispatchPolicy for LeastLoaded {
    fn pick(&self, views: &[ShardView]) -> usize {
        let n = views.len();
        if n == 1 {
            return 0;
        }
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        let mut best = start;
        let mut best_load = views[start].inflight;
        for k in 1..n {
            let i = (start + k) % n;
            if views[i].inflight < best_load {
                best = i;
                best_load = views[i].inflight;
            }
        }
        best
    }

    fn name(&self) -> &'static str {
        "least-loaded"
    }
}

/// Per-shard latency statistics for [`EwmaLatency`].
#[derive(Debug, Clone, Copy, Default)]
struct LatencyEwma {
    /// EWMA of latency (seconds); 0 until the first observation.
    mean: f64,
    /// EWMA of squared deviation (variance estimate).
    var: f64,
    /// Observation count (drives the cold-start ramp).
    count: u64,
}

impl LatencyEwma {
    /// Estimated tail latency: `mean + 2.33·σ` (≈ p99 for a normal
    /// latency distribution; a deliberate, documented approximation —
    /// exact per-shard percentiles would need a full histogram on the
    /// submit path).
    fn p99_estimate(&self) -> f64 {
        self.mean + 2.33 * self.var.max(0.0).sqrt()
    }
}

/// p99-aware dispatch: route to the shard with the lowest
/// `tail_latency_estimate × (occupancy + 1)` score.
pub struct EwmaLatency {
    /// Smoothing factor in (0, 1]; larger adapts faster.
    alpha: f64,
    stats: Vec<Mutex<LatencyEwma>>,
    rr: AtomicUsize,
}

impl EwmaLatency {
    /// New policy over `workers` shards with smoothing factor `alpha`.
    pub fn new(workers: usize, alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha in (0,1]");
        EwmaLatency {
            alpha,
            stats: (0..workers.max(1)).map(|_| Mutex::new(LatencyEwma::default())).collect(),
            rr: AtomicUsize::new(0),
        }
    }

    /// Current `(mean, p99_estimate)` of one shard, in seconds.
    pub fn shard_latency(&self, shard: usize) -> (f64, f64) {
        let s = plock(&self.stats[shard]);
        (s.mean, s.p99_estimate())
    }
}

impl DispatchPolicy for EwmaLatency {
    fn pick(&self, views: &[ShardView]) -> usize {
        // every shard is a candidate even if the policy was sized for
        // fewer (shards beyond `stats` just stay cold/unlearned), so an
        // undersized policy never starves the extra shards
        let n = views.len();
        if n <= 1 {
            return 0;
        }
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        let mut best = start;
        let mut best_score = f64::INFINITY;
        for k in 0..n {
            let i = (start + k) % n;
            // per-shard state is keyed by the view's engine shard id,
            // not its slice position — the engine filters dead shards
            // out of the list, shifting positions.  Cold shards (few
            // observations, or beyond the learned set) score as free
            // capacity so every replica gets probed before the EWMA
            // takes over
            let tail = match self.stats.get(views[i].id) {
                Some(cell) => {
                    let st = *plock(cell);
                    if st.count < 4 {
                        0.0
                    } else {
                        st.p99_estimate()
                    }
                }
                None => 0.0,
            };
            let occupancy = (views[i].inflight + views[i].queue_depth + 1) as f64;
            let score = tail * occupancy + occupancy * 1e-9;
            if score < best_score {
                best = i;
                best_score = score;
            }
        }
        best
    }

    fn observe(&self, shard: usize, latency_secs: f64) {
        if shard >= self.stats.len() {
            return;
        }
        let mut s = plock(&self.stats[shard]);
        s.count += 1;
        if s.count == 1 {
            s.mean = latency_secs;
            s.var = 0.0;
        } else {
            let d = latency_secs - s.mean;
            s.mean += self.alpha * d;
            s.var = (1.0 - self.alpha) * (s.var + self.alpha * d * d);
        }
    }

    fn name(&self) -> &'static str {
        "ewma-p99"
    }
}

/// Named dispatch policies for config files and CLI flags.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DispatchKind {
    /// [`RoundRobin`].
    RoundRobin,
    /// [`LeastLoaded`].
    #[default]
    LeastLoaded,
    /// [`EwmaLatency`] with the default smoothing (`alpha = 0.2`).
    EwmaP99,
}

impl DispatchKind {
    /// Parse from a config/CLI string.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "rr" | "round-robin" | "round_robin" => Some(DispatchKind::RoundRobin),
            "ll" | "least-loaded" | "least_loaded" => Some(DispatchKind::LeastLoaded),
            "ewma" | "ewma-p99" | "p99" => Some(DispatchKind::EwmaP99),
            _ => None,
        }
    }

    /// Canonical config/CLI spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            DispatchKind::RoundRobin => "round-robin",
            DispatchKind::LeastLoaded => "least-loaded",
            DispatchKind::EwmaP99 => "ewma-p99",
        }
    }

    /// Build the policy instance for an engine with `workers` shards.
    pub fn instantiate(&self, workers: usize) -> Arc<dyn DispatchPolicy> {
        match self {
            DispatchKind::RoundRobin => Arc::new(RoundRobin::new()),
            DispatchKind::LeastLoaded => Arc::new(LeastLoaded::new()),
            DispatchKind::EwmaP99 => Arc::new(EwmaLatency::new(workers, 0.2)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn views(loads: &[usize]) -> Vec<ShardView> {
        loads
            .iter()
            .enumerate()
            .map(|(id, &l)| ShardView { id, inflight: l, queue_depth: 0 })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let p = RoundRobin::new();
        let v = views(&[0, 0, 0]);
        let picks: Vec<usize> = (0..6).map(|_| p.pick(&v)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_picks_minimum() {
        let p = LeastLoaded::new();
        let v = views(&[5, 1, 3]);
        for _ in 0..8 {
            assert_eq!(p.pick(&v), 1);
        }
    }

    #[test]
    fn least_loaded_ties_rotate() {
        let p = LeastLoaded::new();
        let v = views(&[2, 2]);
        let picks: std::collections::BTreeSet<usize> = (0..4).map(|_| p.pick(&v)).collect();
        assert_eq!(picks.len(), 2, "equal shards share the load");
    }

    #[test]
    fn ewma_cold_start_probes_every_shard() {
        let p = EwmaLatency::new(3, 0.2);
        let v = views(&[0, 0, 0]);
        let picks: std::collections::BTreeSet<usize> = (0..6).map(|_| p.pick(&v)).collect();
        assert_eq!(picks.len(), 3, "rotating start probes all shards when cold");
    }

    #[test]
    fn ewma_routes_around_slow_shard() {
        let p = EwmaLatency::new(2, 0.5);
        // shard 0 is consistently 10× slower than shard 1
        for _ in 0..16 {
            p.observe(0, 0.010);
            p.observe(1, 0.001);
        }
        let (m0, t0) = p.shard_latency(0);
        let (m1, t1) = p.shard_latency(1);
        assert!(m0 > 5.0 * m1, "EWMA learned the asymmetry: {m0} vs {m1}");
        assert!(t0 >= m0 && t1 >= m1, "tail estimate ≥ mean");
        let v = views(&[1, 1]);
        for _ in 0..8 {
            assert_eq!(p.pick(&v), 1, "equal occupancy → faster shard wins");
        }
        // ...until the fast shard is drowning: occupancy scales the score
        let v = views(&[0, 200]);
        assert_eq!(p.pick(&v), 0, "massive queue on the fast shard flips the choice");
    }

    #[test]
    fn ewma_variance_widens_tail() {
        let p = EwmaLatency::new(1, 0.3);
        for i in 0..32 {
            // alternate 1ms / 9ms: mean ~5ms, high variance
            p.observe(0, if i % 2 == 0 { 0.001 } else { 0.009 });
        }
        let (mean, tail) = p.shard_latency(0);
        assert!(tail > mean + 1e-4, "jittery shard gets a wide tail: {mean} → {tail}");
    }

    #[test]
    fn ewma_undersized_policy_still_covers_all_shards() {
        // policy learned 2 shards, engine has 4: the extra shards count
        // as cold capacity instead of being starved
        let p = EwmaLatency::new(2, 0.2);
        for _ in 0..8 {
            p.observe(0, 0.005);
            p.observe(1, 0.005);
        }
        let v = views(&[1, 1, 1, 1]);
        let picks: std::collections::BTreeSet<usize> = (0..16).map(|_| p.pick(&v)).collect();
        assert!(
            picks.contains(&2) && picks.contains(&3),
            "shards beyond the learned set must still receive traffic: {picks:?}"
        );
        p.observe(7, 0.001); // out-of-range feedback is ignored, not a panic
    }

    /// The engine hands `pick` a *filtered* list when shards are dead,
    /// so slice positions shift; the EWMA state must follow the view's
    /// `id`, not its position.
    #[test]
    fn ewma_keys_state_by_shard_id_not_position() {
        let p = EwmaLatency::new(3, 0.5);
        for _ in 0..8 {
            p.observe(0, 0.050); // shard 0: slow
            p.observe(1, 0.001);
            p.observe(2, 0.001);
        }
        // shard 1 died: the candidate list is [shard 0, shard 2]
        let v = vec![
            ShardView { id: 0, inflight: 1, queue_depth: 0 },
            ShardView { id: 2, inflight: 1, queue_depth: 0 },
        ];
        for _ in 0..6 {
            assert_eq!(
                p.pick(&v),
                1,
                "position 1 (shard 2, fast) must win; keying by position would \
                 score it with shard 1's stats"
            );
        }
    }

    #[test]
    fn kind_strings_round_trip() {
        for k in [DispatchKind::RoundRobin, DispatchKind::LeastLoaded, DispatchKind::EwmaP99] {
            assert_eq!(DispatchKind::parse(k.as_str()), Some(k));
            assert_eq!(k.instantiate(2).name(), k.as_str());
        }
        assert_eq!(DispatchKind::parse("random"), None);
    }
}
