//! Registered sequence families: one descriptor type behind every
//! place the system consumes a low discrepancy sequence.
//!
//! A [`SequenceFamily`] is a small, comparable, copyable value that
//! names *which* sequence to construct — Sobol', Owen-scrambled
//! Sobol', Halton, digit-scrambled Halton, or the counter-based PRNG
//! baseline — with one canonical string form (`sobol`, `sobol:owen=7`,
//! `halton:scramble=7`, `prng:seed=3`, …) used uniformly by CLI flags,
//! config JSON, registry checkpoints, and the wire protocol.  The
//! topology builder, the trainer's low-discrepancy batch sampler, and
//! the sweep service all call [`SequenceFamily::build`] instead of
//! hard-coding a concrete generator, so adding a family (e.g. a
//! learned generator in the spirit of Neural LDS, arXiv:2510.03745)
//! is one new `SequenceKind` arm, not a cross-codebase hunt.
//!
//! The descriptor is deliberately *data*, not a trait object: two
//! processes holding equal descriptors build bitwise-identical
//! sequences, which is what lets `registry::ModelSpec` carry one and
//! remote workers rebuild the same topology from the Publish frame.

use super::halton::Halton;
use super::scramble::OwenScramble;
use super::sobol::{Sobol, MAX_DIMS};
use super::Sequence;
use crate::rng::splitmix64;
use crate::topology::PathSource;
use std::fmt;

/// Which generator family a [`SequenceFamily`] names.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SequenceKind {
    /// The Sobol' (0,1)-sequence in base 2 (paper §4.2), optionally
    /// Owen-scrambled (§4.3) and with bad-dimension skipping.
    Sobol,
    /// The Halton sequence in coprime prime bases (paper §6 future
    /// work), optionally digit-scrambled.
    Halton,
    /// Counter-based PRNG baseline ("fake sequence"): splitmix64 of
    /// `(seed, dim, index)`.  Progressive in the index like the real
    /// sequences, but with none of their stratification.
    Prng,
}

/// A buildable, serializable descriptor of one sequence configuration.
///
/// Canonical string grammar (`parse` ∘ `canonical` is the identity):
///
/// ```text
/// sobol                  Sobol', skip_bad_dims, unscrambled (default)
/// sobol:owen=7           Owen-scrambled Sobol', seed 7
/// sobol:skip=0           Sobol' without bad-dimension skipping
/// sobol:owen=7,skip=0    both
/// halton                 Halton, unscrambled
/// halton:scramble=7      digit-scrambled Halton, seed 7
/// prng                   PRNG baseline, seed 0
/// prng:seed=3            PRNG baseline, seed 3
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SequenceFamily {
    /// Generator family.
    pub kind: SequenceKind,
    /// Scramble seed (Sobol' Owen / Halton digit) or the PRNG seed;
    /// `None` = unscrambled (PRNG: seed 0).
    pub scramble: Option<u64>,
    /// Skip badly-paired dimensions during topology generation
    /// (meaningful for Sobol' only; see §4.3).
    pub skip_bad_dims: bool,
}

impl Default for SequenceFamily {
    /// Today's hard-coded configuration: Sobol' with bad-dimension
    /// skipping and no scrambling.  Existing `ModelSpec`s therefore
    /// stay bitwise-identical.
    fn default() -> Self {
        SequenceFamily { kind: SequenceKind::Sobol, scramble: None, skip_bad_dims: true }
    }
}

impl SequenceFamily {
    /// Plain Sobol' (the default).
    pub fn sobol() -> Self {
        Self::default()
    }

    /// Owen-scrambled Sobol'.
    pub fn sobol_scrambled(seed: u64) -> Self {
        SequenceFamily { kind: SequenceKind::Sobol, scramble: Some(seed), skip_bad_dims: true }
    }

    /// Plain Halton.
    pub fn halton() -> Self {
        SequenceFamily { kind: SequenceKind::Halton, scramble: None, skip_bad_dims: false }
    }

    /// Digit-scrambled Halton.
    pub fn halton_scrambled(seed: u64) -> Self {
        SequenceFamily { kind: SequenceKind::Halton, scramble: Some(seed), skip_bad_dims: false }
    }

    /// Counter-based PRNG baseline.
    pub fn prng(seed: u64) -> Self {
        SequenceFamily { kind: SequenceKind::Prng, scramble: Some(seed), skip_bad_dims: false }
    }

    /// Every family the test-suite exercises (one representative per
    /// registered configuration class).
    pub fn registered() -> Vec<SequenceFamily> {
        vec![
            Self::sobol(),
            Self::sobol_scrambled(7),
            SequenceFamily { kind: SequenceKind::Sobol, scramble: None, skip_bad_dims: false },
            Self::halton(),
            Self::halton_scrambled(7),
            Self::prng(3),
        ]
    }

    /// Parse the canonical string form (see type docs for the grammar).
    pub fn parse(s: &str) -> Result<SequenceFamily, String> {
        let (head, rest) = match s.split_once(':') {
            Some((h, r)) => (h, Some(r)),
            None => (s, None),
        };
        let mut fam = match head {
            "sobol" => Self::sobol(),
            "halton" => Self::halton(),
            "prng" => SequenceFamily { kind: SequenceKind::Prng, scramble: None, skip_bad_dims: false },
            other => return Err(format!("unknown sequence family '{other}'")),
        };
        if let Some(rest) = rest {
            for kv in rest.split(',') {
                let (key, val) = kv
                    .split_once('=')
                    .ok_or_else(|| format!("expected key=value in sequence param '{kv}'"))?;
                match (fam.kind, key) {
                    (SequenceKind::Sobol, "owen")
                    | (SequenceKind::Halton, "scramble")
                    | (SequenceKind::Prng, "seed") => {
                        let seed: u64 = val
                            .parse()
                            .map_err(|_| format!("bad integer '{val}' for sequence '{key}'"))?;
                        fam.scramble = Some(seed);
                    }
                    (SequenceKind::Sobol, "skip") => {
                        fam.skip_bad_dims = match val {
                            "0" | "false" => false,
                            "1" | "true" => true,
                            _ => return Err(format!("bad skip value '{val}' (want 0/1)")),
                        };
                    }
                    _ => return Err(format!("unknown param '{key}' for family '{head}'")),
                }
            }
        }
        Ok(fam)
    }

    /// The canonical string form; `parse` of this yields `self`.
    pub fn canonical(&self) -> String {
        match self.kind {
            SequenceKind::Sobol => {
                let mut params = Vec::new();
                if let Some(s) = self.scramble {
                    params.push(format!("owen={s}"));
                }
                if !self.skip_bad_dims {
                    params.push("skip=0".to_string());
                }
                if params.is_empty() {
                    "sobol".to_string()
                } else {
                    format!("sobol:{}", params.join(","))
                }
            }
            SequenceKind::Halton => match self.scramble {
                None => "halton".to_string(),
                Some(s) => format!("halton:scramble={s}"),
            },
            SequenceKind::Prng => match self.scramble {
                None => "prng".to_string(),
                Some(s) => format!("prng:seed={s}"),
            },
        }
    }

    /// Construct the concrete sequence over `dims` dimensions.
    pub fn build(&self, dims: usize) -> Box<dyn Sequence + Send + Sync> {
        match (self.kind, self.scramble) {
            (SequenceKind::Sobol, None) => Box::new(Sobol::new(dims)),
            (SequenceKind::Sobol, Some(s)) => Box::new(OwenScramble::new(Sobol::new(dims), s)),
            (SequenceKind::Halton, None) => Box::new(Halton::new(dims)),
            (SequenceKind::Halton, Some(s)) => Box::new(Halton::scrambled(dims, s)),
            (SequenceKind::Prng, seed) => {
                Box::new(PrngSequence { dims, seed: seed.unwrap_or(0) })
            }
        }
    }

    /// Dimension budget the topology builder should construct the
    /// sequence with for a `layers`-layer network: Sobol' keeps its
    /// full table so bad-dimension skipping can scan ahead; Halton and
    /// the PRNG use exactly one dimension per layer.
    pub fn topology_dims(&self, layers: usize) -> usize {
        match self.kind {
            SequenceKind::Sobol => MAX_DIMS,
            SequenceKind::Halton | SequenceKind::Prng => layers,
        }
    }

    /// The dedicated sign component for
    /// [`crate::topology::SignPolicy::SequenceDimension`]: a sequence
    /// plus the dimension index to threshold at ½ (paper §4.3).
    pub fn sign_sequence(&self, layers: usize) -> (Box<dyn Sequence + Send + Sync>, usize) {
        match self.kind {
            // far from the topology dims
            SequenceKind::Sobol => (self.build(MAX_DIMS), MAX_DIMS - 1),
            // the next unused prime-base dimension
            SequenceKind::Halton | SequenceKind::Prng => (self.build(layers + 1), layers),
        }
    }

    /// Translate a topology [`PathSource`] into a family descriptor.
    /// `Drand48` has no counterpart (it is sequential, not indexed) and
    /// maps to `None`.
    pub fn from_source(source: &PathSource) -> Option<SequenceFamily> {
        match source {
            PathSource::Sobol { skip_bad_dims, scramble_seed } => Some(SequenceFamily {
                kind: SequenceKind::Sobol,
                scramble: *scramble_seed,
                skip_bad_dims: *skip_bad_dims,
            }),
            PathSource::Halton { scramble_seed } => Some(SequenceFamily {
                kind: SequenceKind::Halton,
                scramble: *scramble_seed,
                skip_bad_dims: false,
            }),
            PathSource::Random { seed } => Some(Self::prng(*seed)),
            PathSource::Drand48 { .. } => None,
        }
    }

    /// The topology [`PathSource`] this family selects.
    pub fn to_source(&self) -> PathSource {
        match self.kind {
            SequenceKind::Sobol => PathSource::Sobol {
                skip_bad_dims: self.skip_bad_dims,
                scramble_seed: self.scramble,
            },
            SequenceKind::Halton => PathSource::Halton { scramble_seed: self.scramble },
            SequenceKind::Prng => PathSource::Random { seed: self.scramble.unwrap_or(0) },
        }
    }
}

impl fmt::Display for SequenceFamily {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.canonical())
    }
}

impl std::str::FromStr for SequenceFamily {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::parse(s)
    }
}

/// Counter-based PRNG "sequence": component `(index, dim)` is the top
/// 32 bits of `splitmix64(seed ^ dim<<40 ^ index·φ)` — exactly the
/// draw the topology builder's random walk has always used, so routing
/// `PathSource::Random` through the unified build path is bitwise
/// neutral.  Progressive in the index; no stratification.
#[derive(Debug, Clone)]
pub struct PrngSequence {
    dims: usize,
    seed: u64,
}

impl PrngSequence {
    /// PRNG sequence over `dims` dimensions.
    pub fn new(dims: usize, seed: u64) -> Self {
        PrngSequence { dims, seed }
    }
}

impl Sequence for PrngSequence {
    fn dims(&self) -> usize {
        self.dims
    }

    fn component_u32(&self, index: u64, dim: usize) -> u32 {
        let h = splitmix64(self.seed ^ (dim as u64) << 40 ^ index.wrapping_mul(0x9E3779B97F4A7C15));
        (h >> 32) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_round_trips_for_all_registered() {
        for fam in SequenceFamily::registered() {
            let s = fam.canonical();
            let back = SequenceFamily::parse(&s).expect(&s);
            assert_eq!(back, fam, "{s}");
        }
    }

    #[test]
    fn parse_accepts_documented_forms() {
        assert_eq!(SequenceFamily::parse("sobol").unwrap(), SequenceFamily::sobol());
        assert_eq!(
            SequenceFamily::parse("sobol:owen=7").unwrap(),
            SequenceFamily::sobol_scrambled(7)
        );
        assert_eq!(
            SequenceFamily::parse("sobol:owen=7,skip=0").unwrap(),
            SequenceFamily { kind: SequenceKind::Sobol, scramble: Some(7), skip_bad_dims: false }
        );
        assert_eq!(SequenceFamily::parse("halton").unwrap(), SequenceFamily::halton());
        assert_eq!(
            SequenceFamily::parse("halton:scramble=9").unwrap(),
            SequenceFamily::halton_scrambled(9)
        );
        assert_eq!(SequenceFamily::parse("prng:seed=3").unwrap(), SequenceFamily::prng(3));
        assert!(SequenceFamily::parse("niederreiter").is_err());
        assert!(SequenceFamily::parse("sobol:seed=3").is_err());
        assert!(SequenceFamily::parse("halton:owen=3").is_err());
        assert!(SequenceFamily::parse("sobol:owen=x").is_err());
    }

    #[test]
    fn source_round_trip() {
        for fam in SequenceFamily::registered() {
            let src = fam.to_source();
            let back = SequenceFamily::from_source(&src).unwrap();
            // `prng` without an explicit seed normalizes to seed 0
            let want = if fam.kind == SequenceKind::Prng && fam.scramble.is_none() {
                SequenceFamily::prng(0)
            } else {
                fam
            };
            assert_eq!(back, want);
        }
        assert!(SequenceFamily::from_source(&PathSource::Drand48 { seed: 1 }).is_none());
    }

    #[test]
    fn prng_matches_random_walk_hash() {
        // the unified topology path must reproduce build_random bitwise
        let seq = PrngSequence::new(4, 42);
        for l in 0..4usize {
            for p in 0..64u64 {
                let h = splitmix64(42 ^ (l as u64) << 40 ^ p.wrapping_mul(0x9E3779B97F4A7C15));
                let n = 300u64;
                assert_eq!(seq.map_to(p, l, n as usize), (((h >> 32) * n) >> 32) as usize);
            }
        }
    }

    #[test]
    fn build_respects_kind_and_scramble() {
        let plain = SequenceFamily::sobol().build(4);
        let scr = SequenceFamily::sobol_scrambled(7).build(4);
        assert_ne!(plain.component_u32(5, 1), scr.component_u32(5, 1));
        let h = SequenceFamily::halton().build(3);
        // dim 1 is base 3: first nonzero value is 1/3
        assert!((h.component(1, 1) - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn map_block_matches_map_to_for_every_family() {
        for fam in SequenceFamily::registered() {
            let dims = fam.topology_dims(3).min(4);
            let seq = fam.build(dims);
            for d in 0..dims.min(3) {
                for n in [8usize, 27, 300] {
                    let block = seq.map_block(d, 64, n);
                    let direct: Vec<usize> = (0..64u64).map(|i| seq.map_to(i, d, n)).collect();
                    assert_eq!(block, direct, "{} dim {d} n {n}", fam.canonical());
                }
            }
        }
    }
}
