//! The van der Corput sequence Φ_b — the 1-dimensional prototype of all
//! radical-inverse based low discrepancy sequences (paper §4.2).
//!
//! Φ_b mirrors the base-b digit expansion of the index at the radix
//! point.  In base 2 this is exactly a bit reversal, which is why the
//! paper notes the hardware realization "amounts to bit reversal"
//! (§4.4).

use crate::util::bit_reverse;

/// Radical inverse Φ₂(i) as a 32-bit fixed-point fraction (numerator of
/// x over 2^32): the 32-bit reversal of `i`.
#[inline]
pub fn phi2_u32(i: u64) -> u32 {
    (i as u32).reverse_bits()
}

/// Radical inverse Φ₂(i) in [0,1).
#[inline]
pub fn phi2(i: u64) -> f64 {
    phi2_u32(i) as f64 * (1.0 / 4294967296.0)
}

/// Radical inverse Φ_b(i) in [0,1) for an arbitrary base `b ≥ 2`.
pub fn phi(b: u32, mut i: u64) -> f64 {
    assert!(b >= 2);
    let inv_b = 1.0 / b as f64;
    let mut inv = inv_b;
    let mut x = 0.0;
    while i > 0 {
        x += (i % b as u64) as f64 * inv;
        i /= b as u64;
        inv *= inv_b;
    }
    x
}

/// The permutation of {0..2^m-1} induced by the first 2^m van der Corput
/// points: `perm[i] = floor(2^m · Φ₂(i))` — i.e. m-bit reversal.
pub fn vdc_permutation(m: u32) -> Vec<u32> {
    assert!(m <= 31);
    (0..1u32 << m).map(|i| bit_reverse(i, m)).collect()
}

/// Inverse of [`vdc_permutation`]; bit reversal is an involution so it is
/// the same permutation, exposed separately for API symmetry with the
/// Sobol' inverse (paper §4.4 backpropagation addressing).
pub fn vdc_inverse_permutation(m: u32) -> Vec<u32> {
    vdc_permutation(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_points_base2() {
        // 0, 1/2, 1/4, 3/4, 1/8, 5/8, 3/8, 7/8
        let expect = [0.0, 0.5, 0.25, 0.75, 0.125, 0.625, 0.375, 0.875];
        for (i, &e) in expect.iter().enumerate() {
            assert!((phi2(i as u64) - e).abs() < 1e-12, "i={i}");
            assert!((phi(2, i as u64) - e).abs() < 1e-12, "i={i} generic");
        }
    }

    #[test]
    fn first_points_base3() {
        // 0, 1/3, 2/3, 1/9, 4/9, 7/9
        let expect = [0.0, 1.0 / 3.0, 2.0 / 3.0, 1.0 / 9.0, 4.0 / 9.0, 7.0 / 9.0];
        for (i, &e) in expect.iter().enumerate() {
            assert!((phi(3, i as u64) - e).abs() < 1e-12, "i={i}");
        }
    }

    #[test]
    fn paper_permutation_example() {
        // Paper §4.2: 16·Φ₂(i) for i=0..16.
        let p = vdc_permutation(4);
        assert_eq!(p, vec![0, 8, 4, 12, 2, 10, 6, 14, 1, 9, 5, 13, 3, 11, 7, 15]);
    }

    #[test]
    fn vdc_blocks_are_permutations() {
        // Every contiguous block k·2^m .. (k+1)·2^m yields a permutation
        // of {0..2^m-1} under floor(2^m Φ₂) — the (0,1)-sequence property.
        for m in [2u32, 4, 6] {
            let n = 1u64 << m;
            for k in 0..4u64 {
                let mut seen = vec![false; n as usize];
                for i in k * n..(k + 1) * n {
                    let v = (phi2_u32(i) as u64 * n as u64 >> 32) as usize;
                    assert!(!seen[v], "m={m} k={k} duplicate {v}");
                    seen[v] = true;
                }
            }
        }
    }

    #[test]
    fn inverse_is_involution() {
        let m = 6;
        let p = vdc_permutation(m);
        let inv = vdc_inverse_permutation(m);
        for i in 0..p.len() {
            assert_eq!(inv[p[i] as usize], i as u32);
        }
    }

    #[test]
    fn fixed_point_and_float_agree() {
        for i in 0..1000u64 {
            let a = phi2(i);
            let b = phi2_u32(i) as f64 / 4294967296.0;
            assert_eq!(a, b);
        }
    }
}
