//! Property checkers for digital nets and sequences.
//!
//! These verify — and let tests/benches *demonstrate* — the structural
//! claims of the paper:
//!
//! * each Sobol' component is a (0,1)-sequence ⇒ progressive
//!   permutations ([`is_progressive_permutation`]),
//! * quality of 2-D projections via the exact t-value of the first 2^m
//!   points ([`t_value_2d`]) — the diagnostic behind "skipping bad
//!   dimensions" (paper §4.3, Table 1 caption).

use super::Sequence;

/// Check that block `k` (of length 2^m) of component `dim` induces a
/// permutation of {0,…,2^m−1} under `floor(2^m · x)`.
pub fn is_progressive_permutation(seq: &dyn Sequence, dim: usize, m: u32, k: u64) -> bool {
    let n = 1u64 << m;
    let mut seen = vec![false; n as usize];
    for i in k * n..(k + 1) * n {
        let slot = seq.map_to(i, dim, n as usize);
        if seen[slot] {
            return false;
        }
        seen[slot] = true;
    }
    true
}

/// Extract the permutation of block `k`: element i-within-block → slot.
pub fn block_permutation(seq: &dyn Sequence, dim: usize, m: u32, k: u64) -> Vec<u32> {
    let n = 1u64 << m;
    (k * n..(k + 1) * n).map(|i| seq.map_to(i, dim, n as usize) as u32).collect()
}

/// Exact t-value of the 2-D projection (dima, dimb) of the first 2^m
/// points: the smallest t such that every elementary interval of volume
/// 2^{t−m} contains exactly 2^t points.
///
/// Small t = well stratified pair; t = m means no guarantee beyond the
/// trivial one (the telltale of a "bad" dimension pair the topology
/// builder should skip).
pub fn t_value_2d(seq: &dyn Sequence, dima: usize, dimb: usize, m: u32) -> u32 {
    let n = 1u64 << m;
    let pts: Vec<(u32, u32)> = (0..n)
        .map(|i| {
            (
                seq.component_u32(i, dima) >> (32 - m.max(1)),
                seq.component_u32(i, dimb) >> (32 - m.max(1)),
            )
        })
        .collect();
    't_loop: for t in 0..=m {
        // Every split m = q + r with q+r = m - t must have exactly 2^t
        // points per cell of the 2^q × 2^r grid.
        let cells_per_axis_budget = m - t;
        for q in 0..=cells_per_axis_budget {
            let r = cells_per_axis_budget - q;
            let mut counts = vec![0u32; 1usize << (q + r)];
            for &(a, b) in &pts {
                let ca = (a >> (m - q).min(31)) as usize & ((1usize << q) - 1).max(0);
                let cb = (b >> (m - r).min(31)) as usize & ((1usize << r) - 1).max(0);
                counts[(ca << r) | cb] += 1;
            }
            let want = 1u32 << t;
            if counts.iter().any(|&c| c != want) {
                continue 't_loop;
            }
        }
        return t;
    }
    m
}

/// Star-discrepancy style diagnostic: max absolute deviation of the
/// empirical CDF over a grid of anchored boxes for a dimension pair.
/// Cheap proxy used in benches to contrast LDS vs PRNG uniformity.
pub fn box_discrepancy_2d(seq: &dyn Sequence, dima: usize, dimb: usize, n: u64, grid: u32) -> f64 {
    let pts: Vec<(f64, f64)> =
        (0..n).map(|i| (seq.component(i, dima), seq.component(i, dimb))).collect();
    let mut worst: f64 = 0.0;
    for gx in 1..=grid {
        for gy in 1..=grid {
            let bx = gx as f64 / grid as f64;
            let by = gy as f64 / grid as f64;
            let inside = pts.iter().filter(|&&(x, y)| x < bx && y < by).count();
            let dev = (inside as f64 / n as f64 - bx * by).abs();
            worst = worst.max(dev);
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qmc::sobol::Sobol;
    use crate::rng::{Pcg32, Rng};

    /// A fake "sequence" backed by a PRNG snapshot, for baselines.
    pub struct RandomPoints {
        pts: Vec<Vec<u32>>,
    }

    impl RandomPoints {
        pub fn new(dims: usize, n: usize, seed: u64) -> Self {
            let mut rng = Pcg32::seeded(seed);
            let pts = (0..n).map(|_| (0..dims).map(|_| rng.next_u32()).collect()).collect();
            RandomPoints { pts }
        }
    }

    impl Sequence for RandomPoints {
        fn dims(&self) -> usize {
            self.pts.first().map_or(0, |p| p.len())
        }
        fn component_u32(&self, index: u64, dim: usize) -> u32 {
            self.pts[index as usize][dim]
        }
    }

    #[test]
    fn sobol_blocks_are_permutations_random_are_not() {
        let sobol = Sobol::new(4);
        for d in 0..4 {
            for k in 0..3 {
                assert!(is_progressive_permutation(&sobol, d, 5, k));
            }
        }
        // Random points of the same size essentially never form
        // permutations for m=5 (probability 32!/32^32 ≈ 1e-13).
        let rnd = RandomPoints::new(2, 32, 3);
        assert!(!is_progressive_permutation(&rnd, 0, 5, 0));
    }

    #[test]
    fn block_permutation_contents() {
        let sobol = Sobol::new(2);
        let p = block_permutation(&sobol, 0, 4, 0);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..16).collect::<Vec<u32>>());
        assert_eq!(p, vec![0, 8, 4, 12, 2, 10, 6, 14, 1, 9, 5, 13, 3, 11, 7, 15]);
    }

    #[test]
    fn t_value_good_pair_is_small() {
        let sobol = Sobol::new(3);
        // dims (0,1) of Sobol' are a (0,m,2)-net in base 2: t = 0.
        assert_eq!(t_value_2d(&sobol, 0, 1, 6), 0);
    }

    #[test]
    fn t_value_random_is_large() {
        let rnd = RandomPoints::new(2, 64, 11);
        let t = t_value_2d(&rnd, 0, 1, 6);
        assert!(t >= 4, "random points should have poor t-value, got {t}");
    }

    #[test]
    fn discrepancy_lds_beats_random() {
        let sobol = Sobol::new(2);
        let rnd = RandomPoints::new(2, 1024, 17);
        let d_lds = box_discrepancy_2d(&sobol, 0, 1, 1024, 8);
        let d_rnd = box_discrepancy_2d(&rnd, 0, 1, 1024, 8);
        assert!(
            d_lds < d_rnd,
            "LDS discrepancy {d_lds} should beat random {d_rnd}"
        );
    }
}
