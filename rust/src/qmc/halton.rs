//! The Halton sequence — the classic radical-inverse sequence in
//! coprime bases — as an alternative topology generator (paper §6
//! future work: *"we like to look at more low-discrepancy sequences"*).
//!
//! Component j is Φ_{b_j}(i) for the j-th prime base.  Unlike the
//! Sobol' sequence, components in base b stratify per blocks of b^m
//! (not 2^m), so the progressive-permutation property holds for
//! power-of-`b_j` block sizes: only dimension 0 (base 2) matches the
//! power-of-two hardware blocking of §4.4.  The topology builder exposes
//! Halton to quantify exactly that trade-off (see
//! `bench_hw_memory`-style comparisons in the tests below).
//!
//! Scrambling: per-digit multiplicative scrambling (a fixed multiplier
//! coprime to the base per dimension) counters the well-known linear
//! correlations of high Halton dimensions.

use super::Sequence;
use crate::rng::splitmix64;

/// First 16 primes (more dimensions than any layer stack here needs).
const PRIMES: [u32; 16] = [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53];

/// The Halton sequence with optional digit scrambling.
#[derive(Debug, Clone)]
pub struct Halton {
    dims: usize,
    /// Per-dimension digit multiplier (1 = unscrambled).
    multipliers: Vec<u32>,
}

impl Halton {
    /// Unscrambled Halton sequence.
    pub fn new(dims: usize) -> Self {
        assert!(dims <= PRIMES.len(), "at most {} Halton dimensions", PRIMES.len());
        Halton { dims, multipliers: vec![1; dims] }
    }

    /// Scrambled variant: per-dimension multipliers derived from `seed`,
    /// coprime to (i.e. non-zero mod) the base.  Base 2 admits only the
    /// identity multiplier, so dimension 0 is unaffected (the pow-2
    /// hardware dimension stays canonical).
    pub fn scrambled(dims: usize, seed: u64) -> Self {
        assert!(dims <= PRIMES.len());
        let multipliers = (0..dims)
            .map(|d| {
                let b = PRIMES[d];
                1 + (splitmix64(seed ^ (d as u64) << 7) % (b as u64 - 1).max(1)) as u32
            })
            .collect();
        Halton { dims, multipliers }
    }

    /// Base of dimension `dim`.
    pub fn base(&self, dim: usize) -> u32 {
        PRIMES[dim]
    }
}

impl Sequence for Halton {
    fn dims(&self) -> usize {
        self.dims
    }

    fn component_u32(&self, index: u64, dim: usize) -> u32 {
        let (num, den) = self.radical_parts(index, dim);
        // exact rational → 32-bit fraction (floor)
        (((num as u128) << 32) / den as u128) as u32
    }

    fn map_to(&self, index: u64, dim: usize, n: usize) -> usize {
        // exact: floor(n · num/den) in integer arithmetic.  Non-dyadic
        // bases have slot boundaries that f32/f64 fractions cannot
        // represent, so the default fixed-point path would round below
        // boundaries and break the permutation property.
        let (num, den) = self.radical_parts(index, dim);
        ((num as u128 * n as u128) / den as u128) as usize
    }

    fn map_block(&self, dim: usize, count: usize, n: usize) -> Vec<usize> {
        // point-wise so every slot goes through the exact-rational
        // `map_to` above; the fixed-point default would round below
        // non-dyadic slot boundaries
        (0..count as u64).map(|i| self.map_to(i, dim, n)).collect()
    }
}

impl Halton {
    /// Radical inverse as an exact rational `num / den`, `den = b^digits`.
    fn radical_parts(&self, mut index: u64, dim: usize) -> (u64, u64) {
        let b = PRIMES[dim] as u64;
        let mult = self.multipliers[dim] as u64;
        let mut num = 0u64;
        let mut den = 1u64;
        while index > 0 {
            let digit = (index % b * mult) % b;
            num = num * b + digit;
            den *= b;
            index /= b;
        }
        (num, den.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qmc::nets::is_progressive_permutation;

    #[test]
    fn dim0_is_van_der_corput_base2() {
        let h = Halton::new(2);
        for i in 0..256u64 {
            let want = crate::qmc::vdc::phi2(i);
            let got = h.component(i, 0);
            assert!((want - got).abs() < 1e-9, "i={i}");
        }
    }

    #[test]
    fn dim1_base3_values() {
        let h = Halton::new(2);
        let expect = [0.0, 1.0 / 3.0, 2.0 / 3.0, 1.0 / 9.0, 4.0 / 9.0, 7.0 / 9.0];
        for (i, &e) in expect.iter().enumerate() {
            assert!((h.component(i as u64, 1) - e).abs() < 1e-9, "i={i}");
        }
    }

    #[test]
    fn stratifies_in_its_own_base_blocks() {
        // base-b component: every contiguous block of b^m points is a
        // permutation of b^m slots
        let h = Halton::new(3);
        for (dim, b) in [(0usize, 2u64), (1, 3), (2, 5)] {
            let n = b * b; // b^2 slots
            for k in 0..3u64 {
                let mut seen = vec![false; n as usize];
                for i in k * n..(k + 1) * n {
                    let slot = h.map_to(i, dim, n as usize);
                    assert!(!seen[slot], "dim {dim} block {k} dup {slot}");
                    seen[slot] = true;
                }
            }
        }
    }

    #[test]
    fn power_of_two_blocks_only_guaranteed_for_base2() {
        // the §4.4 hardware point: only dimension 0 forms permutations
        // over power-of-two blocks; base-3 generally does not.
        let h = Halton::new(2);
        assert!(is_progressive_permutation(&h, 0, 4, 0));
        let mut all_perm = true;
        for k in 0..8 {
            if !is_progressive_permutation(&h, 1, 4, k) {
                all_perm = false;
            }
        }
        assert!(!all_perm, "base-3 should break pow-2 permutation blocks somewhere");
    }

    #[test]
    fn scrambling_preserves_base_stratification() {
        let h = Halton::scrambled(3, 1174);
        for (dim, b) in [(0usize, 2u64), (1, 3), (2, 5)] {
            let n = b * b;
            let mut seen = vec![false; n as usize];
            for i in 0..n {
                let slot = h.map_to(i, dim, n as usize);
                assert!(!seen[slot], "dim {dim}");
                seen[slot] = true;
            }
        }
    }

    #[test]
    fn scrambles_differ_and_are_deterministic() {
        // compare on a high-base dimension (base 11 → 10 multipliers)
        // where distinct seeds almost surely pick distinct multipliers
        let dim = 4;
        let mut distinct = 0;
        for seed in 1..=4u64 {
            let a = Halton::scrambled(6, seed);
            let b = Halton::scrambled(6, seed + 10);
            let a2 = Halton::scrambled(6, seed);
            let same_ab =
                (1..64u64).filter(|&i| a.component_u32(i, dim) == b.component_u32(i, dim)).count();
            if same_ab < 40 {
                distinct += 1;
            }
            for i in 0..64u64 {
                assert_eq!(a.component_u32(i, dim), a2.component_u32(i, dim));
            }
        }
        assert!(distinct >= 2, "most seed pairs should scramble differently");
    }

    #[test]
    fn mean_is_uniform() {
        let h = Halton::new(4);
        for d in 0..4 {
            let m: f64 = (0..2048).map(|i| h.component(i, d)).sum::<f64>() / 2048.0;
            assert!((m - 0.5).abs() < 0.02, "dim {d} mean {m}");
        }
    }
}
