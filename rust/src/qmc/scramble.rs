//! Scrambling of low discrepancy sequences (paper §4.3, Table 1).
//!
//! Low dimensional projections of the Sobol' sequence can exhibit very
//! regular correlations; scrambling [Owe95] decorrelates dimensions while
//! *preserving the (0,1)-sequence property per component* — every
//! contiguous block of 2^m scrambled values still stratifies perfectly,
//! so the progressive-permutation network construction is unaffected.
//!
//! Two scramblers are provided:
//!
//! * [`XorScramble`] — digital shift: XOR with a per-dimension random
//!   word.  Cheapest; preserves all digital-net properties.
//! * [`OwenScramble`] — nested uniform scrambling via the hash-based
//!   construction (Laine-Karras style, a practical stand-in for full
//!   Owen scrambling trees); also preserves per-component
//!   stratification.

use super::{sobol::Sobol, Sequence};
use crate::rng::splitmix64;

/// Digital-shift (XOR) scrambling of an underlying sequence.
#[derive(Debug, Clone)]
pub struct XorScramble<S: Sequence> {
    inner: S,
    shifts: Vec<u32>,
}

impl<S: Sequence> XorScramble<S> {
    /// Derive one shift word per dimension from `seed`.
    pub fn new(inner: S, seed: u64) -> Self {
        let shifts = (0..inner.dims())
            .map(|d| (splitmix64(seed ^ (d as u64).wrapping_mul(0xA24BAED4963EE407)) >> 32) as u32)
            .collect();
        XorScramble { inner, shifts }
    }
}

impl<S: Sequence> Sequence for XorScramble<S> {
    fn dims(&self) -> usize {
        self.inner.dims()
    }

    fn component_u32(&self, index: u64, dim: usize) -> u32 {
        self.inner.component_u32(index, dim) ^ self.shifts[dim]
    }

    fn component_block(&self, dim: usize, n: usize) -> Vec<u32> {
        let mut block = self.inner.component_block(dim, n);
        for v in &mut block {
            *v ^= self.shifts[dim];
        }
        block
    }
}

/// Hash-based nested uniform (Owen-style) scrambling.
///
/// Implements the bit-by-bit scramble where the flip of output bit k
/// depends on all more significant output bits — the defining property of
/// Owen scrambling — using a SplitMix-based keyed hash per prefix.
#[derive(Debug, Clone)]
pub struct OwenScramble<S: Sequence> {
    inner: S,
    seed: u64,
}

impl<S: Sequence> OwenScramble<S> {
    /// Scramble `inner` with `seed` (per-dimension keys are derived).
    pub fn new(inner: S, seed: u64) -> Self {
        OwenScramble { inner, seed }
    }

    #[inline]
    fn scramble_word(&self, x: u32, dim: usize) -> u32 {
        // Laine-Karras style O(1) nested uniform scramble: in
        // reversed-bit space, an "upward-carrying" hash (each bit only
        // influenced by LOWER bits) is exactly an Owen scrambling tree.
        // Reverse → hash → reverse gives the MSB-rooted tree the
        // definition requires.  Far cheaper than a per-bit hash loop
        // (EXPERIMENTS.md §Perf) and preserves the per-component
        // (0,1)-sequence property, which the test-suite checks.
        let key = (splitmix64(self.seed ^ ((dim as u64) << 32 | 0x9E37)) >> 32) as u32;
        let mut v = x.reverse_bits();
        v = v.wrapping_add(key);
        v ^= v.wrapping_mul(0x6C50_B47C);
        v ^= v.wrapping_mul(0xB82F_1E52);
        v ^= v.wrapping_mul(0xC7AF_E638);
        v ^= v.wrapping_mul(0x8D22_F6E6);
        v.reverse_bits()
    }
}

impl<S: Sequence> Sequence for OwenScramble<S> {
    fn dims(&self) -> usize {
        self.inner.dims()
    }

    fn component_u32(&self, index: u64, dim: usize) -> u32 {
        self.scramble_word(self.inner.component_u32(index, dim), dim)
    }

    fn component_block(&self, dim: usize, n: usize) -> Vec<u32> {
        let mut block = self.inner.component_block(dim, n);
        for v in &mut block {
            *v = self.scramble_word(*v, dim);
        }
        block
    }
}

/// Convenience constructors matching Table 1 of the paper: a Sobol'
/// sequence with an optional scrambling seed (`None` = unscrambled).
pub fn sobol_maybe_scrambled(dims: usize, seed: Option<u64>) -> Box<dyn Sequence + Send + Sync> {
    match seed {
        None => Box::new(Sobol::new(dims)),
        Some(s) => Box::new(OwenScramble::new(Sobol::new(dims), s)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn check_01_sequence(seq: &dyn Sequence, dims: usize) {
        for d in 0..dims {
            for m in [3u32, 5] {
                let n = 1u64 << m;
                for k in 0..4u64 {
                    let mut seen = HashSet::new();
                    for i in k * n..(k + 1) * n {
                        let slot = seq.map_to(i, d, n as usize);
                        assert!(seen.insert(slot), "dim {d} m={m} block {k}");
                    }
                }
            }
        }
    }

    #[test]
    fn xor_scramble_preserves_stratification() {
        let seq = XorScramble::new(Sobol::new(6), 1174);
        check_01_sequence(&seq, 6);
    }

    #[test]
    fn owen_scramble_preserves_stratification() {
        for seed in [1174u64, 1741, 4117, 7141] {
            let seq = OwenScramble::new(Sobol::new(6), seed);
            check_01_sequence(&seq, 6);
        }
    }

    #[test]
    fn scrambles_actually_change_points() {
        let plain = Sobol::new(4);
        let x = XorScramble::new(Sobol::new(4), 42);
        let o = OwenScramble::new(Sobol::new(4), 42);
        let mut delta_x = 0;
        let mut delta_o = 0;
        for i in 0..256u64 {
            for d in 0..4 {
                if plain.component_u32(i, d) != x.component_u32(i, d) {
                    delta_x += 1;
                }
                if plain.component_u32(i, d) != o.component_u32(i, d) {
                    delta_o += 1;
                }
            }
        }
        assert!(delta_x > 900, "xor scramble should change nearly all points");
        assert!(delta_o > 900, "owen scramble should change nearly all points");
    }

    #[test]
    fn different_seeds_differ() {
        let a = OwenScramble::new(Sobol::new(2), 1174);
        let b = OwenScramble::new(Sobol::new(2), 1741);
        let same = (0..128u64).filter(|&i| a.component_u32(i, 1) == b.component_u32(i, 1)).count();
        assert!(same < 16, "seeds should give distinct scrambles (same={same})");
    }

    #[test]
    fn scramble_is_deterministic() {
        let a = OwenScramble::new(Sobol::new(3), 7);
        let b = OwenScramble::new(Sobol::new(3), 7);
        for i in 0..64u64 {
            for d in 0..3 {
                assert_eq!(a.component_u32(i, d), b.component_u32(i, d));
            }
        }
    }

    #[test]
    fn boxed_constructor() {
        let plain = sobol_maybe_scrambled(4, None);
        let scr = sobol_maybe_scrambled(4, Some(1174));
        assert_eq!(plain.dims(), 4);
        assert_eq!(scr.dims(), 4);
        assert_ne!(plain.component_u32(5, 1), scr.component_u32(5, 1));
    }

    #[test]
    fn owen_mean_still_uniform() {
        let seq = OwenScramble::new(Sobol::new(2), 99);
        let n = 4096;
        let m: f64 = (0..n).map(|i| seq.component(i, 1)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean={m}");
    }
}
