//! Quasi-Monte Carlo machinery (paper §4): radical inversion, the Sobol'
//! sequence, scrambling, F2 linear algebra, and (t,m,s)-net property
//! checkers.
//!
//! The key structural fact exploited by the paper: each component of the
//! Sobol' sequence is a **(0,1)-sequence in base 2**, so every contiguous
//! block of 2^m indices maps to an equidistant stratification of [0,1) —
//! equivalently, `floor(2^m · x_i)` over such a block is a *permutation*
//! of {0, …, 2^m − 1}.  Connecting consecutive network layers by these
//! *progressive permutations* gives constant fan-in/fan-out, collision-free
//! routing, and natural progressive growth (paper §4.2-4.4).

pub mod f2;
pub mod family;
pub mod halton;
pub mod nets;
pub mod scramble;
pub mod sobol;
pub mod vdc;

pub use family::{PrngSequence, SequenceFamily, SequenceKind};

/// A deterministic point sequence in [0,1)^s addressed by (index, dim).
///
/// Implemented by the Sobol' sequence, its scrambled variant, and — for
/// baseline comparisons — PRNG-backed fake "sequences".
pub trait Sequence {
    /// Number of available dimensions.
    fn dims(&self) -> usize;

    /// Component `dim` of point `index`, as a 32-bit fixed-point fraction
    /// (the integer numerator of x over 2^32).  All sequence math is done
    /// in fixed point so that `floor(n · x)` is exact.
    fn component_u32(&self, index: u64, dim: usize) -> u32;

    /// Component as f64 in [0,1).
    fn component(&self, index: u64, dim: usize) -> f64 {
        self.component_u32(index, dim) as f64 * (1.0 / 4294967296.0)
    }

    /// First `n` values of component `dim` in natural order.  The
    /// default evaluates point-wise; digital sequences override it with
    /// the XOR-doubling recursion `x_{i+2^k} = x_i ⊕ v_{k+1}`, which is
    /// O(1) per point (EXPERIMENTS.md §Perf).
    fn component_block(&self, dim: usize, n: usize) -> Vec<u32> {
        (0..n as u64).map(|i| self.component_u32(i, dim)).collect()
    }

    /// `floor(n · x_index^{(dim)})` computed exactly in integer arithmetic.
    fn map_to(&self, index: u64, dim: usize, n: usize) -> usize {
        debug_assert!(n > 0 && n <= u32::MAX as usize);
        ((self.component_u32(index, dim) as u64 * n as u64) >> 32) as usize
    }

    /// `map_to` over the first `count` indices in natural order.  The
    /// default routes through [`Sequence::component_block`] (digital
    /// sequences keep their XOR-doubling speed) and the fixed-point
    /// multiply of the default `map_to`.  Sequences whose `map_to` must
    /// use exact non-dyadic arithmetic (Halton) override this so the
    /// block path gives the same slots as point-wise `map_to`.
    fn map_block(&self, dim: usize, count: usize, n: usize) -> Vec<usize> {
        debug_assert!(n > 0 && n <= u32::MAX as usize);
        self.component_block(dim, count)
            .into_iter()
            .map(|x| ((x as u64 * n as u64) >> 32) as usize)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::sobol::Sobol;
    use super::*;

    #[test]
    fn map_to_is_exact_for_pow2() {
        let s = Sobol::new(4);
        // floor(16 * Phi_2(i)) over i=0..16 must be the bit-reversal
        // permutation of 0..16 (paper §4.2 example).
        let perm: Vec<usize> = (0..16).map(|i| s.map_to(i, 0, 16)).collect();
        assert_eq!(perm, vec![0, 8, 4, 12, 2, 10, 6, 14, 1, 9, 5, 13, 3, 11, 7, 15]);
    }

    #[test]
    fn component_in_unit_interval() {
        let s = Sobol::new(8);
        for dim in 0..8 {
            for i in 0..256 {
                let x = s.component(i, dim);
                assert!((0.0..1.0).contains(&x), "dim={dim} i={i} x={x}");
            }
        }
    }
}
