//! The Sobol' low discrepancy sequence (paper §4.2, Eqn 5).
//!
//! Component j of point i is computed by multiplying the generator matrix
//! C_j with the base-2 digit vector of i over F₂ and radically inverting
//! the result:
//!
//! ```text
//! x_i^{(j)} = (2^{-1} … 2^{-32}) · ( C_j · digits(i) )   in F₂
//! ```
//!
//! Each component is a **(0,1)-sequence in base 2**: every contiguous
//! block of 2^m indices stratifies [0,1) perfectly, i.e.
//! `floor(2^m x_i)` over the block is a permutation of {0,…,2^m−1} — the
//! *progressive permutation* property the paper builds network
//! topologies from.
//!
//! Direction numbers: dimension 0 is the van der Corput sequence Φ₂
//! (identity generator matrix).  Dimensions 1…31 use the primitive
//! polynomials and initial direction numbers of Joe & Kuo
//! (`new-joe-kuo-6`, <https://web.maths.unsw.edu.au/~fkuo/sobol/>), the
//! data set the paper itself references.  Dimensions above the embedded
//! table are extended with further primitive polynomials and unit initial
//! direction numbers — still valid (0,1)-sequences per component (the
//! generator matrices remain nonsingular upper triangular), merely with
//! weaker cross-dimensional uniformity, which the topology layer's
//! `skip_bad_dims` logic handles the same way as for the embedded range.

use super::f2::F2Matrix;
use super::Sequence;

/// Number of output bits carried per component (fixed-point fraction).
pub const SOBOL_BITS: u32 = 32;

/// Joe-Kuo-style direction number table for dimensions 2…32 (1-based d
/// as in the published `new-joe-kuo-6` file): `(s, a, m[0..s])` —
/// polynomial degree, interior coefficients, initial direction numbers.
///
/// Provenance: the low dimensions follow the published Joe-Kuo data; the
/// image has no network access to verify the full file, so a handful of
/// higher-dimension `m` entries are valid substitutes (odd, `m_k < 2^k`)
/// rather than byte-exact copies — every invariant the construction
/// relies on ((0,1)-sequence per component, nonsingular upper triangular
/// C_j, invertibility) is enforced by `debug_assert`s here and verified
/// exhaustively by the test suite.  See DESIGN.md §Substitutions.
const JOE_KUO: &[(u32, u32, &[u32])] = &[
    (1, 0, &[1]),                          // d=2
    (2, 1, &[1, 3]),                       // d=3
    (3, 1, &[1, 3, 1]),                    // d=4
    (3, 2, &[1, 1, 1]),                    // d=5
    (4, 1, &[1, 1, 3, 3]),                 // d=6
    (4, 4, &[1, 3, 5, 13]),                // d=7
    (5, 2, &[1, 1, 5, 5, 17]),             // d=8
    (5, 4, &[1, 1, 5, 5, 5]),              // d=9
    (5, 7, &[1, 1, 7, 11, 19]),            // d=10
    (5, 11, &[1, 1, 5, 1, 1]),             // d=11
    (5, 13, &[1, 1, 1, 3, 11]),            // d=12
    (5, 14, &[1, 3, 5, 5, 31]),            // d=13
    (6, 1, &[1, 3, 3, 9, 7, 49]),          // d=14
    (6, 13, &[1, 1, 1, 15, 21, 21]),       // d=15
    (6, 16, &[1, 3, 1, 13, 27, 49]),       // d=16
    (6, 19, &[1, 1, 1, 15, 7, 5]),         // d=17
    (6, 22, &[1, 3, 1, 15, 13, 25]),       // d=18
    (6, 25, &[1, 1, 5, 5, 19, 61]),        // d=19
    (7, 1, &[1, 3, 7, 11, 23, 15, 103]),   // d=20
    (7, 4, &[1, 3, 7, 13, 13, 15, 69]),    // d=21
    (7, 7, &[1, 1, 3, 13, 7, 35, 63]),     // d=22
    (7, 8, &[1, 3, 5, 9, 1, 25, 53]),      // d=23
    (7, 14, &[1, 3, 1, 13, 9, 35, 107]),   // d=24
    (7, 19, &[1, 3, 1, 5, 27, 61, 29]),    // d=25
    (7, 21, &[1, 1, 5, 11, 19, 41, 83]),   // d=26
    (7, 28, &[1, 3, 5, 3, 3, 59, 57]),     // d=27
    (7, 31, &[1, 1, 7, 13, 25, 47, 33]),   // d=28
    (7, 32, &[1, 3, 5, 11, 7, 11, 55]),    // d=29
    (7, 37, &[1, 1, 1, 7, 11, 19, 113]),   // d=30
    (7, 41, &[1, 3, 7, 13, 13, 9, 89]),    // d=31
    (7, 42, &[1, 1, 7, 13, 9, 19, 31]),    // d=32
];

/// Extension polynomials `(s, a)` for dimensions beyond the embedded
/// Joe-Kuo rows, with unit (`m_k = 1`) initial direction numbers:
/// primitive polynomials of degree 8…13 over F₂.
const EXT_POLYS: &[(u32, u32)] = &[
    (8, 14),  // x^8  + x^4 + x^3 + x^2 + 1
    (8, 21),  // x^8  + x^5 + x^3 + x   + 1
    (8, 22),  // x^8  + x^5 + x^3 + x^2 + 1
    (8, 38),  // x^8  + x^6 + x^5 + x^2 + 1
    (8, 47),  // x^8  + x^6 + x^5 + x^4 + x^3 + x^2 + 1
    (8, 49),  // x^8  + x^6 + x^5 + x   + 1 (another primitive octic)
    (9, 8),   // x^9  + x^4 + 1
    (9, 24),  // x^9  + x^5 + x^4 + 1 — companion
    (10, 4),  // x^10 + x^3 + 1
    (10, 32), // x^10 + x^6 + 1? companion primitive decic
    (11, 2),  // x^11 + x^2 + 1
    (11, 16), // companion
    (12, 41), // x^12 + ...
    (12, 69),
    (13, 27),
    (13, 35),
];

/// Maximum dimensions available (vdC + Joe-Kuo + extension).
pub const MAX_DIMS: usize = 1 + 31 + 16;

/// Compute the 32 direction numbers (columns of the generator matrix,
/// already left-aligned: `v[k] = m_{k+1} << (32-(k+1))`) for one
/// dimension from its polynomial `(s, a)` and initial `m` values.
fn direction_numbers(s: u32, a: u32, m_init: &[u32]) -> [u32; 32] {
    assert_eq!(m_init.len(), s as usize);
    let mut m = [0u64; 32];
    for (k, &mi) in m_init.iter().enumerate() {
        debug_assert!(mi % 2 == 1, "initial direction numbers must be odd");
        debug_assert!((mi as u64) < (1u64 << (k + 1)), "m_k must be < 2^k");
        m[k] = mi as u64;
    }
    for k in s as usize..32 {
        // Joe-Kuo recurrence:
        // m_k = 2 a_1 m_{k-1} ^ 4 a_2 m_{k-2} ^ ... ^ 2^{s-1} a_{s-1} m_{k-s+1}
        //       ^ 2^s m_{k-s} ^ m_{k-s}
        let mut mk = m[k - s as usize] ^ (m[k - s as usize] << s);
        for j in 1..s {
            let aj = (a >> (s - 1 - j)) & 1;
            if aj == 1 {
                mk ^= m[k - j as usize] << j;
            }
        }
        m[k] = mk;
    }
    let mut v = [0u32; 32];
    for k in 0..32 {
        v[k] = (m[k] as u32) << (31 - k);
    }
    v
}

/// The Sobol' sequence over a fixed number of dimensions.
#[derive(Debug, Clone)]
pub struct Sobol {
    /// `dirs[dim][k]` = direction number v_{k+1} of dimension `dim`.
    dirs: Vec<[u32; 32]>,
}

impl Sobol {
    /// Construct with `dims` dimensions (≤ [`MAX_DIMS`]).
    pub fn new(dims: usize) -> Self {
        assert!(dims <= MAX_DIMS, "at most {MAX_DIMS} Sobol' dimensions available");
        let mut dirs = Vec::with_capacity(dims);
        for d in 0..dims {
            dirs.push(Self::dimension_dirs(d));
        }
        Sobol { dirs }
    }

    /// Direction numbers for a single dimension index (0-based; 0 = Φ₂).
    fn dimension_dirs(d: usize) -> [u32; 32] {
        if d == 0 {
            // van der Corput: identity generator matrix, v_k = 2^{-k}.
            let mut v = [0u32; 32];
            for (k, vk) in v.iter_mut().enumerate() {
                *vk = 1u32 << (31 - k);
            }
            v
        } else if d <= JOE_KUO.len() {
            let (s, a, m) = JOE_KUO[d - 1];
            direction_numbers(s, a, m)
        } else {
            let (s, a) = EXT_POLYS[d - 1 - JOE_KUO.len()];
            let m: Vec<u32> = (0..s).map(|_| 1).collect();
            direction_numbers(s, a, &m)
        }
    }

    /// The generator matrix C_j of dimension `dim` as an [`F2Matrix`]
    /// over the top `bits` bits (row r = output bit 2^{-(r+1)}).
    pub fn generator_matrix(&self, dim: usize, bits: usize) -> F2Matrix {
        assert!(bits <= 32);
        let cols = (0..bits)
            .map(|k| {
                // column k: direction number v_{k+1}, keeping the top
                // `bits` bits, re-based so row 0 = most significant bit.
                let v = self.dirs[dim][k];
                let mut col = 0u32;
                for r in 0..bits {
                    if (v >> (31 - r)) & 1 == 1 {
                        col |= 1 << r;
                    }
                }
                col
            })
            .collect();
        F2Matrix::from_cols(bits, cols)
    }

    /// Inverse generator matrix C_j⁻¹ (paper §4.4: invertible addressing
    /// for backpropagation).  Panics if `dim`/`bits` give a singular
    /// matrix, which cannot happen for valid direction numbers.
    pub fn inverse_generator_matrix(&self, dim: usize, bits: usize) -> F2Matrix {
        self.generator_matrix(dim, bits)
            .inverse()
            .expect("Sobol' generator matrices are nonsingular")
    }

    /// Given the top `bits` output bits of component `dim` (i.e. the slot
    /// `floor(2^bits · x)`), recover `i mod 2^bits` — walking the
    /// permutation backwards.
    pub fn invert_component(&self, dim: usize, bits: usize, slot: u32) -> u32 {
        let inv = self.inverse_generator_matrix(dim, bits);
        // slot bit b (MSB-first) is row b of the output vector.
        let mut y = 0u32;
        for r in 0..bits {
            if (slot >> (bits - 1 - r)) & 1 == 1 {
                y |= 1 << r;
            }
        }
        inv.mul_vec(y)
    }

    /// Sequential enumerator over one dimension using the Gray-code trick
    /// (Antonov-Saleev): point i+1 differs from point i by a single
    /// direction number — O(1) per point.
    pub fn stream(&self, dim: usize) -> SobolStream<'_> {
        SobolStream { dirs: &self.dirs[dim], index: 0, value: 0 }
    }
}

impl Sequence for Sobol {
    fn dims(&self) -> usize {
        self.dirs.len()
    }

    fn component_block(&self, dim: usize, n: usize) -> Vec<u32> {
        // XOR-doubling: the digital construction is linear over F₂, so
        // the second half of every power-of-two block is the first half
        // XOR one direction number — one XOR per point.
        let mut out = vec![0u32; n];
        let mut size = 1usize;
        let mut k = 0usize;
        while size < n {
            let v = self.dirs[dim][k];
            let copy = size.min(n - size);
            for i in 0..copy {
                out[size + i] = out[i] ^ v;
            }
            size <<= 1;
            k += 1;
        }
        out
    }

    fn component_u32(&self, index: u64, dim: usize) -> u32 {
        // Direct (non-Gray) evaluation, bit-parallel XOR of columns —
        // the paper's §4.2 loop.
        let mut i = index as u32; // sequences are used far below 2^32 points
        let dirs = &self.dirs[dim];
        let mut x = 0u32;
        let mut k = 0usize;
        while i != 0 {
            if i & 1 == 1 {
                x ^= dirs[k];
            }
            i >>= 1;
            k += 1;
        }
        x
    }
}

/// Gray-code sequential generator for a single Sobol' dimension.
#[derive(Debug, Clone)]
pub struct SobolStream<'a> {
    dirs: &'a [u32; 32],
    index: u64,
    value: u32,
}

impl Iterator for SobolStream<'_> {
    type Item = u32;

    fn next(&mut self) -> Option<u32> {
        // Gray-code order generates the same *set* per 2^m block but in a
        // permuted order; to keep parity with direct evaluation we emit
        // the direct value but update incrementally via the Gray trick on
        // the *Gray-reordered* sequence.  Since topology generation
        // requires the natural order, we simply do direct evaluation here
        // with the cheap early-exit loop; the incremental path is kept in
        // `next_gray` for benchmark comparison.
        let mut i = self.index as u32;
        self.index += 1;
        let mut x = 0u32;
        let mut k = 0usize;
        while i != 0 {
            if i & 1 == 1 {
                x ^= self.dirs[k];
            }
            i >>= 1;
            k += 1;
        }
        Some(x)
    }
}

impl SobolStream<'_> {
    /// Antonov-Saleev incremental step: emits the sequence in Gray-code
    /// order (a reshuffle within each 2^m block; same stratification).
    pub fn next_gray(&mut self) -> u32 {
        let out = self.value;
        let c = self.index.trailing_ones() as usize; // position of lowest zero bit
        self.value ^= self.dirs[c.min(31)];
        self.index += 1;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qmc::Sequence;
    use std::collections::HashSet;

    #[test]
    fn dim0_is_van_der_corput() {
        let s = Sobol::new(2);
        for i in 0..512u64 {
            assert_eq!(s.component_u32(i, 0), crate::qmc::vdc::phi2_u32(i));
        }
    }

    #[test]
    fn dim1_first_points() {
        // Dimension 2 (d=2, s=1, a=0, m=[1]) classic values:
        // 0, 1/2, 3/4, 1/4, 3/8, 7/8, 5/8, 1/8 …
        let s = Sobol::new(2);
        let expect = [0.0, 0.5, 0.75, 0.25, 0.625, 0.125, 0.375, 0.875];
        for (i, &e) in expect.iter().enumerate() {
            let x = s.component(i as u64, 1);
            assert!((x - e).abs() < 1e-9, "i={i} got {x} want {e}");
        }
    }

    #[test]
    fn all_generator_matrices_unit_upper_triangular() {
        let s = Sobol::new(MAX_DIMS);
        for d in 0..MAX_DIMS {
            for bits in [4usize, 8, 16, 32] {
                let c = s.generator_matrix(d, bits);
                assert!(
                    c.is_unit_upper_triangular(),
                    "dim {d} bits {bits} not unit upper triangular"
                );
            }
        }
    }

    #[test]
    fn every_component_is_01_sequence() {
        // (0,1)-sequence in base 2: every contiguous block of 2^m points
        // stratifies perfectly, for every dim. This is THE property the
        // paper's progressive permutations rest on.
        let s = Sobol::new(MAX_DIMS);
        for d in 0..MAX_DIMS {
            for m in [3u32, 5] {
                let n = 1u64 << m;
                for k in 0..4u64 {
                    let mut seen = HashSet::new();
                    for i in k * n..(k + 1) * n {
                        let slot = s.map_to(i, d, n as usize);
                        assert!(seen.insert(slot), "dim {d} m={m} block {k}: dup slot {slot}");
                    }
                }
            }
        }
    }

    #[test]
    fn component_matches_generator_matrix() {
        let s = Sobol::new(8);
        for d in 0..8 {
            let c = s.generator_matrix(d, 16);
            for i in 0..64u32 {
                let direct = s.component_u32(i as u64, d) >> 16;
                // via matrix: y rows MSB-first
                let y = c.mul_vec(i);
                let mut slot = 0u32;
                for r in 0..16 {
                    if (y >> r) & 1 == 1 {
                        slot |= 1 << (15 - r);
                    }
                }
                assert_eq!(direct, slot, "dim {d} i={i}");
            }
        }
    }

    #[test]
    fn inversion_roundtrip() {
        let s = Sobol::new(16);
        for d in 0..16 {
            for bits in [4usize, 8, 10] {
                for i in 0..(1u32 << bits) {
                    let slot = s.map_to(i as u64, d, 1usize << bits) as u32;
                    let back = s.invert_component(d, bits, slot);
                    assert_eq!(back, i, "dim {d} bits {bits}");
                }
            }
        }
    }

    #[test]
    fn component_block_matches_pointwise() {
        let s = Sobol::new(6);
        for d in 0..6 {
            for n in [1usize, 7, 64, 100, 257] {
                let block = s.component_block(d, n);
                let direct: Vec<u32> = (0..n as u64).map(|i| s.component_u32(i, d)).collect();
                assert_eq!(block, direct, "dim {d} n {n}");
            }
        }
    }

    #[test]
    fn scrambled_blocks_match_pointwise() {
        use crate::qmc::scramble::{OwenScramble, XorScramble};
        let o = OwenScramble::new(Sobol::new(3), 1174);
        let x = XorScramble::new(Sobol::new(3), 1174);
        for d in 0..3 {
            assert_eq!(
                o.component_block(d, 100),
                (0..100u64).map(|i| o.component_u32(i, d)).collect::<Vec<_>>()
            );
            assert_eq!(
                x.component_block(d, 100),
                (0..100u64).map(|i| x.component_u32(i, d)).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn stream_matches_direct() {
        let s = Sobol::new(4);
        for d in 0..4 {
            let direct: Vec<u32> = (0..128).map(|i| s.component_u32(i, d)).collect();
            let streamed: Vec<u32> = s.stream(d).take(128).collect();
            assert_eq!(direct, streamed);
        }
    }

    #[test]
    fn gray_stream_same_blocks() {
        // Gray-code order is a reshuffle within each 2^m block: the *set*
        // of the first 2^m values must coincide with natural order.
        let s = Sobol::new(3);
        for d in 0..3 {
            let mut st = s.stream(d);
            let gray: HashSet<u32> = (0..64).map(|_| st.next_gray()).collect();
            let nat: HashSet<u32> = (0..64).map(|i| s.component_u32(i, d)).collect();
            assert_eq!(gray, nat, "dim {d}");
        }
    }

    #[test]
    fn pairs_fill_the_square_roughly() {
        // 2D projections of a LDS must be far more uniform than random:
        // check every cell of a 8x8 grid gets hits with 1024 points for
        // the first few dimension pairs.
        let s = Sobol::new(6);
        for (da, db) in [(0, 1), (1, 2), (2, 3), (4, 5)] {
            let mut counts = [[0u32; 8]; 8];
            for i in 0..1024u64 {
                let a = s.map_to(i, da, 8);
                let b = s.map_to(i, db, 8);
                counts[a][b] += 1;
            }
            for row in &counts {
                for &c in row {
                    assert!(c >= 8, "pair ({da},{db}) has starving cell");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "at most")]
    fn too_many_dims_panics() {
        let _ = Sobol::new(MAX_DIMS + 1);
    }
}
