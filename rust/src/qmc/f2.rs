//! Linear algebra over the field F₂ of two elements.
//!
//! Generator matrices of digital (t,s)-sequences are nonsingular upper
//! triangular matrices over F₂; the Sobol' component j maps the digit
//! vector of the index through C_j (paper Eqn 5).  Because every C_j is
//! invertible, the network addressing is invertible too — the property
//! the paper uses for backpropagation in hardware (§4.4): computing
//! C_j⁻¹ lets one walk *backwards* through a layer permutation.
//!
//! Matrices are stored column-major as `u32` bit masks: `cols[k]` holds
//! column k, bit r (LSB = row 0) is entry (r, k).  This matches the
//! XOR-accumulation loop of the paper §4.2 exactly.

/// A square matrix over F₂, up to 32×32, stored as columns of bits.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct F2Matrix {
    /// Size (rows == cols == n).
    pub n: usize,
    /// Column bit masks; `cols[k] >> r & 1` is entry (r, k).
    pub cols: Vec<u32>,
}

impl F2Matrix {
    /// Identity matrix of size n.
    pub fn identity(n: usize) -> Self {
        assert!(n <= 32);
        F2Matrix { n, cols: (0..n).map(|k| 1u32 << k).collect() }
    }

    /// Build from columns.
    pub fn from_cols(n: usize, cols: Vec<u32>) -> Self {
        assert!(n <= 32 && cols.len() == n);
        let mask = if n == 32 { u32::MAX } else { (1u32 << n) - 1 };
        assert!(cols.iter().all(|c| c & !mask == 0), "column bits above n");
        F2Matrix { n, cols }
    }

    /// Entry (row, col) as a bool.
    pub fn get(&self, row: usize, col: usize) -> bool {
        (self.cols[col] >> row) & 1 == 1
    }

    /// Matrix-vector product C·d over F₂ where the vector is a bit mask
    /// (bit k = digit d_k).  This is the paper's §4.2 XOR loop.
    #[inline]
    pub fn mul_vec(&self, mut v: u32) -> u32 {
        let mut acc = 0u32;
        let mut k = 0usize;
        while v != 0 {
            if v & 1 == 1 {
                acc ^= self.cols[k];
            }
            v >>= 1;
            k += 1;
        }
        acc
    }

    /// Matrix product self · other over F₂.
    pub fn mul(&self, other: &F2Matrix) -> F2Matrix {
        assert_eq!(self.n, other.n);
        let cols = other.cols.iter().map(|&c| self.mul_vec(c)).collect();
        F2Matrix { n: self.n, cols }
    }

    /// Inverse via Gauss-Jordan elimination; `None` if singular.
    pub fn inverse(&self) -> Option<F2Matrix> {
        let n = self.n;
        // Work row-major for elimination: rows as bit masks over columns.
        let mut a: Vec<u64> = (0..n)
            .map(|r| {
                let mut row = 0u64;
                for c in 0..n {
                    if self.get(r, c) {
                        row |= 1 << c;
                    }
                }
                // augmented identity in high bits
                row | (1u64 << (n + r))
            })
            .collect();
        for col in 0..n {
            // find pivot
            let piv = (col..n).find(|&r| a[r] >> col & 1 == 1)?;
            a.swap(col, piv);
            let prow = a[col];
            for (r, row) in a.iter_mut().enumerate() {
                if r != col && *row >> col & 1 == 1 {
                    *row ^= prow;
                }
            }
        }
        // extract inverse from the augmented half (row-major) → columns.
        let mut cols = vec![0u32; n];
        for (r, row) in a.iter().enumerate() {
            for c in 0..n {
                if row >> (n + c) & 1 == 1 {
                    cols[c] |= 1 << r;
                }
            }
        }
        Some(F2Matrix { n, cols })
    }

    /// `true` iff upper triangular with unit diagonal — the shape every
    /// valid digital-sequence generator matrix must have to give a
    /// (0,1)-sequence component.
    pub fn is_unit_upper_triangular(&self) -> bool {
        // Column k must have bit k set and no bits above k.
        self.cols.iter().enumerate().all(|(k, &c)| {
            let below_mask = if k == 31 { u32::MAX } else { (1u32 << (k + 1)) - 1 };
            (c >> k) & 1 == 1 && c & !below_mask == 0
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg32, Rng};

    #[test]
    fn identity_properties() {
        let id = F2Matrix::identity(8);
        assert!(id.is_unit_upper_triangular());
        for v in [0u32, 1, 0xAB, 0xFF] {
            assert_eq!(id.mul_vec(v), v);
        }
        assert_eq!(id.inverse().unwrap(), id);
    }

    #[test]
    fn mul_vec_matches_get() {
        // brute-force check C·e_k = column k
        let m = F2Matrix::from_cols(4, vec![0b0001, 0b0011, 0b0101, 0b1111]);
        for k in 0..4 {
            assert_eq!(m.mul_vec(1 << k), m.cols[k]);
        }
        // linearity: C(a ^ b) = C a ^ C b
        assert_eq!(m.mul_vec(0b1010), m.mul_vec(0b1000) ^ m.mul_vec(0b0010));
    }

    #[test]
    fn inverse_roundtrip_random_triangular() {
        let mut rng = Pcg32::seeded(5);
        for n in [4usize, 8, 16, 32] {
            // random unit upper triangular is always invertible
            let cols: Vec<u32> = (0..n)
                .map(|k| {
                    let above = if k == 0 { 0 } else { rng.next_u32() & ((1u32 << k) - 1) };
                    above | (1u32 << k)
                })
                .collect();
            let m = F2Matrix::from_cols(n, cols);
            assert!(m.is_unit_upper_triangular());
            let inv = m.inverse().expect("triangular must invert");
            assert_eq!(m.mul(&inv), F2Matrix::identity(n));
            assert_eq!(inv.mul(&m), F2Matrix::identity(n));
            // inverse really inverts the vector map
            for _ in 0..16 {
                let v = rng.next_u32() & if n == 32 { u32::MAX } else { (1 << n) - 1 };
                assert_eq!(inv.mul_vec(m.mul_vec(v)), v);
            }
        }
    }

    #[test]
    fn singular_matrix_has_no_inverse() {
        let m = F2Matrix::from_cols(3, vec![0b001, 0b001, 0b100]); // duplicate column
        assert!(m.inverse().is_none());
    }

    #[test]
    fn triangularity_detector() {
        let good = F2Matrix::from_cols(3, vec![0b001, 0b011, 0b111]);
        assert!(good.is_unit_upper_triangular());
        let bad_diag = F2Matrix::from_cols(3, vec![0b001, 0b001, 0b111]);
        assert!(!bad_diag.is_unit_upper_triangular());
        let lower = F2Matrix::from_cols(3, vec![0b111, 0b010, 0b100]);
        assert!(!lower.is_unit_upper_triangular());
    }
}
