//! Deterministic synthetic image-classification datasets — stand-ins
//! for MNIST / Fashion-MNIST / CIFAR-10 (no network access in this
//! environment; see DESIGN.md §Substitutions).
//!
//! Each class is defined by a deterministic template (a sum of random
//! Gaussian blobs plus an oriented grating, seeded by the class id);
//! samples are translated, brightness-jittered, noisy renderings of
//! their class template.  The tasks preserve what the paper's
//! experiments measure: a dense network clearly beats chance, capacity
//! matters, and relative orderings between topologies/initializations
//! are meaningful.

use super::ClassificationData;
use crate::nn::tensor::Tensor;
use crate::rng::{Pcg32, Rng};

/// Which synthetic dataset family to generate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SynthKind {
    /// 28×28×1, digit-like blobs, mild noise (MNIST stand-in).
    Mnist,
    /// 28×28×1, stripier templates, more noise (Fashion stand-in).
    Fashion,
    /// `hw`׍`hw`×3, colored blob+grating templates (CIFAR stand-in).
    Cifar,
}

/// Generator parameters.
#[derive(Debug, Clone, Copy)]
pub struct SynthConfig {
    /// Dataset family.
    pub kind: SynthKind,
    /// Image side length (28 for MNIST/Fashion; CIFAR default 16 to keep
    /// the sweep benches fast — the paper's 32 is available).
    pub hw: usize,
    /// Number of classes.
    pub classes: usize,
    /// Additive noise σ.
    pub noise: f32,
    /// Max translation jitter in pixels.
    pub jitter: usize,
    /// Master seed.
    pub seed: u64,
}

impl SynthConfig {
    /// MNIST-like defaults (noise/jitter tuned so sparse nets below a
    /// few hundred paths sit visibly under the dense ceiling — the
    /// Fig 7 ramp).
    pub fn mnist(seed: u64) -> Self {
        SynthConfig { kind: SynthKind::Mnist, hw: 28, classes: 10, noise: 0.22, jitter: 3, seed }
    }

    /// Fashion-MNIST-like defaults (harder than MNIST, as in the paper).
    pub fn fashion(seed: u64) -> Self {
        SynthConfig { kind: SynthKind::Fashion, hw: 28, classes: 10, noise: 0.30, jitter: 3, seed }
    }

    /// CIFAR-10-like defaults (16×16×3 for bench speed).  Noisier and
    /// with confusable classes (templates share a common base) so CNNs
    /// do not saturate within the reduced budgets — keeping the Fig
    /// 8/10 and Table 1–3 orderings visible.
    pub fn cifar(seed: u64) -> Self {
        SynthConfig { kind: SynthKind::Cifar, hw: 16, classes: 10, noise: 0.35, jitter: 3, seed }
    }

    /// Channels for the family.
    pub fn channels(&self) -> usize {
        match self.kind {
            SynthKind::Cifar => 3,
            _ => 1,
        }
    }
}

/// Class template: per channel, a dense `hw×hw` image in [0,1].
fn class_template(cfg: &SynthConfig, class: usize) -> Vec<f32> {
    let raw = class_template_raw(cfg, class as u64 + 1, class);
    if cfg.kind != SynthKind::Cifar {
        return raw;
    }
    // CIFAR-like classes share a common base pattern (natural images all
    // contain sky/ground/texture); only part of the signal is
    // class-specific, which keeps the task from saturating instantly.
    let base = class_template_raw(cfg, 0xBA5E, 0);
    raw.iter().zip(&base).map(|(r, b)| 0.55 * r + 0.45 * b).collect()
}

fn class_template_raw(cfg: &SynthConfig, stream: u64, class: usize) -> Vec<f32> {
    let c = cfg.channels();
    let hw = cfg.hw;
    let mut rng = Pcg32::new(cfg.seed ^ 0xC1A55, stream);
    let mut img = vec![0.0f32; c * hw * hw];
    let blobs = match cfg.kind {
        SynthKind::Mnist => 3,
        SynthKind::Fashion => 5,
        SynthKind::Cifar => 4,
    };
    for ch in 0..c {
        // Gaussian blobs
        for _ in 0..blobs {
            let cx = rng.next_f32() * hw as f32;
            let cy = rng.next_f32() * hw as f32;
            let sx = 1.5 + rng.next_f32() * (hw as f32 / 6.0);
            let sy = 1.5 + rng.next_f32() * (hw as f32 / 6.0);
            let amp = 0.5 + rng.next_f32() * 0.5;
            for y in 0..hw {
                for x in 0..hw {
                    let dx = (x as f32 - cx) / sx;
                    let dy = (y as f32 - cy) / sy;
                    img[ch * hw * hw + y * hw + x] += amp * (-0.5 * (dx * dx + dy * dy)).exp();
                }
            }
        }
        // oriented grating (orientation + frequency keyed by class)
        let theta = class as f32 * std::f32::consts::PI / cfg.classes as f32;
        let freq = match cfg.kind {
            SynthKind::Mnist => 0.0, // pure blobs
            SynthKind::Fashion => 0.55,
            SynthKind::Cifar => 0.45 + 0.1 * ch as f32,
        };
        if freq > 0.0 {
            let (s, co) = theta.sin_cos();
            let phase = rng.next_f32() * std::f32::consts::TAU;
            for y in 0..hw {
                for x in 0..hw {
                    let u = co * x as f32 + s * y as f32;
                    img[ch * hw * hw + y * hw + x] += 0.35 * (freq * u + phase).sin();
                }
            }
        }
    }
    // normalize template to [0,1]
    let mn = img.iter().cloned().fold(f32::INFINITY, f32::min);
    let mx = img.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let range = (mx - mn).max(1e-6);
    for v in &mut img {
        *v = (*v - mn) / range;
    }
    img
}

/// Render one sample of a class: translate + brightness jitter + noise.
fn render_sample(cfg: &SynthConfig, template: &[f32], rng: &mut Pcg32) -> Vec<f32> {
    let c = cfg.channels();
    let hw = cfg.hw;
    let j = cfg.jitter as i32;
    let dx = rng.next_below((2 * j + 1) as u32) as i32 - j;
    let dy = rng.next_below((2 * j + 1) as u32) as i32 - j;
    let gain = 0.8 + rng.next_f32() * 0.4;
    let mut img = vec![0.0f32; c * hw * hw];
    for ch in 0..c {
        for y in 0..hw {
            for x in 0..hw {
                let sx = x as i32 - dx;
                let sy = y as i32 - dy;
                let v = if sx >= 0 && sx < hw as i32 && sy >= 0 && sy < hw as i32 {
                    template[ch * hw * hw + sy as usize * hw + sx as usize]
                } else {
                    0.0
                };
                let noise = (rng.next_f32() - 0.5) * 2.0 * cfg.noise;
                img[ch * hw * hw + y * hw + x] = (v * gain + noise).clamp(0.0, 1.0);
            }
        }
    }
    img
}

/// Generate `n` samples.  Returns flat images `[N, C, H, W]` (C=1 kept
/// as a real dim so CNNs and MLPs share data via reshape).
pub fn generate(cfg: &SynthConfig, n: usize, split_seed: u64) -> ClassificationData {
    let c = cfg.channels();
    let hw = cfg.hw;
    let templates: Vec<Vec<f32>> = (0..cfg.classes).map(|k| class_template(cfg, k)).collect();
    let mut rng = Pcg32::new(cfg.seed ^ split_seed, 77);
    let mut x = Tensor::zeros(&[n, c, hw, hw]);
    let mut y = Vec::with_capacity(n);
    let f = c * hw * hw;
    for i in 0..n {
        let cls = rng.next_below(cfg.classes as u32);
        let img = render_sample(cfg, &templates[cls as usize], &mut rng);
        x.data[i * f..(i + 1) * f].copy_from_slice(&img);
        y.push(cls);
    }
    ClassificationData { x, y, classes: cfg.classes }
}

/// Convenience: train/test pair with disjoint sample streams.
pub fn train_test(cfg: &SynthConfig, n_train: usize, n_test: usize) -> (ClassificationData, ClassificationData) {
    (generate(cfg, n_train, 0x7EA1), generate(cfg, n_test, 0x7E57))
}

/// Flattened (`[N, C·H·W]`) copy for MLP consumption.
pub fn flatten(d: &ClassificationData) -> ClassificationData {
    ClassificationData {
        x: d.x.clone().reshape(&[d.len(), d.features()]),
        y: d.y.clone(),
        classes: d.classes,
    }
}

/// MNIST-like train/test pair, flattened, normalized.
pub struct SynthMnist;

impl SynthMnist {
    /// `(train, test)` of the given sizes, flattened and normalized.
    pub fn new(n_train: usize, n_test: usize, seed: u64) -> (ClassificationData, ClassificationData) {
        let cfg = SynthConfig::mnist(seed);
        let (mut tr, mut te) = train_test(&cfg, n_train, n_test);
        super::augment::normalize_pair(&mut tr, &mut te);
        (flatten(&tr), flatten(&te))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn shapes_and_ranges() {
        for cfg in [SynthConfig::mnist(1), SynthConfig::fashion(1), SynthConfig::cifar(1)] {
            let d = generate(&cfg, 32, 0);
            assert_eq!(d.x.shape, vec![32, cfg.channels(), cfg.hw, cfg.hw]);
            assert_eq!(d.y.len(), 32);
            assert!(d.x.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
            assert!(d.y.iter().all(|&c| (c as usize) < cfg.classes));
        }
    }

    #[test]
    fn deterministic() {
        let cfg = SynthConfig::cifar(9);
        let a = generate(&cfg, 16, 0);
        let b = generate(&cfg, 16, 0);
        assert_eq!(a.x.data, b.x.data);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn splits_differ() {
        let cfg = SynthConfig::mnist(9);
        let (tr, te) = train_test(&cfg, 16, 16);
        assert_ne!(tr.x.data, te.x.data);
    }

    #[test]
    fn classes_are_distinguishable() {
        // Same-class samples must be closer (on average) than
        // cross-class samples: the fundamental learnability check.
        let cfg = SynthConfig::mnist(3);
        let d = generate(&cfg, 200, 0);
        let f = d.features();
        let dist = |a: usize, b: usize| -> f32 {
            d.x.data[a * f..(a + 1) * f]
                .iter()
                .zip(&d.x.data[b * f..(b + 1) * f])
                .map(|(x, y)| (x - y) * (x - y))
                .sum()
        };
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for i in 0..60 {
            for k in i + 1..60 {
                if d.y[i] == d.y[k] {
                    same.push(dist(i, k));
                } else {
                    diff.push(dist(i, k));
                }
            }
        }
        let ms: f32 = same.iter().sum::<f32>() / same.len() as f32;
        let md: f32 = diff.iter().sum::<f32>() / diff.len() as f32;
        assert!(ms < 0.7 * md, "same-class dist {ms} vs cross {md}");
    }

    #[test]
    fn all_classes_appear() {
        let cfg = SynthConfig::cifar(2);
        let d = generate(&cfg, 300, 0);
        let seen: HashSet<u32> = d.y.iter().cloned().collect();
        assert_eq!(seen.len(), 10);
    }

    #[test]
    fn flatten_roundtrip() {
        let cfg = SynthConfig::mnist(1);
        let d = generate(&cfg, 4, 0);
        let f = flatten(&d);
        assert_eq!(f.x.shape, vec![4, 784]);
        assert_eq!(f.x.data, d.x.data);
    }

    #[test]
    fn synthmnist_convenience() {
        let (tr, te) = SynthMnist::new(64, 32, 5);
        assert_eq!(tr.x.shape, vec![64, 784]);
        assert_eq!(te.x.shape, vec![32, 784]);
        // normalized: mean approx 0
        let m: f32 = tr.x.data.iter().sum::<f32>() / tr.x.len() as f32;
        assert!(m.abs() < 0.1, "mean={m}");
    }
}
