//! Normalization and training-time augmentation (paper §5.2: mean/std
//! normalization over the training set, random horizontal flips, and
//! 4-pixel pad + random crop).

use super::ClassificationData;
use crate::nn::tensor::Tensor;
use crate::rng::{Pcg32, Rng};

/// Per-channel mean/std statistics.
#[derive(Debug, Clone)]
pub struct ChannelStats {
    /// Mean per channel.
    pub mean: Vec<f32>,
    /// Std per channel.
    pub std: Vec<f32>,
}

/// Compute per-channel statistics of a `[N, C, H, W]` dataset.
pub fn channel_stats(d: &ClassificationData) -> ChannelStats {
    assert_eq!(d.x.shape.len(), 4, "channel stats need [N,C,H,W]");
    let (n, c) = (d.x.shape[0], d.x.shape[1]);
    let hw: usize = d.x.shape[2..].iter().product();
    let mut mean = vec![0.0f64; c];
    let mut var = vec![0.0f64; c];
    for i in 0..n {
        for ch in 0..c {
            let base = (i * c + ch) * hw;
            for k in 0..hw {
                mean[ch] += d.x.data[base + k] as f64;
            }
        }
    }
    let cnt = (n * hw) as f64;
    for m in &mut mean {
        *m /= cnt;
    }
    for i in 0..n {
        for ch in 0..c {
            let base = (i * c + ch) * hw;
            for k in 0..hw {
                let dlt = d.x.data[base + k] as f64 - mean[ch];
                var[ch] += dlt * dlt;
            }
        }
    }
    ChannelStats {
        mean: mean.iter().map(|&m| m as f32).collect(),
        std: var.iter().map(|&v| ((v / cnt).sqrt().max(1e-6)) as f32).collect(),
    }
}

/// Normalize in place with the given statistics.
pub fn normalize(d: &mut ClassificationData, stats: &ChannelStats) {
    let (n, c) = (d.x.shape[0], d.x.shape[1]);
    let hw: usize = d.x.shape[2..].iter().product();
    for i in 0..n {
        for ch in 0..c {
            let base = (i * c + ch) * hw;
            for k in 0..hw {
                d.x.data[base + k] = (d.x.data[base + k] - stats.mean[ch]) / stats.std[ch];
            }
        }
    }
}

/// Normalize train and test with the *training* statistics (paper §5.2).
pub fn normalize_pair(train: &mut ClassificationData, test: &mut ClassificationData) {
    let stats = channel_stats(train);
    normalize(train, &stats);
    normalize(test, &stats);
}

/// Random horizontal flip + pad-`pad`/random-crop of a batch, in place.
/// Applied per sample with probability ½ for the flip.
pub fn augment_batch(x: &mut Tensor, pad: usize, rng: &mut Pcg32) {
    let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut scratch = vec![0.0f32; c * h * w];
    for bi in 0..b {
        let flip = rng.next_u32() & 1 == 1;
        let dy = rng.next_below((2 * pad + 1) as u32) as isize - pad as isize;
        let dx = rng.next_below((2 * pad + 1) as u32) as isize - pad as isize;
        let img = &mut x.data[bi * c * h * w..(bi + 1) * c * h * w];
        scratch.copy_from_slice(img);
        for ch in 0..c {
            for y in 0..h {
                for xx in 0..w {
                    let sx0 = if flip { w - 1 - xx } else { xx };
                    let sy = y as isize + dy;
                    let sx = sx0 as isize + dx;
                    let v = if sy >= 0 && sy < h as isize && sx >= 0 && sx < w as isize {
                        scratch[ch * h * w + sy as usize * w + sx as usize]
                    } else {
                        0.0
                    };
                    img[ch * h * w + y * w + xx] = v;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> ClassificationData {
        ClassificationData {
            x: Tensor::from_vec((0..32).map(|v| v as f32 / 31.0).collect(), &[2, 2, 2, 4]),
            y: vec![0, 1],
            classes: 2,
        }
    }

    #[test]
    fn normalization_zero_mean_unit_std() {
        let mut d = toy();
        let stats = channel_stats(&d);
        normalize(&mut d, &stats);
        let after = channel_stats(&d);
        for ch in 0..2 {
            assert!(after.mean[ch].abs() < 1e-5);
            assert!((after.std[ch] - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn pair_uses_train_stats() {
        let mut tr = toy();
        let mut te = toy();
        te.x.scale(2.0);
        normalize_pair(&mut tr, &mut te);
        let tr_stats = channel_stats(&tr);
        let te_stats = channel_stats(&te);
        assert!(tr_stats.mean[0].abs() < 1e-5);
        // test normalized with train stats — its mean need not be zero
        assert!(te_stats.mean[0].abs() > 0.1);
    }

    #[test]
    fn augment_preserves_shape_and_determinism() {
        let mut a = Tensor::from_vec((0..48).map(|v| v as f32).collect(), &[1, 3, 4, 4]);
        let mut b = a.clone();
        let mut r1 = Pcg32::seeded(5);
        let mut r2 = Pcg32::seeded(5);
        augment_batch(&mut a, 1, &mut r1);
        augment_batch(&mut b, 1, &mut r2);
        assert_eq!(a.data, b.data, "same seed same augmentation");
        assert_eq!(a.shape, vec![1, 3, 4, 4]);
    }

    #[test]
    fn flip_only_mirrors() {
        // find a seed whose first sample flips with zero shift: then row
        // content is mirrored
        for seed in 0..64 {
            let mut rng = Pcg32::seeded(seed);
            let flip = rng.next_u32() & 1 == 1;
            let dy = rng.next_below(1) as isize;
            let dx = rng.next_below(1) as isize;
            if flip && dy == 0 && dx == 0 {
                let mut t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[1, 1, 1, 4]);
                let mut rng = Pcg32::seeded(seed);
                augment_batch(&mut t, 0, &mut rng);
                assert_eq!(t.data, vec![4.0, 3.0, 2.0, 1.0]);
                return;
            }
        }
        panic!("no pure-flip seed found in 64 tries (improbable)");
    }
}
