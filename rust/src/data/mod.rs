//! Datasets and batching.
//!
//! The paper evaluates on MNIST, Fashion-MNIST and CIFAR-10.  This image
//! has no network access, so [`synth`] provides deterministic synthetic
//! stand-ins with the same tensor shapes and class counts (see DESIGN.md
//! §Substitutions); [`augment`] implements the paper's augmentation
//! (random horizontal flips, pad-4 + crop) and mean/std normalization.

pub mod augment;
pub mod synth;

use crate::nn::tensor::Tensor;
use crate::rng::{Pcg32, Rng};

/// A labelled classification dataset held in memory.
#[derive(Debug, Clone)]
pub struct ClassificationData {
    /// Inputs `[N, …]` (e.g. `[N, 784]` or `[N, 3, H, W]`).
    pub x: Tensor,
    /// Labels, one per row.
    pub y: Vec<u32>,
    /// Number of classes.
    pub classes: usize,
}

impl ClassificationData {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Features per sample.
    pub fn features(&self) -> usize {
        self.x.features()
    }

    /// Copy a batch given sample indices.
    pub fn gather(&self, idx: &[usize]) -> (Tensor, Vec<u32>) {
        let f = self.features();
        let mut shape = self.x.shape.clone();
        shape[0] = idx.len();
        let mut x = Tensor::zeros(&shape);
        let mut y = Vec::with_capacity(idx.len());
        for (k, &i) in idx.iter().enumerate() {
            x.data[k * f..(k + 1) * f].copy_from_slice(&self.x.data[i * f..(i + 1) * f]);
            y.push(self.y[i]);
        }
        (x, y)
    }

    /// Shuffled epoch order.
    pub fn epoch_order(&self, seed: u64) -> Vec<usize> {
        let mut order = Vec::new();
        self.epoch_order_into(seed, &mut order);
        order
    }

    /// Shuffled epoch order written into a caller-held scratch — the
    /// training loop reuses one Vec across epochs instead of
    /// reallocating `len` indices per epoch.
    pub fn epoch_order_into(&self, seed: u64, order: &mut Vec<usize>) {
        order.clear();
        order.extend(0..self.len());
        let mut rng = Pcg32::seeded(seed);
        rng.shuffle(order);
    }

    /// Iterate over batches of a given order.
    pub fn batches<'a>(
        &'a self,
        order: &'a [usize],
        batch_size: usize,
    ) -> impl Iterator<Item = (Tensor, Vec<u32>)> + 'a {
        order.chunks(batch_size).map(move |chunk| self.gather(chunk))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> ClassificationData {
        ClassificationData {
            x: Tensor::from_vec((0..20).map(|v| v as f32).collect(), &[10, 2]),
            y: (0..10).map(|v| (v % 3) as u32).collect(),
            classes: 3,
        }
    }

    #[test]
    fn gather_preserves_rows() {
        let d = toy();
        let (x, y) = d.gather(&[3, 0, 7]);
        assert_eq!(x.shape, vec![3, 2]);
        assert_eq!(x.row(0), &[6.0, 7.0]);
        assert_eq!(x.row(1), &[0.0, 1.0]);
        assert_eq!(y, vec![0, 0, 1]);
    }

    #[test]
    fn epoch_order_is_permutation() {
        let d = toy();
        let order = d.epoch_order(3);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn epoch_order_into_reuses_scratch_and_matches() {
        let d = toy();
        let mut scratch = vec![99usize; 32]; // stale garbage must not leak
        d.epoch_order_into(3, &mut scratch);
        assert_eq!(scratch, d.epoch_order(3), "scratch path is bitwise-identical");
        let cap = scratch.capacity();
        d.epoch_order_into(4, &mut scratch);
        assert_eq!(scratch.capacity(), cap, "refill does not reallocate");
        assert_eq!(scratch, d.epoch_order(4));
    }

    #[test]
    fn batch_iteration_covers_all() {
        let d = toy();
        let order = d.epoch_order(1);
        let mut count = 0;
        for (x, y) in d.batches(&order, 4) {
            assert_eq!(x.batch(), y.len());
            count += y.len();
        }
        assert_eq!(count, 10);
    }

    #[test]
    fn keeps_nd_shape() {
        let d =
            ClassificationData { x: Tensor::zeros(&[4, 3, 2, 2]), y: vec![0; 4], classes: 2 };
        let (x, _) = d.gather(&[0, 1]);
        assert_eq!(x.shape, vec![2, 3, 2, 2]);
    }
}
