//! Multi-tenant model registry: versioned weight snapshots behind
//! deterministic model specs.
//!
//! The paper's construction makes a model almost free to *store*: a
//! topology is a pure function of `(layer sizes, path count)` under a
//! fixed Sobol' source, and the weights of a path-sparse net are a few
//! KB — the regime where per-tenant personalized models are
//! economical.  The registry is the serving-side embodiment:
//!
//! * [`ModelSpec`] — the deterministic part.  `sizes/paths/seed/kernel`
//!   rebuild the topology and init bit-for-bit in any process
//!   ([`ModelSpec::build`]), so a spec never ships weights it does not
//!   have to.
//! * [`Snapshot`] — the learned part.  An immutable, versioned copy of
//!   a net's `w`/`bias` vectors.  Versions are append-only: once
//!   published, a `(model, version)` pair resolves to the same bits
//!   forever — which is what lets an in-flight request pin the version
//!   it was admitted under while a newer snapshot is published
//!   underneath it (the hot-publish invariant `tests/registry.rs`
//!   pins).
//! * [`Registry`] — the store: `ModelId → spec + ordered snapshot
//!   chain`, in memory, optionally mirrored to a directory in the
//!   `SBNC` checkpoint format ([`crate::coordinator::checkpoint`]) via
//!   [`persist`].
//! * [`cache::ModelCache`] — the per-shard bounded LRU of *built*
//!   backends, cold-loading from the registry on miss (hit/miss/evict
//!   counters land in [`crate::coordinator::Metrics`]).
//!
//! Concurrency: the registry is `Mutex`-guarded and snapshots are
//! `Arc`ed — publishing clones nothing and readers hold no lock while
//! using a snapshot.  Reads are read-your-writes: a `publish` that
//! returned version `v` is immediately resolvable at `v` by every
//! subsequent `snapshot`/`latest_version` call.

pub mod cache;
pub mod persist;

use crate::nn::init::Init;
use crate::nn::kernel::KernelKind;
use crate::nn::sparse::{SparseMlp, SparseMlpConfig};
use crate::nn::Model;
use crate::qmc::SequenceFamily;
use crate::topology::TopologyBuilder;
use crate::util::sync::plock;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// The deterministic half of a registered model: everything needed to
/// rebuild its topology and initial weights bit-for-bit in any
/// process.  The path source is named by `sequence` (a
/// [`SequenceFamily`] descriptor; the default is the historical
/// Sobol'-with-skipping configuration, so pre-existing specs build the
/// exact same bits) and the init scheme is `ConstantRandomSign` — the
/// same spec the `shard-worker` CLI builds from, so a spec that
/// crossed the wire and one parsed from a CLI produce identical
/// replicas.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    /// Layer sizes, input first.
    pub sizes: Vec<usize>,
    /// Path count.
    pub paths: usize,
    /// Init seed.
    pub seed: u64,
    /// Compute kernel the built backend uses.
    pub kernel: KernelKind,
    /// Sequence family generating the topology (wire-encoded in the
    /// Publish frame and the registry checkpoint, so remote workers
    /// rebuild the same topology).
    pub sequence: SequenceFamily,
}

impl ModelSpec {
    /// Features per sample (`sizes[0]`).
    pub fn features(&self) -> usize {
        self.sizes[0]
    }

    /// Classes per sample (`sizes.last()`).
    pub fn classes(&self) -> usize {
        *self.sizes.last().expect("spec has at least one layer")
    }

    /// Transitions (weight groups) of the spec'd topology.
    pub fn transitions(&self) -> usize {
        self.sizes.len().saturating_sub(1)
    }

    /// Build the model this spec describes, deterministically: same
    /// spec → bitwise-identical topology, init, and kernel in every
    /// process.
    pub fn build(&self) -> SparseMlp {
        let topo = TopologyBuilder::new(&self.sizes)
            .paths(self.paths)
            .source(self.sequence.to_source())
            .build();
        let mut net = SparseMlp::new(
            &topo,
            SparseMlpConfig {
                init: Init::ConstantRandomSign,
                seed: self.seed,
                ..Default::default()
            },
        );
        net.set_kernel(self.kernel);
        net
    }

    /// Shape-check a weight payload against this spec: one `paths`-long
    /// weight vector per transition; per-layer bias vectors either
    /// empty (bias disabled) or `sizes[l+1]` long.
    pub fn validate_weights(&self, w: &[Vec<f32>], bias: &[Vec<f32>]) -> Result<(), String> {
        if w.len() != self.transitions() {
            return Err(format!(
                "snapshot has {} weight transitions, spec {:?} needs {}",
                w.len(),
                self.sizes,
                self.transitions()
            ));
        }
        for (t, wt) in w.iter().enumerate() {
            if wt.len() != self.paths {
                return Err(format!(
                    "transition {t} has {} weights, spec has {} paths",
                    wt.len(),
                    self.paths
                ));
            }
        }
        if bias.len() != self.transitions() {
            return Err(format!(
                "snapshot has {} bias layers, spec needs {}",
                bias.len(),
                self.transitions()
            ));
        }
        for (l, bl) in bias.iter().enumerate() {
            if !bl.is_empty() && bl.len() != self.sizes[l + 1] {
                return Err(format!(
                    "bias layer {l} has {} entries, spec layer holds {}",
                    bl.len(),
                    self.sizes[l + 1]
                ));
            }
        }
        Ok(())
    }

    /// The spec of ensemble member `member` derived from this base
    /// spec: identical sizes/paths/kernel (so the member shares the
    /// base topology and cost — the paper's cheap-replica property),
    /// init seed replaced by [`member_seed`].  Member 0 **is** the base
    /// spec, so a 1-member ensemble serves the base model's exact bits.
    pub fn member(&self, member: usize) -> ModelSpec {
        ModelSpec { seed: member_seed(self.seed, member), ..self.clone() }
    }
}

/// Deterministic per-member init seed: member 0 keeps the base seed;
/// member `m > 0` mixes `base ^ (m · golden-gamma)` through
/// [`splitmix64`].  The xor pre-mix uses an odd multiplier, so for a
/// fixed base the pre-mix is a bijection over `m` and splitmix64 (a
/// bijection itself) keeps distinct members on distinct seeds.
///
/// [`splitmix64`]: crate::rng::splitmix64
pub fn member_seed(base: u64, member: usize) -> u64 {
    if member == 0 {
        base
    } else {
        crate::rng::splitmix64(base ^ (member as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

/// One immutable versioned weight snapshot.  Snapshots are the unit of
/// publish: capture from a (possibly still-training) net, publish into
/// a registry, apply onto a spec-built replica elsewhere — the applied
/// replica is bitwise-identical to the captured net.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// Version number within the model's chain (1-based; `0` is never
    /// a valid published version — the wire uses it for "unresolved").
    pub version: u64,
    /// Per-transition path weights, `w[t][p]`.
    pub w: Vec<Vec<f32>>,
    /// Per-layer biases (empty vecs when bias is disabled).
    pub bias: Vec<Vec<f32>>,
}

impl Snapshot {
    /// Capture the learnable state of `net` as version `version`.
    pub fn capture(version: u64, net: &SparseMlp) -> Self {
        Snapshot { version, w: net.w.clone(), bias: net.bias.clone() }
    }

    /// Copy this snapshot's weights into `net` (shapes must match —
    /// build `net` from the owning [`ModelSpec`]).
    pub fn apply(&self, net: &mut SparseMlp) -> Result<(), String> {
        if net.w.len() != self.w.len() {
            return Err(format!(
                "snapshot has {} transitions, net has {}",
                self.w.len(),
                net.w.len()
            ));
        }
        for (t, (dst, src)) in net.w.iter_mut().zip(&self.w).enumerate() {
            if dst.len() != src.len() {
                return Err(format!(
                    "transition {t}: snapshot holds {} weights, net {}",
                    src.len(),
                    dst.len()
                ));
            }
            dst.copy_from_slice(src);
        }
        if net.bias.len() != self.bias.len() {
            return Err(format!(
                "snapshot has {} bias layers, net has {}",
                self.bias.len(),
                net.bias.len()
            ));
        }
        for (l, (dst, src)) in net.bias.iter_mut().zip(&self.bias).enumerate() {
            if dst.len() != src.len() {
                return Err(format!(
                    "bias layer {l}: snapshot holds {}, net {}",
                    src.len(),
                    dst.len()
                ));
            }
            dst.copy_from_slice(src);
        }
        Ok(())
    }
}

/// One model's slot in the registry: the spec plus its append-only
/// snapshot chain (ascending versions).
#[derive(Debug)]
struct Entry {
    spec: ModelSpec,
    snaps: Vec<Arc<Snapshot>>,
}

/// Versioned multi-tenant model store.  Cheap to share (`Arc<Registry>`
/// is the idiom); all methods take `&self`.
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<BTreeMap<u64, Entry>>,
    dir: Option<PathBuf>,
}

impl Registry {
    /// New empty in-memory registry (no persistence).
    pub fn new() -> Self {
        Self::default()
    }

    /// Directory-backed registry: existing snapshot files under `dir`
    /// (written by earlier [`Registry::publish`] calls) are loaded, and
    /// every future publish is mirrored to `dir` in the `SBNC`
    /// checkpoint format.
    pub fn with_dir<P: Into<PathBuf>>(dir: P) -> Result<Self, String> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .map_err(|e| format!("registry dir {}: {e}", dir.display()))?;
        let mut reg = Registry { inner: Mutex::new(BTreeMap::new()), dir: None };
        persist::load_dir(&dir, &mut reg)?;
        reg.dir = Some(dir);
        Ok(reg)
    }

    /// Register a model id with its deterministic spec.  Idempotent
    /// for an identical spec; an id re-registered with a *different*
    /// spec is an error (specs are immutable — versions change, the
    /// topology does not).
    pub fn register(&self, model_id: u64, spec: ModelSpec) -> Result<(), String> {
        let mut inner = plock(&self.inner);
        match inner.get(&model_id) {
            Some(e) if e.spec == spec => Ok(()),
            Some(e) => Err(format!(
                "model {model_id} already registered with a different spec \
                 ({:?} vs {:?})",
                e.spec.sizes, spec.sizes
            )),
            None => {
                inner.insert(model_id, Entry { spec, snaps: Vec::new() });
                Ok(())
            }
        }
    }

    /// All registered model ids, ascending.
    pub fn models(&self) -> Vec<u64> {
        plock(&self.inner).keys().copied().collect()
    }

    /// The spec registered for `model_id`.
    pub fn spec(&self, model_id: u64) -> Option<ModelSpec> {
        plock(&self.inner).get(&model_id).map(|e| e.spec.clone())
    }

    /// Newest published version of `model_id` (`None` when the model
    /// is unknown or has no snapshot yet).
    pub fn latest_version(&self, model_id: u64) -> Option<u64> {
        plock(&self.inner).get(&model_id).and_then(|e| e.snaps.last()).map(|s| s.version)
    }

    /// The snapshot of `model_id` at exactly `version`.
    pub fn snapshot(&self, model_id: u64, version: u64) -> Option<Arc<Snapshot>> {
        plock(&self.inner)
            .get(&model_id)
            .and_then(|e| e.snaps.iter().find(|s| s.version == version).cloned())
    }

    /// Publish new weights as the next version of `model_id`; returns
    /// the assigned version (1 for the first snapshot).  Shapes are
    /// validated against the spec before anything becomes visible.
    pub fn publish(
        &self,
        model_id: u64,
        w: Vec<Vec<f32>>,
        bias: Vec<Vec<f32>>,
    ) -> Result<u64, String> {
        let next = self.latest_version(model_id).unwrap_or(0) + 1;
        self.publish_at(model_id, next, w, bias)?;
        Ok(next)
    }

    /// Publish new weights at an explicitly assigned `version` — the
    /// worker-side half of hot publish, where the coordinator's
    /// registry is authoritative for version numbers and the worker
    /// must store the snapshot at exactly the number that will arrive
    /// in pinned requests.  Re-publishing an existing version with
    /// identical bits is a no-op (publishes are retried over the
    /// wire); different bits at an existing version is an error —
    /// versions are immutable.
    pub fn publish_at(
        &self,
        model_id: u64,
        version: u64,
        w: Vec<Vec<f32>>,
        bias: Vec<Vec<f32>>,
    ) -> Result<(), String> {
        if version == 0 {
            return Err("snapshot versions are 1-based; 0 is reserved".into());
        }
        let mut inner = plock(&self.inner);
        let entry = inner
            .get_mut(&model_id)
            .ok_or_else(|| format!("model {model_id} is not registered"))?;
        entry.spec.validate_weights(&w, &bias)?;
        let snap = Arc::new(Snapshot { version, w, bias });
        match entry.snaps.binary_search_by_key(&version, |s| s.version) {
            Ok(i) => {
                if *entry.snaps[i] != *snap {
                    return Err(format!(
                        "model {model_id} version {version} already published \
                         with different bits (versions are immutable)"
                    ));
                }
                return Ok(()); // idempotent retry
            }
            Err(i) => entry.snaps.insert(i, snap.clone()),
        }
        let (spec, dir) = (entry.spec.clone(), self.dir.clone());
        drop(inner);
        if let Some(dir) = dir {
            persist::save_snapshot(&dir, model_id, &spec, &snap)?;
        }
        Ok(())
    }

    /// Build `model_id` at `version`: spec-built replica with the
    /// snapshot applied.  This is the cache's cold-load path; the
    /// result is bitwise-identical to the net the snapshot was captured
    /// from (pinned in `tests/registry.rs`).
    pub fn build_model(&self, model_id: u64, version: u64) -> Result<SparseMlp, String> {
        let spec = self
            .spec(model_id)
            .ok_or_else(|| format!("model {model_id} is not registered"))?;
        let snap = self
            .snapshot(model_id, version)
            .ok_or_else(|| format!("model {model_id} has no version {version}"))?;
        let mut net = spec.build();
        snap.apply(&mut net)?;
        Ok(net)
    }

    /// Internal: insert an entry loaded from disk (see [`persist`]).
    pub(crate) fn load_entry(
        &mut self,
        model_id: u64,
        spec: ModelSpec,
        snap: Arc<Snapshot>,
    ) -> Result<(), String> {
        let inner = self.inner.get_mut().expect("unshared registry during load");
        match inner.get_mut(&model_id) {
            Some(e) => {
                if e.spec != spec {
                    return Err(format!(
                        "registry dir holds conflicting specs for model {model_id}"
                    ));
                }
                match e.snaps.binary_search_by_key(&snap.version, |s| s.version) {
                    Ok(_) => Err(format!(
                        "registry dir holds duplicate snapshot files for \
                         model {model_id} v{}",
                        snap.version
                    )),
                    Err(i) => {
                        e.snaps.insert(i, snap);
                        Ok(())
                    }
                }
            }
            None => {
                inner.insert(model_id, Entry { spec, snaps: vec![snap] });
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ModelSpec {
        ModelSpec {
            sizes: vec![8, 16, 4],
            paths: 64,
            seed: 3,
            kernel: KernelKind::Scalar,
            sequence: SequenceFamily::default(),
        }
    }

    #[test]
    fn sequence_field_selects_topology() {
        // same sizes/paths/seed, different family → different topology,
        // each deterministic on rebuild
        let base = spec();
        let halton = ModelSpec { sequence: SequenceFamily::halton(), ..spec() };
        let a = base.build();
        let b = halton.build();
        assert_eq!(b.topo.index, halton.build().topo.index, "family build is deterministic");
        assert_ne!(a.topo.index, b.topo.index, "families generate distinct topologies");
    }

    #[test]
    fn spec_builds_deterministically() {
        let s = spec();
        assert_eq!(s.features(), 8);
        assert_eq!(s.classes(), 4);
        assert_eq!(s.transitions(), 2);
        let a = s.build();
        let b = s.build();
        for (wa, wb) in a.w.iter().zip(&b.w) {
            for (x, y) in wa.iter().zip(wb) {
                assert_eq!(x.to_bits(), y.to_bits(), "same spec → same init bits");
            }
        }
    }

    #[test]
    fn member_specs_share_topology_but_not_seed() {
        let base = spec();
        assert_eq!(base.member(0), base, "member 0 is the base spec");
        let mut seeds = std::collections::BTreeSet::new();
        for m in 0..16 {
            let ms = base.member(m);
            assert_eq!(ms.sizes, base.sizes);
            assert_eq!(ms.paths, base.paths);
            assert_eq!(ms.kernel, base.kernel);
            assert_eq!(ms, base.member(m), "member derivation is deterministic");
            seeds.insert(ms.seed);
        }
        assert_eq!(seeds.len(), 16, "all member seeds distinct");
        // different base seeds derive different member families
        let other = ModelSpec { seed: 4, ..spec() };
        assert_ne!(member_seed(base.seed, 1), member_seed(other.seed, 1));
    }

    #[test]
    fn read_your_writes_per_version() {
        let reg = Registry::new();
        reg.register(7, spec()).unwrap();
        assert_eq!(reg.latest_version(7), None);
        let mut net = spec().build();
        let v1 = reg.publish(7, net.w.clone(), net.bias.clone()).unwrap();
        assert_eq!(v1, 1);
        assert_eq!(reg.latest_version(7), Some(1));
        // mutate and publish again: both versions stay resolvable with
        // their own bits
        for wt in net.w.iter_mut() {
            for v in wt.iter_mut() {
                *v *= 2.0;
            }
        }
        let v2 = reg.publish(7, net.w.clone(), net.bias.clone()).unwrap();
        assert_eq!(v2, 2);
        assert_eq!(reg.latest_version(7), Some(2));
        let s1 = reg.snapshot(7, 1).unwrap();
        let s2 = reg.snapshot(7, 2).unwrap();
        for (a, b) in s1.w[0].iter().zip(&s2.w[0]) {
            assert_eq!((*a * 2.0).to_bits(), b.to_bits(), "v1 bits untouched by v2 publish");
        }
        assert!(reg.snapshot(7, 3).is_none());
        assert!(reg.snapshot(8, 1).is_none());
    }

    #[test]
    fn register_is_idempotent_but_spec_immutable() {
        let reg = Registry::new();
        reg.register(1, spec()).unwrap();
        reg.register(1, spec()).unwrap();
        let other = ModelSpec { sizes: vec![8, 32, 4], ..spec() };
        assert!(reg.register(1, other).is_err());
        assert_eq!(reg.models(), vec![1]);
    }

    #[test]
    fn publish_validates_shapes_and_versions() {
        let reg = Registry::new();
        reg.register(1, spec()).unwrap();
        assert!(reg.publish(2, vec![], vec![]).is_err(), "unknown model");
        assert!(reg.publish(1, vec![vec![0.0; 64]], vec![]).is_err(), "wrong transitions");
        let net = spec().build();
        assert!(
            reg.publish_at(1, 0, net.w.clone(), net.bias.clone()).is_err(),
            "version 0 reserved"
        );
        reg.publish_at(1, 5, net.w.clone(), net.bias.clone()).unwrap();
        // idempotent retry with identical bits
        reg.publish_at(1, 5, net.w.clone(), net.bias.clone()).unwrap();
        // same version, different bits: rejected
        let mut w2 = net.w.clone();
        w2[0][0] += 1.0;
        assert!(reg.publish_at(1, 5, w2, net.bias.clone()).is_err());
        // auto-assign continues after the explicit version
        let v = reg.publish(1, net.w.clone(), net.bias.clone()).unwrap();
        assert_eq!(v, 6);
    }

    #[test]
    fn snapshot_apply_round_trips_bitwise() {
        let s = spec();
        let mut trained = s.build();
        // nudge weights so the snapshot differs from init
        for wt in trained.w.iter_mut() {
            for (i, v) in wt.iter_mut().enumerate() {
                *v += (i as f32) * 0.125;
            }
        }
        let snap = Snapshot::capture(1, &trained);
        let mut fresh = s.build();
        snap.apply(&mut fresh).unwrap();
        for (wa, wb) in trained.w.iter().zip(&fresh.w) {
            for (x, y) in wa.iter().zip(wb) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        for (ba, bb) in trained.bias.iter().zip(&fresh.bias) {
            for (x, y) in ba.iter().zip(bb) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // wrong-shaped target is a typed error, not a panic
        let mut other = ModelSpec { sizes: vec![8, 32, 4], ..spec() }.build();
        assert!(snap.apply(&mut other).is_err());
    }

    #[test]
    fn build_model_equals_source_net_forward() {
        use crate::nn::tensor::Tensor;
        let s = spec();
        let mut trained = s.build();
        for wt in trained.w.iter_mut() {
            for (i, v) in wt.iter_mut().enumerate() {
                *v -= (i % 7) as f32 * 0.03125;
            }
        }
        let reg = Registry::new();
        reg.register(9, s.clone()).unwrap();
        let v = reg.publish(9, trained.w.clone(), trained.bias.clone()).unwrap();
        let mut rebuilt = reg.build_model(9, v).unwrap();
        let x = Tensor::from_vec(vec![0.5; 8], &[1, 8]);
        let ya = trained.forward(&x, false);
        let yb = rebuilt.forward(&x, false);
        for (a, b) in ya.data.iter().zip(&yb.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "cold-loaded model forwards identically");
        }
        assert!(reg.build_model(9, 99).is_err());
        assert!(reg.build_model(99, 1).is_err());
    }
}
