//! Directory-backed registry persistence in the `SBNC` checkpoint
//! format.
//!
//! One file per `(model, version)` snapshot, named
//! `m{model_id}_v{version}.sbnc`.  The checkpoint's f32 blobs carry
//! the per-transition weight vectors (`w.0000`, `w.0001`, …) and bias
//! layers (`b.0000`, …); the JSON meta header carries the
//! [`ModelSpec`] plus identity, so a directory is self-describing — a
//! fresh [`Registry::with_dir`](super::Registry::with_dir) rebuilds
//! specs and snapshot chains from the files alone.  f32 values travel
//! as raw little-endian bits end to end, so a snapshot loaded from
//! disk serves bitwise-identically to the one that was saved (the
//! cold-load half of the hot-publish invariant).
//!
//! These free functions are also the replacement surface for the
//! deprecated [`Checkpoint::save`]/[`Checkpoint::load`] convenience
//! wrappers in [`crate::coordinator::checkpoint`].

use super::{ModelSpec, Registry, Snapshot};
use crate::config::json::JsonValue;
use crate::coordinator::checkpoint::Checkpoint;
use crate::nn::kernel::KernelKind;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Snapshot file name for `(model_id, version)`.
pub fn snapshot_file_name(model_id: u64, version: u64) -> String {
    format!("m{model_id}_v{version}.sbnc")
}

/// Parse a snapshot file name back to `(model_id, version)`; `None`
/// for files that are not registry snapshots (the scan skips them).
fn parse_file_name(name: &str) -> Option<(u64, u64)> {
    let rest = name.strip_prefix('m')?.strip_suffix(".sbnc")?;
    let (id, ver) = rest.split_once("_v")?;
    Some((id.parse().ok()?, ver.parse().ok()?))
}

/// Encode `(spec, snapshot)` as a checkpoint: weight/bias blobs plus a
/// self-describing meta header.
pub fn to_checkpoint(model_id: u64, spec: &ModelSpec, snap: &Snapshot) -> Checkpoint {
    let mut ck = Checkpoint::new();
    for (t, wt) in snap.w.iter().enumerate() {
        ck.f32s.insert(format!("w.{t:04}"), wt.clone());
    }
    for (l, bl) in snap.bias.iter().enumerate() {
        ck.f32s.insert(format!("b.{l:04}"), bl.clone());
    }
    ck.meta.insert("format".into(), JsonValue::String("sobolnet-registry-snapshot".into()));
    ck.meta.insert("model_id".into(), JsonValue::Number(model_id as f64));
    ck.meta.insert("version".into(), JsonValue::Number(snap.version as f64));
    ck.meta.insert(
        "sizes".into(),
        JsonValue::Array(spec.sizes.iter().map(|&s| JsonValue::Number(s as f64)).collect()),
    );
    ck.meta.insert("paths".into(), JsonValue::Number(spec.paths as f64));
    ck.meta.insert("seed".into(), JsonValue::Number(spec.seed as f64));
    ck.meta.insert("kernel".into(), JsonValue::String(spec.kernel.as_str().into()));
    ck.meta.insert("sequence".into(), JsonValue::String(spec.sequence.canonical()));
    ck
}

/// Decode a registry snapshot checkpoint back to
/// `(model_id, spec, snapshot)`.
pub fn from_checkpoint(ck: &Checkpoint) -> Result<(u64, ModelSpec, Snapshot), String> {
    let meta_usize = |key: &str| -> Result<usize, String> {
        ck.meta.get(key).and_then(|v| v.as_usize()).ok_or_else(|| {
            format!("registry snapshot meta missing or non-integer '{key}'")
        })
    };
    match ck.meta.get("format").and_then(|v| v.as_str()) {
        Some("sobolnet-registry-snapshot") => {}
        other => {
            return Err(format!(
                "not a registry snapshot (format meta = {other:?})"
            ))
        }
    }
    let model_id = meta_usize("model_id")? as u64;
    let version = meta_usize("version")? as u64;
    let sizes: Vec<usize> = ck
        .meta
        .get("sizes")
        .and_then(|v| v.as_array())
        .ok_or("registry snapshot meta missing 'sizes'")?
        .iter()
        .map(|v| v.as_usize().ok_or("non-integer layer size in snapshot meta"))
        .collect::<Result<_, _>>()?;
    let kernel_str = ck
        .meta
        .get("kernel")
        .and_then(|v| v.as_str())
        .ok_or("registry snapshot meta missing 'kernel'")?;
    // absent "sequence" (files written before the SequenceFamily
    // refactor) means the historical default: Sobol' with skipping
    let sequence = match ck.meta.get("sequence") {
        None => crate::qmc::SequenceFamily::default(),
        Some(v) => {
            let s = v.as_str().ok_or("non-string 'sequence' in snapshot meta")?;
            crate::qmc::SequenceFamily::parse(s)
                .map_err(|e| format!("bad 'sequence' in snapshot meta: {e}"))?
        }
    };
    let spec = ModelSpec {
        sizes,
        paths: meta_usize("paths")?,
        seed: meta_usize("seed")? as u64,
        kernel: KernelKind::parse(kernel_str)
            .ok_or_else(|| format!("unknown kernel '{kernel_str}' in snapshot meta"))?,
        sequence,
    };
    let mut w = Vec::with_capacity(spec.transitions());
    let mut bias = Vec::with_capacity(spec.transitions());
    for t in 0..spec.transitions() {
        let wt = ck
            .f32s
            .get(&format!("w.{t:04}"))
            .ok_or_else(|| format!("registry snapshot missing blob w.{t:04}"))?;
        w.push(wt.clone());
        // bias blobs are optional per layer (empty = bias disabled)
        bias.push(ck.f32s.get(&format!("b.{t:04}")).cloned().unwrap_or_default());
    }
    spec.validate_weights(&w, &bias)?;
    Ok((model_id, spec, Snapshot { version, w, bias }))
}

/// Write one snapshot file into `dir` (atomic: written to a temp name
/// in the same directory, then renamed — a concurrent
/// [`load_dir`] never sees a half-written snapshot).
pub fn save_snapshot(
    dir: &Path,
    model_id: u64,
    spec: &ModelSpec,
    snap: &Snapshot,
) -> Result<(), String> {
    let ck = to_checkpoint(model_id, spec, snap);
    let path = dir.join(snapshot_file_name(model_id, snap.version));
    let tmp = dir.join(format!(
        ".{}.tmp-{}",
        snapshot_file_name(model_id, snap.version),
        std::process::id()
    ));
    save_checkpoint_file(&ck, &tmp)?;
    std::fs::rename(&tmp, &path).map_err(|e| format!("rename to {}: {e}", path.display()))?;
    Ok(())
}

/// Load one snapshot file.
pub fn load_snapshot(path: &Path) -> Result<(u64, ModelSpec, Snapshot), String> {
    let ck = load_checkpoint_file(path)?;
    from_checkpoint(&ck).map_err(|e| format!("{}: {e}", path.display()))
}

/// Write any [`Checkpoint`] to a file — the non-deprecated replacement
/// for [`Checkpoint::save`].
pub fn save_checkpoint_file(ck: &Checkpoint, path: &Path) -> Result<(), String> {
    let f = std::fs::File::create(path).map_err(|e| format!("{}: {e}", path.display()))?;
    ck.write_to(std::io::BufWriter::new(f))
        .map_err(|e| format!("write {}: {e}", path.display()))
}

/// Read any [`Checkpoint`] from a file — the non-deprecated
/// replacement for [`Checkpoint::load`].
pub fn load_checkpoint_file(path: &Path) -> Result<Checkpoint, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    Checkpoint::read_from(std::io::BufReader::new(f))
        .map_err(|e| format!("{}: {e}", path.display()))
}

/// Scan `dir` for snapshot files and load them into `reg` (ascending
/// `(model, version)` order so chains come out sorted regardless of
/// directory iteration order).
pub(super) fn load_dir(dir: &Path, reg: &mut Registry) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("scan {}: {e}", dir.display()))?;
    let mut files: Vec<(u64, u64, PathBuf)> = Vec::new();
    for entry in entries {
        let entry = entry.map_err(|e| format!("scan {}: {e}", dir.display()))?;
        let name = entry.file_name();
        if let Some((id, ver)) = name.to_str().and_then(parse_file_name) {
            files.push((id, ver, entry.path()));
        }
    }
    files.sort();
    for (_, _, path) in &files {
        let (model_id, spec, snap) = load_snapshot(path)?;
        reg.load_entry(model_id, spec, Arc::new(snap))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::registry::Registry;

    fn spec() -> ModelSpec {
        ModelSpec {
            sizes: vec![6, 12, 3],
            paths: 32,
            seed: 11,
            kernel: KernelKind::Scalar,
            sequence: crate::qmc::SequenceFamily::default(),
        }
    }

    #[test]
    fn non_default_sequence_survives_codec_and_absent_key_defaults() {
        // a non-Sobol' family round-trips through the checkpoint meta
        let s = ModelSpec { sequence: crate::qmc::SequenceFamily::halton_scrambled(9), ..spec() };
        let net = s.build();
        let snap = Snapshot::capture(1, &net);
        let ck = to_checkpoint(1, &s, &snap);
        let (_, spec2, _) = from_checkpoint(&ck).unwrap();
        assert_eq!(spec2.sequence, s.sequence);
        // a checkpoint written before the refactor (no "sequence" key)
        // decodes to the historical default family
        let mut old = to_checkpoint(2, &spec(), &Snapshot::capture(1, &spec().build()));
        old.meta.remove("sequence");
        let (_, spec3, _) = from_checkpoint(&old).unwrap();
        assert_eq!(spec3.sequence, crate::qmc::SequenceFamily::default());
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("sobolnet_registry_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn file_names_round_trip() {
        assert_eq!(snapshot_file_name(7, 3), "m7_v3.sbnc");
        assert_eq!(parse_file_name("m7_v3.sbnc"), Some((7, 3)));
        assert_eq!(parse_file_name("m7_v3.json"), None);
        assert_eq!(parse_file_name("x7_v3.sbnc"), None);
        assert_eq!(parse_file_name("m7v3.sbnc"), None);
        assert_eq!(parse_file_name("m_v.sbnc"), None);
    }

    #[test]
    fn checkpoint_codec_round_trips_bitwise() {
        let s = spec();
        let net = s.build();
        let snap = Snapshot::capture(4, &net);
        let ck = to_checkpoint(42, &s, &snap);
        let (id, spec2, snap2) = from_checkpoint(&ck).unwrap();
        assert_eq!(id, 42);
        assert_eq!(spec2, s);
        assert_eq!(snap2.version, 4);
        for (a, b) in snap.w.iter().zip(&snap2.w) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // a plain (non-registry) checkpoint is a typed error
        assert!(from_checkpoint(&Checkpoint::new()).is_err());
    }

    #[test]
    fn dir_persistence_round_trips_registry() {
        let dir = temp_dir("roundtrip");
        {
            let reg = Registry::with_dir(&dir).unwrap();
            reg.register(5, spec()).unwrap();
            let mut net = spec().build();
            reg.publish(5, net.w.clone(), net.bias.clone()).unwrap();
            net.w[0][0] += 0.5;
            reg.publish(5, net.w.clone(), net.bias.clone()).unwrap();
        }
        // a fresh registry over the same dir sees both versions
        let reg2 = Registry::with_dir(&dir).unwrap();
        assert_eq!(reg2.models(), vec![5]);
        assert_eq!(reg2.latest_version(5), Some(2));
        assert_eq!(reg2.spec(5), Some(spec()));
        let s1 = reg2.snapshot(5, 1).unwrap();
        let s2 = reg2.snapshot(5, 2).unwrap();
        assert_eq!((s1.w[0][0] + 0.5).to_bits(), s2.w[0][0].to_bits());
        // non-snapshot files in the dir are ignored by the scan
        std::fs::write(dir.join("notes.txt"), b"hello").unwrap();
        assert!(Registry::with_dir(&dir).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
