//! Bounded per-shard LRU cache of *built* tenant backends.
//!
//! Worker shards do not hold every tenant's model resident: each shard
//! owns one `ModelCache` holding at most `cap` built
//! [`ModelBackend`]s keyed by `(model_id, version)`.  A hit moves the
//! entry to most-recently-used; a miss cold-loads from the
//! [`Registry`] (spec build + snapshot apply — bitwise-identical to
//! the net the snapshot was captured from) and, at capacity, evicts
//! the least-recently-used entry.  Hit/miss/eviction counts are
//! recorded on the shard's [`Metrics`]
//! (`cache_hits`/`cache_misses`/`cache_evictions`).
//!
//! The cache is single-owner (one per worker thread) — no lock, no
//! sharing; the registry behind it is the shared, locked object.
//! Because keys include the version, a hot publish never mutates a
//! cached entry: the old version stays resident (and keeps serving
//! requests admitted under it) until LRU pressure retires it.

use super::Registry;
use crate::coordinator::Metrics;
use crate::engine::ModelBackend;
use crate::nn::sparse::SparseMlp;
use std::sync::atomic::Ordering;

/// One cached, ready-to-serve tenant backend.
struct Entry {
    model_id: u64,
    version: u64,
    backend: ModelBackend<SparseMlp>,
}

/// Bounded LRU of built tenant backends (see the module docs).
pub struct ModelCache {
    cap: usize,
    batch: usize,
    /// LRU order: index 0 is the eviction candidate, the last entry is
    /// the most recently used.
    entries: Vec<Entry>,
}

impl ModelCache {
    /// New empty cache holding at most `cap` built models (clamped to
    /// ≥ 1), each with batch capacity `batch`.
    pub fn new(cap: usize, batch: usize) -> Self {
        ModelCache { cap: cap.max(1), batch, entries: Vec::new() }
    }

    /// Capacity bound.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Resident entry count.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// `true` when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Resident keys in LRU order (first = next eviction candidate,
    /// last = most recently used).
    pub fn keys(&self) -> Vec<(u64, u64)> {
        self.entries.iter().map(|e| (e.model_id, e.version)).collect()
    }

    /// `true` when `(model_id, version)` is resident (does not touch
    /// LRU order or counters).
    pub fn contains(&self, model_id: u64, version: u64) -> bool {
        self.entries.iter().any(|e| e.model_id == model_id && e.version == version)
    }

    /// The backend for `(model_id, version)`: resident entry on a hit
    /// (moved to most-recently-used), cold-loaded from `registry` on a
    /// miss (evicting the LRU entry at capacity).  Counters land on
    /// `metrics`.
    pub fn get_or_load(
        &mut self,
        registry: &Registry,
        model_id: u64,
        version: u64,
        metrics: &Metrics,
    ) -> Result<&mut ModelBackend<SparseMlp>, String> {
        if let Some(i) =
            self.entries.iter().position(|e| e.model_id == model_id && e.version == version)
        {
            metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
            let e = self.entries.remove(i);
            self.entries.push(e);
        } else {
            metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
            let spec = registry
                .spec(model_id)
                .ok_or_else(|| format!("model {model_id} is not registered"))?;
            let net = registry.build_model(model_id, version)?;
            let backend =
                ModelBackend::new(net, self.batch, spec.features(), spec.classes());
            if self.entries.len() >= self.cap {
                metrics.cache_evictions.fetch_add(1, Ordering::Relaxed);
                self.entries.remove(0);
            }
            self.entries.push(Entry { model_id, version, backend });
        }
        Ok(&mut self.entries.last_mut().expect("entry just pushed").backend)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::registry::ModelSpec;
    use crate::nn::kernel::KernelKind;

    fn registry_with(ids: &[u64]) -> Registry {
        let reg = Registry::new();
        for &id in ids {
            let spec = ModelSpec {
                sizes: vec![4, 8, 2],
                paths: 16,
                seed: id, // distinct weights per tenant
                kernel: KernelKind::Scalar,
                sequence: crate::qmc::SequenceFamily::default(),
            };
            reg.register(id, spec.clone()).unwrap();
            let net = spec.build();
            reg.publish(id, net.w.clone(), net.bias.clone()).unwrap();
        }
        reg
    }

    #[test]
    fn lru_eviction_order_and_counters() {
        let reg = registry_with(&[1, 2, 3]);
        let m = Metrics::new();
        let mut cache = ModelCache::new(2, 4);
        assert!(cache.is_empty());
        cache.get_or_load(&reg, 1, 1, &m).unwrap();
        cache.get_or_load(&reg, 2, 1, &m).unwrap();
        assert_eq!(cache.keys(), vec![(1, 1), (2, 1)]);
        // hit on 1 moves it to MRU; 2 becomes the eviction candidate
        cache.get_or_load(&reg, 1, 1, &m).unwrap();
        assert_eq!(cache.keys(), vec![(2, 1), (1, 1)]);
        // loading 3 at capacity evicts 2 (the LRU), not 1
        cache.get_or_load(&reg, 3, 1, &m).unwrap();
        assert_eq!(cache.keys(), vec![(1, 1), (3, 1)]);
        assert!(!cache.contains(2, 1));
        assert_eq!(m.cache_hits.load(Ordering::Relaxed), 1);
        assert_eq!(m.cache_misses.load(Ordering::Relaxed), 3);
        assert_eq!(m.cache_evictions.load(Ordering::Relaxed), 1);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.cap(), 2);
    }

    #[test]
    fn cold_load_is_bitwise_equal_to_first_load() {
        use crate::engine::InferenceBackend;
        let reg = registry_with(&[1, 2]);
        let m = Metrics::new();
        let mut cache = ModelCache::new(1, 4);
        // [capacity × features] buffer with one real row, zero padding
        let mut x = vec![0.0f32; 4 * 4];
        x[..4].copy_from_slice(&[0.25, -0.5, 1.0, 0.125]);
        let first = cache.get_or_load(&reg, 1, 1, &m).unwrap().infer_rows(&x, 1);
        // force eviction of model 1, then cold-load it again
        cache.get_or_load(&reg, 2, 1, &m).unwrap();
        assert!(!cache.contains(1, 1));
        let again = cache.get_or_load(&reg, 1, 1, &m).unwrap().infer_rows(&x, 1);
        assert_eq!(first.len(), again.len());
        for (a, b) in first.iter().zip(&again) {
            assert_eq!(a.to_bits(), b.to_bits(), "evict + cold-load returns identical bits");
        }
        assert_eq!(m.cache_evictions.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn unknown_model_or_version_is_typed_error() {
        let reg = registry_with(&[1]);
        let m = Metrics::new();
        let mut cache = ModelCache::new(2, 4);
        assert!(cache.get_or_load(&reg, 9, 1, &m).is_err());
        assert!(cache.get_or_load(&reg, 1, 9, &m).is_err());
        // failed loads do not leave entries behind
        assert!(cache.is_empty());
    }
}
