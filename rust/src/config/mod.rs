//! Configuration system: a small JSON parser/serializer (the `serde`
//! substrate) plus typed experiment configuration structs used by the
//! CLI and the coordinator.

pub mod json;

use crate::nn::init::Init;
use crate::topology::{PathSource, SignPolicy};
use json::JsonValue;

/// Experiment-level configuration (CLI `--config file.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// Layer sizes, input first.
    pub layer_sizes: Vec<usize>,
    /// Number of paths.
    pub paths: usize,
    /// Path source: "sobol", "random", "drand48".
    pub source: PathSource,
    /// Sign policy: "none", "alternating", "half", "dimension".
    pub sign_policy: SignPolicy,
    /// Init scheme (see [`Init::parse`]).
    pub init: Init,
    /// Epochs.
    pub epochs: usize,
    /// Batch size.
    pub batch_size: usize,
    /// Base learning rate.
    pub lr: f32,
    /// Momentum.
    pub momentum: f32,
    /// Weight decay.
    pub weight_decay: f32,
    /// Train-set size.
    pub n_train: usize,
    /// Test-set size.
    pub n_test: usize,
    /// Master seed.
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            layer_sizes: vec![784, 300, 300, 10],
            paths: 1024,
            source: PathSource::Sobol { skip_bad_dims: true, scramble_seed: None },
            sign_policy: SignPolicy::None,
            init: Init::ConstantRandomSign,
            epochs: 8,
            batch_size: 64,
            lr: 0.1,
            momentum: 0.9,
            weight_decay: 1e-4,
            n_train: 4096,
            n_test: 1024,
            seed: 0,
        }
    }
}

impl ExperimentConfig {
    /// Parse from a JSON object; missing keys fall back to defaults.
    pub fn from_json(v: &JsonValue) -> Result<Self, String> {
        let mut cfg = ExperimentConfig::default();
        let obj = v.as_object().ok_or("config root must be an object")?;
        // deferred: keys iterate alphabetically (BTreeMap), so
        // scramble_seed may precede source — apply it after the loop.
        let mut scramble: Option<u64> = None;
        for (key, val) in obj {
            match key.as_str() {
                "layer_sizes" => {
                    cfg.layer_sizes = val
                        .as_array()
                        .ok_or("layer_sizes must be an array")?
                        .iter()
                        .map(|x| x.as_usize().ok_or("layer size must be an integer"))
                        .collect::<Result<_, _>>()?;
                }
                "paths" => cfg.paths = val.as_usize().ok_or("paths must be integer")?,
                "epochs" => cfg.epochs = val.as_usize().ok_or("epochs must be integer")?,
                "batch_size" => cfg.batch_size = val.as_usize().ok_or("batch_size int")?,
                "n_train" => cfg.n_train = val.as_usize().ok_or("n_train int")?,
                "n_test" => cfg.n_test = val.as_usize().ok_or("n_test int")?,
                "seed" => cfg.seed = val.as_usize().ok_or("seed int")? as u64,
                "lr" => cfg.lr = val.as_f64().ok_or("lr number")? as f32,
                "momentum" => cfg.momentum = val.as_f64().ok_or("momentum number")? as f32,
                "weight_decay" => {
                    cfg.weight_decay = val.as_f64().ok_or("weight_decay number")? as f32
                }
                "source" => {
                    let s = val.as_str().ok_or("source must be string")?;
                    cfg.source = match s {
                        "sobol" => PathSource::Sobol { skip_bad_dims: true, scramble_seed: None },
                        "sobol-raw" => {
                            PathSource::Sobol { skip_bad_dims: false, scramble_seed: None }
                        }
                        "random" => PathSource::Random { seed: cfg.seed },
                        "drand48" => PathSource::Drand48 { seed: cfg.seed as u32 },
                        "halton" => PathSource::Halton { scramble_seed: None },
                        other => return Err(format!("unknown source '{other}'")),
                    };
                }
                "scramble_seed" => {
                    scramble = Some(val.as_usize().ok_or("scramble_seed int")? as u64);
                }
                "comment" | "description" => {}
                "sign_policy" => {
                    let s = val.as_str().ok_or("sign_policy string")?;
                    cfg.sign_policy = match s {
                        "none" => SignPolicy::None,
                        "alternating" => SignPolicy::AlternatingPath,
                        "half" => SignPolicy::FirstHalfPositive,
                        "dimension" => SignPolicy::SequenceDimension,
                        other => return Err(format!("unknown sign_policy '{other}'")),
                    };
                }
                "init" => {
                    let s = val.as_str().ok_or("init string")?;
                    cfg.init = Init::parse(s).ok_or_else(|| format!("unknown init '{s}'"))?;
                }
                other => return Err(format!("unknown config key '{other}'")),
            }
        }
        if let Some(seed) = scramble {
            match cfg.source {
                PathSource::Sobol { skip_bad_dims, .. } => {
                    cfg.source =
                        PathSource::Sobol { skip_bad_dims, scramble_seed: Some(seed) };
                }
                PathSource::Halton { .. } => {
                    cfg.source = PathSource::Halton { scramble_seed: Some(seed) };
                }
                _ => {}
            }
        }
        Ok(cfg)
    }

    /// Load from a JSON file.
    pub fn load(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        let v = json::parse(&text)?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_roundtrip() {
        let v = json::parse("{}").unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(cfg, ExperimentConfig::default());
    }

    #[test]
    fn full_config_parses() {
        let text = r#"{
            "layer_sizes": [784, 512, 10],
            "paths": 2048,
            "source": "sobol",
            "scramble_seed": 1174,
            "sign_policy": "alternating",
            "init": "sign-along-path",
            "epochs": 3,
            "batch_size": 32,
            "lr": 0.05,
            "momentum": 0.8,
            "weight_decay": 0.001,
            "n_train": 100,
            "n_test": 50,
            "seed": 9
        }"#;
        let cfg = ExperimentConfig::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.layer_sizes, vec![784, 512, 10]);
        assert_eq!(cfg.paths, 2048);
        assert_eq!(
            cfg.source,
            PathSource::Sobol { skip_bad_dims: true, scramble_seed: Some(1174) }
        );
        assert_eq!(cfg.sign_policy, SignPolicy::AlternatingPath);
        assert_eq!(cfg.init, Init::ConstantSignAlongPath);
        assert_eq!(cfg.batch_size, 32);
        assert!((cfg.lr - 0.05).abs() < 1e-7);
    }

    #[test]
    fn unknown_key_rejected() {
        let v = json::parse(r#"{"bogus": 1}"#).unwrap();
        assert!(ExperimentConfig::from_json(&v).is_err());
    }

    #[test]
    fn bad_types_rejected() {
        let v = json::parse(r#"{"paths": "many"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&v).is_err());
    }
}
