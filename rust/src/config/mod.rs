//! Configuration system: a small JSON parser/serializer (the `serde`
//! substrate) plus typed experiment configuration structs used by the
//! CLI and the coordinator.

pub mod json;

use crate::engine::{AdmissionPolicy, DispatchKind, EnsembleMode};
use crate::nn::init::Init;
use crate::nn::kernel::KernelKind;
use crate::qmc::SequenceFamily;
use crate::topology::{PathSource, SignPolicy};
use json::JsonValue;
use std::collections::BTreeMap;

/// Multi-process serving knobs (`"serve": {"remote": {...}}`): where
/// the worker shards live when they are separate OS processes.  Feeds
/// the remote path of [`crate::engine::EngineBuilder`] (see
/// `docs/ARCHITECTURE.md` for the transport itself).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RemoteSection {
    /// Pre-started `shard-worker` addresses (`unix:/path`,
    /// `tcp:host:port`).  Empty = not remote (unless `spawn` is set).
    pub addrs: Vec<String>,
    /// Number of `shard-worker` child processes for the CLI to spawn
    /// (`0` = none; ignored when `addrs` is non-empty).
    pub spawn: usize,
    /// Poll each worker's stats frame every N batches (`0` = only the
    /// final poll at shutdown).
    pub stats_every: u64,
    /// Budget in milliseconds for the initial connect + `Hello`
    /// handshake per shard (covers spawned-worker startup).
    pub connect_timeout_ms: u64,
    /// Reconnect attempts per failed exchange before a shard is
    /// declared dead.
    pub retry_attempts: u32,
    /// Base reconnect backoff in milliseconds; doubles per attempt,
    /// capped at [`crate::engine::remote::client::BACKOFF_CAP`].
    pub retry_backoff_ms: u64,
    /// Hedge deadline floor in milliseconds: an exchange not answered
    /// within `max(hedge_after, 2 × recent p99)` re-fires at a sibling
    /// replica (`0` = hedging off; needs `serve.replicas` ≥ 2 to have
    /// a sibling).
    pub hedge_after_ms: u64,
    /// Health-prober cadence in milliseconds (`0` = no prober).
    pub probe_interval_ms: u64,
}

impl Default for RemoteSection {
    fn default() -> Self {
        RemoteSection {
            addrs: Vec::new(),
            spawn: 0,
            stats_every: 8,
            connect_timeout_ms: 30_000,
            retry_attempts: 3,
            retry_backoff_ms: 50,
            hedge_after_ms: 0,
            probe_interval_ms: 250,
        }
    }
}

impl RemoteSection {
    /// Parse from a JSON object; missing keys fall back to defaults.
    pub fn from_json(v: &JsonValue) -> Result<Self, String> {
        let mut cfg = RemoteSection::default();
        let obj = v.as_object().ok_or("serve.remote section must be an object")?;
        for (key, val) in obj {
            match key.as_str() {
                "addrs" => {
                    cfg.addrs = val
                        .as_array()
                        .ok_or("serve.remote.addrs must be an array")?
                        .iter()
                        .map(|a| {
                            a.as_str()
                                .map(|s| s.to_string())
                                .ok_or("serve.remote.addrs entries must be strings")
                        })
                        .collect::<Result<_, _>>()?;
                }
                "spawn" => cfg.spawn = val.as_usize().ok_or("serve.remote.spawn int")?,
                "stats_every" => {
                    cfg.stats_every = val.as_usize().ok_or("serve.remote.stats_every int")? as u64
                }
                "connect_timeout_ms" => {
                    cfg.connect_timeout_ms =
                        val.as_usize().ok_or("serve.remote.connect_timeout_ms int")? as u64
                }
                "retry_attempts" => {
                    cfg.retry_attempts =
                        val.as_usize().ok_or("serve.remote.retry_attempts int")? as u32
                }
                "retry_backoff_ms" => {
                    cfg.retry_backoff_ms =
                        val.as_usize().ok_or("serve.remote.retry_backoff_ms int")? as u64
                }
                "hedge_after_ms" => {
                    cfg.hedge_after_ms =
                        val.as_usize().ok_or("serve.remote.hedge_after_ms int")? as u64
                }
                "probe_interval_ms" => {
                    cfg.probe_interval_ms =
                        val.as_usize().ok_or("serve.remote.probe_interval_ms int")? as u64
                }
                "comment" | "description" => {}
                other => return Err(format!("unknown serve.remote key '{other}'")),
            }
        }
        Ok(cfg)
    }

    /// Serialize to a JSON object (round-trips through
    /// [`RemoteSection::from_json`]).
    pub fn to_json(&self) -> JsonValue {
        let mut m = BTreeMap::new();
        m.insert(
            "addrs".to_string(),
            JsonValue::Array(self.addrs.iter().map(|a| JsonValue::String(a.clone())).collect()),
        );
        m.insert("spawn".to_string(), JsonValue::Number(self.spawn as f64));
        m.insert("stats_every".to_string(), JsonValue::Number(self.stats_every as f64));
        m.insert(
            "connect_timeout_ms".to_string(),
            JsonValue::Number(self.connect_timeout_ms as f64),
        );
        m.insert("retry_attempts".to_string(), JsonValue::Number(self.retry_attempts as f64));
        m.insert(
            "retry_backoff_ms".to_string(),
            JsonValue::Number(self.retry_backoff_ms as f64),
        );
        m.insert("hedge_after_ms".to_string(), JsonValue::Number(self.hedge_after_ms as f64));
        m.insert(
            "probe_interval_ms".to_string(),
            JsonValue::Number(self.probe_interval_ms as f64),
        );
        JsonValue::Object(m)
    }
}

/// Serving/engine knobs of an experiment config (`"serve": {...}`),
/// so engine setup is file-drivable like training.  Feeds
/// [`crate::engine::EngineBuilder::from_config`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeSection {
    /// Number of worker shards.
    pub workers: usize,
    /// Backend batch capacity.
    pub batch: usize,
    /// Max milliseconds a worker waits for a full batch before flushing.
    pub max_wait_ms: u64,
    /// Per-shard admission queue depth bound (`0` = unbounded).
    pub queue_depth: usize,
    /// Dispatch policy: "round-robin", "least-loaded", "ewma-p99".
    pub dispatch: DispatchKind,
    /// Admission policy: "block", "shed-newest", "shed-oldest".
    pub admission: AdmissionPolicy,
    /// Compute kernel: "auto", "scalar", "simd", "sign", "int8"
    /// ([`crate::nn::kernel`]).
    pub kernel: KernelKind,
    /// Sequence family the served model's topology is drawn from, in
    /// canonical string form (`"sobol"`, `"sobol:owen=7"`,
    /// `"halton:scramble=3"`, `"prng:seed=1"`, …) — see
    /// [`crate::qmc::SequenceFamily`].
    pub sequence: SequenceFamily,
    /// Replicas per remote shard group (`1` = no replication; the
    /// spawned/required worker count is `workers × replicas`).
    pub replicas: usize,
    /// Model-registry snapshot directory for multi-tenant serving
    /// (empty = in-memory only / no registry; the CLI decides whether
    /// to attach one — see `sobolnet serve --registry`).
    pub registry: String,
    /// Per-shard weight-cache capacity in models (LRU;
    /// [`crate::registry::cache::ModelCache`]).  Clamped to ≥ 1 by
    /// `EngineBuilder::from_config`.
    pub model_cache: usize,
    /// Ensemble members served behind a single submit (`1` = plain
    /// serving).  Worker/shard counts are per member, so the engine
    /// runs `workers × ensemble` shards — see
    /// [`crate::engine::EngineBuilder::ensemble`].
    pub ensemble: usize,
    /// Ensemble merge rule: "mean" or "vote"
    /// ([`crate::engine::EnsembleMode`]).
    pub ensemble_mode: EnsembleMode,
    /// K-of-N quorum: a merge may close over K members once the
    /// straggler deadline passes (`0` = wait for every member).
    pub quorum: usize,
    /// Multi-process subsection (`"remote": {...}`).
    pub remote: RemoteSection,
}

impl Default for ServeSection {
    fn default() -> Self {
        ServeSection {
            workers: 2,
            batch: 64,
            max_wait_ms: 2,
            queue_depth: 1024,
            dispatch: DispatchKind::LeastLoaded,
            admission: AdmissionPolicy::Block,
            kernel: KernelKind::Auto,
            sequence: SequenceFamily::default(),
            replicas: 1,
            registry: String::new(),
            model_cache: 8,
            ensemble: 1,
            ensemble_mode: EnsembleMode::Mean,
            quorum: 0,
            remote: RemoteSection::default(),
        }
    }
}

impl ServeSection {
    /// Parse from a JSON object; missing keys fall back to defaults.
    pub fn from_json(v: &JsonValue) -> Result<Self, String> {
        let mut cfg = ServeSection::default();
        let obj = v.as_object().ok_or("serve section must be an object")?;
        for (key, val) in obj {
            match key.as_str() {
                "workers" => cfg.workers = val.as_usize().ok_or("serve.workers int")?,
                "batch" => cfg.batch = val.as_usize().ok_or("serve.batch int")?,
                "max_wait_ms" => {
                    cfg.max_wait_ms = val.as_usize().ok_or("serve.max_wait_ms int")? as u64
                }
                "queue_depth" => {
                    cfg.queue_depth = val.as_usize().ok_or("serve.queue_depth int")?
                }
                "dispatch" => {
                    let s = val.as_str().ok_or("serve.dispatch string")?;
                    cfg.dispatch = DispatchKind::parse(s)
                        .ok_or_else(|| format!("unknown serve.dispatch '{s}'"))?;
                }
                "admission" => {
                    let s = val.as_str().ok_or("serve.admission string")?;
                    cfg.admission = AdmissionPolicy::parse(s)
                        .ok_or_else(|| format!("unknown serve.admission '{s}'"))?;
                }
                "kernel" => {
                    let s = val.as_str().ok_or("serve.kernel string")?;
                    cfg.kernel = KernelKind::parse(s)
                        .ok_or_else(|| format!("unknown serve.kernel '{s}'"))?;
                }
                "sequence" => {
                    let s = val.as_str().ok_or("serve.sequence string")?;
                    cfg.sequence = SequenceFamily::parse(s)?;
                }
                "replicas" => cfg.replicas = val.as_usize().ok_or("serve.replicas int")?,
                "registry" => {
                    cfg.registry =
                        val.as_str().ok_or("serve.registry string")?.to_string()
                }
                "model_cache" => {
                    cfg.model_cache = val.as_usize().ok_or("serve.model_cache int")?
                }
                "ensemble" => cfg.ensemble = val.as_usize().ok_or("serve.ensemble int")?,
                "ensemble_mode" => {
                    let s = val.as_str().ok_or("serve.ensemble_mode string")?;
                    cfg.ensemble_mode = EnsembleMode::parse(s)?;
                }
                "quorum" => cfg.quorum = val.as_usize().ok_or("serve.quorum int")?,
                "remote" => cfg.remote = RemoteSection::from_json(val)?,
                "comment" | "description" => {}
                other => return Err(format!("unknown serve key '{other}'")),
            }
        }
        Ok(cfg)
    }

    /// Serialize to a JSON object (round-trips through
    /// [`ServeSection::from_json`]).
    pub fn to_json(&self) -> JsonValue {
        let mut m = BTreeMap::new();
        m.insert("workers".to_string(), JsonValue::Number(self.workers as f64));
        m.insert("batch".to_string(), JsonValue::Number(self.batch as f64));
        m.insert("max_wait_ms".to_string(), JsonValue::Number(self.max_wait_ms as f64));
        m.insert("queue_depth".to_string(), JsonValue::Number(self.queue_depth as f64));
        m.insert(
            "dispatch".to_string(),
            JsonValue::String(self.dispatch.as_str().to_string()),
        );
        m.insert(
            "admission".to_string(),
            JsonValue::String(self.admission.as_str().to_string()),
        );
        m.insert("kernel".to_string(), JsonValue::String(self.kernel.as_str().to_string()));
        m.insert("sequence".to_string(), JsonValue::String(self.sequence.canonical()));
        m.insert("replicas".to_string(), JsonValue::Number(self.replicas as f64));
        m.insert("registry".to_string(), JsonValue::String(self.registry.clone()));
        m.insert("model_cache".to_string(), JsonValue::Number(self.model_cache as f64));
        m.insert("ensemble".to_string(), JsonValue::Number(self.ensemble as f64));
        m.insert(
            "ensemble_mode".to_string(),
            JsonValue::String(self.ensemble_mode.as_str().to_string()),
        );
        m.insert("quorum".to_string(), JsonValue::Number(self.quorum as f64));
        m.insert("remote".to_string(), self.remote.to_json());
        JsonValue::Object(m)
    }
}

/// Experiment-level configuration (CLI `--config file.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// Layer sizes, input first.
    pub layer_sizes: Vec<usize>,
    /// Number of paths.
    pub paths: usize,
    /// Path source: "sobol", "random", "drand48".
    pub source: PathSource,
    /// Sign policy: "none", "alternating", "half", "dimension".
    pub sign_policy: SignPolicy,
    /// Init scheme (see [`Init::parse`]).
    pub init: Init,
    /// Epochs.
    pub epochs: usize,
    /// Batch size.
    pub batch_size: usize,
    /// Base learning rate.
    pub lr: f32,
    /// Momentum.
    pub momentum: f32,
    /// Weight decay.
    pub weight_decay: f32,
    /// Train-set size.
    pub n_train: usize,
    /// Test-set size.
    pub n_test: usize,
    /// Master seed.
    pub seed: u64,
    /// Serving/engine section (`"serve": {...}`).
    pub serve: ServeSection,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            layer_sizes: vec![784, 300, 300, 10],
            paths: 1024,
            source: PathSource::Sobol { skip_bad_dims: true, scramble_seed: None },
            sign_policy: SignPolicy::None,
            init: Init::ConstantRandomSign,
            epochs: 8,
            batch_size: 64,
            lr: 0.1,
            momentum: 0.9,
            weight_decay: 1e-4,
            n_train: 4096,
            n_test: 1024,
            seed: 0,
            serve: ServeSection::default(),
        }
    }
}

impl ExperimentConfig {
    /// Parse from a JSON object; missing keys fall back to defaults.
    pub fn from_json(v: &JsonValue) -> Result<Self, String> {
        let mut cfg = ExperimentConfig::default();
        let obj = v.as_object().ok_or("config root must be an object")?;
        // deferred: keys iterate alphabetically (BTreeMap), so
        // scramble_seed may precede source — apply it after the loop.
        let mut scramble: Option<u64> = None;
        // deferred for the same reason: a canonical `sequence` string
        // overrides `source`/`scramble_seed` whichever order they
        // appear in.
        let mut sequence: Option<SequenceFamily> = None;
        for (key, val) in obj {
            match key.as_str() {
                "layer_sizes" => {
                    cfg.layer_sizes = val
                        .as_array()
                        .ok_or("layer_sizes must be an array")?
                        .iter()
                        .map(|x| x.as_usize().ok_or("layer size must be an integer"))
                        .collect::<Result<_, _>>()?;
                }
                "paths" => cfg.paths = val.as_usize().ok_or("paths must be integer")?,
                "epochs" => cfg.epochs = val.as_usize().ok_or("epochs must be integer")?,
                "batch_size" => cfg.batch_size = val.as_usize().ok_or("batch_size int")?,
                "n_train" => cfg.n_train = val.as_usize().ok_or("n_train int")?,
                "n_test" => cfg.n_test = val.as_usize().ok_or("n_test int")?,
                "seed" => cfg.seed = val.as_usize().ok_or("seed int")? as u64,
                "lr" => cfg.lr = val.as_f64().ok_or("lr number")? as f32,
                "momentum" => cfg.momentum = val.as_f64().ok_or("momentum number")? as f32,
                "weight_decay" => {
                    cfg.weight_decay = val.as_f64().ok_or("weight_decay number")? as f32
                }
                "source" => {
                    let s = val.as_str().ok_or("source must be string")?;
                    cfg.source = match s {
                        "sobol" => PathSource::Sobol { skip_bad_dims: true, scramble_seed: None },
                        "sobol-raw" => {
                            PathSource::Sobol { skip_bad_dims: false, scramble_seed: None }
                        }
                        "random" => PathSource::Random { seed: cfg.seed },
                        "drand48" => PathSource::Drand48 { seed: cfg.seed as u32 },
                        "halton" => PathSource::Halton { scramble_seed: None },
                        other => return Err(format!("unknown source '{other}'")),
                    };
                }
                "scramble_seed" => {
                    scramble = Some(val.as_usize().ok_or("scramble_seed int")? as u64);
                }
                "sequence" => {
                    let s = val.as_str().ok_or("sequence must be string")?;
                    sequence = Some(SequenceFamily::parse(s)?);
                }
                "serve" => cfg.serve = ServeSection::from_json(val)?,
                "comment" | "description" => {}
                "sign_policy" => {
                    let s = val.as_str().ok_or("sign_policy string")?;
                    cfg.sign_policy = match s {
                        "none" => SignPolicy::None,
                        "alternating" => SignPolicy::AlternatingPath,
                        "half" => SignPolicy::FirstHalfPositive,
                        "dimension" => SignPolicy::SequenceDimension,
                        other => return Err(format!("unknown sign_policy '{other}'")),
                    };
                }
                "init" => {
                    let s = val.as_str().ok_or("init string")?;
                    cfg.init = Init::parse(s).ok_or_else(|| format!("unknown init '{s}'"))?;
                }
                other => return Err(format!("unknown config key '{other}'")),
            }
        }
        if let Some(seed) = scramble {
            match cfg.source {
                PathSource::Sobol { skip_bad_dims, .. } => {
                    cfg.source =
                        PathSource::Sobol { skip_bad_dims, scramble_seed: Some(seed) };
                }
                PathSource::Halton { .. } => {
                    cfg.source = PathSource::Halton { scramble_seed: Some(seed) };
                }
                _ => {}
            }
        }
        if let Some(fam) = sequence {
            cfg.source = fam.to_source();
        }
        Ok(cfg)
    }

    /// Load from a JSON file.
    pub fn load(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        let v = json::parse(&text)?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_roundtrip() {
        let v = json::parse("{}").unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(cfg, ExperimentConfig::default());
    }

    #[test]
    fn full_config_parses() {
        let text = r#"{
            "layer_sizes": [784, 512, 10],
            "paths": 2048,
            "source": "sobol",
            "scramble_seed": 1174,
            "sign_policy": "alternating",
            "init": "sign-along-path",
            "epochs": 3,
            "batch_size": 32,
            "lr": 0.05,
            "momentum": 0.8,
            "weight_decay": 0.001,
            "n_train": 100,
            "n_test": 50,
            "seed": 9
        }"#;
        let cfg = ExperimentConfig::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.layer_sizes, vec![784, 512, 10]);
        assert_eq!(cfg.paths, 2048);
        assert_eq!(
            cfg.source,
            PathSource::Sobol { skip_bad_dims: true, scramble_seed: Some(1174) }
        );
        assert_eq!(cfg.sign_policy, SignPolicy::AlternatingPath);
        assert_eq!(cfg.init, Init::ConstantSignAlongPath);
        assert_eq!(cfg.batch_size, 32);
        assert!((cfg.lr - 0.05).abs() < 1e-7);
    }

    #[test]
    fn unknown_key_rejected() {
        let v = json::parse(r#"{"bogus": 1}"#).unwrap();
        assert!(ExperimentConfig::from_json(&v).is_err());
    }

    #[test]
    fn bad_types_rejected() {
        let v = json::parse(r#"{"paths": "many"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&v).is_err());
    }

    #[test]
    fn serve_section_parses_inside_experiment_config() {
        let text = r#"{
            "paths": 512,
            "serve": {
                "workers": 4,
                "batch": 32,
                "max_wait_ms": 5,
                "queue_depth": 128,
                "dispatch": "ewma-p99",
                "admission": "shed-newest"
            }
        }"#;
        let cfg = ExperimentConfig::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.paths, 512);
        assert_eq!(cfg.serve.workers, 4);
        assert_eq!(cfg.serve.batch, 32);
        assert_eq!(cfg.serve.max_wait_ms, 5);
        assert_eq!(cfg.serve.queue_depth, 128);
        assert_eq!(cfg.serve.dispatch, DispatchKind::EwmaP99);
        assert_eq!(cfg.serve.admission, AdmissionPolicy::ShedNewest);
    }

    #[test]
    fn serve_section_round_trips_through_serializer() {
        let section = ServeSection {
            workers: 8,
            batch: 16,
            max_wait_ms: 1,
            queue_depth: 64,
            dispatch: DispatchKind::RoundRobin,
            admission: AdmissionPolicy::ShedOldest,
            kernel: KernelKind::Simd,
            sequence: SequenceFamily::halton_scrambled(9),
            replicas: 2,
            registry: "/tmp/reg".to_string(),
            model_cache: 4,
            ensemble: 3,
            ensemble_mode: EnsembleMode::Vote,
            quorum: 2,
            remote: RemoteSection::default(),
        };
        let text = section.to_json().to_string_compact();
        let back = ServeSection::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, section, "serialize → parse is the identity");
        // defaults round-trip too, and partial objects fall back to them
        let dflt = ServeSection::default();
        let text = dflt.to_json().to_string_compact();
        assert_eq!(ServeSection::from_json(&json::parse(&text).unwrap()).unwrap(), dflt);
        let partial = json::parse(r#"{"workers": 3}"#).unwrap();
        let cfg = ServeSection::from_json(&partial).unwrap();
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.dispatch, dflt.dispatch);
        assert_eq!(cfg.kernel, KernelKind::Auto);
        assert_eq!(cfg.registry, "", "no registry by default");
        assert_eq!(cfg.model_cache, 8);
        assert_eq!(cfg.ensemble, 1, "plain serving by default");
        assert_eq!(cfg.ensemble_mode, EnsembleMode::Mean);
        assert_eq!(cfg.quorum, 0, "full merge by default");
        // multi-tenant knobs parse
        let j = json::parse(r#"{"registry": "/var/reg", "model_cache": 2}"#).unwrap();
        let cfg = ServeSection::from_json(&j).unwrap();
        assert_eq!(cfg.registry, "/var/reg");
        assert_eq!(cfg.model_cache, 2);
        // ensemble knobs parse
        let j =
            json::parse(r#"{"ensemble": 5, "ensemble_mode": "vote", "quorum": 3}"#).unwrap();
        let cfg = ServeSection::from_json(&j).unwrap();
        assert_eq!(cfg.ensemble, 5);
        assert_eq!(cfg.ensemble_mode, EnsembleMode::Vote);
        assert_eq!(cfg.quorum, 3);
        assert!(
            ServeSection::from_json(&json::parse(r#"{"registry": 7}"#).unwrap()).is_err(),
            "registry must be a string path"
        );
        // every kernel spelling parses
        for k in ["auto", "scalar", "simd", "sign", "int8"] {
            let j = json::parse(&format!(r#"{{"kernel": "{k}"}}"#)).unwrap();
            assert_eq!(ServeSection::from_json(&j).unwrap().kernel.as_str(), k);
        }
        // every registered sequence family round-trips through its
        // canonical string
        for fam in SequenceFamily::registered() {
            let j = json::parse(&format!(r#"{{"sequence": "{}"}}"#, fam.canonical())).unwrap();
            assert_eq!(ServeSection::from_json(&j).unwrap().sequence, fam);
        }
        assert!(
            ServeSection::from_json(&json::parse(r#"{"sequence": "fibonacci"}"#).unwrap())
                .is_err(),
            "unknown family is a typed error"
        );
    }

    #[test]
    fn sequence_key_overrides_source() {
        // `sequence` wins regardless of the (alphabetical) key order
        // BTreeMap iterates the object in
        let text = r#"{"source": "random", "sequence": "sobol:owen=5"}"#;
        let cfg = ExperimentConfig::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(
            cfg.source,
            PathSource::Sobol { skip_bad_dims: true, scramble_seed: Some(5) }
        );
        let text = r#"{"sequence": "halton"}"#;
        let cfg = ExperimentConfig::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.source, PathSource::Halton { scramble_seed: None });
    }

    #[test]
    fn remote_section_round_trips() {
        let text = r#"{
            "serve": {
                "workers": 4,
                "remote": {
                    "addrs": ["unix:/tmp/shard-a.sock", "tcp:127.0.0.1:7070"],
                    "spawn": 0,
                    "stats_every": 4
                }
            }
        }"#;
        let cfg = ExperimentConfig::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(
            cfg.serve.remote.addrs,
            vec!["unix:/tmp/shard-a.sock".to_string(), "tcp:127.0.0.1:7070".to_string()]
        );
        assert_eq!(cfg.serve.remote.spawn, 0);
        assert_eq!(cfg.serve.remote.stats_every, 4);
        // unset transport knobs fall back to defaults
        assert_eq!(cfg.serve.remote.connect_timeout_ms, 30_000);
        assert_eq!(cfg.serve.remote.retry_attempts, 3);
        assert_eq!(cfg.serve.remote.hedge_after_ms, 0, "hedging defaults to off");
        assert_eq!(cfg.serve.replicas, 1);
        // serializer round-trips, with and without defaults
        let sec = RemoteSection {
            addrs: vec!["unix:/x.sock".into()],
            spawn: 3,
            stats_every: 1,
            connect_timeout_ms: 5_000,
            retry_attempts: 2,
            retry_backoff_ms: 25,
            hedge_after_ms: 40,
            probe_interval_ms: 100,
        };
        let back =
            RemoteSection::from_json(&json::parse(&sec.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back, sec);
        let dflt = ServeSection::default();
        let back =
            ServeSection::from_json(&json::parse(&dflt.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back, dflt, "serve section with remote subsection round-trips");
        // fault-tolerance knobs parse from the serve section
        let text = r#"{"replicas": 2, "remote": {"hedge_after_ms": 30, "probe_interval_ms": 0}}"#;
        let sec = ServeSection::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(sec.replicas, 2);
        assert_eq!(sec.remote.hedge_after_ms, 30);
        assert_eq!(sec.remote.probe_interval_ms, 0, "prober can be configured off");
        // malformed remote sections are typed errors
        assert!(RemoteSection::from_json(&json::parse(r#"{"bogus": 1}"#).unwrap()).is_err());
        assert!(RemoteSection::from_json(&json::parse(r#"{"addrs": [1]}"#).unwrap()).is_err());
        assert!(RemoteSection::from_json(&json::parse(r#"{"spawn": "two"}"#).unwrap()).is_err());
    }

    #[test]
    fn serve_section_rejects_unknown_keys_and_policies() {
        assert!(ServeSection::from_json(&json::parse(r#"{"bogus": 1}"#).unwrap()).is_err());
        assert!(ServeSection::from_json(&json::parse(r#"{"dispatch": "psychic"}"#).unwrap())
            .is_err());
        assert!(ServeSection::from_json(&json::parse(r#"{"admission": "yolo"}"#).unwrap())
            .is_err());
        assert!(
            ServeSection::from_json(&json::parse(r#"{"kernel": "avx512"}"#).unwrap()).is_err()
        );
        assert!(ServeSection::from_json(&json::parse(r#"{"ensemble_mode": "median"}"#).unwrap())
            .is_err());
        assert!(
            ServeSection::from_json(&json::parse(r#"{"quorum": "half"}"#).unwrap()).is_err()
        );
    }
}
