//! Minimal JSON parser and serializer (RFC 8259 subset sufficient for
//! configuration files and checkpoint metadata): objects, arrays,
//! strings with escapes, numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    /// `null`
    Null,
    /// Boolean.
    Bool(bool),
    /// Any number (stored as f64).
    Number(f64),
    /// String.
    String(String),
    /// Array.
    Array(Vec<JsonValue>),
    /// Object (sorted keys for deterministic serialization).
    Object(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    /// As object, if it is one.
    pub fn as_object(&self) -> Option<&BTreeMap<String, JsonValue>> {
        match self {
            JsonValue::Object(m) => Some(m),
            _ => None,
        }
    }

    /// As array.
    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Array(a) => Some(a),
            _ => None,
        }
    }

    /// As string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::String(s) => Some(s),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Number(n) => Some(*n),
            _ => None,
        }
    }

    /// As non-negative integer (rejects fractional values).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            JsonValue::Number(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        self.as_object()?.get(key)
    }

    /// Serialize to a compact JSON string.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Number(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            JsonValue::String(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            JsonValue::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            JsonValue::Object(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    JsonValue::String(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<JsonValue, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing characters at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos.saturating_sub(1)))
        }
    }

    fn literal(&mut self, lit: &str, v: JsonValue) -> Result<JsonValue, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<JsonValue, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::String(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected '{}' at byte {}", c as char, self.pos)),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn object(&mut self) -> Result<JsonValue, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(JsonValue::Object(map)),
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue, String> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Array(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(JsonValue::Array(arr)),
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")? as char;
                            code = code * 16 + c.to_digit(16).ok_or("bad hex in \\u")?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err("bad escape".into()),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequence
                    let start = self.pos - 1;
                    let len = if c >> 5 == 0b110 {
                        2
                    } else if c >> 4 == 0b1110 {
                        3
                    } else {
                        4
                    };
                    self.pos = start + len;
                    let chunk = self
                        .bytes
                        .get(start..start + len)
                        .ok_or("truncated utf-8")?;
                    s.push_str(std::str::from_utf8(chunk).map_err(|e| e.to_string())?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<JsonValue, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(JsonValue::Number)
            .map_err(|e| format!("bad number '{text}': {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), JsonValue::Null);
        assert_eq!(parse("true").unwrap(), JsonValue::Bool(true));
        assert_eq!(parse("false").unwrap(), JsonValue::Bool(false));
        assert_eq!(parse("42").unwrap(), JsonValue::Number(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), JsonValue::Number(-350.0));
        assert_eq!(parse(r#""hi""#).unwrap(), JsonValue::String("hi".into()));
    }

    #[test]
    fn nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&JsonValue::Null));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""line\nbreak \"q\" A tab\t""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "line\nbreak \"q\" A tab\t");
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""héllo → ☺""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → ☺");
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nulle").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("01abc").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn as_usize_strictness() {
        assert_eq!(parse("5").unwrap().as_usize(), Some(5));
        assert_eq!(parse("5.5").unwrap().as_usize(), None);
        assert_eq!(parse("-5").unwrap().as_usize(), None);
    }

    #[test]
    fn serialize_roundtrip() {
        let text = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"nested":{"x":-3}}"#;
        let v = parse(text).unwrap();
        let out = v.to_string_compact();
        assert_eq!(parse(&out).unwrap(), v);
        assert_eq!(out, text);
    }

    #[test]
    fn whitespace_tolerated() {
        let v = parse("  {\n\t\"a\" :\r 1 }  ").unwrap();
        assert_eq!(v.get("a").unwrap().as_usize(), Some(1));
    }
}
