//! Pseudo-random number generators — the *baseline* path samplers of the
//! paper (Sec 3 uses `drand48()` in Fig 3), plus general-purpose PRNGs for
//! data synthesis and random initialization.
//!
//! The `rand` crate is not available offline, so the generators are
//! implemented from their published recurrences:
//!
//! * [`Drand48`] — POSIX `drand48` LCG, bit-exact, to mirror the paper's
//!   reference implementation in Fig 3.
//! * [`Pcg32`] — PCG-XSH-RR 64/32 (O'Neill 2014), the default engine.
//! * [`SplitMix64`] — stateless-seedable mixer, used for seeding and
//!   Owen-style hashing in [`crate::qmc::scramble`].
//! * [`XorShift64Star`] — cheap generator for the bank-conflict traces.

/// Common interface for all generators in this crate.
pub trait Rng {
    /// Next 32 uniformly distributed bits.
    fn next_u32(&mut self) -> u32;

    /// Next 64 uniformly distributed bits (default: two u32 draws).
    fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform `f64` in `[0, 1)` with 53 random bits.
    fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)` with 24 random bits.
    fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free bound for
    /// our purposes; modulo bias is negligible for n ≪ 2^32 but we use the
    /// widening-multiply trick anyway).
    fn next_below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        ((self.next_u32() as u64 * n as u64) >> 32) as u32
    }

    /// Standard normal via Box-Muller (one value; second is discarded for
    /// simplicity — initialization is not on the hot path).
    fn next_normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher-Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// POSIX `drand48`: X_{n+1} = (a·X_n + c) mod 2^48 with a = 0x5DEECE66D,
/// c = 0xB.  `next_f64` mirrors `drand48()` exactly (48-bit mantissa).
#[derive(Debug, Clone)]
pub struct Drand48 {
    state: u64,
}

impl Drand48 {
    const A: u64 = 0x5DEECE66D;
    const C: u64 = 0xB;
    const MASK: u64 = (1 << 48) - 1;

    /// Seed like `srand48(seed)`: high 32 bits from the seed, low 16 bits
    /// set to 0x330E.
    pub fn new(seed: u32) -> Self {
        Drand48 { state: ((seed as u64) << 16 | 0x330E) & Self::MASK }
    }

    fn step(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(Self::A).wrapping_add(Self::C) & Self::MASK;
        self.state
    }

    /// Exact `drand48()` output: the 48 state bits as a fraction.
    pub fn drand48(&mut self) -> f64 {
        self.step() as f64 / (1u64 << 48) as f64
    }
}

impl Rng for Drand48 {
    fn next_u32(&mut self) -> u32 {
        (self.step() >> 16) as u32
    }

    fn next_f64(&mut self) -> f64 {
        self.drand48()
    }
}

/// PCG-XSH-RR 64/32 — small, fast, statistically excellent.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    const MULT: u64 = 6364136223846793005;

    /// Create from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.state.wrapping_mul(Self::MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(Self::MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience: single-argument seeding with a fixed stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }
}

impl Rng for Pcg32 {
    fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }
}

/// SplitMix64 — used for seeding and as the hash in Owen scrambling.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a seed.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }
}

/// One stateless SplitMix64 step: a high-quality 64-bit mixer.
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng for SplitMix64 {
    fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xorshift64* — minimal-state generator for synthetic access traces.
#[derive(Debug, Clone)]
pub struct XorShift64Star {
    state: u64,
}

impl XorShift64Star {
    /// Create from a non-zero seed (zero is mapped to a fixed constant).
    pub fn new(seed: u64) -> Self {
        XorShift64Star { state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed } }
    }
}

impl Rng for XorShift64Star {
    fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drand48_matches_posix_reference() {
        // Reference values from glibc: srand48(0); drand48() thrice.
        let mut r = Drand48::new(0);
        let v1 = r.drand48();
        let v2 = r.drand48();
        let v3 = r.drand48();
        assert!((v1 - 0.17082803610628972).abs() < 1e-12, "v1={v1}");
        assert!((v2 - 0.7499019804849638).abs() < 1e-12, "v2={v2}");
        assert!((v3 - 0.09637165562356742).abs() < 1e-12, "v3={v3}");
    }

    #[test]
    fn pcg32_is_deterministic_and_distinct_per_stream() {
        let mut a = Pcg32::new(42, 54);
        let mut b = Pcg32::new(42, 54);
        let mut c = Pcg32::new(42, 55);
        let xs: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let ys: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        let zs: Vec<u32> = (0..8).map(|_| c.next_u32()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniformity_rough() {
        // Mean of 10k uniforms should be close to 0.5 for every generator.
        fn check<R: Rng>(mut r: R) {
            let n = 10_000;
            let m: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
            assert!((m - 0.5).abs() < 0.02, "mean={m}");
        }
        check(Pcg32::seeded(1));
        check(SplitMix64::new(2));
        check(XorShift64Star::new(3));
        check(Drand48::new(4));
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Pcg32::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should occur");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle should move things");
    }

    #[test]
    fn normal_has_zero_mean_unit_var() {
        let mut r = Pcg32::seeded(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
        assert!(m.abs() < 0.05, "mean={m}");
        assert!((v - 1.0).abs() < 0.1, "var={v}");
    }

    #[test]
    fn splitmix_stateless_matches_reference() {
        // Known-answer test from the SplitMix64 reference (Vigna).
        // seed 0: first output 0xE220A8397B1DCDAF
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220A8397B1DCDAF);
    }
}
